"""Static TPU resource analysis of the L1 Pallas kernels.

`interpret=True` timings are CPU-numpy and not a TPU proxy, so the perf
story for L1 is *structural*: VMEM residency per grid step, HBM traffic,
arithmetic intensity, and the implied roofline regime on a reference TPU
(v4: 275 TFLOP/s bf16 MXU, 1.2 TB/s HBM, 16 MiB VMEM/core).

Usage: python -m compile.analyze
"""

import dataclasses

from .kernels import gossip

TPU_HBM_BW = 1.2e12        # bytes/s
TPU_MXU_F32 = 68.75e12     # f32 FLOP/s (v4 ~ 275/4)
TPU_VMEM = 16 * 1024 * 1024


@dataclasses.dataclass
class KernelReport:
    name: str
    vmem_bytes: int
    hbm_bytes: float
    flops: float

    @property
    def intensity(self) -> float:
        return self.flops / self.hbm_bytes

    @property
    def bound(self) -> str:
        # Machine balance point: FLOP/byte where compute time = memory time.
        balance = TPU_MXU_F32 / TPU_HBM_BW
        return "compute-bound" if self.intensity > balance else "memory-bound"

    @property
    def est_time_s(self) -> float:
        return max(self.hbm_bytes / TPU_HBM_BW, self.flops / TPU_MXU_F32)


def analyze_gossip(n: int, p: int, p_block: int) -> KernelReport:
    """The fused DmSGD mixing kernel: X' = W(X−γM), M' = W(βM+G)."""
    vmem = gossip.vmem_footprint(n, min(p_block, p))
    # HBM traffic: read X, M, G once; write X', M' once; W once per block.
    blocks = -(-p // p_block)
    hbm = 4.0 * (5 * n * p + blocks * n * n)
    # FLOPs: elementwise (3 n p) + two n×n @ n×p matmuls (2 · 2 n² p).
    flops = 3.0 * n * p + 4.0 * n * n * p
    return KernelReport(f"gossip n={n} P={p} block={p_block}", vmem, hbm, flops)


def analyze_matmul(m: int, k: int, n: int, bm: int, bk: int, bn: int) -> KernelReport:
    """Blocked matmul: per (i,j) output tile, stream K-tiles of A and B."""
    vmem = 4 * (bm * bk + bk * bn + bm * bn)
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    hbm = 4.0 * (gm * gn * gk * (bm * bk + bk * bn) + m * n)
    flops = 2.0 * m * k * n
    return KernelReport(f"matmul {m}x{k}x{n} tiles {bm}/{bk}/{bn}", vmem, hbm, flops)


def main():
    print(f"reference TPU: HBM {TPU_HBM_BW/1e12:.1f} TB/s, MXU {TPU_MXU_F32/1e12:.1f} f32 TFLOP/s, "
          f"VMEM {TPU_VMEM>>20} MiB, balance {TPU_MXU_F32/TPU_HBM_BW:.0f} FLOP/B\n")
    reports = [
        analyze_gossip(8, 865_024, gossip.P_BLOCK),
        analyze_gossip(64, 865_024, gossip.P_BLOCK),
        analyze_gossip(256, 865_024, gossip.P_BLOCK),
        analyze_matmul(512, 128, 512, 128, 128, 128),
        analyze_matmul(4096, 4096, 4096, 128, 128, 128),
    ]
    for r in reports:
        ok = "OK " if r.vmem_bytes <= TPU_VMEM else "OVER"
        print(f"{r.name}")
        print(f"  VMEM/block: {r.vmem_bytes/2**20:6.2f} MiB [{ok}]   "
              f"HBM: {r.hbm_bytes/1e6:9.2f} MB   FLOPs: {r.flops/1e9:8.3f} G")
        print(f"  intensity: {r.intensity:7.2f} FLOP/B -> {r.bound}; "
              f"est. kernel time on v4: {r.est_time_s*1e6:.1f} us")
    # Tile sweep for the large-matmul regime: bigger output tiles raise
    # arithmetic intensity past the machine balance point.
    print("\nmatmul 4096^3 tile sweep (output-tile reuse):")
    for b in (128, 256, 512):
        r = analyze_matmul(4096, 4096, 4096, b, 128, b)
        ok = "OK " if r.vmem_bytes <= TPU_VMEM else "OVER"
        print(f"  {b}x{b}: intensity {r.intensity:7.1f} FLOP/B ({r.bound}), "
              f"VMEM {r.vmem_bytes/2**20:5.2f} MiB [{ok}], est {r.est_time_s*1e6:7.1f} us")

    print("\ngossip kernel is memory-bound by design (intensity ≈ n FLOP/B for "
          "n nodes);\nthe single-pass fusion is therefore the roofline move: "
          "5 streams instead of 8\n(separate premix+mix would re-read X, M and "
          "spill the intermediates).")


if __name__ == "__main__":
    main()
