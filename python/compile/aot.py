"""AOT pipeline: lower the L2 JAX functions (with their L1 Pallas kernels)
to HLO **text** artifacts the Rust runtime loads via PJRT.

HLO text — NOT ``lowered.compiler_ir("hlo").serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per artifact plus ``manifest.json``
describing shapes/dtypes (consumed by ``rust/src/runtime/artifact.rs``).
Skips artifacts whose HLO already exists and is newer than this package's
sources (so ``make artifacts`` is a cheap no-op on rebuilds).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _input_meta(specs):
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in specs
    ]


class Builder:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.manifest = {"version": 1, "artifacts": []}
        os.makedirs(out_dir, exist_ok=True)
        pkg_dir = os.path.dirname(os.path.abspath(__file__))
        self.src_mtime = max(
            os.path.getmtime(os.path.join(root, f))
            for root, _, files in os.walk(pkg_dir)
            for f in files
            if f.endswith(".py")
        )

    def emit(self, name, fn, specs, num_outputs, meta=None):
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": _input_meta(specs),
            "num_outputs": num_outputs,
            "meta": meta or {},
        }
        self.manifest["artifacts"].append(entry)
        if (
            not self.force
            and os.path.exists(path)
            and os.path.getmtime(path) >= self.src_mtime
        ):
            print(f"  [skip] {name} (up to date)")
            return
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [emit] {name}: {len(text)} chars, inputs={len(specs)}")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=2)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


# The e2e transformer configuration (examples/transformer_e2e.rs).
E2E_CFG = model.TransformerConfig(vocab=256, d_model=128, n_layers=4, n_heads=4, seq=64)
E2E_BATCH = 8
# A small configuration for fast integration tests.
SMALL_CFG = model.TransformerConfig(vocab=256, d_model=32, n_layers=2, n_heads=2, seq=16)
SMALL_BATCH = 2
# Gossip artifact sizes: n nodes mixing the e2e model's flat state.
GOSSIP_N = 8


def build(out_dir: str, force: bool = False):
    b = Builder(out_dir, force)

    # --- logistic regression grad oracle (d=10, B=32; Appendix D.5) -----
    d, batch = 10, 32
    b.emit(
        "logreg_grad",
        model.logreg_loss_and_grad,
        [spec((d,)), spec((batch, d)), spec((batch,))],
        num_outputs=2,
        meta={"d": d, "batch": batch},
    )

    # --- transformer train step: (flat_params, window) -> (loss, grad) --
    for name, cfg, bs in (
        ("transformer_step", E2E_CFG, E2E_BATCH),
        ("transformer_step_small", SMALL_CFG, SMALL_BATCH),
    ):
        p = model.param_count(cfg)
        fn = lambda flat, window, cfg=cfg: model.transformer_loss_and_grad(cfg, flat, window)
        b.emit(
            name,
            fn,
            [spec((p,)), spec((bs, cfg.seq + 1), jnp.int32)],
            num_outputs=2,
            meta={
                "param_count": p,
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "seq": cfg.seq,
                "batch": bs,
            },
        )

    # --- gossip update (Pallas kernel) over the e2e model state ---------
    for name, n, p in (
        ("gossip_update", GOSSIP_N, model.param_count(E2E_CFG)),
        ("gossip_update_small", 4, 96),
    ):
        b.emit(
            name,
            model.gossip_update,
            [
                spec((n, n)),
                spec((n, p)),
                spec((n, p)),
                spec((n, p)),
                spec((), jnp.float32),
                spec((), jnp.float32),
            ],
            num_outputs=2,
            meta={"n": n, "p": p},
        )

    # --- one-peer specialized gossip (no W materialization) -------------
    from .kernels import one_peer as one_peer_kernel

    n, pp = GOSSIP_N, model.param_count(E2E_CFG)
    b.emit(
        "gossip_one_peer",
        one_peer_kernel.gossip_one_peer,
        [
            spec((), jnp.int32),
            spec((n, pp)),
            spec((n, pp)),
            spec((n, pp)),
            spec((), jnp.float32),
            spec((), jnp.float32),
        ],
        num_outputs=2,
        meta={"n": n, "p": pp},
    )

    # --- initial parameters for the e2e example (raw little-endian f32) --
    # The Rust coordinator needs a *correct* init (layer-norm scales = 1);
    # exporting it here keeps the layout contract in one place.
    import numpy as np

    for fname, cfg in (
        ("transformer_init.bin", E2E_CFG),
        ("transformer_init_small.bin", SMALL_CFG),
    ):
        path = os.path.join(out_dir, fname)
        if force or not os.path.exists(path) or os.path.getmtime(path) < b.src_mtime:
            flat = np.asarray(model.init_params(cfg, seed=0), dtype="<f4")
            flat.tofile(path)
            print(f"  [emit] {fname}: {flat.size} params")
        else:
            print(f"  [skip] {fname} (up to date)")

    b.finish()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rebuild even if up to date")
    args = ap.parse_args()
    build(args.out_dir, args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
