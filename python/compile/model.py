"""Layer-2 JAX models, lowered AOT to HLO text for the Rust runtime.

Three entry points, matching the Rust runtime's artifact contract
(flat f32 parameter vectors in, ``(loss, grad)`` out — so the Rust
coordinator can treat every model as an opaque vector):

* :func:`logreg_loss_and_grad` — the logistic-regression workload of
  Appendix D.5 (used by runtime integration tests to cross-check the
  pure-Rust implementation).
* :func:`transformer_loss_and_grad` — a decoder-only byte-level
  transformer LM (the deep-training workload of the end-to-end example).
* :func:`gossip_update` — Algorithm 1's fused mixing update, delegating
  to the Layer-1 Pallas kernel so the kernel lowers into the same HLO the
  Rust hot path executes.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import gossip as gossip_kernel
from .kernels import matmul as matmul_kernel

# ---------------------------------------------------------------------------
# Logistic regression (Appendix D.5)
# ---------------------------------------------------------------------------


def logreg_loss(x, h, y):
    """Mean logistic loss: (1/B) Σ ln(1 + exp(−y·hᵀx)), y ∈ {±1}."""
    z = h @ x
    return jnp.mean(jax.nn.softplus(-y * z))


@jax.jit
def logreg_loss_and_grad(x, h, y):
    """Returns (loss, grad) — the per-node gradient oracle."""
    return jax.value_and_grad(logreg_loss)(x, h, y)


# ---------------------------------------------------------------------------
# Decoder-only transformer LM with flat parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    seq: int = 64

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def param_shapes(cfg: TransformerConfig):
    """Ordered (name, shape) list — the flat layout contract with Rust."""
    shapes = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        shapes += [
            (f"l{layer}.ln1_scale", (cfg.d_model,)),
            (f"l{layer}.ln1_bias", (cfg.d_model,)),
            (f"l{layer}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{layer}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{layer}.ln2_scale", (cfg.d_model,)),
            (f"l{layer}.ln2_bias", (cfg.d_model,)),
            (f"l{layer}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{layer}.b1", (cfg.d_ff,)),
            (f"l{layer}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{layer}.b2", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf_scale", (cfg.d_model,)),
        ("lnf_bias", (cfg.d_model,)),
        ("unembed", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def param_count(cfg: TransformerConfig) -> int:
    total = 0
    for _, shape in param_shapes(cfg):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


def unflatten(cfg: TransformerConfig, flat):
    """Slice the flat vector into the named parameter dict."""
    params = {}
    offset = 0
    for name, shape in param_shapes(cfg):
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[offset : offset + size].reshape(shape)
        offset += size
    return params


def init_params(cfg: TransformerConfig, seed: int = 0):
    """Deterministic init: scaled-normal weights, ones/zeros layer norms."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if "scale" in name:
            chunk = jnp.ones(shape, jnp.float32)
        elif "bias" in name or name.endswith(".b1") or name.endswith(".b2"):
            chunk = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (1.0 / fan_in) ** 0.5
            chunk = std * jax.random.normal(sub, shape, jnp.float32)
        chunks.append(chunk.reshape(-1))
    return jnp.concatenate(chunks)


def _layer_norm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def _dense(x, w, use_pallas):
    """2-D dense over the last axis, optionally via the Pallas kernel."""
    if not use_pallas:
        return x @ w
    flat = x.reshape(-1, x.shape[-1])
    out = matmul_kernel.matmul(flat, w)
    return out.reshape(*x.shape[:-1], w.shape[-1])


def forward(cfg: TransformerConfig, params, tokens, *, use_pallas: bool = False):
    """Causal LM logits for tokens (B, S) with S == cfg.seq."""
    b, s = tokens.shape
    h = params["embed"][tokens] + params["pos_embed"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.finfo(jnp.float32).min
    for layer in range(cfg.n_layers):
        p = lambda k: params[f"l{layer}.{k}"]  # noqa: E731
        # Attention block.
        x = _layer_norm(h, p("ln1_scale"), p("ln1_bias"))
        qkv = _dense(x, p("wqkv"), use_pallas)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (cfg.d_head**0.5)
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + _dense(out, p("wo"), use_pallas)
        # MLP block.
        x = _layer_norm(h, p("ln2_scale"), p("ln2_bias"))
        x = _dense(x, p("w1"), use_pallas) + p("b1")
        x = jax.nn.gelu(x)
        x = _dense(x, p("w2"), use_pallas) + p("b2")
        h = h + x
    h = _layer_norm(h, params["lnf_scale"], params["lnf_bias"])
    return h @ params["unembed"]


def transformer_loss(cfg: TransformerConfig, flat, window, *, use_pallas: bool = False):
    """Mean next-token cross entropy over a (B, S+1) token window."""
    params = unflatten(cfg, flat)
    inputs = window[:, :-1]
    targets = window[:, 1:]
    logits = forward(cfg, params, inputs, use_pallas=use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def transformer_loss_and_grad(cfg: TransformerConfig, flat, window, *, use_pallas: bool = False):
    """(loss, grad) with grad flattened to match ``flat`` — the artifact
    signature the Rust coordinator consumes."""
    fn = lambda f: transformer_loss(cfg, f, window, use_pallas=use_pallas)  # noqa: E731
    return jax.value_and_grad(fn)(flat)


# ---------------------------------------------------------------------------
# Gossip update (Layer-1 Pallas kernel behind the L2 entry point)
# ---------------------------------------------------------------------------


def gossip_update(w, x, m, g, beta, gamma):
    """Algorithm 1's fused mixing update; lowers the Pallas kernel into the
    artifact HLO."""
    return gossip_kernel.gossip_dmsgd(w, x, m, g, beta, gamma)
