"""Pallas kernel for the fused DmSGD gossip update (Algorithm 1).

This is the paper's compute hot-spot on the coordinator side: for stacked
node state ``X, M, G ∈ R^{n×P}`` and weight matrix ``W ∈ R^{n×n}``,

    X' = W (X − γ M)        M' = W (β M + G)

The operation is memory-bound in P (n is at most a few hundred, P is the
model size). TPU mapping (DESIGN.md §Hardware-Adaptation): tile the P
dimension into VMEM-sized blocks; W (tiny) stays resident per block; each
of X, M, G is streamed through VMEM exactly once, and the two small
``n × n @ n × p_block`` matmuls hit the MXU. On this testbed the kernel
runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec structure is preserved either way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default P-tile. 3 input streams + 2 output streams of (n × P_BLOCK) f32
# plus the (n × n) W must fit VMEM (≈16 MiB): for n ≤ 256,
# 5 · 256 · 2048 · 4 B ≈ 10.5 MiB. See python/tests/test_kernels.py for
# the footprint assertion.
P_BLOCK = 2048

# VMEM budget used for the footprint check (bytes).
VMEM_BYTES = 16 * 1024 * 1024


def vmem_footprint(n: int, p_block: int) -> int:
    """Bytes resident in VMEM for one grid step of the gossip kernel."""
    streams = 5  # x, m, g in; x', m' out
    return 4 * (streams * n * p_block + n * n)


def _gossip_kernel(w_ref, x_ref, m_ref, g_ref, beta_ref, gamma_ref, xo_ref, mo_ref):
    w = w_ref[...]
    x = x_ref[...]
    m = m_ref[...]
    g = g_ref[...]
    beta = beta_ref[0]
    gamma = gamma_ref[0]
    # One pass over m for both halves of the update.
    xo_ref[...] = jnp.dot(w, x - gamma * m, preferred_element_type=jnp.float32)
    mo_ref[...] = jnp.dot(w, beta * m + g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("p_block", "interpret"))
def gossip_dmsgd(w, x, m, g, beta, gamma, *, p_block: int = P_BLOCK, interpret: bool = True):
    """Fused DmSGD mixing update via Pallas.

    Args:
      w: (n, n) f32 weight matrix.
      x, m, g: (n, p) f32 stacked state.
      beta, gamma: f32 scalars (0-d or python floats).
      p_block: P-dimension tile; the final tile is padded by Pallas.
      interpret: run in interpret mode (required on CPU PJRT).

    Returns:
      (x', m') — both (n, p) f32.
    """
    n, p = x.shape
    assert w.shape == (n, n) and m.shape == (n, p) and g.shape == (n, p)
    pb = min(p_block, p)
    grid = (pl.cdiv(p, pb),)
    beta_arr = jnp.full((1,), beta, jnp.float32)
    gamma_arr = jnp.full((1,), gamma, jnp.float32)
    state_spec = pl.BlockSpec((n, pb), lambda i: (0, i))
    out_shape = (
        jax.ShapeDtypeStruct((n, p), jnp.float32),
        jax.ShapeDtypeStruct((n, p), jnp.float32),
    )
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # W resident per block
            state_spec,
            state_spec,
            state_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(state_spec, state_spec),
        out_shape=out_shape,
        interpret=interpret,
    )(w, x, m, g, beta_arr, gamma_arr)
