"""Blocked Pallas matmul targeting the MXU systolic array.

Used by the transformer MLP (``model.py``) when ``use_pallas=True`` and as
the standalone kernel benchmark. TPU mapping: 128×128 MXU-shaped tiles
with an f32 accumulator carried across the K grid dimension; on this
testbed it runs under ``interpret=True`` (the CPU PJRT client cannot
execute Mosaic custom-calls), so correctness is validated here and MXU
utilization is *estimated* from the BlockSpec in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile sizes.
BM, BK, BN = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    # Grid is (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension so
    # the f32 accumulator in o_ref is revisited across k steps.
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(a, b, *, bm: int = BM, bk: int = BK, bn: int = BN, interpret: bool = True):
    """C = A @ B with A (m, k) and B (k, n), f32 accumulation.

    Tiles are clamped to the operand shapes; ragged edges are padded by
    Pallas's BlockSpec machinery.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    # Pad ragged edges to tile multiples: out-of-bounds block contents are
    # undefined in Pallas, and an undefined K-edge would poison the
    # accumulator. Zero padding is exact for matmul.
    mp, kp, np_ = -(-m // bm_) * bm_, -(-k // bk_) * bk_, -(-n // bn_) * bn_
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
