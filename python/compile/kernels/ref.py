"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references at
build time (pytest + hypothesis sweeps in ``python/tests``). The oracles
are deliberately written in the most direct jnp form — no tiling, no
tricks — so they serve as the semantic ground truth.
"""

import jax.numpy as jnp


def gossip_dmsgd_ref(w, x, m, g, beta, gamma):
    """Algorithm 1's fused mixing update (the paper's core operation).

    x' = W (x − γ m)
    m' = W (β m + g)

    Args:
      w: (n, n) doubly-stochastic weight matrix.
      x, m, g: (n, p) stacked per-node parameters / momenta / gradients.
      beta, gamma: scalars.
    Returns:
      (x', m') each (n, p).
    """
    x_new = w @ (x - gamma * m)
    m_new = w @ (beta * m + g)
    return x_new, m_new


def matmul_ref(a, b):
    """Plain matmul oracle (f32 accumulate)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)
