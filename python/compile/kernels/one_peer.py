"""Specialized Pallas kernel for *one-peer* gossip updates.

The one-peer exponential realization has exactly two nonzeros per row of
W (½ on the diagonal, ½ at hop offset `2^t`), so materializing W and
paying an `n×n @ n×p` MXU matmul per block is wasted work. This kernel
computes Algorithm 1's update directly from the hop:

    x'_i = ½ (x_i − γ m_i) + ½ (x_{i+h} − γ m_{i+h})
    m'_i = ½ (β m_i + g_i) + ½ (β m_{i+h} + g_{i+h})

i.e. a roll-and-average along the node axis — pure VPU streaming, no MXU,
no W in VMEM. For n = 256 this removes the n² weight block and ~4·n²·p
FLOPs per update relative to the dense kernel (see compile.analyze).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

P_BLOCK = 4096


def _one_peer_kernel(hop_ref, x_ref, m_ref, g_ref, beta_ref, gamma_ref, xo_ref, mo_ref):
    hop = hop_ref[0]
    beta = beta_ref[0]
    gamma = gamma_ref[0]
    xh = x_ref[...] - gamma * m_ref[...]
    mh = beta * m_ref[...] + g_ref[...]
    # Row i's peer is row (i + hop) mod n: roll by -hop along nodes.
    xo_ref[...] = 0.5 * (xh + jnp.roll(xh, -hop, axis=0))
    mo_ref[...] = 0.5 * (mh + jnp.roll(mh, -hop, axis=0))


@functools.partial(jax.jit, static_argnames=("p_block", "interpret"))
def gossip_one_peer(hop, x, m, g, beta, gamma, *, p_block: int = P_BLOCK, interpret: bool = True):
    """One-peer fused DmSGD update.

    Args:
      hop: i32 scalar — the neighbor offset `2^{mod(k, τ)}`.
      x, m, g: (n, p) f32 stacked state.
      beta, gamma: f32 scalars.
    Returns:
      (x', m') — both (n, p) f32.
    """
    n, p = x.shape
    pb = min(p_block, p)
    grid = (pl.cdiv(p, pb),)
    state_spec = pl.BlockSpec((n, pb), lambda i: (0, i))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _one_peer_kernel,
        grid=grid,
        in_specs=[scalar, state_spec, state_spec, state_spec, scalar, scalar],
        out_specs=(state_spec, state_spec),
        out_shape=(
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ),
        interpret=interpret,
    )(
        jnp.full((1,), hop, jnp.int32),
        x,
        m,
        g,
        jnp.full((1,), beta, jnp.float32),
        jnp.full((1,), gamma, jnp.float32),
    )
