"""L2 model correctness: shapes, flat-parameter contract, gradients."""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model

CFG = model.TransformerConfig(vocab=64, d_model=16, n_layers=2, n_heads=2, seq=8)


def window(batch, cfg=CFG, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, cfg.vocab, size=(batch, cfg.seq + 1)), jnp.int32)


def test_param_count_matches_flat_layout():
    flat = model.init_params(CFG, 0)
    assert flat.shape == (model.param_count(CFG),)
    params = model.unflatten(CFG, flat)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == model.param_count(CFG)
    # Round-trip: re-flattening in layout order reproduces the vector.
    again = jnp.concatenate([params[n].reshape(-1) for n, _ in model.param_shapes(CFG)])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))


def test_forward_shapes_and_initial_loss():
    flat = model.init_params(CFG, 1)
    win = window(3)
    logits = model.forward(CFG, model.unflatten(CFG, flat), win[:, :-1])
    assert logits.shape == (3, CFG.seq, CFG.vocab)
    loss, grad = model.transformer_loss_and_grad(CFG, flat, win)
    # Near-uniform prediction at init: loss ≈ ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0
    assert grad.shape == flat.shape
    assert np.isfinite(np.asarray(grad)).all()


def test_causality():
    """Changing a future token must not change past logits."""
    flat = model.init_params(CFG, 2)
    params = model.unflatten(CFG, flat)
    win = window(1, seed=3)
    tokens = win[:, :-1]
    logits_a = model.forward(CFG, params, tokens)
    tokens_b = tokens.at[0, -1].set((tokens[0, -1] + 1) % CFG.vocab)
    logits_b = model.forward(CFG, params, tokens_b)
    np.testing.assert_allclose(
        np.asarray(logits_a[0, :-1]), np.asarray(logits_b[0, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits_a[0, -1]), np.asarray(logits_b[0, -1]))


def test_gradient_matches_finite_differences():
    flat = model.init_params(CFG, 4)
    win = window(2, seed=5)
    loss, grad = model.transformer_loss_and_grad(CFG, flat, win)
    rng = np.random.default_rng(6)
    idx = rng.choice(flat.shape[0], size=6, replace=False)
    eps = 1e-3
    for j in idx:
        e = jnp.zeros_like(flat).at[j].set(eps)
        lp = model.transformer_loss(CFG, flat + e, win)
        lm = model.transformer_loss(CFG, flat - e, win)
        fd = float(lp - lm) / (2 * eps)
        gj = float(grad[j])
        assert abs(fd - gj) < 5e-3 + 0.05 * abs(gj), f"idx {j}: fd={fd} grad={gj}"


def test_training_reduces_loss():
    """A few full-batch steps on a fixed window must overfit it."""
    flat = model.init_params(CFG, 7)
    win = window(2, seed=8)
    loss0, _ = model.transformer_loss_and_grad(CFG, flat, win)
    for _ in range(30):
        _, grad = model.transformer_loss_and_grad(CFG, flat, win)
        flat = flat - 0.5 * grad
    loss1, _ = model.transformer_loss_and_grad(CFG, flat, win)
    assert float(loss1) < 0.5 * float(loss0), f"{float(loss0)} -> {float(loss1)}"


def test_pallas_mlp_path_matches_jnp_path():
    """use_pallas=True routes the MLP through the Pallas matmul kernel and
    must agree with the jnp path."""
    flat = model.init_params(CFG, 9)
    win = window(2, seed=10)
    a = model.transformer_loss(CFG, flat, win, use_pallas=False)
    b = model.transformer_loss(CFG, flat, win, use_pallas=True)
    assert abs(float(a) - float(b)) < 1e-4


def test_logreg_grad_matches_manual():
    rng = np.random.default_rng(11)
    d, b = 10, 32
    x = jnp.array(rng.standard_normal(d), jnp.float32)
    h = jnp.array(rng.standard_normal((b, d)), jnp.float32)
    y = jnp.array(rng.choice([-1.0, 1.0], size=b), jnp.float32)
    loss, grad = model.logreg_loss_and_grad(x, h, y)
    z = np.asarray(h) @ np.asarray(x)
    yz = np.asarray(y) * z
    manual_loss = np.mean(np.log1p(np.exp(-yz)))
    sig = 1.0 / (1.0 + np.exp(yz))
    manual_grad = -(np.asarray(y) * sig) @ np.asarray(h) / b
    assert abs(float(loss) - manual_loss) < 1e-5
    np.testing.assert_allclose(np.asarray(grad), manual_grad, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,p", [(4, 96), (8, 40)])
def test_gossip_update_entrypoint(n, p):
    """The L2 gossip entry point (what the artifact lowers) equals the
    dense reference."""
    rng = np.random.default_rng(12)
    w = np.ones((n, n), np.float32) / n
    x = rng.standard_normal((n, p)).astype(np.float32)
    m = rng.standard_normal((n, p)).astype(np.float32)
    g = rng.standard_normal((n, p)).astype(np.float32)
    xo, mo = model.gossip_update(
        jnp.array(w), jnp.array(x), jnp.array(m), jnp.array(g),
        jnp.float32(0.9), jnp.float32(0.1),
    )
    np.testing.assert_allclose(np.asarray(xo), w @ (x - 0.1 * m), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), w @ (0.9 * m + g), rtol=1e-5, atol=1e-5)
