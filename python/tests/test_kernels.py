"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and block sizes) so tiling edge cases — ragged
tiles, single-row stacks, blocks larger than the operand — are all
exercised against ``ref.py``.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gossip, matmul, ref


def doubly_stochastic(n: int, rng: np.random.Generator) -> np.ndarray:
    """Random doubly-stochastic matrix by Sinkhorn iteration."""
    w = rng.uniform(0.1, 1.0, size=(n, n))
    for _ in range(50):
        w /= w.sum(axis=1, keepdims=True)
        w /= w.sum(axis=0, keepdims=True)
    return w.astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12),
    p=st.integers(1, 257),
    p_block=st.sampled_from([1, 7, 64, 2048]),
    beta=st.floats(0.0, 0.99),
    gamma=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_gossip_matches_ref(n, p, p_block, beta, gamma, seed):
    rng = np.random.default_rng(seed)
    w = doubly_stochastic(n, rng)
    x = rng.standard_normal((n, p)).astype(np.float32)
    m = rng.standard_normal((n, p)).astype(np.float32)
    g = rng.standard_normal((n, p)).astype(np.float32)
    xo, mo = gossip.gossip_dmsgd(
        jnp.array(w), jnp.array(x), jnp.array(m), jnp.array(g), beta, gamma, p_block=p_block
    )
    xr, mr = ref.gossip_dmsgd_ref(w, x, m, g, beta, gamma)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-5, atol=1e-5)


def test_gossip_exact_averaging_after_tau_steps():
    """Lemma 1, executed through the kernel: τ one-peer mixes = exact mean."""
    n, p, tau = 8, 33, 3
    rng = np.random.default_rng(1)
    x = rng.standard_normal((n, p)).astype(np.float32)
    m = np.zeros((n, p), np.float32)
    g = np.zeros((n, p), np.float32)
    for t in range(tau):
        w = np.zeros((n, n), np.float32)
        for i in range(n):
            w[i, i] += 0.5
            w[i, (i + (1 << t)) % n] += 0.5
        x, m = (np.asarray(a) for a in gossip.gossip_dmsgd(
            jnp.array(w), jnp.array(x), jnp.array(m), jnp.array(g), 0.0, 0.0
        ))
    mean = np.asarray(x).mean(axis=0, keepdims=True)
    np.testing.assert_allclose(np.asarray(x), np.repeat(mean, n, axis=0), atol=1e-5)


def test_gossip_preserves_mean():
    """Doubly-stochastic W keeps the node-mean invariant (γ = 0)."""
    rng = np.random.default_rng(2)
    n, p = 6, 100
    w = doubly_stochastic(n, rng)
    x = rng.standard_normal((n, p)).astype(np.float32)
    z = np.zeros_like(x)
    xo, _ = gossip.gossip_dmsgd(jnp.array(w), jnp.array(x), jnp.array(z), jnp.array(z), 0.0, 0.0)
    np.testing.assert_allclose(
        np.asarray(xo).mean(axis=0), x.mean(axis=0), rtol=1e-4, atol=1e-5
    )


def test_gossip_vmem_footprint_within_budget():
    """The default BlockSpec fits the 16 MiB VMEM budget up to n = 256."""
    assert gossip.vmem_footprint(256, gossip.P_BLOCK) <= gossip.VMEM_BYTES
    assert gossip.vmem_footprint(64, gossip.P_BLOCK) <= gossip.VMEM_BYTES // 4


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 180),
    n=st.integers(1, 200),
    block=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, block, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    c = matmul.matmul(jnp.array(a), jnp.array(b), bm=block, bk=block, bn=block)
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(ref.matmul_ref(a, b)), rtol=1e-4, atol=1e-4
    )


def test_matmul_identity():
    a = np.eye(64, dtype=np.float32)
    b = np.arange(64 * 32, dtype=np.float32).reshape(64, 32)
    c = matmul.matmul(jnp.array(a), jnp.array(b), bm=16, bk=16, bn=16)
    np.testing.assert_allclose(np.asarray(c), b)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    """bf16 inputs accumulate in f32 (the MXU contract)."""
    rng = np.random.default_rng(3)
    a = jnp.array(rng.standard_normal((48, 48)), dtype)
    b = jnp.array(rng.standard_normal((48, 48)), dtype)
    c = matmul.matmul(a, b, bm=16, bk=16, bn=16)
    assert c.dtype == jnp.float32
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(c),
        np.asarray(a, np.float32) @ np.asarray(b, np.float32),
        rtol=tol,
        atol=tol,
    )


# ---------------------------------------------------------------------------
# One-peer specialized kernel (kernels/one_peer.py)
# ---------------------------------------------------------------------------

from compile.kernels import one_peer  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    tau_exp=st.integers(1, 5),
    p=st.integers(1, 300),
    t=st.integers(0, 8),
    p_block=st.sampled_from([32, 4096]),
    beta=st.floats(0.0, 0.99),
    gamma=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_one_peer_kernel_matches_dense_gossip(tau_exp, p, t, p_block, beta, gamma, seed):
    n = 1 << tau_exp
    hop = 1 << (t % tau_exp)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    m = rng.standard_normal((n, p)).astype(np.float32)
    g = rng.standard_normal((n, p)).astype(np.float32)
    w = np.zeros((n, n), np.float32)
    for i in range(n):
        w[i, i] += 0.5
        w[i, (i + hop) % n] += 0.5
    xo, mo = one_peer.gossip_one_peer(
        hop, jnp.array(x), jnp.array(m), jnp.array(g), beta, gamma, p_block=p_block
    )
    xr, mr = ref.gossip_dmsgd_ref(w, x, m, g, beta, gamma)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-5, atol=1e-5)


def test_one_peer_tau_steps_reach_exact_average():
    """Lemma 1 through the specialized kernel."""
    n, p = 16, 40
    rng = np.random.default_rng(4)
    x = rng.standard_normal((n, p)).astype(np.float32)
    m = np.zeros((n, p), np.float32)
    g = np.zeros((n, p), np.float32)
    for t in range(4):  # tau = log2(16)
        x, m = (
            np.asarray(a)
            for a in one_peer.gossip_one_peer(1 << t, jnp.array(x), jnp.array(m), jnp.array(g), 0.0, 0.0)
        )
    np.testing.assert_allclose(x, np.repeat(x.mean(axis=0, keepdims=True), n, axis=0), atol=1e-5)
