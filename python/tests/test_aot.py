"""AOT pipeline: HLO-text emission, manifest integrity, idempotence."""

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_is_parseable_hlo():
    lowered = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32), jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Text form — no serialized proto bytes.
    assert text.isprintable() or "\n" in text


def test_build_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as tmp:
        aot.build(tmp)
        manifest = json.load(open(os.path.join(tmp, "manifest.json")))
        names = {a["name"] for a in manifest["artifacts"]}
        assert {
            "logreg_grad",
            "transformer_step",
            "transformer_step_small",
            "gossip_update",
            "gossip_update_small",
        } <= names
        for a in manifest["artifacts"]:
            path = os.path.join(tmp, a["file"])
            assert os.path.exists(path), a["name"]
            head = open(path).read(200)
            assert "HloModule" in head
            assert a["num_outputs"] == 2
            for inp in a["inputs"]:
                assert inp["dtype"] in ("float32", "int32")


def test_build_is_idempotent_no_op():
    with tempfile.TemporaryDirectory() as tmp:
        aot.build(tmp)
        stamps = {
            f: os.path.getmtime(os.path.join(tmp, f)) for f in os.listdir(tmp) if f.endswith(".hlo.txt")
        }
        aot.build(tmp)  # second run must skip all artifacts
        for f, t in stamps.items():
            assert os.path.getmtime(os.path.join(tmp, f)) == t, f


def test_manifest_shapes_match_configs():
    with tempfile.TemporaryDirectory() as tmp:
        aot.build(tmp)
        manifest = json.load(open(os.path.join(tmp, "manifest.json")))
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        ts = by_name["transformer_step"]
        p = model.param_count(aot.E2E_CFG)
        assert ts["inputs"][0]["shape"] == [p]
        assert ts["inputs"][1]["shape"] == [aot.E2E_BATCH, aot.E2E_CFG.seq + 1]
        assert ts["meta"]["param_count"] == p
        gu = by_name["gossip_update"]
        assert gu["inputs"][1]["shape"] == [aot.GOSSIP_N, p]
