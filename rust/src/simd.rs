//! Explicit SIMD-friendly micro-kernel primitives for the training hot
//! path (docs/DESIGN.md §Perf).
//!
//! # Contract
//!
//! * **Lane width.** The f32 kernels process the parameter dimension in
//!   fixed blocks of [`LANES`] = 8 elements with per-block register
//!   accumulators; f64 reductions use [`F64_LANES`] = 4. The block loops
//!   are written so LLVM maps one block to one AVX/NEON vector op.
//! * **FMA / rounding policy.** All kernels fold multiplies and adds
//!   through [`fmaf`]/[`fmad`]. When the build enables the `fma` target
//!   feature (see `.cargo/config.toml`, `target-cpu=native`) these are
//!   single-rounded hardware `mul_add`s; otherwise they fall back to the
//!   two-rounding `a * b + c` (never the libm soft-float `mul_add`,
//!   which is ~50× slower). Rounding therefore differs between an
//!   FMA-enabled and an FMA-less *build*, but is fixed within a build —
//!   which is all the determinism contract pins.
//! * **Determinism argument.** Vectorization is across the parameter
//!   dimension only: every output element `k` is still the same
//!   ascending-`j` fold of `fmaf` it would be in a sequential loop, and
//!   an f32 store/load is exact — so blocking can never change a bit,
//!   and the engine's lane-count invariance
//!   (tests/engine_determinism.rs) is untouched. The scalar reference
//!   kernels (see [`scalar_kernels`]) evaluate the identical per-element
//!   fold one element at a time, which is why tests/kernels.rs can pin
//!   vectorized vs. scalar **bitwise**.

use std::sync::atomic::{AtomicBool, Ordering};

/// f32 block width of the vectorized kernels.
pub const LANES: usize = 8;

/// f64 block width of the ordered reductions.
pub const F64_LANES: usize = 4;

/// Fused multiply-add `a * b + c` (f32) under the policy above.
#[cfg(target_feature = "fma")]
#[inline(always)]
pub fn fmaf(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c)
}

/// Fused multiply-add `a * b + c` (f32) under the policy above.
#[cfg(not(target_feature = "fma"))]
#[inline(always)]
pub fn fmaf(a: f32, b: f32, c: f32) -> f32 {
    a * b + c
}

/// Fused multiply-add `a * b + c` (f64) under the policy above.
#[cfg(target_feature = "fma")]
#[inline(always)]
pub fn fmad(a: f64, b: f64, c: f64) -> f64 {
    a.mul_add(b, c)
}

/// Fused multiply-add `a * b + c` (f64) under the policy above.
#[cfg(not(target_feature = "fma"))]
#[inline(always)]
pub fn fmad(a: f64, b: f64, c: f64) -> f64 {
    a * b + c
}

/// When set, the mixing kernels dispatch to their retained scalar
/// reference twins (identical per-element `fmaf` fold, one element at a
/// time — no blocking). This is the comparator the benches time and the
/// oracle tests/kernels.rs pins bitwise against the vectorized path.
static SCALAR_KERNELS: AtomicBool = AtomicBool::new(false);

/// Are the scalar reference kernels selected?
#[inline(always)]
pub fn scalar_kernels() -> bool {
    SCALAR_KERNELS.load(Ordering::Relaxed)
}

/// Select the scalar reference kernels (process-wide; tests and benches
/// only — prefer the RAII [`ScalarGuard`]).
pub fn set_scalar_kernels(on: bool) {
    SCALAR_KERNELS.store(on, Ordering::Relaxed);
}

/// RAII selector for the scalar reference kernels: scalar while alive,
/// vectorized again on drop.
pub struct ScalarGuard(());

impl ScalarGuard {
    pub fn new() -> ScalarGuard {
        set_scalar_kernels(true);
        ScalarGuard(())
    }
}

impl Default for ScalarGuard {
    fn default() -> Self {
        ScalarGuard::new()
    }
}

impl Drop for ScalarGuard {
    fn drop(&mut self) {
        set_scalar_kernels(false);
    }
}

/// `out[k] = fmaf(src[k], scale, out[k])` over the whole slice, 8-lane
/// blocked. Per-element order of the surrounding accumulation (e.g. the
/// row loop of `StackedParams::mean_into`) is untouched — blocking across
/// `k` cannot regroup any single element's fold.
#[inline]
pub fn accumulate_scaled(out: &mut [f32], src: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), src.len());
    let n = out.len();
    let blocks = n / LANES;
    for blk in 0..blocks {
        let k0 = blk * LANES;
        let o = &mut out[k0..k0 + LANES];
        let s = &src[k0..k0 + LANES];
        for l in 0..LANES {
            o[l] = fmaf(s[l], scale, o[l]);
        }
    }
    for k in blocks * LANES..n {
        out[k] = fmaf(src[k], scale, out[k]);
    }
}

/// Ordered f64 reduction of `Σ_k ((a[k] − b[k]) as f64)²` with
/// [`F64_LANES`] partial accumulators: element `k` lands in accumulator
/// `k % F64_LANES`, and the partials combine in fixed ascending order.
/// The result is a pure function of the two slices — independent of any
/// sharding or lane count — which is what lets the serial
/// `StackedParams::consensus_distance` and the engine's sharded probe
/// share it and agree bitwise.
#[inline]
pub fn sum_sq_diff(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f64; F64_LANES];
    let blocks = n / F64_LANES;
    for blk in 0..blocks {
        let k0 = blk * F64_LANES;
        for l in 0..F64_LANES {
            let d = (a[k0 + l] - b[k0 + l]) as f64;
            acc[l] = fmad(d, d, acc[l]);
        }
    }
    for (l, k) in (blocks * F64_LANES..n).enumerate() {
        let d = (a[k] - b[k]) as f64;
        acc[l] = fmad(d, d, acc[l]);
    }
    ((acc[0] + acc[1]) + acc[2]) + acc[3]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmaf_matches_reference_to_one_ulp_regime() {
        // Whatever the build's FMA policy, fmaf is one of the two
        // correct evaluations of a*b + c.
        let (a, b, c) = (1.25f32, 3.5f32, -0.75f32);
        let plain = a * b + c;
        let fused = a.mul_add(b, c);
        let got = fmaf(a, b, c);
        assert!(got == plain || got == fused);
    }

    #[test]
    fn accumulate_scaled_matches_sequential_bitwise() {
        // Blocking across k must not change a single bit vs. the naive
        // element-at-a-time loop using the same fmaf.
        for n in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let src: Vec<f32> = (0..n).map(|k| (k as f32 * 0.37).sin()).collect();
            let mut out: Vec<f32> = (0..n).map(|k| (k as f32 * 0.11).cos()).collect();
            let mut want = out.clone();
            for k in 0..n {
                want[k] = fmaf(src[k], 0.125, want[k]);
            }
            accumulate_scaled(&mut out, &src, 0.125);
            for k in 0..n {
                assert_eq!(out[k].to_bits(), want[k].to_bits(), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn sum_sq_diff_is_close_and_deterministic() {
        for n in [0usize, 1, 3, 4, 5, 63, 64, 65] {
            let a: Vec<f32> = (0..n).map(|k| (k as f32 * 0.3).sin()).collect();
            let b: Vec<f32> = (0..n).map(|k| (k as f32 * 0.2).cos()).collect();
            let naive: f64 = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| {
                    let d = (x - y) as f64;
                    d * d
                })
                .sum();
            let got = sum_sq_diff(&a, &b);
            assert!((got - naive).abs() <= 1e-12 * naive.max(1.0), "n={n}: {got} vs {naive}");
            // Pure function: repeated calls identical.
            assert_eq!(got.to_bits(), sum_sq_diff(&a, &b).to_bits());
        }
    }

    #[test]
    fn scalar_guard_restores_vectorized() {
        assert!(!scalar_kernels());
        {
            let _g = ScalarGuard::new();
            assert!(scalar_kernels());
        }
        assert!(!scalar_kernels());
    }
}
