//! Minimal complex-number arithmetic (f64) for DFT-based circulant
//! eigenvalue computation.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A complex number `re + j·im` over `f64`.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    /// Purely real complex number.
    #[inline]
    pub fn real(re: f64) -> Self {
        Complex { re, im: 0.0 }
    }

    /// `e^{jθ} = cos θ + j sin θ`.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        Complex { re: theta.cos(), im: theta.sin() }
    }

    /// Squared magnitude `|z|²`.
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude `|z|`.
    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, rhs: Complex) -> Complex {
        Complex { re: self.re + rhs.re, im: self.im + rhs.im }
    }
}

impl AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, rhs: Complex) {
        self.re += rhs.re;
        self.im += rhs.im;
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, rhs: Complex) -> Complex {
        Complex { re: self.re - rhs.re, im: self.im - rhs.im }
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, rhs: Complex) -> Complex {
        Complex {
            re: self.re * rhs.re - self.im * rhs.im,
            im: self.re * rhs.im + self.im * rhs.re,
        }
    }
}

impl Div for Complex {
    type Output = Complex;
    #[inline]
    fn div(self, rhs: Complex) -> Complex {
        let d = rhs.norm_sqr();
        Complex {
            re: (self.re * rhs.re + self.im * rhs.im) / d,
            im: (self.im * rhs.re - self.re * rhs.im) / d,
        }
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let a = Complex::new(1.5, -2.0);
        let b = Complex::new(-0.25, 3.0);
        assert_eq!(a + b, Complex::new(1.25, 1.0));
        assert_eq!(a - b, Complex::new(1.75, -5.0));
        // (1.5 - 2j)(-0.25 + 3j) = -0.375 + 4.5j + 0.5j + 6 = 5.625 + 5j
        let p = a * b;
        assert!((p.re - 5.625).abs() < 1e-12 && (p.im - 5.0).abs() < 1e-12);
        let q = p / b;
        assert!((q.re - a.re).abs() < 1e-12 && (q.im - a.im).abs() < 1e-12);
    }

    #[test]
    fn cis_unit_circle() {
        let z = Complex::cis(std::f64::consts::FRAC_PI_2);
        assert!(z.re.abs() < 1e-15 && (z.im - 1.0).abs() < 1e-15);
        assert!((Complex::cis(1.234).abs() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn conj_and_norm() {
        let z = Complex::new(3.0, 4.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.norm_sqr(), 25.0);
        assert_eq!(z.conj(), Complex::new(3.0, -4.0));
        let zz = z * z.conj();
        assert!((zz.re - 25.0).abs() < 1e-12 && zz.im.abs() < 1e-12);
    }
}
