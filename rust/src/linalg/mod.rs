//! Dense linear-algebra substrate.
//!
//! The spectral analysis in the paper needs three tools, all implemented
//! here from scratch (no external linear-algebra crates):
//!
//! * [`Complex`] arithmetic and a radix-agnostic [`fft`] module — circulant
//!   weight matrices (static exponential graph, Eq. (5)) have eigenvalues
//!   given by the DFT of their generating vector (Lemma 2 of the paper).
//! * A cyclic [`jacobi`] eigensolver for symmetric matrices — the
//!   Metropolis weight matrices of ring/star/grid/torus are symmetric.
//! * [`power`] iteration on `(W−J)ᵀ(W−J)` for the consensus-relevant
//!   spectral norm `‖W − 11ᵀ/n‖₂` of arbitrary (possibly non-symmetric,
//!   time-varying) weight matrices.

pub mod complex;
pub mod fft;
pub mod jacobi;
pub mod matrix;
pub mod power;

pub use complex::Complex;
pub use matrix::Matrix;
