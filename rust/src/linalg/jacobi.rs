//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! The Metropolis weight matrices of ring, star, grid and torus topologies
//! are symmetric doubly-stochastic, so their full real spectrum is obtained
//! here. Convergence: off-diagonal Frobenius mass strictly decreases each
//! rotation; we sweep until it drops below `tol · ‖A‖_F`.

use super::matrix::Matrix;

/// Result of a symmetric eigendecomposition.
pub struct SymmetricEig {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
}

/// Compute all eigenvalues of a symmetric matrix with the cyclic Jacobi
/// method. Panics if `a` is not square; callers should ensure symmetry
/// (asymmetry below `1e-9` is tolerated and symmetrized).
pub fn sym_eigenvalues(a: &Matrix) -> SymmetricEig {
    assert_eq!(a.rows(), a.cols(), "jacobi: non-square input");
    let n = a.rows();
    // Work on a symmetrized copy to wash out representation noise.
    let mut m = a.clone();
    for i in 0..n {
        for j in (i + 1)..n {
            let s = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = s;
            m[(j, i)] = s;
        }
    }
    let norm = m.fro_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * norm;
    let max_sweeps = 64;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol / (n as f64) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Classic Jacobi rotation angle.
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation G(p,q,θ)ᵀ · M · G(p,q,θ).
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
            }
        }
    }

    let mut values: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    values.sort_by(|a, b| b.partial_cmp(a).unwrap());
    SymmetricEig { values }
}

/// Second-largest eigenvalue *magnitude* of a symmetric doubly-stochastic
/// matrix: `ρ(W) = max_{λ_i ≠ λ_max} |λ_i|` where the top eigenvalue 1 is
/// excluded once.
pub fn sym_rho(w: &Matrix) -> f64 {
    let eig = sym_eigenvalues(w);
    // Exclude exactly one copy of the (largest) Perron eigenvalue ≈ 1.
    let mut mags: Vec<f64> = eig.values.iter().map(|v| v.abs()).collect();
    // values are sorted descending; values[0] ≈ 1 is the Perron root.
    let perron_idx = 0;
    mags.remove(perron_idx);
    mags.into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_eigs_are_diagonal() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 0.5, 2.0].iter().enumerate() {
            a[(i, i)] = *v;
        }
        let eig = sym_eigenvalues(&a);
        assert_eq!(eig.values, vec![3.0, 2.0, 0.5, -1.0]);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let eig = sym_eigenvalues(&a);
        assert!((eig.values[0] - 3.0).abs() < 1e-12);
        assert!((eig.values[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_frobenius_invariants() {
        // Random symmetric matrix: Σλ = tr(A), Σλ² = ‖A‖_F².
        let n = 12;
        let mut a = Matrix::zeros(n, n);
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        };
        for i in 0..n {
            for j in i..n {
                let v = next();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
        let fro2 = a.fro_norm().powi(2);
        let eig = sym_eigenvalues(&a);
        let sum: f64 = eig.values.iter().sum();
        let sum2: f64 = eig.values.iter().map(|v| v * v).sum();
        assert!((sum - tr).abs() < 1e-9, "trace mismatch: {sum} vs {tr}");
        assert!((sum2 - fro2).abs() < 1e-8, "fro mismatch: {sum2} vs {fro2}");
    }

    #[test]
    fn rho_of_averaging_matrix_is_zero() {
        let j = Matrix::averaging(6);
        assert!(sym_rho(&j) < 1e-12);
    }

    #[test]
    fn rho_of_identity_is_one() {
        // I has eigenvalue 1 with multiplicity n; removing one copy leaves 1.
        assert!((sym_rho(&Matrix::eye(5)) - 1.0).abs() < 1e-12);
    }
}
