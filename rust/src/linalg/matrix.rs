//! Row-major dense `f64` matrices.
//!
//! This is the analysis-grade matrix type used by the topology, spectral and
//! consensus modules (weight matrices are small: `n ≤ a few hundred`).
//! Training state uses flat `f32` buffers in `coordinator` instead.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Matrix { rows, cols, data: data.to_vec() }
    }

    /// The exact-averaging matrix `J = 11ᵀ/n`.
    pub fn averaging(n: usize) -> Self {
        Matrix { rows: n, cols: n, data: vec![1.0 / n as f64; n * n] }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow the underlying row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable borrow of the underlying row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix-matrix product `self · rhs`.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        // ikj loop order: stream rhs rows, accumulate into the output row.
        for i in 0..self.rows {
            let orow = &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &rhs.data[k * rhs.cols..(k + 1) * rhs.cols];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix-vector product `self · v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(v.iter()) {
                acc += a * b;
            }
            out[i] = acc;
        }
        out
    }

    /// `self − rhs`.
    pub fn sub(&self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(rhs.data.iter()).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self + rhs`.
    pub fn add(&self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(rhs.data.iter()).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scale every entry by `s`.
    pub fn scale(&self, s: f64) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Max absolute entry (ℓ∞ over entries).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, a| m.max(a.abs()))
    }

    /// Is this matrix symmetric to tolerance `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Residue `W − 11ᵀ/n` of a square matrix (the consensus error operator).
    pub fn consensus_residue(&self) -> Matrix {
        assert_eq!(self.rows, self.cols, "residue of a non-square matrix");
        self.sub(&Matrix::averaging(self.rows))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:8.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_matmul_neutral() {
        let a = Matrix::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i2 = Matrix::eye(2);
        let i3 = Matrix::eye(3);
        assert_eq!(i2.matmul(&a), a);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(3, 3, &[1.0, 0.5, 0.0, 0.25, 0.25, 0.5, 0.0, 0.0, 1.0]);
        let v = vec![1.0, 2.0, 3.0];
        let got = a.matvec(&v);
        let as_mat = a.matmul(&Matrix::from_rows(3, 1, &v));
        assert_eq!(got, as_mat.as_slice());
    }

    #[test]
    fn transpose_involutive() {
        let a = Matrix::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn averaging_is_idempotent_projection() {
        let j = Matrix::averaging(5);
        let jj = j.matmul(&j);
        assert!(jj.sub(&j).max_abs() < 1e-14);
        assert!(j.is_symmetric(0.0));
    }

    #[test]
    fn residue_of_averaging_is_zero() {
        let j = Matrix::averaging(7);
        assert!(j.consensus_residue().max_abs() < 1e-15);
    }

    #[test]
    fn fro_norm_known() {
        let a = Matrix::from_rows(2, 2, &[3.0, 0.0, 4.0, 0.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-14);
    }
}
