//! Power iteration for spectral norms.
//!
//! The consensus analysis needs `‖W − 11ᵀ/n‖₂` for arbitrary (possibly
//! non-symmetric, possibly products of time-varying) weight matrices —
//! Proposition 1 establishes this equals ρ(W) for exponential graphs, and
//! Fig. 12 tracks `‖∏ Ŵ^{(i)}‖₂²` over iterations. Since `‖A‖₂² =
//! λ_max(AᵀA)` and `AᵀA` is symmetric PSD, plain power iteration converges
//! monotonically in the Rayleigh quotient.

use super::matrix::Matrix;

/// Deterministic starting vector that is extremely unlikely to be orthogonal
/// to the top eigenvector: pseudo-random entries from a fixed LCG.
fn seed_vector(n: usize, seed: u64) -> Vec<f64> {
    let mut state = seed | 1;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        })
        .collect()
}

fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

/// Largest eigenvalue of a symmetric PSD matrix via power iteration.
pub fn psd_top_eigenvalue(a: &Matrix, max_iters: usize, tol: f64) -> f64 {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    if n == 0 {
        return 0.0;
    }
    let mut v = seed_vector(n, 0xE55AF00D);
    normalize(&mut v);
    let mut lambda = 0.0;
    for _ in 0..max_iters {
        let mut w = a.matvec(&v);
        let norm = normalize(&mut w);
        if (norm - lambda).abs() <= tol * lambda.max(1e-30) {
            return norm;
        }
        lambda = norm;
        v = w;
    }
    lambda
}

/// Spectral norm `‖A‖₂ = σ_max(A)` via power iteration on `AᵀA`.
pub fn spectral_norm(a: &Matrix) -> f64 {
    let ata = a.transpose().matmul(a);
    psd_top_eigenvalue(&ata, 10_000, 1e-14).max(0.0).sqrt()
}

/// `‖W − 11ᵀ/n‖₂` — the consensus contraction factor of a weight matrix.
pub fn consensus_norm(w: &Matrix) -> f64 {
    spectral_norm(&w.consensus_residue())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Matrix::zeros(3, 3);
        a[(0, 0)] = -4.0;
        a[(1, 1)] = 2.0;
        a[(2, 2)] = 1.0;
        assert!((spectral_norm(&a) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn spectral_norm_nonsymmetric_known() {
        // A = [[0, 2], [0, 0]] has σ_max = 2 (ρ(A) = 0 — norm ≠ spectral radius).
        let a = Matrix::from_rows(2, 2, &[0.0, 2.0, 0.0, 0.0]);
        assert!((spectral_norm(&a) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_norm_of_averaging_is_zero() {
        assert!(consensus_norm(&Matrix::averaging(8)) < 1e-9);
    }

    #[test]
    fn consensus_norm_of_identity_is_one() {
        assert!((consensus_norm(&Matrix::eye(8)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn matches_jacobi_on_symmetric() {
        // For symmetric W, ‖W − J‖₂ should equal max |λ_i| over non-Perron λ
        // when W is doubly stochastic. Use a symmetric gossip-like matrix.
        let w = Matrix::from_rows(
            3,
            3,
            &[0.5, 0.25, 0.25, 0.25, 0.5, 0.25, 0.25, 0.25, 0.5],
        );
        let via_power = consensus_norm(&w);
        let via_jacobi = crate::linalg::jacobi::sym_rho(&w);
        assert!((via_power - via_jacobi).abs() < 1e-9, "{via_power} vs {via_jacobi}");
    }
}
