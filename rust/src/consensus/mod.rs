//! Consensus / partial-averaging analysis (Sec. 4 of the paper).
//!
//! Implements the numerical studies behind Fig. 4 (residue decay of static
//! vs one-peer exponential vs random matching), Fig. 10 (non-power-of-2
//! sizes), Fig. 11 (sampling strategies) and Fig. 12 (`‖∏ Ŵ^{(i)}‖₂²`),
//! plus the exact-averaging verification of Lemma 1.
//!
//! Gossip simulation is sparse-first and engine-routed:
//! [`residue_decay`] walks the schedule's cached plans with `O(nnz)`
//! sparse matvecs sharded over the same persistent worker pool the
//! trainer uses ([`Engine::gossip_into`] — row-local, bitwise-identical
//! for any lane count), so large-`n` sweeps never touch a dense matrix
//! and never spawn per-step threads. Only the spectral-norm study
//! ([`residue_product_norms`]) goes through the dense escape hatch (it
//! needs full matrix products for `‖·‖₂`).

use crate::engine::Engine;
use crate::linalg::{power, Matrix};
use crate::netsim::NetSim;
use crate::topology::schedule::Schedule;
use crate::topology::{Topology, TopologyKind};
use crate::util::rng::Pcg;

/// One gossip step on a vector of node values: `x ← W x` (dense form;
/// kept as an escape hatch for ad-hoc matrices — the simulation loops
/// use the sparse `MixingPlan::matvec` directly).
pub fn gossip_step(w: &Matrix, x: &[f64]) -> Vec<f64> {
    w.matvec(x)
}

/// Consensus residue of node values: `‖x − x̄·1‖₂`.
pub fn residue_norm(x: &[f64]) -> f64 {
    let n = x.len() as f64;
    let mean = x.iter().sum::<f64>() / n;
    x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>().sqrt()
}

/// Run `iters` gossip steps of a topology schedule starting from a random
/// vector; return the residue norm after each step, normalized by the
/// initial residue (this is the y-axis of Figs. 4/10/11).
///
/// Sizes a pool automatically ([`Engine::auto`]; single-lane below the
/// threshold) and delegates to [`residue_decay_on`].
pub fn residue_decay(kind: TopologyKind, n: usize, iters: usize, seed: u64) -> Vec<f64> {
    residue_decay_on(&Engine::auto(n, 1), kind, n, iters, seed)
}

/// [`residue_decay`] for any registered topology family (the open
/// registry — finite-time base-(k+1)/CECA included).
pub fn residue_decay_topo(topo: Topology, n: usize, iters: usize, seed: u64) -> Vec<f64> {
    residue_decay_on_topo(&Engine::auto(n, 1), topo, n, iters, seed)
}

/// [`residue_decay`] on a caller-supplied engine: every gossip step is a
/// sharded `W x` on the persistent pool (double-buffered — no per-step
/// allocation, no per-step threads). Row-local sparse dot products make
/// the trajectory bitwise-identical for any lane count.
pub fn residue_decay_on(
    engine: &Engine,
    kind: TopologyKind,
    n: usize,
    iters: usize,
    seed: u64,
) -> Vec<f64> {
    residue_decay_on_topo(engine, kind.family(), n, iters, seed)
}

/// [`residue_decay_on`] for any registered topology family.
pub fn residue_decay_on_topo(
    engine: &Engine,
    topo: Topology,
    n: usize,
    iters: usize,
    seed: u64,
) -> Vec<f64> {
    let mut sched = Schedule::from_family(topo, n, seed);
    let mut rng = Pcg::new(seed ^ 0xD15C0, 1);
    let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f64; n];
    let r0 = residue_norm(&x).max(f64::MIN_POSITIVE);
    let mut out = Vec::with_capacity(iters);
    for k in 0..iters {
        engine.gossip_into(sched.plan_at(k), &x, &mut y);
        std::mem::swap(&mut x, &mut y);
        out.push(residue_norm(&x) / r0);
    }
    out
}

/// [`residue_decay`] under a simulated faulty network: each gossip step
/// mixes through the round's *degraded* plan when the simulator dropped
/// exchanges or partitioned nodes (docs/DESIGN.md §NetSim), so the
/// curve shows how much of a topology's averaging power survives a
/// lossy fabric. With a faultless scenario this reproduces
/// [`residue_decay`] exactly (the degraded plan is `None` every round).
pub fn residue_decay_under_faults(
    kind: TopologyKind,
    n: usize,
    iters: usize,
    seed: u64,
    sim: &mut NetSim,
    msg_bytes: f64,
) -> Vec<f64> {
    let mut sched = Schedule::new(kind, n, seed);
    let mut rng = Pcg::new(seed ^ 0xD15C0, 1);
    let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let r0 = residue_norm(&x).max(f64::MIN_POSITIVE);
    let mut out = Vec::with_capacity(iters);
    for k in 0..iters {
        let plan = sched.plan_at(k);
        let outcome = sim.simulate_round(k, plan, msg_bytes);
        x = outcome.degraded.as_ref().unwrap_or(plan).matvec(&x);
        out.push(residue_norm(&x) / r0);
    }
    out
}

/// Fig. 12's quantity: `‖∏_{i=0}^{k−1} Ŵ^{(i)}‖₂²` for the one-peer
/// exponential schedule, where `Ŵ = W − 11ᵀ/n`, for `k = 1..iters`.
pub fn residue_product_norms(kind: TopologyKind, n: usize, iters: usize, seed: u64) -> Vec<f64> {
    let mut sched = Schedule::new(kind, n, seed);
    let mut prod = Matrix::eye(n);
    let mut out = Vec::with_capacity(iters);
    for k in 0..iters {
        // Dense escape hatch (to_dense): spectral norms need the full
        // matrix product — this is analysis, not the training path.
        let w_hat = sched.weight_at(k).consensus_residue();
        prod = w_hat.matmul(&prod);
        let norm = power::spectral_norm(&prod);
        out.push(norm * norm);
    }
    out
}

/// Max-abs error `‖∏_{k0 ≤ k < k0+period} W^{(k)} − J‖_∞` through a
/// family's schedule plans — the generalized exact-averaging probe.
/// (The CECA-style merge rounds do not commute, so only offsets that
/// are multiples of the period average exactly; the circulant families
/// are offset-invariant.)
pub fn schedule_period_error(topo: Topology, n: usize, period: usize, k0: usize) -> f64 {
    let mut sched = Schedule::from_family(topo, n, 0);
    let mut prod = Matrix::eye(n);
    for k in k0..k0 + period.max(1) {
        prod = sched.plan_at(k).to_dense().matmul(&prod);
    }
    prod.sub(&Matrix::averaging(n)).max_abs()
}

/// [`schedule_period_error`] at the family's declared exact-averaging
/// period (`None` when the family declares none at this `n` — e.g.
/// one-peer exponential off powers of two).
pub fn exact_period_error(topo: Topology, n: usize, k0: usize) -> Option<f64> {
    topo.exact_period(n).map(|period| schedule_period_error(topo, n, period, k0))
}

/// Lemma 1 check: max-abs error `‖∏_{t} W^{(t)} − J‖_∞` over one period of
/// τ one-peer matrices starting at offset `k0`.
pub fn one_peer_period_error(n: usize, k0: usize) -> f64 {
    let tau = crate::topology::exponential::tau(n).max(1);
    let mut prod = Matrix::eye(n);
    for k in k0..k0 + tau {
        let w = crate::topology::exponential::one_peer_exp_weights(n, k % tau);
        prod = w.matmul(&prod);
    }
    prod.sub(&Matrix::averaging(n)).max_abs()
}

/// ρ_max of Lemma 6: `max_i ‖Ŵ^{(i)}‖₂` over one period of the one-peer
/// schedule.
pub fn one_peer_rho_max(n: usize) -> f64 {
    let tau = crate::topology::exponential::tau(n).max(1);
    (0..tau)
        .map(|t| {
            let w = crate::topology::exponential::one_peer_exp_weights(n, t);
            power::spectral_norm(&w.consensus_residue())
        })
        .fold(0.0, f64::max)
}

/// Number of gossip steps for the residue to fall below `tol` (∞ ⇒
/// `iters`). Reported in Fig. 4-style comparisons.
pub fn steps_to_tolerance(kind: TopologyKind, n: usize, tol: f64, iters: usize, seed: u64) -> usize {
    let decay = residue_decay(kind, n, iters, seed);
    decay.iter().position(|&r| r < tol).map(|p| p + 1).unwrap_or(iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residue_norm_basics() {
        assert!(residue_norm(&[2.0, 2.0, 2.0]) < 1e-15);
        let r = residue_norm(&[1.0, -1.0]);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn residue_decay_identical_across_lane_counts() {
        // The engine-routed gossip is row-local: any pool size must
        // reproduce the single-lane trajectory bit for bit.
        let serial = residue_decay(TopologyKind::OnePeerExp, 16, 12, 3);
        for lanes in [2usize, 4, 7] {
            let pooled =
                residue_decay_on(&Engine::new(lanes), TopologyKind::OnePeerExp, 16, 12, 3);
            assert_eq!(serial, pooled, "lanes={lanes}");
        }
    }

    #[test]
    fn one_peer_exact_average_after_tau_steps() {
        // Lemma 1 (vector form): residue hits machine zero at k = τ for
        // n a power of two, from any starting offset.
        for n in [4usize, 8, 16, 32] {
            let tau = crate::topology::exponential::tau(n);
            let decay = residue_decay(TopologyKind::OnePeerExp, n, tau + 2, 99);
            assert!(decay[tau - 1] < 1e-12, "n={n}: {decay:?}");
            for k0 in 0..tau {
                assert!(one_peer_period_error(n, k0) < 1e-12, "n={n} k0={k0}");
            }
        }
    }

    #[test]
    fn one_peer_not_exact_for_non_power_of_two() {
        // Fig. 10: for n ∉ 2^ℕ the residue decays but never hits zero in
        // one period.
        for n in [5usize, 6, 9, 12] {
            let tau = crate::topology::exponential::tau(n);
            let decay = residue_decay(TopologyKind::OnePeerExp, n, 4 * tau, 7);
            assert!(decay[tau - 1] > 1e-8, "n={n}");
            // ... but still decays asymptotically.
            assert!(decay[4 * tau - 1] < decay[tau - 1], "n={n}");
        }
    }

    #[test]
    fn static_exp_decays_geometrically_not_exactly() {
        // Fig. 4: static exponential only converges asymptotically.
        let n = 16;
        let decay = residue_decay(TopologyKind::StaticExp, n, 12, 3);
        for k in 1..12 {
            assert!(decay[k] < decay[k - 1] + 1e-15, "not monotone at {k}");
        }
        assert!(decay[3] > 1e-6, "static exp should not be exact at tau");
        // Rate consistent with ρ = (τ−1)/(τ+1) = 0.6 for n=16... within slack.
        let rho = crate::spectral::static_exp_rho_bound(n);
        assert!(decay[11] < rho.powi(8), "decay too slow: {}", decay[11]);
    }

    #[test]
    fn faulty_gossip_breaks_exact_averaging_clean_reproduces_it() {
        use crate::costmodel::CostModel;
        use crate::netsim::{NetSim, Scenario};
        let n = 16;
        let tau = crate::topology::exponential::tau(n);
        // Faultless scenario: bit-for-bit the plain residue_decay curve.
        let mut clean = NetSim::new(&CostModel::paper_default(0.1), Scenario::clean(), 3);
        let plain = residue_decay(TopologyKind::OnePeerExp, n, 3 * tau, 3);
        let cleaned =
            residue_decay_under_faults(TopologyKind::OnePeerExp, n, 3 * tau, 3, &mut clean, 1e6);
        assert_eq!(plain, cleaned);
        // Heavy transient loss: exact averaging at k = τ cannot survive
        // (at p = 0.5 over n/2 pairs per round, a drop fires with
        // near-certainty under any healthy seed), but the renormalized
        // plans still contract the residue.
        let lossy_scen = Scenario { drop_prob: 0.5, dropout: Vec::new(), ..Scenario::lossy() };
        let mut lossy = NetSim::new(&CostModel::paper_default(0.1), lossy_scen, 3);
        let faulty =
            residue_decay_under_faults(TopologyKind::OnePeerExp, n, 3 * tau, 3, &mut lossy, 1e6);
        assert!(lossy.dropped_total > 0, "no drops fired at p=0.5");
        assert!(faulty[tau - 1] > cleaned[tau - 1], "loss should delay consensus");
        assert!(faulty[3 * tau - 1] < 1.0, "renormalized gossip should still contract");
    }

    #[test]
    fn random_match_decays_asymptotically() {
        let n = 16;
        let decay = residue_decay(TopologyKind::RandomMatch, n, 40, 5);
        assert!(decay[39] < 1e-3, "random matching failed to mix: {}", decay[39]);
        assert!(decay[3] > 1e-12, "random matching should not be exact at tau");
    }

    #[test]
    fn residue_product_hits_zero_for_one_peer_pow2() {
        // Fig. 12: ‖∏ Ŵ‖² drops to 0 at k = τ.
        let n = 16;
        let tau = crate::topology::exponential::tau(n);
        let norms = residue_product_norms(TopologyKind::OnePeerExp, n, tau + 1, 1);
        assert!(norms[tau - 1] < 1e-20, "{norms:?}");
        assert!(norms[0] > 0.5, "single realization should contract mildly");
    }

    #[test]
    fn rho_max_is_at_most_one() {
        for n in [4usize, 8, 16, 64] {
            let r = one_peer_rho_max(n);
            assert!(r <= 1.0 + 1e-9 && r > 0.5, "n={n} rho_max={r}");
        }
    }

    #[test]
    fn finite_time_families_average_exactly_for_any_n() {
        // The registry's finite-time families (base-(k+1), CECA-style)
        // hit exact consensus at their declared period for arbitrary n —
        // exactly where Fig. 10 shows one-peer exp cannot.
        for name in ["base4", "ceca"] {
            let topo = crate::topology::family::find(name).unwrap();
            for n in [6usize, 12, 24] {
                let period = topo.exact_period(n).expect("finite-time family declares a period");
                let decay = residue_decay_topo(topo, n, 2 * period, 9);
                assert!(decay[period - 1] < 1e-12, "{name} n={n}: {decay:?}");
                let err = exact_period_error(topo, n, 0).unwrap();
                assert!(err < 1e-12, "{name} n={n}: |prod - J| = {err}");
            }
        }
    }

    #[test]
    fn perm_order_also_exact() {
        // Appendix B.3.2: random permutation keeps periodic exact averaging.
        let n = 16;
        let tau = crate::topology::exponential::tau(n);
        let decay = residue_decay(TopologyKind::OnePeerExpPerm, n, tau, 13);
        assert!(decay[tau - 1] < 1e-12, "{decay:?}");
    }

    #[test]
    fn uniform_sampling_not_periodically_exact() {
        // With replacement a period usually misses an exponent; over a few
        // periods it still converges with probability one.
        let n = 16;
        let tau = crate::topology::exponential::tau(n);
        let decay = residue_decay(TopologyKind::OnePeerExpUniform, n, 12 * tau, 21);
        assert!(decay[12 * tau - 1] < 1e-6, "uniform sampling failed to mix");
    }
}
