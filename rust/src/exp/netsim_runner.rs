//! Table 2/3-style **simulated time-to-target** sweep over the
//! network simulator: topology × cluster size × scenario
//! (clean / straggler / lossy), on a heterogeneous quadratic workload
//! where consensus is the whole game (each node pulls toward its own
//! target; the global optimum is the mean target, so a topology only
//! wins by actually averaging).
//!
//! The sweep runs through the declarative harness (docs/DESIGN.md
//! §Sweep): cells are scheduled in parallel under the lane budget and
//! served from the result cache on re-runs. Emits `netsim.json`
//! (machine-parseable, consumed by the CLI integration test),
//! `netsim.csv`, and a paper-style text table. The headline (pinned by
//! `tests/netsim.rs`): in the clean scenario at n = 64 the exponential
//! graphs reach the target in less simulated wall-clock than ring/grid
//! — the paper's Table 2 trade-off — while the straggler scenario slows
//! every topology's clock without touching its trajectory and the lossy
//! scenario costs extra iterations through degraded plans.
//!
//! **Plan-only mode** (`plan_only=on`, the `--large-n` axis): the same
//! table at n up to 2²⁰ with no P-dimensional training state. Each node
//! carries one scalar drawn from a hash coin; the target is the exact
//! initial mean, so consensus (what the paper's exact-averaging story
//! is about) is the entire objective, and the live state is the plan's
//! CSR plus a handful of n-vectors — `O(n + edges)`. Rounds still run
//! through the full [`NetSim`] (times, faults, degraded plans, bytes),
//! and the state mixes through [`MixingPlan::matvec_into`] on the
//! degraded-or-original plan, double-buffered so a round allocates
//! nothing.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::NetSimRunConfig;
use crate::coordinator::trainer::{QuadraticProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::costmodel::CostModel;
use crate::engine::budget_lanes;
use crate::netsim::{coin, NetSim, Scenario};
use crate::optim::AlgorithmKind;
use crate::sweep::{Axis, Col, Grid, Record, Sink, Sweep};
use crate::topology::exponential::one_peer_exp_plan;
use crate::topology::plan::MixingPlan;
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::json::Json;
use crate::util::table::TextTable;
use anyhow::{anyhow, Context, Result};

/// One cell of the sweep.
#[derive(Clone, Debug)]
pub struct NetSimCell {
    pub topology: TopologyKind,
    pub n: usize,
    pub scenario: String,
    /// Did the run reach `err ≤ tol · err₀` within the budget?
    pub reached: bool,
    /// Iterations to target (the full budget when not reached).
    pub iters_to_target: usize,
    /// Simulated seconds to target (total simulated time when not
    /// reached — the honest "still not there after the whole budget").
    pub time_to_target: f64,
    /// Total simulated seconds of the whole budget (plan-only cells
    /// stop at the target, so their total spans only executed rounds).
    pub total_time: f64,
    pub final_err: f64,
    pub err0: f64,
    /// Exchanges lost and rounds degraded across the run.
    pub dropped: usize,
    pub degraded_rounds: usize,
    /// Payload bytes on the wire across the run (sum of
    /// [`crate::netsim::RoundOutcome::bytes_on_wire`]) — the baseline
    /// column future compression work has to beat.
    pub bytes_on_wire: f64,
}

impl NetSimCell {
    /// The cacheable sweep record of one cell.
    fn record(&self) -> Record {
        Record::new()
            .with("topology", self.topology.name())
            .with("n", self.n)
            .with("scenario", self.scenario.as_str())
            .with("reached", self.reached)
            .with("iters_to_target", self.iters_to_target)
            .with("time_to_target", self.time_to_target)
            .with("total_time", self.total_time)
            .with("final_err", self.final_err)
            .with("err0", self.err0)
            .with("dropped", self.dropped)
            .with("degraded_rounds", self.degraded_rounds)
            .with("bytes_on_wire", self.bytes_on_wire)
    }

    /// Inverse of [`NetSimCell::record`] (cache-served cells).
    fn from_record(rec: &Record) -> Result<NetSimCell> {
        let name = rec.text("topology");
        Ok(NetSimCell {
            topology: TopologyKind::parse(name)
                .ok_or_else(|| anyhow!("cached cell has unknown topology {name}"))?,
            n: rec.num("n") as usize,
            scenario: rec.text("scenario").to_string(),
            reached: rec.flag("reached"),
            iters_to_target: rec.num("iters_to_target") as usize,
            time_to_target: rec.num("time_to_target"),
            total_time: rec.num("total_time"),
            final_err: rec.num("final_err"),
            err0: rec.num("err0"),
            dropped: rec.num("dropped") as usize,
            degraded_rounds: rec.num("degraded_rounds") as usize,
            bytes_on_wire: rec.num("bytes_on_wire"),
        })
    }
}

/// Run one (topology, n, scenario) cell.
pub fn time_to_target(
    cfg: &NetSimRunConfig,
    kind: TopologyKind,
    n: usize,
    scenario: &Scenario,
) -> NetSimCell {
    time_to_target_with(cfg, kind, n, scenario, None)
}

/// [`time_to_target`] under an explicit engine lane cap (the sweep
/// scheduler's per-job budget); `None` keeps automatic sizing. The
/// trajectory is bitwise identical either way (§Engine determinism).
pub fn time_to_target_with(
    cfg: &NetSimRunConfig,
    kind: TopologyKind,
    n: usize,
    scenario: &Scenario,
    lane_cap: Option<usize>,
) -> NetSimCell {
    // Same problem for every topology/scenario at a given n: node i
    // pulls toward its own random target, optimum = mean target.
    let provider = QuadraticProvider::random(n, cfg.dim, 0.0, cfg.seed ^ ((n as u64) << 20));
    let cbar = provider.targets.mean();
    let err0 = {
        // Initial params are all-zero, so err₀ = ‖c̄‖².
        cbar.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().max(1e-12)
    };
    let opt = AlgorithmKind::DmSgd.build(n, &vec![0.0f32; cfg.dim], 0.8);
    let sim = NetSim::new(&CostModel::paper_default(cfg.compute), scenario.clone(), cfg.seed);
    let mut trainer = Trainer::new(
        Schedule::new(kind, n, cfg.seed),
        opt,
        &provider,
        TrainConfig {
            iters: cfg.iters,
            lr: LrSchedule::HalveEvery { init: 0.1, every: (cfg.iters / 8).max(1) },
            warmup_allreduce: false,
            record_every: 1,
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, n, n * cfg.dim)),
            seed: cfg.seed,
            msg_bytes: Some(cfg.msg_bytes),
            cost: None,
            // The trainer is the single pricing point: it routes gossip
            // rounds through `CompressorKind::wire_bytes` — no raw
            // `cfg.msg_bytes` reaches the wire from here.
            compressor: cfg.compressor,
            ..Default::default()
        },
    )
    .with_netsim(sim);
    // Mean squared distance of node params to the global optimum,
    // probed every iteration (record_every = 1).
    let mut errs: Vec<f64> = Vec::with_capacity(cfg.iters);
    let hist = trainer.run_with(|_, params| errs.push(params.mean_sq_error_to(&cbar)));
    let total_time = hist.sim_time;
    let target = cfg.tol * err0;
    let hit = errs.iter().position(|&e| e <= target);
    let (reached, iters_to_target, time_to_target) = match hit {
        Some(k) => (true, k + 1, hist.round_times[..=k].iter().sum()),
        None => (false, cfg.iters, total_time),
    };
    let sim = trainer.netsim.as_ref().expect("netsim attached above");
    NetSimCell {
        topology: kind,
        n,
        scenario: scenario.name.clone(),
        reached,
        iters_to_target,
        time_to_target,
        total_time,
        final_err: errs.last().copied().unwrap_or(err0),
        err0,
        dropped: sim.dropped_total,
        degraded_rounds: sim.degraded_rounds,
        bytes_on_wire: sim.bytes_on_wire_total,
    }
}

/// Run one plan-only cell: scalar consensus to the initial mean at
/// large `n`, no training state. One-peer exponential plans are built
/// round by round straight from the closed form — a `Schedule` would
/// precompute all τ period plans, which at n = 2²⁰ is a gigabyte of
/// cached CSR; every other family still goes through the schedule (its
/// caching is exactly right for static plans).
pub fn plan_only_time_to_target(
    cfg: &NetSimRunConfig,
    kind: TopologyKind,
    n: usize,
    scenario: &Scenario,
) -> NetSimCell {
    let cost = CostModel::paper_default(cfg.compute);
    let mut sim = NetSim::new(&cost, scenario.clone(), cfg.seed);
    // Deterministic scalar state: node i starts at a pure hash coin (the
    // same n-keyed seed split as the training path's provider).
    let seed = cfg.seed ^ ((n as u64) << 20);
    let mut x: Vec<f64> = (0..n).map(|i| coin(seed, 0, i, i, 0x1A17)).collect();
    let xbar = x.iter().sum::<f64>() / n as f64;
    let sq_err = |x: &[f64]| x.iter().map(|&v| (v - xbar) * (v - xbar)).sum::<f64>() / n as f64;
    let err0 = sq_err(&x).max(1e-12);
    let target = cfg.tol * err0;

    let mut sched = if kind == TopologyKind::OnePeerExp {
        None
    } else {
        Some(Schedule::new(kind, n, cfg.seed))
    };
    let mut buf = vec![0.0f64; n];
    let mut total_time = 0.0f64;
    let mut final_err = err0;
    let mut hit: Option<usize> = None;
    for k in 0..cfg.iters {
        let plan_storage;
        let plan: &MixingPlan = match sched.as_mut() {
            Some(s) => s.plan_at(k),
            None => {
                plan_storage = one_peer_exp_plan(n, k);
                &plan_storage
            }
        };
        // Price the scalar round through the same single point as the
        // training path: the compressor owns the payload size.
        let out = sim.simulate_round(k, plan, cfg.compressor.wire_bytes(cfg.msg_bytes));
        let mix = out.degraded.as_ref().unwrap_or(plan);
        mix.matvec_into(&x, &mut buf);
        std::mem::swap(&mut x, &mut buf);
        total_time += out.iteration_time(cost.overlap);
        final_err = sq_err(&x);
        if final_err <= target {
            hit = Some(k);
            break;
        }
    }
    let (reached, iters_to_target) = match hit {
        Some(k) => (true, k + 1),
        None => (false, cfg.iters),
    };
    NetSimCell {
        topology: kind,
        n,
        scenario: scenario.name.clone(),
        reached,
        iters_to_target,
        time_to_target: total_time,
        total_time,
        final_err,
        err0,
        dropped: sim.dropped_total,
        degraded_rounds: sim.degraded_rounds,
        bytes_on_wire: sim.bytes_on_wire_total,
    }
}

/// Run the full sweep (parallel, cache-aware), print the table, and
/// write `netsim.json` + `netsim.csv` under `out_dir`. Returns every
/// cell for programmatic assertions (tests) on top of the emitted
/// artifacts.
pub fn netsim_table(cfg: &NetSimRunConfig, out_dir: &Path) -> Result<Vec<NetSimCell>> {
    cfg.validate()?;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    #[derive(Clone, Debug)]
    struct Spec {
        scenario: Scenario,
        kind: TopologyKind,
        n: usize,
    }
    let grid = Grid::product3(
        &Axis::new("scenario", cfg.scenarios.clone()),
        &Axis::new("topology", cfg.topologies.clone()),
        &Axis::new("n", cfg.nodes.clone()),
        |scenario, &kind, &n| Spec { scenario: scenario.clone(), kind, n },
    );
    let mut sweep = Sweep::new("netsim", cfg.seed, 1.0).jobs(cfg.sweep.jobs);
    if cfg.sweep.cache {
        sweep = sweep.cache_under(out_dir);
    }
    let out = sweep.run(
        grid.cells(),
        |spec| {
            format!(
                "{:?} {:?} n={} iters={} dim={} tol={} msg_bytes={} compute={} plan_only={} \
                 compressor={}",
                spec.kind, spec.scenario, spec.n, cfg.iters, cfg.dim, cfg.tol, cfg.msg_bytes,
                cfg.compute, cfg.plan_only, cfg.compressor.label()
            )
        },
        |spec, cc| {
            let cell = if cfg.plan_only {
                plan_only_time_to_target(cfg, spec.kind, spec.n, &spec.scenario)
            } else {
                time_to_target_with(cfg, spec.kind, spec.n, &spec.scenario, Some(cc.lanes))
            };
            vec![cell.record()]
        },
    );
    let cells = out
        .iter()
        .map(|cell| NetSimCell::from_record(&cell.records[0]))
        .collect::<Result<Vec<_>>>()?;

    // Text table: one row per topology × n, one column pair per scenario.
    let mut header = vec!["topology".to_string(), "n".to_string()];
    for s in &cfg.scenarios {
        header.push(format!("{} t2t(s)", s.name));
        header.push(format!("{} iters", s.name));
    }
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for &kind in &cfg.topologies {
        for &n in &cfg.nodes {
            let mut row = vec![kind.name().to_string(), n.to_string()];
            for s in &cfg.scenarios {
                let c = cells
                    .iter()
                    .find(|c| c.topology == kind && c.n == n && c.scenario == s.name)
                    .expect("cell exists");
                row.push(if c.reached {
                    format!("{:.1}", c.time_to_target)
                } else {
                    format!(">{:.1}", c.total_time)
                });
                row.push(c.iters_to_target.to_string());
            }
            t.row(row);
        }
    }

    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("n"),
        Col::auto("scenario"),
        Col::auto("reached"),
        Col::auto("iters_to_target"),
        Col::auto("time_to_target"),
        Col::auto("total_time"),
        Col::auto("final_err"),
        Col::auto("dropped"),
        Col::auto("degraded_rounds"),
        Col::auto("bytes_on_wire"),
    ]);
    for cell in &out {
        sink.push(&cell.records[0]);
    }
    // CSV through the sink schema; the JSON keeps its bespoke row-object
    // shape (the CLI integration test and external consumers parse it).
    sink.write_csv(out_dir, "netsim")?;

    let json = cells_to_json(cfg, &cells);
    std::fs::write(out_dir.join("netsim.json"), json.to_string())
        .with_context(|| format!("writing {}", out_dir.join("netsim.json").display()))?;

    println!("NetSim — simulated time-to-target (err ≤ {} · err₀), DmSGD", cfg.tol);
    println!("{}", t.render());
    println!("  scenarios: clean = uniform failure-free; straggler = slow nodes (same");
    println!("  trajectory, slower clock); lossy = 30% exchange drops + dropout window");
    println!("  json: {}", out_dir.join("netsim.json").display());
    println!("  csv:  {}", out_dir.join("netsim.csv").display());
    Ok(cells)
}

fn cells_to_json(cfg: &NetSimRunConfig, cells: &[NetSimCell]) -> Json {
    let mut root = BTreeMap::new();
    root.insert("tol".to_string(), Json::Num(cfg.tol));
    root.insert("iters".to_string(), Json::Num(cfg.iters as f64));
    root.insert(
        "rows".to_string(),
        Json::Arr(
            cells
                .iter()
                .map(|c| {
                    let mut o = BTreeMap::new();
                    o.insert("topology".into(), Json::Str(c.topology.name().into()));
                    o.insert("n".into(), Json::Num(c.n as f64));
                    o.insert("scenario".into(), Json::Str(c.scenario.clone()));
                    o.insert("reached".into(), Json::Bool(c.reached));
                    o.insert("iters_to_target".into(), Json::Num(c.iters_to_target as f64));
                    o.insert("time_to_target".into(), Json::Num(c.time_to_target));
                    o.insert("total_time".into(), Json::Num(c.total_time));
                    o.insert("final_err".into(), Json::Num(c.final_err));
                    o.insert("err0".into(), Json::Num(c.err0));
                    o.insert("dropped".into(), Json::Num(c.dropped as f64));
                    o.insert("degraded_rounds".into(), Json::Num(c.degraded_rounds as f64));
                    o.insert("bytes_on_wire".into(), Json::Num(c.bytes_on_wire));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_emits_artifacts_and_orders_scenarios() {
        let tmp = std::env::temp_dir().join(format!("expograph-netsim-{}", std::process::id()));
        let cfg = NetSimRunConfig {
            nodes: vec![8],
            topologies: vec![TopologyKind::OnePeerExp],
            scenarios: vec![Scenario::clean(), Scenario::straggler()],
            iters: 120,
            ..Default::default()
        };
        let cells = netsim_table(&cfg, &tmp).unwrap();
        assert_eq!(cells.len(), 2);
        assert!(tmp.join("netsim.json").exists());
        assert!(tmp.join("netsim.csv").exists());
        let text = std::fs::read_to_string(tmp.join("netsim.json")).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("rows").unwrap().as_array().unwrap().len(), 2);
        // Stragglers never touch the plan: identical iteration counts,
        // strictly slower simulated clock.
        let clean = &cells[0];
        let strag = &cells[1];
        assert_eq!(clean.iters_to_target, strag.iters_to_target);
        assert!(strag.time_to_target > clean.time_to_target);
        assert_eq!(strag.degraded_rounds, 0);
        // A warm second sweep (served from `<out>/.cache/`) reproduces
        // the cells and artifacts byte-for-byte.
        let csv_cold = std::fs::read(tmp.join("netsim.csv")).unwrap();
        let json_cold = std::fs::read(tmp.join("netsim.json")).unwrap();
        let again = netsim_table(&cfg, &tmp).unwrap();
        assert_eq!(again.len(), 2);
        assert_eq!(again[0].time_to_target, clean.time_to_target);
        assert_eq!(std::fs::read(tmp.join("netsim.csv")).unwrap(), csv_cold);
        assert_eq!(std::fs::read(tmp.join("netsim.json")).unwrap(), json_cold);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn plan_only_sweep_reaches_consensus_and_records_bytes() {
        let tmp =
            std::env::temp_dir().join(format!("expograph-netsim-po-{}", std::process::id()));
        let cfg = NetSimRunConfig {
            nodes: vec![64],
            topologies: vec![TopologyKind::OnePeerExp],
            scenarios: vec![Scenario::clean(), Scenario::lossy()],
            iters: 200,
            plan_only: true,
            ..Default::default()
        };
        let cells = netsim_table(&cfg, &tmp).unwrap();
        assert_eq!(cells.len(), 2);
        let (clean, lossy) = (&cells[0], &cells[1]);
        // Lemma 1 at n = 2⁶: τ = 6 one-peer rounds average exactly, so
        // scalar consensus hits any tolerance within one period.
        assert!(clean.reached, "clean one-peer exp must reach consensus");
        assert!(clean.iters_to_target <= 6, "exact averaging within τ rounds");
        assert!(clean.bytes_on_wire > 0.0, "bytes ledger must be populated");
        assert!(lossy.degraded_rounds > 0, "30% drops must degrade rounds");
        assert!(lossy.iters_to_target >= clean.iters_to_target);
        let text = std::fs::read_to_string(tmp.join("netsim.json")).unwrap();
        let doc = Json::parse(&text).unwrap();
        let rows = doc.get("rows").unwrap().as_array().unwrap();
        assert!(rows[0].get("bytes_on_wire").is_some(), "json carries the bytes column");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
