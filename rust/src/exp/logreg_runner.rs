//! Shared machinery for the logistic-regression experiments (Fig. 1,
//! Fig. 13, Tables 7–8): a [`GradProvider`] over the Appendix D.5 data,
//! the exact global minimizer (for the MSE-to-`x*` y-axis), and a runner
//! returning the MSE curve per algorithm/topology.

use crate::coordinator::trainer::{GradProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::data::logreg::{generate, LogRegConfig, LogRegProblem};
use crate::engine::budget_lanes;
use crate::optim::AlgorithmKind;
use crate::sweep::Record;
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg;

/// Per-node minibatch gradients over the logistic-regression shards
/// (f64 inner compute, f32 at the optimizer boundary).
pub struct LogRegProvider<'a> {
    pub problem: &'a LogRegProblem,
    pub batch: usize,
}

impl GradProvider for LogRegProvider<'_> {
    fn dim(&self) -> usize {
        self.problem.d
    }

    fn nodes(&self) -> usize {
        self.problem.shards.len()
    }

    fn grad(&self, node: usize, params: &[f32], iter: usize, seed: u64, out: &mut [f32]) -> f32 {
        let shard = &self.problem.shards[node];
        let mut rng = Pcg::new(
            seed ^ (node as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (iter as u64) << 20,
            0x10C,
        );
        let batch: Vec<usize> = (0..self.batch).map(|_| rng.below(shard.m)).collect();
        let x64: Vec<f64> = params.iter().map(|&v| v as f64).collect();
        let mut g64 = vec![0.0f64; shard.d];
        shard.minibatch_grad(&x64, &batch, &mut g64);
        for (o, g) in out.iter_mut().zip(g64.iter()) {
            *o = *g as f32;
        }
        // Report the minibatch loss.
        let mut loss = 0.0;
        for &m in &batch {
            let z: f64 = shard.feature(m).iter().zip(&x64).map(|(h, w)| h * w).sum();
            let yz = -shard.labels[m] * z;
            loss += if yz > 30.0 { yz } else { (1.0 + yz.exp()).ln() };
        }
        (loss / self.batch as f64) as f32
    }
}

/// Exact minimizer of the *global* objective `f = (1/n)Σ f_i` via
/// full-batch gradient descent with backtracking-free long run.
pub fn global_minimizer(problem: &LogRegProblem, iters: usize) -> Vec<f64> {
    let d = problem.d;
    let n = problem.shards.len();
    let mut x = vec![0.0f64; d];
    let mut g = vec![0.0f64; d];
    let mut g_node = vec![0.0f64; d];
    for _ in 0..iters {
        g.iter_mut().for_each(|v| *v = 0.0);
        for shard in &problem.shards {
            shard.full_grad(&x, &mut g_node);
            for (acc, v) in g.iter_mut().zip(g_node.iter()) {
                *acc += v / n as f64;
            }
        }
        // L ≈ max eig of (1/4M)HᵀH; feature std √10, d small → lr 0.05 is
        // stable for the App. D.5 scaling.
        for (xi, gi) in x.iter_mut().zip(g.iter()) {
            *xi -= 0.05 * gi;
        }
    }
    x
}

/// One experiment run: MSE-to-`x*` sampled every `record_every` iters.
pub struct MseCurve {
    pub iters: Vec<usize>,
    pub mse: Vec<f64>,
}

/// Configuration for a logreg training run.
pub struct LogRegRun {
    pub topology: TopologyKind,
    pub algorithm: AlgorithmKind,
    pub beta: f32,
    pub lr: LrSchedule,
    pub iters: usize,
    pub batch: usize,
    pub record_every: usize,
    pub seed: u64,
}

/// Run one (topology, algorithm) combination; `x_star` is the global
/// minimizer to measure against.
pub fn run_logreg(problem: &LogRegProblem, x_star: &[f64], run: &LogRegRun) -> MseCurve {
    run_logreg_with(problem, x_star, run, None)
}

/// [`run_logreg`] under an explicit engine **lane cap** (the sweep
/// scheduler's per-job budget — docs/DESIGN.md §Sweep). `None` keeps
/// the trainer's automatic lane sizing; the trajectory is bitwise
/// identical either way (§Engine determinism).
pub fn run_logreg_with(
    problem: &LogRegProblem,
    x_star: &[f64],
    run: &LogRegRun,
    lane_cap: Option<usize>,
) -> MseCurve {
    let n = problem.shards.len();
    let provider = LogRegProvider { problem, batch: run.batch };
    let opt = run.algorithm.build(n, &vec![0.0f32; problem.d], run.beta);
    let mut trainer = Trainer::new(
        Schedule::new(run.topology, n, run.seed),
        opt,
        &provider,
        TrainConfig {
            iters: run.iters,
            lr: run.lr.clone(),
            warmup_allreduce: false,
            record_every: run.record_every,
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, n, n * problem.d)),
            seed: run.seed,
            msg_bytes: None,
            cost: None,
            ..Default::default()
        },
    );
    let x_star32: Vec<f32> = x_star.iter().map(|&v| v as f32).collect();
    let mut iters = Vec::new();
    let mut mse = Vec::new();
    trainer.run_with(|k, params| {
        iters.push(k);
        mse.push(params.mean_sq_error_to(&x_star32));
    });
    MseCurve { iters, mse }
}

/// The curve's final MSE sample, or NaN (with a stderr warning) when
/// the history is empty — tiny `--scale` runs must render a `-`, not
/// crash on `.last().unwrap()`. NaN flows through the sweep sink's
/// unified non-finite policy (docs/DESIGN.md §Sweep).
pub fn final_mse(curve: &MseCurve) -> f64 {
    match curve.mse.last() {
        Some(&v) => v,
        None => {
            eprintln!("[exp] warning: empty MSE history (scale too small?); reporting NaN");
            f64::NAN
        }
    }
}

/// Serialize a curve as sweep cell records (`iter`, `mse` per sample) —
/// the cacheable form of one training cell's output.
pub fn curve_records(curve: &MseCurve) -> Vec<Record> {
    curve
        .iters
        .iter()
        .zip(&curve.mse)
        .map(|(&k, &mse)| Record::new().with("iter", k).with("mse", mse))
        .collect()
}

/// Inverse of [`curve_records`] (used when a cell is served from cache).
pub fn records_curve(records: &[Record]) -> MseCurve {
    MseCurve {
        iters: records.iter().map(|r| r.num("iter") as usize).collect(),
        mse: records.iter().map(|r| r.num("mse")).collect(),
    }
}

/// Average several seeds' MSE curves pointwise.
pub fn average_curves(curves: &[MseCurve]) -> MseCurve {
    assert!(!curves.is_empty());
    let len = curves[0].mse.len();
    let mut mse = vec![0.0; len];
    for c in curves {
        assert_eq!(c.mse.len(), len);
        for (acc, v) in mse.iter_mut().zip(c.mse.iter()) {
            *acc += v / curves.len() as f64;
        }
    }
    MseCurve { iters: curves[0].iters.clone(), mse }
}

/// Standard problem for the figure experiments.
pub fn paper_problem(nodes: usize, samples: usize, heterogeneous: bool, seed: u64) -> LogRegProblem {
    generate(&LogRegConfig { nodes, samples_per_node: samples, dim: 10, heterogeneous, seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizer_beats_zero_vector() {
        let p = paper_problem(4, 300, true, 3);
        let x = global_minimizer(&p, 300);
        let mean_loss =
            |v: &[f64]| p.shards.iter().map(|s| s.loss(v)).sum::<f64>() / p.shards.len() as f64;
        assert!(mean_loss(&x) < mean_loss(&vec![0.0; p.d]) - 0.05);
    }

    #[test]
    fn dmsgd_mse_decreases_toward_x_star() {
        let p = paper_problem(8, 500, false, 4);
        let x_star = global_minimizer(&p, 400);
        let run = LogRegRun {
            topology: TopologyKind::OnePeerExp,
            algorithm: AlgorithmKind::DmSgd,
            beta: 0.8,
            lr: LrSchedule::HalveEvery { init: 0.1, every: 400 },
            iters: 1200,
            batch: 16,
            record_every: 50,
            seed: 7,
        };
        let curve = run_logreg(&p, &x_star, &run);
        let first = curve.mse[0];
        let last = *curve.mse.last().unwrap();
        assert!(last < 0.1 * first, "mse {first} -> {last}");
    }

    #[test]
    fn average_of_identical_curves_is_identity() {
        let c1 = MseCurve { iters: vec![0, 1], mse: vec![1.0, 0.5] };
        let c2 = MseCurve { iters: vec![0, 1], mse: vec![3.0, 1.5] };
        let avg = average_curves(&[c1, c2]);
        assert_eq!(avg.mse, vec![2.0, 1.0]);
    }

    #[test]
    fn final_mse_is_nan_not_panic_on_empty_history() {
        let full = MseCurve { iters: vec![0, 25], mse: vec![1.0, 0.25] };
        assert_eq!(final_mse(&full), 0.25);
        let empty = MseCurve { iters: vec![], mse: vec![] };
        assert!(final_mse(&empty).is_nan());
    }

    #[test]
    fn curve_record_roundtrip() {
        let c = MseCurve { iters: vec![0, 25, 50], mse: vec![1.0, 0.5, 0.125] };
        let back = records_curve(&curve_records(&c));
        assert_eq!(back.iters, c.iters);
        assert_eq!(back.mse, c.mse);
    }
}
