//! Ablation studies for the design choices docs/DESIGN.md calls out, plus the
//! paper's future-work direction (symmetric time-varying graphs).

use super::logreg_runner::{global_minimizer, paper_problem, run_logreg, LogRegRun};
use super::Ctx;
use crate::coordinator::trainer::{TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::optim::AlgorithmKind;
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::csv::CsvWriter;
use crate::util::table::TextTable;
use anyhow::Result;

/// Corollary 3 ablation: warm-up all-reduce zeroes the initial-phase
/// consensus term. Measures the consensus distance over the first periods
/// and the final MSE with/without warm-up.
pub fn ablation_warmup(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(2000);
    let problem = paper_problem(n, 1000, true, ctx.seed);
    let x_star = global_minimizer(&problem, 400);
    let x_star32: Vec<f32> = x_star.iter().map(|&v| v as f32).collect();
    let mut csv = CsvWriter::new(&["warmup", "iter", "consensus", "mse"]);
    let mut finals = Vec::new();
    for warmup in [true, false] {
        let provider =
            super::logreg_runner::LogRegProvider { problem: &problem, batch: 8 };
        // Different random init per node when warm-up is off, so the
        // ablation actually has something to reduce.
        let mut init = crate::coordinator::StackedParams::zeros(n, problem.d);
        let mut rng = crate::util::rng::Pcg::seeded(ctx.seed ^ 0xAB1);
        for v in init.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let opt: Box<dyn crate::optim::Optimizer> =
            Box::new(crate::optim::DmSgd::new(init, 0.8));
        let mut trainer = Trainer::new(
            Schedule::new(TopologyKind::OnePeerExp, n, ctx.seed),
            opt,
            &provider,
            TrainConfig {
                iters,
                lr: LrSchedule::HalveEvery { init: 0.1, every: iters / 3 },
                warmup_allreduce: warmup,
                record_every: 10,
                parallel_grads: false,
                lanes: None,
                seed: ctx.seed,
                msg_bytes: None,
                cost: None,
            },
        );
        let mut last_mse = 0.0;
        let hist = trainer.run_with(|_, params| {
            last_mse = params.mean_sq_error_to(&x_star32);
        });
        for (k, c) in &hist.consensus {
            csv.row_f64(&[warmup as usize as f64, *k as f64, *c, f64::NAN]);
        }
        finals.push((warmup, hist.consensus[0].1, last_mse));
    }
    csv.write(ctx.csv_path("ablation_warmup"))?;
    println!("Ablation — warm-up all-reduce (Corollary 3), n={n}");
    let mut t = TextTable::new(&["warmup", "initial consensus", "final MSE"]);
    for (w, c0, mse) in finals {
        t.row(vec![w.to_string(), format!("{c0:.3e}"), format!("{mse:.3e}")]);
    }
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("ablation_warmup").display());
    Ok(())
}

/// One-peer sampling-order ablation (Appendix B.3.2), end-to-end: the
/// consensus-level story of Fig. 11 carried through actual DmSGD training.
pub fn ablation_sampling(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(3000);
    let problem = paper_problem(n, 2000, true, ctx.seed);
    let x_star = global_minimizer(&problem, 400);
    let orders = [
        TopologyKind::OnePeerExp,
        TopologyKind::OnePeerExpPerm,
        TopologyKind::OnePeerExpUniform,
    ];
    let mut t = TextTable::new(&["order", "final MSE", "mean MSE (last quarter)"]);
    let mut csv = CsvWriter::new(&["order", "final_mse", "tail_mse"]);
    println!("Ablation — one-peer sampling order, DmSGD, n={n}, {iters} iters");
    for kind in orders {
        let curve = run_logreg(
            &problem,
            &x_star,
            &LogRegRun {
                topology: kind,
                algorithm: AlgorithmKind::DmSgd,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.2, every: 1000 },
                iters,
                batch: 8,
                record_every: 50,
                seed: ctx.seed + 2,
            },
        );
        let q = curve.mse.len() * 3 / 4;
        let tail = curve.mse[q..].iter().sum::<f64>() / (curve.mse.len() - q) as f64;
        t.row(vec![
            kind.name().into(),
            format!("{:.3e}", curve.mse.last().unwrap()),
            format!("{tail:.3e}"),
        ]);
        csv.row(&[
            kind.name().into(),
            format!("{}", curve.mse.last().unwrap()),
            format!("{tail}"),
        ]);
    }
    csv.write(ctx.csv_path("ablation_sampling"))?;
    println!("{}", t.render());
    println!("  expected: cyclic ≈ random-perm ≤ uniform-sampling (exactness of Lemma 1)");
    println!("  csv: {}", ctx.csv_path("ablation_sampling").display());
    Ok(())
}

/// Future-work study (paper conclusion): symmetric time-varying graphs
/// and bias-corrected methods. Compares, on heterogeneous data:
/// DmSGD/one-peer-exp, gradient tracking/one-peer-exp (asymmetric OK),
/// D²-lazy/static-hypercube (symmetric static), and documents that naive
/// D² over one-peer hypercube matchings is unstable.
pub fn ablation_symmetric(ctx: &Ctx) -> Result<()> {
    let n = 32; // power of two: hypercube variants valid
    let iters = ctx.scaled(3000);
    let problem = paper_problem(n, 2000, true, ctx.seed + 5);
    let x_star = global_minimizer(&problem, 400);
    let runs = [
        ("dmsgd/one_peer_exp", TopologyKind::OnePeerExp, AlgorithmKind::DmSgd),
        ("dmsgd/one_peer_hypercube", TopologyKind::OnePeerHypercube, AlgorithmKind::DmSgd),
        ("tracking/one_peer_exp", TopologyKind::OnePeerExp, AlgorithmKind::GradientTracking),
        ("d2_lazy/hypercube", TopologyKind::Hypercube, AlgorithmKind::D2),
        ("d2_lazy/one_peer_hypercube", TopologyKind::OnePeerHypercube, AlgorithmKind::D2),
        ("parallel", TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
    ];
    let mut t = TextTable::new(&["method/topology", "final MSE", "per-iter comm"]);
    let mut csv = CsvWriter::new(&["method", "topology", "final_mse"]);
    println!("Ablation — symmetric time-varying graphs (future work), n={n}, hetero data");
    for (label, kind, algo) in runs {
        let curve = run_logreg(
            &problem,
            &x_star,
            &LogRegRun {
                topology: kind,
                algorithm: algo,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.1, every: 1000 },
                iters,
                batch: 8,
                record_every: 50,
                seed: ctx.seed + 6,
            },
        );
        let final_mse = *curve.mse.last().unwrap();
        let comm = crate::costmodel::analytic_degree(kind, n);
        t.row(vec![
            label.into(),
            if final_mse.is_finite() { format!("{final_mse:.3e}") } else { "DIVERGED".into() },
            if kind == TopologyKind::FullyConnected { "n-1 (allreduce)".into() } else { comm.to_string() },
        ]);
        csv.row(&[algo.name().into(), kind.name().into(), format!("{final_mse}")]);
    }
    csv.write(ctx.csv_path("ablation_symmetric"))?;
    println!("{}", t.render());
    println!("  reading: on *deterministic* heterogeneous problems lazy D² over the");
    println!("  one-peer hypercube is exact (see examples/symmetric_timevarying.rs), but");
    println!("  under stochastic gradients its marginally-stable mode amplifies noise —");
    println!("  evidence that the paper's open problem (symmetric time-varying graphs");
    println!("  matching one-peer-exp) is genuinely open for SGD-style methods.");
    println!("  csv: {}", ctx.csv_path("ablation_symmetric").display());
    Ok(())
}
