//! Ablation studies for the design choices docs/DESIGN.md calls out, plus the
//! paper's future-work direction (symmetric time-varying graphs) — all
//! declared as sweep grids (docs/DESIGN.md §Sweep).

use super::logreg_runner::{
    final_mse, global_minimizer, paper_problem, run_logreg_with, LogRegRun,
};
use super::Ctx;
use crate::coordinator::trainer::{TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::data::logreg::LogRegProblem;
use crate::engine::budget_lanes;
use crate::optim::AlgorithmKind;
use crate::sweep::{table_num, Col, NumFmt, Record, Sink};
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::table::TextTable;
use anyhow::Result;
use std::sync::OnceLock;

/// Shared problem setup memoized across an ablation's cells: cold runs
/// solve (problem, x*) once for the whole grid, warm (cached) runs
/// never solve it.
type ProblemSetup = OnceLock<(LogRegProblem, Vec<f64>)>;

/// Corollary 3 ablation: warm-up all-reduce zeroes the initial-phase
/// consensus term. Measures the consensus distance over the first periods
/// and the final MSE with/without warm-up. Each cell's record stream is
/// its consensus samples plus one final-MSE summary row.
pub fn ablation_warmup(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(2000);
    let seed = ctx.seed;
    let cells = [true, false];
    let setup: ProblemSetup = OnceLock::new();
    let out = ctx.runner("ablation_warmup").run(
        &cells,
        |warmup| format!("warmup={warmup} n={n} iters={iters}"),
        |&warmup, cc| {
            let (problem, x_star) = setup.get_or_init(|| {
                let problem = paper_problem(n, 1000, true, seed);
                let x_star = global_minimizer(&problem, 400);
                (problem, x_star)
            });
            let x_star32: Vec<f32> = x_star.iter().map(|&v| v as f32).collect();
            let provider = super::logreg_runner::LogRegProvider { problem, batch: 8 };
            // Different random init per node when warm-up is off, so the
            // ablation actually has something to reduce.
            let mut init = crate::coordinator::StackedParams::zeros(n, problem.d);
            let mut rng = crate::util::rng::Pcg::seeded(seed ^ 0xAB1);
            for v in init.data.iter_mut() {
                *v = rng.normal() as f32;
            }
            let opt: Box<dyn crate::optim::Optimizer> =
                Box::new(crate::optim::DmSgd::new(init, 0.8));
            let mut trainer = Trainer::new(
                Schedule::new(TopologyKind::OnePeerExp, n, seed),
                opt,
                &provider,
                TrainConfig {
                    iters,
                    lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 3).max(1) },
                    warmup_allreduce: warmup,
                    record_every: 10,
                    parallel_grads: false,
                    lanes: Some(budget_lanes(cc.lanes, n, n * problem.d)),
                    seed,
                    msg_bytes: None,
                    cost: None,
                    ..Default::default()
                },
            );
            let mut last_mse = 0.0;
            let hist = trainer.run_with(|_, params| {
                last_mse = params.mean_sq_error_to(&x_star32);
            });
            let mut records: Vec<Record> = hist
                .consensus
                .iter()
                .map(|&(k, c)| {
                    Record::new()
                        .with("warmup", usize::from(warmup))
                        .with("iter", k)
                        .with("consensus", c)
                        .with("mse", f64::NAN)
                })
                .collect();
            // Summary row: final MSE to x* (empty consensus field).
            records.push(
                Record::new()
                    .with("warmup", usize::from(warmup))
                    .with("iter", iters)
                    .with("consensus", f64::NAN)
                    .with("mse", last_mse),
            );
            records
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("warmup"),
        Col::auto("iter"),
        Col::auto("consensus"),
        Col::auto("mse"),
    ]);
    for cell in &out {
        for rec in &cell.records {
            sink.push(rec);
        }
    }
    sink.write(&ctx.out_dir, "ablation_warmup")?;
    println!("Ablation — warm-up all-reduce (Corollary 3), n={n}");
    let mut t = TextTable::new(&["warmup", "initial consensus", "final MSE"]);
    for (cell, &warmup) in out.iter().zip(&cells) {
        let initial = cell.records.first().map_or(f64::NAN, |r| r.num("consensus"));
        let last = cell.records.last().map_or(f64::NAN, |r| r.num("mse"));
        t.row(vec![
            warmup.to_string(),
            table_num(initial, NumFmt::Sci(3)),
            table_num(last, NumFmt::Sci(3)),
        ]);
    }
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("ablation_warmup").display());
    Ok(())
}

/// One-peer sampling-order ablation (Appendix B.3.2), end-to-end: the
/// consensus-level story of Fig. 11 carried through actual DmSGD training.
pub fn ablation_sampling(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(3000);
    let seed = ctx.seed;
    let cells = [
        TopologyKind::OnePeerExp,
        TopologyKind::OnePeerExpPerm,
        TopologyKind::OnePeerExpUniform,
    ];
    let setup: ProblemSetup = OnceLock::new();
    let out = ctx.runner("ablation_sampling").run(
        &cells,
        |kind| format!("{kind:?} n={n} iters={iters}"),
        |&kind, cc| {
            let (problem, x_star) = setup.get_or_init(|| {
                let problem = paper_problem(n, 2000, true, seed);
                let x_star = global_minimizer(&problem, 400);
                (problem, x_star)
            });
            let curve = run_logreg_with(
                problem,
                x_star,
                &LogRegRun {
                    topology: kind,
                    algorithm: AlgorithmKind::DmSgd,
                    beta: 0.8,
                    lr: LrSchedule::HalveEvery { init: 0.2, every: 1000 },
                    iters,
                    batch: 8,
                    record_every: 50,
                    seed: seed + 2,
                },
                Some(cc.lanes),
            );
            let tail = if curve.mse.is_empty() {
                f64::NAN
            } else {
                let q = curve.mse.len() * 3 / 4;
                curve.mse[q..].iter().sum::<f64>() / (curve.mse.len() - q) as f64
            };
            vec![Record::new()
                .with("order", kind.name())
                .with("final_mse", final_mse(&curve))
                .with("tail_mse", tail)]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("order"),
        Col::auto("final_mse"),
        Col::auto("tail_mse"),
    ]);
    let mut t = TextTable::new(&["order", "final MSE", "mean MSE (last quarter)"]);
    println!("Ablation — one-peer sampling order, DmSGD, n={n}, {iters} iters");
    for cell in &out {
        let rec = &cell.records[0];
        sink.push(rec);
        t.row(vec![
            rec.text("order").to_string(),
            table_num(rec.num("final_mse"), NumFmt::Sci(3)),
            table_num(rec.num("tail_mse"), NumFmt::Sci(3)),
        ]);
    }
    sink.write(&ctx.out_dir, "ablation_sampling")?;
    println!("{}", t.render());
    println!("  expected: cyclic ≈ random-perm ≤ uniform-sampling (exactness of Lemma 1)");
    println!("  csv: {}", ctx.csv_path("ablation_sampling").display());
    Ok(())
}

/// Future-work study (paper conclusion): symmetric time-varying graphs
/// and bias-corrected methods. Compares, on heterogeneous data:
/// DmSGD/one-peer-exp, gradient tracking/one-peer-exp (asymmetric OK),
/// D²-lazy/static-hypercube (symmetric static), and documents that naive
/// D² over one-peer hypercube matchings is unstable.
pub fn ablation_symmetric(ctx: &Ctx) -> Result<()> {
    let n = 32; // power of two: hypercube variants valid
    let iters = ctx.scaled(3000);
    let seed = ctx.seed;
    let cells = [
        ("dmsgd/one_peer_exp", TopologyKind::OnePeerExp, AlgorithmKind::DmSgd),
        ("dmsgd/one_peer_hypercube", TopologyKind::OnePeerHypercube, AlgorithmKind::DmSgd),
        ("tracking/one_peer_exp", TopologyKind::OnePeerExp, AlgorithmKind::GradientTracking),
        ("d2_lazy/hypercube", TopologyKind::Hypercube, AlgorithmKind::D2),
        ("d2_lazy/one_peer_hypercube", TopologyKind::OnePeerHypercube, AlgorithmKind::D2),
        ("parallel", TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
    ];
    let setup: ProblemSetup = OnceLock::new();
    let out = ctx.runner("ablation_symmetric").run(
        &cells,
        |cell| format!("{cell:?} n={n} iters={iters}"),
        |&(label, kind, algo), cc| {
            let (problem, x_star) = setup.get_or_init(|| {
                let problem = paper_problem(n, 2000, true, seed + 5);
                let x_star = global_minimizer(&problem, 400);
                (problem, x_star)
            });
            let curve = run_logreg_with(
                problem,
                x_star,
                &LogRegRun {
                    topology: kind,
                    algorithm: algo,
                    beta: 0.8,
                    lr: LrSchedule::HalveEvery { init: 0.1, every: 1000 },
                    iters,
                    batch: 8,
                    record_every: 50,
                    seed: seed + 6,
                },
                Some(cc.lanes),
            );
            vec![Record::new()
                .with("method", algo.name())
                .with("topology", kind.name())
                .with("label", label)
                .with("final_mse", final_mse(&curve))]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("method"),
        Col::auto("topology"),
        Col::auto("final_mse"),
    ]);
    let mut t = TextTable::new(&["method/topology", "final MSE", "per-iter comm"]);
    println!("Ablation — symmetric time-varying graphs (future work), n={n}, hetero data");
    for (cell, &(_, kind, _)) in out.iter().zip(&cells) {
        let rec = &cell.records[0];
        sink.push(rec);
        let mse = rec.num("final_mse");
        let comm = crate::costmodel::analytic_degree(kind, n);
        t.row(vec![
            rec.text("label").to_string(),
            if mse.is_finite() { table_num(mse, NumFmt::Sci(3)) } else { "DIVERGED".into() },
            if kind == TopologyKind::FullyConnected {
                "n-1 (allreduce)".into()
            } else {
                comm.to_string()
            },
        ]);
    }
    sink.write(&ctx.out_dir, "ablation_symmetric")?;
    println!("{}", t.render());
    println!("  reading: on *deterministic* heterogeneous problems lazy D² over the");
    println!("  one-peer hypercube is exact (see examples/symmetric_timevarying.rs), but");
    println!("  under stochastic gradients its marginally-stable mode amplifies noise —");
    println!("  evidence that the paper's open problem (symmetric time-varying graphs");
    println!("  matching one-peer-exp) is genuinely open for SGD-style methods.");
    println!("  csv: {}", ctx.csv_path("ablation_symmetric").display());
    Ok(())
}
