//! `table_finite_time` — exact averaging and time-to-accuracy beyond
//! powers of two: one-peer exponential vs the open-registry finite-time
//! families (base-(k+1) after Takezawa et al., CECA-style one/two-peer
//! after Ding et al.) at n ∈ {12, 24, 48, 64}.
//!
//! Three of the four sizes are deliberately **not** powers of two —
//! exactly where Lemma 1 fails for the one-peer exponential graph
//! (Fig. 10) and where the finite-time families still multiply to `J`
//! in O(log n) rounds. Each cell reports (a) the gossip residue at the
//! family's period and the steps to drive it below 1e-9, and (b)
//! simulated time-to-accuracy for DmSGD on the heterogeneous quadratic
//! (the netsim runner's workload, priced by the α-β cost model from
//! each round's realized plan degree). Runs through the §Sweep harness:
//! parallel cells under the lane budget, Record/Sink output to
//! `results/table_finite_time.{csv,json}`, and cache keys covering the
//! family axis.

use super::Ctx;
use crate::consensus;
use crate::coordinator::trainer::{QuadraticProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::costmodel::CostModel;
use crate::engine::budget_lanes;
use crate::optim::AlgorithmKind;
use crate::sweep::{table_num, Axis, Col, Grid, NumFmt, Record, Sink};
use crate::topology::exponential::tau;
use crate::topology::family;
use crate::topology::schedule::Schedule;
use crate::topology::{Topology, TopologyKind};
use crate::util::table::TextTable;
use anyhow::Result;

/// The cluster sizes of the comparison — 12, 24, 48 are not powers of
/// two (one-peer exp cannot average exactly there); 64 is the paper's
/// headline size where all three families are exact.
pub const FINITE_TIME_SIZES: [usize; 4] = [12, 24, 48, 64];

/// The family axis: the paper's one-peer exponential plus the two
/// finite-time arbitrary-n families from the open registry.
pub fn finite_time_families() -> Vec<Topology> {
    vec![
        TopologyKind::OnePeerExp.family(),
        family::find("base4").expect("base4 is registered"),
        family::find("ceca").expect("ceca is registered"),
    ]
}

/// One cell of the grid. The derived `Debug` is the cache-key spec, so
/// the family name participates in the key (a `base4` cell can never be
/// served from a `one_peer_exp` cell's cache entry).
#[derive(Clone, Debug)]
struct FiniteTimeCell {
    topo: Topology,
    n: usize,
}

/// Protocol constants (mirrors the netsim runner's workload so the
/// numbers are comparable across the two tables).
const DIM: usize = 32;
const TOL: f64 = 0.05;
const MSG_BYTES: f64 = 25.5e6 * 4.0;
const COMPUTE: f64 = 0.4;
/// Gossip-decay probe budget (steps). Cheap (O(nnz) matvecs at n ≤ 64)
/// and long enough that one-peer exp's asymptotic decay at
/// non-power-of-two n can realistically cross 1e-9 within it.
const DECAY_WINDOW: usize = 400;

fn run_cell(cell: &FiniteTimeCell, iters: usize, seed: u64, lane_cap: Option<usize>) -> Record {
    let topo = cell.topo;
    let n = cell.n;
    let period = topo.exact_period(n);
    // The probe period: the family's exact period, or τ(n) for families
    // (one-peer exp off powers of two) that only decay asymptotically.
    let probe_period = period.unwrap_or_else(|| tau(n).max(1));

    // (a) Pure gossip: residue at the period boundary, steps to 1e-9.
    // The window is generous (the asymptotically-decaying one-peer exp
    // at non-power-of-two n needs many periods to cross 1e-9) so a `-`
    // in the output means "not within DECAY_WINDOW steps", not an
    // artifact of a tight probe — the window is reported alongside.
    let decay = consensus::residue_decay_topo(topo, n, DECAY_WINDOW, seed);
    let residue_at_period = decay[probe_period - 1];
    let steps_to_1e9 = decay.iter().position(|&r| r < 1e-9).map(|p| p + 1);

    // (b) DmSGD time-to-accuracy on the heterogeneous quadratic: node i
    // pulls toward its own target, the optimum is the mean target, so a
    // family only wins by actually averaging.
    let provider = QuadraticProvider::random(n, DIM, 0.0, seed ^ ((n as u64) << 20));
    let cbar = provider.targets.mean();
    let err0 = cbar.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().max(1e-12);
    let opt = AlgorithmKind::DmSgd.build(n, &vec![0.0f32; DIM], 0.8);
    let mut trainer = Trainer::new(
        Schedule::from_family(topo, n, seed),
        opt,
        &provider,
        TrainConfig {
            iters,
            lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 8).max(1) },
            warmup_allreduce: false,
            record_every: 1,
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, n, n * DIM)),
            seed,
            msg_bytes: Some(MSG_BYTES),
            cost: Some(CostModel::paper_default(COMPUTE)),
            ..Default::default()
        },
    );
    let mut errs: Vec<f64> = Vec::with_capacity(iters);
    let hist = trainer.run_with(|_, params| errs.push(params.mean_sq_error_to(&cbar)));
    let target = TOL * err0;
    let hit = errs.iter().position(|&e| e <= target);
    let (reached, iters_to_target, time_to_target) = match hit {
        Some(k) => (true, k + 1, hist.round_times[..=k].iter().sum::<f64>()),
        None => (false, iters, hist.sim_time),
    };

    // Realized worst-round communication degree over one period.
    let max_degree = {
        let mut sched = Schedule::from_family(topo, n, seed);
        (0..probe_period).map(|k| sched.plan_at(k).max_degree).max().unwrap_or(0)
    };

    Record::new()
        .with("topology", topo.name())
        .with("n", n)
        .with("exact", period.is_some())
        .with("period", period.map_or(f64::NAN, |p| p as f64))
        .with("residue_at_period", residue_at_period)
        .with("steps_to_1e9", steps_to_1e9.map_or(f64::NAN, |s| s as f64))
        .with("max_degree", max_degree)
        .with("reached", reached)
        .with("iters_to_target", iters_to_target)
        .with("time_to_target", time_to_target)
        .with("final_err", errs.last().copied().unwrap_or(err0))
}

/// Run the sweep, print the paper-style pivot, and write
/// `results/table_finite_time.{csv,json}`.
pub fn table_finite_time(ctx: &Ctx) -> Result<()> {
    let families = finite_time_families();
    let sizes = FINITE_TIME_SIZES;
    let iters = ctx.scaled(900);
    let seed = ctx.seed;
    let grid = Grid::product2(
        &Axis::new("topology", families.clone()),
        &Axis::new("n", sizes.to_vec()),
        |&topo, &n| FiniteTimeCell { topo, n },
    );
    let out = ctx.runner("table_finite_time").run(
        grid.cells(),
        |cell| {
            format!(
                "{cell:?} iters={iters} dim={DIM} tol={TOL} msg_bytes={MSG_BYTES} \
                 compute={COMPUTE}"
            )
        },
        |cell, cc| vec![run_cell(cell, iters, seed, Some(cc.lanes))],
    );

    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("n"),
        Col::auto("exact"),
        Col::auto("period"),
        Col::auto("residue_at_period"),
        Col::auto("steps_to_1e9"),
        Col::auto("max_degree"),
        Col::auto("reached"),
        Col::auto("iters_to_target"),
        Col::auto("time_to_target"),
        Col::auto("final_err"),
    ]);
    for cell in &out {
        sink.push(&cell.records[0]);
    }
    sink.write(&ctx.out_dir, "table_finite_time")?;

    let mut t = TextTable::new(&[
        "topology",
        "n",
        "tau",
        "deg",
        "residue@tau",
        "steps to 1e-9",
        "iters to target",
        "t2t (s)",
    ]);
    for (fi, topo) in families.iter().enumerate() {
        for (ni, &n) in sizes.iter().enumerate() {
            let rec = &out[fi * sizes.len() + ni].records[0];
            t.row(vec![
                topo.name().to_string(),
                n.to_string(),
                if rec.flag("exact") {
                    table_num(rec.num("period"), NumFmt::Auto)
                } else {
                    format!("- ({})", tau(n))
                },
                table_num(rec.num("max_degree"), NumFmt::Auto),
                table_num(rec.num("residue_at_period"), NumFmt::Sci(1)),
                if rec.num("steps_to_1e9").is_finite() {
                    table_num(rec.num("steps_to_1e9"), NumFmt::Auto)
                } else {
                    format!(">{DECAY_WINDOW}")
                },
                table_num(rec.num("iters_to_target"), NumFmt::Auto),
                if rec.flag("reached") {
                    table_num(rec.num("time_to_target"), NumFmt::Fixed(1))
                } else {
                    format!(">{}", table_num(rec.num("time_to_target"), NumFmt::Fixed(1)))
                },
            ]);
        }
    }
    println!("Finite-time exact averaging beyond powers of two (DmSGD, tol = {TOL}·err0)");
    println!("{}", t.render());
    println!("  n = 12/24/48 are not powers of two: one-peer exp cannot average");
    println!("  exactly there (Lemma 1 / Fig. 10); base-(k+1) and CECA-style");
    println!("  schedules reach the exact average every tau rounds for any n.");
    println!("  csv: {}", ctx.csv_path("table_finite_time").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_finite_time_sweep_emits_artifacts() {
        let tmp = std::env::temp_dir().join(format!("expograph-ft-{}", std::process::id()));
        let ctx = Ctx { out_dir: tmp.clone(), scale: 0.05, seed: 1, sweep: Default::default() };
        table_finite_time(&ctx).unwrap();
        assert!(tmp.join("table_finite_time.csv").exists());
        assert!(tmp.join("table_finite_time.json").exists());
        let csv = std::fs::read_to_string(tmp.join("table_finite_time.csv")).unwrap();
        for needle in ["one_peer_exp", "base4", "ceca"] {
            assert!(csv.contains(needle), "csv missing {needle}");
        }
        std::fs::remove_dir_all(&tmp).ok();
    }
}
