//! `table_async` — execution mode × scenario × topology on
//! **simulated time-to-accuracy** (docs/DESIGN.md §Async runtime).
//!
//! The bulk-synchronous round pays the fleet's slowest node every
//! iteration; the bounded-staleness executor only gates wave `k` on the
//! fleet having *released* wave `k − τ − 1`, so a slow node costs its
//! partners a stale read instead of a global stall. This table measures
//! that trade on the heterogeneous quadratic (the `netsim` /
//! `table_compression` workload): under timing faults (persistent
//! straggler, transiently flaky nodes) async τ ∈ {1, 2} should reach the
//! accuracy target in strictly less simulated wall-clock than sync on
//! the one-peer exponential graph, while on a clean network the two
//! agree (uniform times never force a stale read).
//!
//! Emits `table_async.csv` / `.json` and a paper-style text table.

use std::collections::BTreeMap;

use super::Ctx;
use crate::coordinator::trainer::{ExecutionMode, QuadraticProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::costmodel::CostModel;
use crate::engine::budget_lanes;
use crate::netsim::{NetSim, Scenario};
use crate::optim::AlgorithmKind;
use crate::sweep::{Axis, Col, Grid, Record, Sink};
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::json::Json;
use crate::util::table::TextTable;
use anyhow::{Context, Result};

/// Topology rows of the table.
const KINDS: [TopologyKind; 2] = [TopologyKind::OnePeerExp, TopologyKind::StaticExp];

/// Timing-only scenarios (the async executor rejects faulty ones).
fn scenarios() -> Vec<Scenario> {
    vec![Scenario::clean(), Scenario::straggler(), Scenario::flaky()]
}

/// Execution-mode columns of the table.
fn modes() -> Vec<ExecutionMode> {
    vec![
        ExecutionMode::Sync,
        ExecutionMode::Async { tau: 1 },
        ExecutionMode::Async { tau: 2 },
    ]
}

/// One cell: a full training run to the accuracy target.
#[derive(Clone, Debug)]
pub struct AsyncCell {
    pub topology: TopologyKind,
    pub scenario: String,
    pub execution: ExecutionMode,
    pub reached: bool,
    pub iters_to_target: usize,
    /// Simulated seconds up to (and including) the round that hit the
    /// target — the full budget's clock when not reached.
    pub time_to_target: f64,
    pub final_err: f64,
    /// Engine dispatches per training iteration — the out-of-order
    /// executor's headline economy (amortized O(1) vs 2·waves serial).
    pub dispatches_per_iter: f64,
}

fn cell_record(c: &AsyncCell) -> Record {
    Record::new()
        .with("topology", c.topology.name())
        .with("scenario", c.scenario.as_str())
        .with("execution", c.execution.label().as_str())
        .with("reached", c.reached)
        .with("iters_to_target", c.iters_to_target)
        .with("time_to_target", c.time_to_target)
        .with("final_err", c.final_err)
        .with("dispatches_per_iter", c.dispatches_per_iter)
}

fn cell_from_record(rec: &Record) -> Result<AsyncCell> {
    let tname = rec.text("topology");
    let ename = rec.text("execution");
    Ok(AsyncCell {
        topology: TopologyKind::parse(tname)
            .ok_or_else(|| anyhow::anyhow!("cached cell has unknown topology {tname}"))?,
        scenario: rec.text("scenario").to_string(),
        execution: ExecutionMode::parse(ename)
            .ok_or_else(|| anyhow::anyhow!("cached cell has unknown execution mode {ename}"))?,
        reached: rec.flag("reached"),
        iters_to_target: rec.num("iters_to_target") as usize,
        time_to_target: rec.num("time_to_target"),
        final_err: rec.num("final_err"),
        // Tolerate cached cells recorded before this column existed.
        dispatches_per_iter: rec.get("dispatches_per_iter").map(|v| v.num()).unwrap_or(f64::NAN),
    })
}

/// Run one (topology, scenario, execution) cell at the sweep's fixed
/// n/dim — the `table_compression` protocol with the network clock as
/// the moving part instead of the wire format.
fn run_cell(
    ctx: &Ctx,
    kind: TopologyKind,
    scenario: &Scenario,
    execution: ExecutionMode,
    lane_cap: Option<usize>,
) -> AsyncCell {
    let n = 16;
    let dim = 32;
    let iters = ctx.scaled(1200);
    let tol = 0.01;
    let provider = QuadraticProvider::random(n, dim, 0.0, ctx.seed ^ 0xA5);
    let cbar = provider.targets.mean();
    let err0 = cbar.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().max(1e-12);
    let opt = AlgorithmKind::DmSgd.build(n, &vec![0.0f32; dim], 0.8);
    let sim = NetSim::new(&CostModel::paper_default(0.01), scenario.clone(), ctx.seed);
    let mut trainer = Trainer::new(
        Schedule::new(kind, n, ctx.seed),
        opt,
        &provider,
        TrainConfig {
            iters,
            lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 8).max(1) },
            warmup_allreduce: false,
            record_every: 1,
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, n, n * dim)),
            seed: ctx.seed,
            msg_bytes: Some(4.0 * dim as f64),
            cost: None,
            execution,
            ..Default::default()
        },
    )
    .with_netsim(sim);
    let mut errs: Vec<f64> = Vec::with_capacity(iters);
    let hist = trainer.run_with(|_, params| errs.push(params.mean_sq_error_to(&cbar)));
    let target = tol * err0;
    let hit = errs.iter().position(|&e| e <= target);
    let (reached, iters_to_target, time_to_target) = match hit {
        Some(k) => (true, k + 1, hist.round_times[..=k].iter().sum()),
        None => (false, iters, hist.sim_time),
    };
    AsyncCell {
        topology: kind,
        scenario: scenario.name.clone(),
        execution,
        reached,
        iters_to_target,
        time_to_target,
        final_err: errs.last().copied().unwrap_or(err0),
        dispatches_per_iter: hist.dispatches as f64 / iters.max(1) as f64,
    }
}

/// Run the sweep (parallel, cache-aware), print the table, and write
/// `table_async.csv` + `.json`. Returns the cells for test assertions
/// on top of the artifacts.
pub fn table_async_cells(ctx: &Ctx) -> Result<Vec<AsyncCell>> {
    std::fs::create_dir_all(&ctx.out_dir)
        .with_context(|| format!("creating {}", ctx.out_dir.display()))?;
    #[derive(Clone, Debug)]
    struct Spec {
        kind: TopologyKind,
        scenario: Scenario,
        execution: ExecutionMode,
    }
    let grid = Grid::product3(
        &Axis::new("topology", KINDS.to_vec()),
        &Axis::new("scenario", scenarios()),
        &Axis::new("execution", modes()),
        |&kind, scenario, &execution| Spec { kind, scenario: scenario.clone(), execution },
    );
    let out = ctx.runner("table_async").run(
        grid.cells(),
        |spec| format!("{:?} {} {}", spec.kind, spec.scenario.name, spec.execution.label()),
        |spec, cc| {
            vec![cell_record(&run_cell(
                ctx,
                spec.kind,
                &spec.scenario,
                spec.execution,
                Some(cc.lanes),
            ))]
        },
    );
    let cells = out
        .iter()
        .map(|cell| cell_from_record(&cell.records[0]))
        .collect::<Result<Vec<_>>>()?;

    // Text table: one row per (topology, scenario), simulated
    // time-to-target per execution mode — the staleness dividend at a
    // glance.
    let mut header = vec!["topology".to_string(), "scenario".to_string()];
    for mode in modes() {
        header.push(format!("{} time", mode.label()));
        header.push(format!("{} iters", mode.label()));
    }
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for &kind in &KINDS {
        for scenario in scenarios() {
            let mut row = vec![kind.name().to_string(), scenario.name.clone()];
            for mode in modes() {
                let c = cells
                    .iter()
                    .find(|c| {
                        c.topology == kind && c.scenario == scenario.name && c.execution == mode
                    })
                    .expect("cell exists");
                row.push(if c.reached {
                    format!("{:.2}s", c.time_to_target)
                } else {
                    format!(">{:.2}s", c.time_to_target)
                });
                row.push(c.iters_to_target.to_string());
            }
            t.row(row);
        }
    }

    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("scenario"),
        Col::auto("execution"),
        Col::auto("reached"),
        Col::auto("iters_to_target"),
        Col::auto("time_to_target"),
        Col::auto("final_err"),
        Col::auto("dispatches_per_iter"),
    ]);
    // Re-serialize the parsed cells (not the raw cached records) so
    // runs resumed from a pre-`dispatches_per_iter` cache still emit
    // every column (missing values degrade to NaN ⇒ empty CSV cell).
    for c in &cells {
        sink.push(&cell_record(c));
    }
    sink.write_csv(&ctx.out_dir, "table_async")?;

    let mut root = BTreeMap::new();
    root.insert(
        "rows".to_string(),
        Json::Arr(
            cells
                .iter()
                .map(|c| {
                    let mut o = BTreeMap::new();
                    o.insert("topology".into(), Json::Str(c.topology.name().into()));
                    o.insert("scenario".into(), Json::Str(c.scenario.clone()));
                    o.insert("execution".into(), Json::Str(c.execution.label()));
                    o.insert("reached".into(), Json::Bool(c.reached));
                    o.insert("iters_to_target".into(), Json::Num(c.iters_to_target as f64));
                    o.insert("time_to_target".into(), Json::Num(c.time_to_target));
                    o.insert("final_err".into(), Json::Num(c.final_err));
                    o.insert("dispatches_per_iter".into(), Json::Num(c.dispatches_per_iter));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let path = ctx.out_dir.join("table_async.json");
    std::fs::write(&path, Json::Obj(root).to_string())
        .with_context(|| format!("writing {}", path.display()))?;

    println!("Async — simulated time-to-accuracy (err ≤ 0.01 · err₀), DmSGD, n = 16");
    println!("{}", t.render());
    println!("  sync pays the slowest node per round; async:τ gates wave k on the");
    println!("  fleet's release of wave k-τ-1 and reads partner payloads ≤ τ stale.");
    println!("  csv: {}", ctx.csv_path("table_async").display());
    Ok(cells)
}

/// `expograph exp table_async` entry point.
pub fn table_async(ctx: &Ctx) -> Result<()> {
    table_async_cells(ctx).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_staleness_beats_sync_under_timing_faults() {
        let tmp = std::env::temp_dir().join(format!("expograph-async-{}", std::process::id()));
        let ctx = Ctx { out_dir: tmp.clone(), ..Ctx::default() };
        let cells = table_async_cells(&ctx).unwrap();
        assert_eq!(cells.len(), KINDS.len() * scenarios().len() * modes().len());
        assert!(tmp.join("table_async.csv").exists());
        assert!(tmp.join("table_async.json").exists());
        let get = |scenario: &str, mode: ExecutionMode| {
            cells
                .iter()
                .find(|c| {
                    c.topology == TopologyKind::OnePeerExp
                        && c.scenario == scenario
                        && c.execution == mode
                })
                .expect("cell exists")
        };
        // On a clean one-peer network everything reaches the target.
        assert!(get("clean", ExecutionMode::Sync).reached);
        assert!(get("clean", ExecutionMode::Async { tau: 1 }).reached);
        // The acceptance headline: under at least one timing-fault
        // scenario, some async τ ∈ {1, 2} reaches the accuracy target in
        // strictly less simulated wall-clock than sync on one-peer exp.
        let mut wins = Vec::new();
        for scenario in ["straggler", "flaky"] {
            let sync = get(scenario, ExecutionMode::Sync);
            for tau in [1usize, 2] {
                let asyn = get(scenario, ExecutionMode::Async { tau });
                if asyn.reached && asyn.time_to_target < sync.time_to_target {
                    wins.push((scenario, tau, sync.time_to_target / asyn.time_to_target));
                }
            }
        }
        assert!(
            !wins.is_empty(),
            "no (scenario, τ) pair beat sync on simulated time-to-target: {cells:?}"
        );
        std::fs::remove_dir_all(&tmp).ok();
    }
}
