//! Shared machinery for the deep-training-style experiments (Tables 2, 3,
//! 4, 9, 10): an MLP-classification [`GradProvider`] over sharded
//! Gaussian-mixture data, and a runner reporting validation accuracy plus
//! the simulated wall-clock of the paper's actual workload (ImageNet /
//! ResNet-50 message sizes through the α-β cost model — see docs/DESIGN.md
//! §Substitutions).

use crate::coordinator::trainer::{GradProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::costmodel::CostModel;
use crate::data::classify::{generate, ClassifyConfig, ClassifyData};
use crate::data::shard::{shard, Sharding, Shards};
use crate::engine::budget_lanes;
use crate::models::{Mlp, MlpConfig};
use crate::optim::AlgorithmKind;
use crate::sweep::Record;
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg;

/// Per-node MLP gradients over the sharded classification data.
pub struct ClassifyProvider<'a> {
    pub data: &'a ClassifyData,
    pub shards: &'a Shards,
    pub mlp: Mlp,
    pub batch: usize,
}

impl GradProvider for ClassifyProvider<'_> {
    fn dim(&self) -> usize {
        self.mlp.cfg.param_count()
    }

    fn nodes(&self) -> usize {
        self.shards.num_nodes()
    }

    fn grad(&self, node: usize, params: &[f32], iter: usize, seed: u64, out: &mut [f32]) -> f32 {
        let local = self.shards.node(node);
        let mut rng = Pcg::new(
            seed ^ (node as u64).wrapping_mul(0xD1B54A32D192ED03) ^ (iter as u64) << 18,
            0xC1A,
        );
        let batch: Vec<usize> = (0..self.batch).map(|_| local[rng.below(local.len())]).collect();
        self.mlp.loss_grad(params, &self.data.train, &batch, out)
    }
}

/// One deep-training-style run specification.
#[derive(Clone, Debug)]
pub struct ClassifySpec {
    pub nodes: usize,
    pub topology: TopologyKind,
    pub algorithm: AlgorithmKind,
    pub hidden: usize,
    pub iters: usize,
    pub batch: usize,
    pub lr: f32,
    pub beta: f32,
    pub heterogeneous: bool,
    pub seed: u64,
}

/// Result row: the analogue of one cell of Tables 2/3/4.
#[derive(Clone, Debug)]
pub struct ClassifyResult {
    pub val_acc: f64,
    pub final_loss: f64,
    /// Simulated 90-epoch ImageNet wall clock in hours (cost model with
    /// the paper's ResNet-50-scale message size, NOT this MLP's size).
    pub sim_hours: f64,
    pub consensus: f64,
}

/// Simulated Table 2 wall-clock: 90 epochs of ImageNet (1,281,167 images)
/// at global batch `256·n`, ResNet-50 messages (25.5 M params ≈ 102 MB),
/// compute ≈ 0.4 s/iteration per node, 70% comm/compute overlap.
pub fn simulated_imagenet_hours(kind: TopologyKind, n: usize) -> f64 {
    let iters_per_epoch = 1_281_167.0 / (256.0 * n as f64);
    let cost = CostModel::paper_default(0.4);
    let msg_bytes = 25.5e6 * 4.0;
    let per_iter = cost.iteration_time(kind, n, msg_bytes);
    90.0 * iters_per_epoch * per_iter / 3600.0
}

/// Run one specification on the given dataset.
pub fn run_classify(data: &ClassifyData, spec: &ClassifySpec) -> ClassifyResult {
    run_classify_with(data, spec, None)
}

/// [`run_classify`] under an explicit engine **lane cap** (the sweep
/// scheduler's per-job budget — docs/DESIGN.md §Sweep). `None` keeps
/// the trainer's automatic lane sizing; the trajectory is bitwise
/// identical either way (§Engine determinism).
pub fn run_classify_with(
    data: &ClassifyData,
    spec: &ClassifySpec,
    lane_cap: Option<usize>,
) -> ClassifyResult {
    let mode = if spec.heterogeneous {
        Sharding::Heterogeneous { alpha: 0.3 }
    } else {
        Sharding::Homogeneous
    };
    let shards = shard(&data.train, spec.nodes, mode, spec.seed);
    let mlp = Mlp::new(MlpConfig {
        input: data.train.dim,
        hidden: spec.hidden,
        classes: data.train.classes,
    });
    let dim = mlp.cfg.param_count();
    let provider = ClassifyProvider { data, shards: &shards, mlp, batch: spec.batch };
    let init = mlp.init(spec.seed ^ 0xAB);
    let opt = spec.algorithm.build(spec.nodes, &init, spec.beta);
    let mut trainer = Trainer::new(
        Schedule::new(spec.topology, spec.nodes, spec.seed),
        opt,
        &provider,
        TrainConfig {
            iters: spec.iters,
            lr: LrSchedule::Milestones {
                init: spec.lr,
                factor: 0.1,
                milestones: vec![spec.iters * 2 / 3, spec.iters * 8 / 9],
                warmup: spec.iters / 20,
            },
            warmup_allreduce: true,
            record_every: (spec.iters / 10).max(1),
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, spec.nodes, spec.nodes * dim)),
            seed: spec.seed,
            msg_bytes: None,
            cost: None,
            ..Default::default()
        },
    );
    let hist = trainer.run();
    // Validation accuracy of the *mean* model (the paper evaluates the
    // averaged model after training).
    let mean = trainer.optimizer.params().mean();
    let val_acc = mlp.accuracy(&mean, &data.val);
    let tail = hist.loss.len().saturating_sub(20);
    let final_loss = hist.loss[tail..].iter().sum::<f64>() / (hist.loss.len() - tail) as f64;
    ClassifyResult {
        val_acc,
        final_loss,
        sim_hours: simulated_imagenet_hours(spec.topology, spec.nodes),
        consensus: hist.consensus.last().map(|c| c.1).unwrap_or(0.0),
    }
}

/// The uniform sweep record for one classification cell — every table
/// experiment (2/3/4/9/10) emits this shape and lets its sink select
/// the columns it needs.
pub fn classify_record(spec: &ClassifySpec, r: &ClassifyResult) -> Record {
    Record::new()
        .with("topology", spec.topology.name())
        .with("algorithm", spec.algorithm.name())
        .with("nodes", spec.nodes)
        .with("val_acc", r.val_acc)
        .with("sim_hours", r.sim_hours)
        .with("final_loss", r.final_loss)
        .with("consensus", r.consensus)
}

/// The shared dataset for the table experiments.
pub fn table_dataset(seed: u64) -> ClassifyData {
    generate(&ClassifyConfig {
        dim: 32,
        classes: 10,
        train_per_class: 400,
        val_per_class: 100,
        separation: 3.0,
        seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dmsgd_learns_classification_over_one_peer_exp() {
        let data = table_dataset(3);
        let spec = ClassifySpec {
            nodes: 8,
            topology: TopologyKind::OnePeerExp,
            algorithm: AlgorithmKind::DmSgd,
            hidden: 32,
            iters: 600,
            batch: 32,
            lr: 0.1,
            beta: 0.9,
            heterogeneous: false,
            seed: 1,
        };
        let r = run_classify(&data, &spec);
        assert!(r.val_acc > 0.75, "val acc {}", r.val_acc);
        assert!(r.final_loss < 1.0, "final loss {}", r.final_loss);
    }

    #[test]
    fn simulated_hours_ordering_matches_paper() {
        // Table 2, n=32: one-peer ≈ match < ring < grid < static exp <
        // half-random.
        let n = 32;
        let h = |k| simulated_imagenet_hours(k, n);
        assert!(h(TopologyKind::OnePeerExp) <= h(TopologyKind::Ring));
        assert!(h(TopologyKind::Ring) < h(TopologyKind::Grid2D));
        assert!(h(TopologyKind::Grid2D) < h(TopologyKind::StaticExp));
        assert!(h(TopologyKind::StaticExp) < h(TopologyKind::HalfRandom));
        // Linear speedup: n=32 is faster than n=4 for one-peer.
        assert!(
            simulated_imagenet_hours(TopologyKind::OnePeerExp, 32)
                < simulated_imagenet_hours(TopologyKind::OnePeerExp, 4) / 4.0
        );
    }
}
