//! `table_compression` — topology × compressor on **time-to-accuracy
//! and bytes-to-accuracy** (docs/DESIGN.md §Compression).
//!
//! The paper's economy argument is about message *count*: one-peer
//! exponential graphs reach the target in Õ(1) exchanges per round. The
//! [`crate::compress`] axis composes the orthogonal lever — message
//! *size* — and this table shows the two multiply: one-peer exp + top-k
//! reaches the accuracy target with strictly fewer bytes than
//! uncompressed one-peer exp, which itself dominates denser topologies.
//!
//! Protocol: DmSGD on the heterogeneous quadratic (each node pulls
//! toward its own target; the optimum is the mean target, so consensus
//! is the whole game — same workload as the `netsim` sweep), clean
//! network so the bytes ledger is exactly the per-round directed-slot
//! count priced through [`CompressorKind::wire_bytes`]. Emits
//! `table_compression.csv` / `.json` and a paper-style text table.

use std::collections::BTreeMap;

use super::Ctx;
use crate::compress::CompressorKind;
use crate::coordinator::trainer::{QuadraticProvider, TrainConfig, Trainer};
use crate::coordinator::LrSchedule;
use crate::costmodel::CostModel;
use crate::engine::budget_lanes;
use crate::netsim::{NetSim, Scenario};
use crate::optim::AlgorithmKind;
use crate::sweep::{Axis, Col, Grid, Record, Sink};
use crate::topology::schedule::Schedule;
use crate::topology::TopologyKind;
use crate::util::json::Json;
use crate::util::table::TextTable;
use anyhow::{Context, Result};

/// Topology rows of the table, cheapest wire first in the rendering.
const KINDS: [TopologyKind; 3] =
    [TopologyKind::OnePeerExp, TopologyKind::StaticExp, TopologyKind::Ring];

/// Compressor columns of the table.
fn compressors() -> Vec<CompressorKind> {
    vec![
        CompressorKind::Identity,
        CompressorKind::TopK { frac: 0.125 },
        CompressorKind::Int8,
    ]
}

/// One cell: a full training run to the accuracy target.
#[derive(Clone, Debug)]
pub struct CompressionCell {
    pub topology: TopologyKind,
    pub compressor: CompressorKind,
    pub reached: bool,
    pub iters_to_target: usize,
    pub time_to_target: f64,
    /// Bytes on the wire up to (and including) the round that hit the
    /// target — the budget when not reached.
    pub bytes_to_target: f64,
    pub final_err: f64,
}

fn cell_record(c: &CompressionCell) -> Record {
    Record::new()
        .with("topology", c.topology.name())
        .with("compressor", c.compressor.label().as_str())
        .with("reached", c.reached)
        .with("iters_to_target", c.iters_to_target)
        .with("time_to_target", c.time_to_target)
        .with("bytes_to_target", c.bytes_to_target)
        .with("final_err", c.final_err)
}

fn cell_from_record(rec: &Record) -> Result<CompressionCell> {
    let tname = rec.text("topology");
    let cname = rec.text("compressor");
    Ok(CompressionCell {
        topology: TopologyKind::parse(tname)
            .ok_or_else(|| anyhow::anyhow!("cached cell has unknown topology {tname}"))?,
        compressor: CompressorKind::parse(cname)
            .ok_or_else(|| anyhow::anyhow!("cached cell has unknown compressor {cname}"))?,
        reached: rec.flag("reached"),
        iters_to_target: rec.num("iters_to_target") as usize,
        time_to_target: rec.num("time_to_target"),
        bytes_to_target: rec.num("bytes_to_target"),
        final_err: rec.num("final_err"),
    })
}

/// Run one (topology, compressor) cell at the sweep's fixed n/dim.
fn run_cell(
    ctx: &Ctx,
    kind: TopologyKind,
    comp: CompressorKind,
    lane_cap: Option<usize>,
) -> CompressionCell {
    let n = 16;
    let dim = 32;
    let iters = ctx.scaled(1200);
    let tol = 0.01;
    let provider = QuadraticProvider::random(n, dim, 0.0, ctx.seed ^ 0xC0);
    let cbar = provider.targets.mean();
    let err0 = cbar.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().max(1e-12);
    let opt = AlgorithmKind::DmSgd.build(n, &vec![0.0f32; dim], 0.8);
    let sim = NetSim::new(&CostModel::paper_default(0.01), Scenario::clean(), ctx.seed);
    let mut trainer = Trainer::new(
        Schedule::new(kind, n, ctx.seed),
        opt,
        &provider,
        TrainConfig {
            iters,
            lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 8).max(1) },
            warmup_allreduce: false,
            record_every: 1,
            parallel_grads: false,
            lanes: lane_cap.map(|cap| budget_lanes(cap, n, n * dim)),
            seed: ctx.seed,
            msg_bytes: Some(4.0 * dim as f64),
            cost: None,
            compressor: comp,
            ..Default::default()
        },
    )
    .with_netsim(sim);
    let mut errs: Vec<f64> = Vec::with_capacity(iters);
    let hist = trainer.run_with(|_, params| errs.push(params.mean_sq_error_to(&cbar)));
    let target = tol * err0;
    let hit = errs.iter().position(|&e| e <= target);
    let (reached, iters_to_target, time_to_target, bytes_to_target) = match hit {
        Some(k) => (
            true,
            k + 1,
            hist.round_times[..=k].iter().sum(),
            hist.round_bytes[..=k].iter().sum(),
        ),
        None => (
            false,
            iters,
            hist.sim_time,
            hist.round_bytes.iter().sum(),
        ),
    };
    CompressionCell {
        topology: kind,
        compressor: comp,
        reached,
        iters_to_target,
        time_to_target,
        bytes_to_target,
        final_err: errs.last().copied().unwrap_or(err0),
    }
}

/// Run the sweep (parallel, cache-aware), print the table, and write
/// `table_compression.csv` + `.json`. Returns the cells for test
/// assertions on top of the artifacts.
pub fn table_compression_cells(ctx: &Ctx) -> Result<Vec<CompressionCell>> {
    std::fs::create_dir_all(&ctx.out_dir)
        .with_context(|| format!("creating {}", ctx.out_dir.display()))?;
    #[derive(Clone, Debug)]
    struct Spec {
        kind: TopologyKind,
        comp: CompressorKind,
    }
    let grid = Grid::product2(
        &Axis::new("topology", KINDS.to_vec()),
        &Axis::new("compressor", compressors()),
        |&kind, &comp| Spec { kind, comp },
    );
    let out = ctx.runner("table_compression").run(
        grid.cells(),
        |spec| format!("{:?} compressor={}", spec.kind, spec.comp.label()),
        |spec, cc| vec![cell_record(&run_cell(ctx, spec.kind, spec.comp, Some(cc.lanes)))],
    );
    let cells = out
        .iter()
        .map(|cell| cell_from_record(&cell.records[0]))
        .collect::<Result<Vec<_>>>()?;

    // Text table: one row per topology, (bytes, iters) pair per
    // compressor — the bytes-to-accuracy economy at a glance.
    let mut header = vec!["topology".to_string()];
    for comp in compressors() {
        header.push(format!("{} bytes", comp.label()));
        header.push(format!("{} iters", comp.label()));
    }
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for &kind in &KINDS {
        let mut row = vec![kind.name().to_string()];
        for comp in compressors() {
            let c = cells
                .iter()
                .find(|c| c.topology == kind && c.compressor == comp)
                .expect("cell exists");
            row.push(if c.reached {
                format!("{:.2e}", c.bytes_to_target)
            } else {
                format!(">{:.2e}", c.bytes_to_target)
            });
            row.push(c.iters_to_target.to_string());
        }
        t.row(row);
    }

    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("compressor"),
        Col::auto("reached"),
        Col::auto("iters_to_target"),
        Col::auto("time_to_target"),
        Col::auto("bytes_to_target"),
        Col::auto("final_err"),
    ]);
    for cell in &out {
        sink.push(&cell.records[0]);
    }
    sink.write_csv(&ctx.out_dir, "table_compression")?;

    let mut root = BTreeMap::new();
    root.insert(
        "rows".to_string(),
        Json::Arr(
            cells
                .iter()
                .map(|c| {
                    let mut o = BTreeMap::new();
                    o.insert("topology".into(), Json::Str(c.topology.name().into()));
                    o.insert("compressor".into(), Json::Str(c.compressor.label()));
                    o.insert("reached".into(), Json::Bool(c.reached));
                    o.insert("iters_to_target".into(), Json::Num(c.iters_to_target as f64));
                    o.insert("time_to_target".into(), Json::Num(c.time_to_target));
                    o.insert("bytes_to_target".into(), Json::Num(c.bytes_to_target));
                    o.insert("final_err".into(), Json::Num(c.final_err));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let path = ctx.out_dir.join("table_compression.json");
    std::fs::write(&path, Json::Obj(root).to_string())
        .with_context(|| format!("writing {}", path.display()))?;

    println!("Compression — bytes-to-accuracy (err ≤ 0.01 · err₀), DmSGD, n = 16");
    println!("{}", t.render());
    println!("  wire pricing: identity = dense; topk:f ships 2f of dense (index+value");
    println!("  pairs); int8 ships dense/4 + scale. One ledger: netsim bytes_on_wire.");
    println!("  csv: {}", ctx.csv_path("table_compression").display());
    Ok(cells)
}

/// `expograph exp table_compression` entry point.
pub fn table_compression(ctx: &Ctx) -> Result<()> {
    table_compression_cells(ctx).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compressed_one_peer_exp_dominates_the_bytes_frontier() {
        let tmp = std::env::temp_dir()
            .join(format!("expograph-compression-{}", std::process::id()));
        let ctx = Ctx { out_dir: tmp.clone(), ..Ctx::default() };
        let cells = table_compression_cells(&ctx).unwrap();
        assert_eq!(cells.len(), KINDS.len() * compressors().len());
        assert!(tmp.join("table_compression.csv").exists());
        assert!(tmp.join("table_compression.json").exists());
        let get = |kind: TopologyKind, comp: CompressorKind| {
            cells
                .iter()
                .find(|c| c.topology == kind && c.compressor == comp)
                .expect("cell exists")
        };
        let dense = get(TopologyKind::OnePeerExp, CompressorKind::Identity);
        let topk = get(TopologyKind::OnePeerExp, CompressorKind::TopK { frac: 0.125 });
        let int8 = get(TopologyKind::OnePeerExp, CompressorKind::Int8);
        // Every one-peer cell reaches the target, the ledger is
        // populated, and the headline holds: compressed one-peer exp
        // hits the accuracy target with strictly fewer bytes than
        // uncompressed one-peer exp.
        for c in [dense, topk, int8] {
            assert!(c.reached, "{:?} must reach the target", c.compressor);
            assert!(c.bytes_to_target > 0.0, "bytes ledger must be populated");
        }
        assert!(
            topk.bytes_to_target < dense.bytes_to_target,
            "top-k one-peer ({}) must beat dense one-peer ({}) on bytes",
            topk.bytes_to_target,
            dense.bytes_to_target
        );
        assert!(
            int8.bytes_to_target < dense.bytes_to_target,
            "int8 one-peer must beat dense one-peer on bytes"
        );
        // And the topology economy composes: dense one-peer already
        // undercuts dense static exp on bytes per round.
        let static_dense = get(TopologyKind::StaticExp, CompressorKind::Identity);
        assert!(dense.bytes_to_target < static_dense.bytes_to_target);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
