//! Figure experiments (Figs. 1, 3, 4, 10, 11, 12, 13), declared as
//! sweep grids: the cell axis is the figure's series (topology,
//! sampling order, n, trial …), each cell's records are its iteration
//! series, and the wide per-figure CSV/JSON is assembled from the
//! grid-ordered results (docs/DESIGN.md §Sweep).

use super::logreg_runner::{
    average_curves, curve_records, final_mse, global_minimizer, paper_problem, records_curve,
    run_logreg_with, LogRegRun, MseCurve,
};
use super::{Ctx, TRANSIENT_KINDS};
use crate::consensus;
use crate::coordinator::{transient_iterations, LrSchedule};
use crate::data::logreg::LogRegProblem;
use crate::optim::AlgorithmKind;
use crate::spectral;
use crate::sweep::{table_num, Col, NumFmt, Record, Sink, Value};
use crate::topology::TopologyKind;
use crate::util::table::TextTable;
use anyhow::Result;
use std::sync::OnceLock;

/// Shared problem setup memoized across the cells that use it: cold
/// runs solve each (problem, x*) pair exactly once no matter how many
/// cells share it, and a fully warm (cached) sweep never solves it at
/// all.
type ProblemSetup = OnceLock<(LogRegProblem, Vec<f64>)>;

/// Assemble the wide per-figure sink — first column `first`, one column
/// per series — from equal-length series in grid order.
fn wide_sink(first: &str, labels: &[String], series: &[Vec<f64>]) -> Sink {
    let mut cols = vec![Col::auto(first)];
    cols.extend(labels.iter().map(|l| Col::auto(l.as_str())));
    let mut sink = Sink::new(cols);
    let len = series.first().map_or(0, Vec::len);
    for k in 0..len {
        let mut row = vec![Value::Num(k as f64 + 1.0)];
        row.extend(series.iter().map(|s| Value::Num(s[k])));
        sink.push_values(row);
    }
    sink
}

/// Fig. 1 — transient-iteration illustration: DSGD vs parallel SGD on
/// homogeneous logistic regression; the curves merge after the transient
/// phase.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(6000);
    let seed = ctx.seed;
    let cells = [
        (TopologyKind::Ring, AlgorithmKind::DSgd),
        (TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
    ];
    let setup: ProblemSetup = OnceLock::new();
    let out = ctx.runner("fig1").run(
        &cells,
        |cell| format!("{cell:?} n={n} iters={iters}"),
        |&(kind, algo), cc| {
            let (problem, x_star) = setup.get_or_init(|| {
                let problem = paper_problem(n, 2000, false, seed);
                let x_star = global_minimizer(&problem, 600);
                (problem, x_star)
            });
            let run = LogRegRun {
                topology: kind,
                algorithm: algo,
                beta: 0.0,
                lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 5).max(1) },
                iters,
                batch: 8,
                record_every: 25,
                seed,
            };
            curve_records(&run_logreg_with(problem, x_star, &run, Some(cc.lanes)))
        },
    );
    let dec = records_curve(&out[0].records);
    let par = records_curve(&out[1].records);
    let mut sink = Sink::new(vec![
        Col::auto("iter"),
        Col::auto("dsgd_ring_mse"),
        Col::auto("parallel_mse"),
    ]);
    for i in 0..dec.iters.len() {
        sink.push_values(vec![
            Value::Num(dec.iters[i] as f64),
            Value::Num(dec.mse[i]),
            Value::Num(par.mse[i]),
        ]);
    }
    sink.write(&ctx.out_dir, "fig1")?;

    let t = transient_iterations(&dec.mse, &par.mse, 2.0, 4);
    println!("Fig. 1 — transient iterations (DSGD/ring vs parallel SGD, n={n})");
    match t {
        Some(idx) => println!(
            "  curves merge at recorded sample {idx} (≈ iteration {})",
            dec.iters[idx]
        ),
        None => println!("  curves did not merge within {iters} iterations"),
    }
    println!(
        "  final MSE: dsgd={} parallel={}",
        table_num(final_mse(&dec), NumFmt::Sci(3)),
        table_num(final_mse(&par), NumFmt::Sci(3))
    );
    println!("  csv: {}", ctx.csv_path("fig1").display());
    Ok(())
}

/// Fig. 3 — spectral gap `1 − ρ` vs n for ring / grid / static exp,
/// against the Proposition 1 line `2/(1+⌈log₂n⌉)`. The grid axis is n.
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let ns: Vec<usize> = (4..=290).step_by(2).collect();
    let out = ctx.runner("fig3").run(
        &ns,
        |n| format!("n={n}"),
        |&n, _| {
            vec![Record::new()
                .with("n", n)
                .with("ring", spectral::topology_gap(TopologyKind::Ring, n, 0))
                .with("grid", spectral::topology_gap(TopologyKind::Grid2D, n, 0))
                .with("static_exp", spectral::topology_gap(TopologyKind::StaticExp, n, 0))
                .with("prop1_theory", 1.0 - spectral::static_exp_rho_bound(n))],
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("n"),
        Col::auto("ring"),
        Col::auto("grid"),
        Col::auto("static_exp"),
        Col::auto("prop1_theory"),
    ]);
    let mut max_dev_even = 0.0f64;
    for cell in &out {
        let rec = &cell.records[0];
        max_dev_even = max_dev_even.max((rec.num("static_exp") - rec.num("prop1_theory")).abs());
        sink.push(rec);
    }
    sink.write(&ctx.out_dir, "fig3")?;
    println!("Fig. 3 — spectral gaps for n = 4..290 (even n)");
    println!("  max |measured − Prop.1| over even n: {max_dev_even:.2e} (paper: exact match)");
    let mut t = TextTable::new(&["n", "1-rho ring", "1-rho grid", "1-rho static exp", "theory"]);
    for n in [8usize, 32, 64, 128, 256] {
        let idx = ns.iter().position(|&m| m == n).expect("n is on the even grid");
        let rec = &out[idx].records[0];
        t.row(vec![
            n.to_string(),
            table_num(rec.num("ring"), NumFmt::Fixed(4)),
            table_num(rec.num("grid"), NumFmt::Fixed(4)),
            table_num(rec.num("static_exp"), NumFmt::Fixed(4)),
            table_num(rec.num("prop1_theory"), NumFmt::Fixed(4)),
        ]);
    }
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("fig3").display());
    Ok(())
}

/// Run a residue-decay style sweep: one cell per labelled series, each
/// producing `iters` records of `{k, residue}` (clamped away from exact
/// zero for log plots), and return the series in grid order.
fn residue_series(
    ctx: &Ctx,
    id: &str,
    cells: &[(String, TopologyKind, usize)],
    iters: usize,
    decay: impl Fn(TopologyKind, usize, usize, u64) -> Vec<f64> + Sync,
) -> Vec<Vec<f64>> {
    let seed = ctx.seed;
    let out = ctx.runner(id).run(
        cells,
        |cell| format!("{cell:?} iters={iters}"),
        |(_, kind, n), _| {
            decay(*kind, *n, iters, seed)
                .into_iter()
                .enumerate()
                .map(|(k, v)| Record::new().with("k", k + 1).with("residue", v.max(1e-300)))
                .collect()
        },
    );
    out.iter()
        .map(|cell| cell.records.iter().map(|r| r.num("residue")).collect())
        .collect()
}

/// Fig. 4 — consensus residue decay: one-peer exp hits exact averaging at
/// τ steps; static exp and random matching only decay asymptotically.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let n = 16;
    let iters = 24;
    let cells: Vec<(String, TopologyKind, usize)> = [
        ("one_peer_exp", TopologyKind::OnePeerExp),
        ("static_exp", TopologyKind::StaticExp),
        ("random_match", TopologyKind::RandomMatch),
    ]
    .into_iter()
    .map(|(label, kind)| (label.to_string(), kind, n))
    .collect();
    let series = residue_series(ctx, "fig4", &cells, iters, consensus::residue_decay);
    let labels: Vec<String> = cells.iter().map(|c| c.0.clone()).collect();
    wide_sink("iter", &labels, &series).write(&ctx.out_dir, "fig4")?;

    let tau = crate::topology::exponential::tau(n);
    println!("Fig. 4 — consensus residue decay, n = {n} (τ = {tau})");
    let mut t = TextTable::new(&["k", "one-peer exp", "static exp", "random match"]);
    for k in 0..10 {
        t.row(vec![
            (k + 1).to_string(),
            table_num(series[0][k], NumFmt::Sci(3)),
            table_num(series[1][k], NumFmt::Sci(3)),
            table_num(series[2][k], NumFmt::Sci(3)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "  one-peer residue at k=τ: {} (exact averaging, Lemma 1)",
        table_num(series[0][tau - 1], NumFmt::Sci(1))
    );
    println!("  csv: {}", ctx.csv_path("fig4").display());
    Ok(())
}

/// Fig. 10 — one-peer exponential residue decay when n is NOT a power of
/// two: asymptotic, not periodic-exact.
pub fn fig10(ctx: &Ctx) -> Result<()> {
    let sizes = [5usize, 6, 9, 12];
    let iters = 30;
    let cells: Vec<(String, TopologyKind, usize)> = sizes
        .iter()
        .map(|&n| (format!("n{n}"), TopologyKind::OnePeerExp, n))
        .collect();
    let series = residue_series(ctx, "fig10", &cells, iters, consensus::residue_decay);
    let labels: Vec<String> = cells.iter().map(|c| c.0.clone()).collect();
    wide_sink("iter", &labels, &series).write(&ctx.out_dir, "fig10")?;
    println!("Fig. 10 — one-peer exp with n not a power of 2 (no exact averaging)");
    for (i, &n) in sizes.iter().enumerate() {
        let tau = crate::topology::exponential::tau(n);
        println!(
            "  n={n}: residue at k=τ={tau}: {} (>0), at k=30: {}",
            table_num(series[i][tau - 1], NumFmt::Sci(2)),
            table_num(series[i][iters - 1], NumFmt::Sci(2))
        );
    }
    println!("  csv: {}", ctx.csv_path("fig10").display());
    Ok(())
}

/// Fig. 11 — one-peer sampling strategies: cyclic and random-permutation
/// achieve periodic exact averaging; uniform sampling only asymptotic.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    let n = 16;
    let iters = 24;
    let cells: Vec<(String, TopologyKind, usize)> = [
        ("cyclic", TopologyKind::OnePeerExp),
        ("random_perm", TopologyKind::OnePeerExpPerm),
        ("uniform_sampling", TopologyKind::OnePeerExpUniform),
    ]
    .into_iter()
    .map(|(label, kind)| (label.to_string(), kind, n))
    .collect();
    let series = residue_series(ctx, "fig11", &cells, iters, consensus::residue_decay);
    let labels: Vec<String> = cells.iter().map(|c| c.0.clone()).collect();
    wide_sink("iter", &labels, &series).write(&ctx.out_dir, "fig11")?;
    let tau = crate::topology::exponential::tau(n);
    println!("Fig. 11 — one-peer sampling strategies, n = {n}");
    println!(
        "  residue at k=τ: cyclic={} perm={} uniform={}",
        table_num(series[0][tau - 1], NumFmt::Sci(1)),
        table_num(series[1][tau - 1], NumFmt::Sci(1)),
        table_num(series[2][tau - 1], NumFmt::Sci(1))
    );
    println!(
        "  residue at k={iters}: uniform={} (asymptotic only)",
        table_num(series[2][iters - 1], NumFmt::Sci(1))
    );
    println!("  csv: {}", ctx.csv_path("fig11").display());
    Ok(())
}

/// Fig. 12 — `‖∏_{i<k} Ŵ^{(i)}‖₂²` for one-peer exp over different n:
/// drops to exactly 0 at k = τ(n).
pub fn fig12(ctx: &Ctx) -> Result<()> {
    let sizes = [8usize, 16, 32, 64];
    let iters = 8;
    let cells: Vec<(String, TopologyKind, usize)> = sizes
        .iter()
        .map(|&n| (format!("n{n}"), TopologyKind::OnePeerExp, n))
        .collect();
    // Product norms can be exactly zero (the whole point of the figure),
    // so they bypass the log-plot clamp of `residue_series`.
    let seed = ctx.seed;
    let out = ctx.runner("fig12").run(
        &cells,
        |cell| format!("{cell:?} iters={iters}"),
        |(_, kind, n), _| {
            consensus::residue_product_norms(*kind, *n, iters, seed)
                .into_iter()
                .enumerate()
                .map(|(k, v)| Record::new().with("k", k + 1).with("residue", v))
                .collect()
        },
    );
    let series: Vec<Vec<f64>> = out
        .iter()
        .map(|cell| cell.records.iter().map(|r| r.num("residue")).collect())
        .collect();
    let labels: Vec<String> = cells.iter().map(|c| c.0.clone()).collect();
    wide_sink("k", &labels, &series).write(&ctx.out_dir, "fig12")?;
    println!("Fig. 12 — ‖∏ Ŵ^(i)‖₂² vs k for one-peer exponential");
    let mut header = vec!["k".to_string()];
    header.extend(sizes.iter().map(|n| format!("n={n}")));
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for k in 0..iters {
        t.row(
            std::iter::once((k + 1).to_string())
                .chain(series.iter().map(|s| table_num(s[k], NumFmt::Sci(2))))
                .collect(),
        );
    }
    println!("{}", t.render());
    for (i, &n) in sizes.iter().enumerate() {
        let tau = crate::topology::exponential::tau(n);
        println!("  n={n}: zero at k=τ={tau}? {}", series[i][tau - 1] < 1e-18);
    }
    println!("  csv: {}", ctx.csv_path("fig12").display());
    Ok(())
}

/// Fig. 13 — DmSGD convergence curves (MSE to x*) across topologies on
/// heterogeneous logistic regression: n=64, d=10, β=0.8, γ=0.2 halved
/// every 1000 iterations, averaged over trials. The grid is
/// (series × trial), so every trial of every curve is its own parallel,
/// cacheable cell.
pub fn fig13(ctx: &Ctx) -> Result<()> {
    let n = 64;
    let iters = ctx.scaled(6000);
    let trials = ctx.scaled(5);
    let samples = ctx.scaled(14_000).min(14_000).max(500);
    let kinds: Vec<(&'static str, TopologyKind, AlgorithmKind)> = std::iter::once((
        "parallel",
        TopologyKind::FullyConnected,
        AlgorithmKind::ParallelSgd,
    ))
    .chain(
        TRANSIENT_KINDS
            .into_iter()
            .map(|kind| (kind.name(), kind, AlgorithmKind::DmSgd)),
    )
    .collect();

    #[derive(Clone, Debug)]
    struct Fig13Cell {
        kind: TopologyKind,
        algo: AlgorithmKind,
        trial: usize,
    }
    let mut cells = Vec::new();
    for &(_, kind, algo) in &kinds {
        for trial in 0..trials {
            cells.push(Fig13Cell { kind, algo, trial });
        }
    }
    let seed = ctx.seed;
    // One shared (problem, x*) per trial — the five topology series of a
    // trial reuse it instead of re-solving the minimizer per cell.
    let setups: Vec<ProblemSetup> = (0..trials).map(|_| OnceLock::new()).collect();
    let out = ctx.runner("fig13").run(
        &cells,
        |cell| format!("{cell:?} n={n} iters={iters} samples={samples}"),
        |cell, cc| {
            let (problem, x_star) = setups[cell.trial].get_or_init(|| {
                let problem = paper_problem(n, samples, true, seed + cell.trial as u64);
                let x_star = global_minimizer(&problem, 500);
                (problem, x_star)
            });
            let run = LogRegRun {
                topology: cell.kind,
                algorithm: cell.algo,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.2, every: 1000 },
                iters,
                batch: 8,
                record_every: 50,
                seed: seed + 1000 + cell.trial as u64,
            };
            curve_records(&run_logreg_with(problem, x_star, &run, Some(cc.lanes)))
        },
    );
    let mut curves: Vec<(String, MseCurve)> = Vec::new();
    for (si, (label, _, _)) in kinds.iter().enumerate() {
        let trial_curves: Vec<MseCurve> = (0..trials)
            .map(|t| records_curve(&out[si * trials + t].records))
            .collect();
        curves.push((label.to_string(), average_curves(&trial_curves)));
        println!(
            "  {label:<14} final MSE {}",
            table_num(final_mse(&curves.last().unwrap().1), NumFmt::Sci(3))
        );
    }
    let labels: Vec<String> = curves.iter().map(|(l, _)| l.clone()).collect();
    let mut cols = vec![Col::auto("iter")];
    cols.extend(labels.iter().map(|l| Col::auto(l.as_str())));
    let mut sink = Sink::new(cols);
    for i in 0..curves[0].1.iters.len() {
        let mut row = vec![Value::Num(curves[0].1.iters[i] as f64)];
        row.extend(curves.iter().map(|(_, c)| Value::Num(c.mse[i])));
        sink.push_values(row);
    }
    sink.write(&ctx.out_dir, "fig13")?;

    // Transient iterations relative to the parallel baseline.
    println!("Fig. 13 — DmSGD convergence, n={n}, {trials} trial(s), {iters} iters");
    let par = &curves[0].1;
    for (label, curve) in curves.iter().skip(1) {
        let t = transient_iterations(&curve.mse, &par.mse, 1.5, 4)
            .map(|i| curve.iters[i] as i64)
            .unwrap_or(-1);
        println!("  {label:<14} transient iterations ≈ {t}");
    }
    println!("  csv: {}", ctx.csv_path("fig13").display());
    Ok(())
}
