//! Figure experiments (Figs. 1, 3, 4, 10, 11, 12, 13).

use super::logreg_runner::{
    average_curves, global_minimizer, paper_problem, run_logreg, LogRegRun, MseCurve,
};
use super::Ctx;
use crate::consensus;
use crate::coordinator::{transient_iterations, LrSchedule};
use crate::optim::AlgorithmKind;
use crate::spectral;
use crate::topology::TopologyKind;
use crate::util::csv::CsvWriter;
use crate::util::table::TextTable;
use anyhow::Result;

/// Fig. 1 — transient-iteration illustration: DSGD vs parallel SGD on
/// homogeneous logistic regression; the curves merge after the transient
/// phase.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let iters = ctx.scaled(6000);
    let problem = paper_problem(n, 2000, false, ctx.seed);
    let x_star = global_minimizer(&problem, 600);
    let lr = LrSchedule::HalveEvery { init: 0.1, every: iters / 5 };
    let mk_run = |topology, algorithm| LogRegRun {
        topology,
        algorithm,
        beta: 0.0,
        lr: lr.clone(),
        iters,
        batch: 8,
        record_every: 25,
        seed: ctx.seed,
    };
    let dec = run_logreg(&problem, &x_star, &mk_run(TopologyKind::Ring, AlgorithmKind::DSgd));
    let par = run_logreg(
        &problem,
        &x_star,
        &mk_run(TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
    );

    let mut csv = CsvWriter::new(&["iter", "dsgd_ring_mse", "parallel_mse"]);
    for i in 0..dec.iters.len() {
        csv.row_f64(&[dec.iters[i] as f64, dec.mse[i], par.mse[i]]);
    }
    csv.write(ctx.csv_path("fig1"))?;

    let t = transient_iterations(&dec.mse, &par.mse, 2.0, 4);
    println!("Fig. 1 — transient iterations (DSGD/ring vs parallel SGD, n={n})");
    match t {
        Some(idx) => println!(
            "  curves merge at recorded sample {idx} (≈ iteration {})",
            dec.iters[idx]
        ),
        None => println!("  curves did not merge within {iters} iterations"),
    }
    println!("  final MSE: dsgd={:.3e} parallel={:.3e}", dec.mse.last().unwrap(), par.mse.last().unwrap());
    println!("  csv: {}", ctx.csv_path("fig1").display());
    Ok(())
}

/// Fig. 3 — spectral gap `1 − ρ` vs n for ring / grid / static exp,
/// against the Proposition 1 line `2/(1+⌈log₂n⌉)`.
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let mut csv = CsvWriter::new(&["n", "ring", "grid", "static_exp", "prop1_theory"]);
    let mut max_dev_even = 0.0f64;
    for n in (4..=290).step_by(2) {
        let ring = spectral::topology_gap(TopologyKind::Ring, n, 0);
        let grid = spectral::topology_gap(TopologyKind::Grid2D, n, 0);
        let exp = spectral::topology_gap(TopologyKind::StaticExp, n, 0);
        let theory = 1.0 - spectral::static_exp_rho_bound(n);
        max_dev_even = max_dev_even.max((exp - theory).abs());
        csv.row_f64(&[n as f64, ring, grid, exp, theory]);
    }
    csv.write(ctx.csv_path("fig3"))?;
    println!("Fig. 3 — spectral gaps for n = 4..290 (even n)");
    println!("  max |measured − Prop.1| over even n: {max_dev_even:.2e} (paper: exact match)");
    let mut t = TextTable::new(&["n", "1-rho ring", "1-rho grid", "1-rho static exp", "theory"]);
    for n in [8usize, 32, 64, 128, 256] {
        t.row(vec![
            n.to_string(),
            format!("{:.4}", spectral::topology_gap(TopologyKind::Ring, n, 0)),
            format!("{:.4}", spectral::topology_gap(TopologyKind::Grid2D, n, 0)),
            format!("{:.4}", spectral::topology_gap(TopologyKind::StaticExp, n, 0)),
            format!("{:.4}", 1.0 - spectral::static_exp_rho_bound(n)),
        ]);
    }
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("fig3").display());
    Ok(())
}

fn residue_decay_csv(
    ctx: &Ctx,
    name: &str,
    series: &[(String, Vec<f64>)],
    iters: usize,
) -> Result<()> {
    let mut header: Vec<&str> = vec!["iter"];
    for (label, _) in series {
        header.push(label);
    }
    let mut csv = CsvWriter::new(&header);
    for k in 0..iters {
        let mut row = vec![k as f64 + 1.0];
        for (_, decay) in series {
            row.push(decay[k].max(1e-300));
        }
        csv.row_f64(&row);
    }
    csv.write(ctx.csv_path(name))?;
    Ok(())
}

/// Fig. 4 — consensus residue decay: one-peer exp hits exact averaging at
/// τ steps; static exp and random matching only decay asymptotically.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let n = 16;
    let iters = 24;
    let series: Vec<(String, Vec<f64>)> = [
        ("one_peer_exp", TopologyKind::OnePeerExp),
        ("static_exp", TopologyKind::StaticExp),
        ("random_match", TopologyKind::RandomMatch),
    ]
    .into_iter()
    .map(|(label, kind)| (label.to_string(), consensus::residue_decay(kind, n, iters, ctx.seed)))
    .collect();
    residue_decay_csv(ctx, "fig4", &series, iters)?;

    let tau = crate::topology::exponential::tau(n);
    println!("Fig. 4 — consensus residue decay, n = {n} (τ = {tau})");
    let mut t = TextTable::new(&["k", "one-peer exp", "static exp", "random match"]);
    for k in 0..10 {
        t.row(vec![
            (k + 1).to_string(),
            format!("{:.3e}", series[0].1[k]),
            format!("{:.3e}", series[1].1[k]),
            format!("{:.3e}", series[2].1[k]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "  one-peer residue at k=τ: {:.1e} (exact averaging, Lemma 1)",
        series[0].1[tau - 1]
    );
    println!("  csv: {}", ctx.csv_path("fig4").display());
    Ok(())
}

/// Fig. 10 — one-peer exponential residue decay when n is NOT a power of
/// two: asymptotic, not periodic-exact.
pub fn fig10(ctx: &Ctx) -> Result<()> {
    let sizes = [5usize, 6, 9, 12];
    let iters = 30;
    let series: Vec<(String, Vec<f64>)> = sizes
        .iter()
        .map(|&n| {
            (format!("n{n}"), consensus::residue_decay(TopologyKind::OnePeerExp, n, iters, ctx.seed))
        })
        .collect();
    residue_decay_csv(ctx, "fig10", &series, iters)?;
    println!("Fig. 10 — one-peer exp with n not a power of 2 (no exact averaging)");
    for (i, &n) in sizes.iter().enumerate() {
        let tau = crate::topology::exponential::tau(n);
        println!(
            "  n={n}: residue at k=τ={tau}: {:.2e} (>0), at k=30: {:.2e}",
            series[i].1[tau - 1],
            series[i].1[iters - 1]
        );
    }
    println!("  csv: {}", ctx.csv_path("fig10").display());
    Ok(())
}

/// Fig. 11 — one-peer sampling strategies: cyclic and random-permutation
/// achieve periodic exact averaging; uniform sampling only asymptotic.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    let n = 16;
    let iters = 24;
    let series: Vec<(String, Vec<f64>)> = [
        ("cyclic", TopologyKind::OnePeerExp),
        ("random_perm", TopologyKind::OnePeerExpPerm),
        ("uniform_sampling", TopologyKind::OnePeerExpUniform),
    ]
    .into_iter()
    .map(|(label, kind)| (label.to_string(), consensus::residue_decay(kind, n, iters, ctx.seed)))
    .collect();
    residue_decay_csv(ctx, "fig11", &series, iters)?;
    let tau = crate::topology::exponential::tau(n);
    println!("Fig. 11 — one-peer sampling strategies, n = {n}");
    println!("  residue at k=τ: cyclic={:.1e} perm={:.1e} uniform={:.1e}",
        series[0].1[tau - 1], series[1].1[tau - 1], series[2].1[tau - 1]);
    println!("  residue at k={iters}: uniform={:.1e} (asymptotic only)", series[2].1[iters - 1]);
    println!("  csv: {}", ctx.csv_path("fig11").display());
    Ok(())
}

/// Fig. 12 — `‖∏_{i<k} Ŵ^{(i)}‖₂²` for one-peer exp over different n:
/// drops to exactly 0 at k = τ(n).
pub fn fig12(ctx: &Ctx) -> Result<()> {
    let sizes = [8usize, 16, 32, 64];
    let iters = 8;
    let mut header = vec!["k".to_string()];
    header.extend(sizes.iter().map(|n| format!("n{n}")));
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::new(&href);
    let norms: Vec<Vec<f64>> = sizes
        .iter()
        .map(|&n| consensus::residue_product_norms(TopologyKind::OnePeerExp, n, iters, ctx.seed))
        .collect();
    for k in 0..iters {
        let mut row = vec![k as f64 + 1.0];
        for series in &norms {
            row.push(series[k]);
        }
        csv.row_f64(&row);
    }
    csv.write(ctx.csv_path("fig12"))?;
    println!("Fig. 12 — ‖∏ Ŵ^(i)‖₂² vs k for one-peer exponential");
    let mut t = TextTable::new(&["k", "n=8", "n=16", "n=32", "n=64"]);
    for k in 0..iters {
        t.row(
            std::iter::once((k + 1).to_string())
                .chain(norms.iter().map(|s| format!("{:.2e}", s[k])))
                .collect(),
        );
    }
    println!("{}", t.render());
    for (i, &n) in sizes.iter().enumerate() {
        let tau = crate::topology::exponential::tau(n);
        println!("  n={n}: zero at k=τ={tau}? {}", norms[i][tau - 1] < 1e-18);
    }
    println!("  csv: {}", ctx.csv_path("fig12").display());
    Ok(())
}

/// Fig. 13 — DmSGD convergence curves (MSE to x*) across topologies on
/// heterogeneous logistic regression: n=64, d=10, β=0.8, γ=0.2 halved
/// every 1000 iterations, averaged over trials.
pub fn fig13(ctx: &Ctx) -> Result<()> {
    let n = 64;
    let iters = ctx.scaled(6000);
    let trials = ctx.scaled(5);
    let samples = ctx.scaled(14_000).min(14_000).max(500);
    let kinds = [
        ("parallel", TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
        ("ring", TopologyKind::Ring, AlgorithmKind::DmSgd),
        ("grid", TopologyKind::Grid2D, AlgorithmKind::DmSgd),
        ("static_exp", TopologyKind::StaticExp, AlgorithmKind::DmSgd),
        ("one_peer_exp", TopologyKind::OnePeerExp, AlgorithmKind::DmSgd),
    ];
    let mut curves: Vec<(String, MseCurve)> = Vec::new();
    for (label, kind, algo) in kinds {
        let mut trials_curves = Vec::new();
        for trial in 0..trials {
            let problem = paper_problem(n, samples, true, ctx.seed + trial as u64);
            let x_star = global_minimizer(&problem, 500);
            let run = LogRegRun {
                topology: kind,
                algorithm: algo,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.2, every: 1000 },
                iters,
                batch: 8,
                record_every: 50,
                seed: ctx.seed + 1000 + trial as u64,
            };
            trials_curves.push(run_logreg(&problem, &x_star, &run));
        }
        curves.push((label.to_string(), average_curves(&trials_curves)));
        println!(
            "  {label:<14} final MSE {:.3e}",
            curves.last().unwrap().1.mse.last().unwrap()
        );
    }
    let mut header = vec!["iter".to_string()];
    header.extend(curves.iter().map(|(l, _)| l.clone()));
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::new(&href);
    for i in 0..curves[0].1.iters.len() {
        let mut row = vec![curves[0].1.iters[i] as f64];
        for (_, c) in &curves {
            row.push(c.mse[i]);
        }
        csv.row_f64(&row);
    }
    csv.write(ctx.csv_path("fig13"))?;

    // Transient iterations relative to the parallel baseline.
    println!("Fig. 13 — DmSGD convergence, n={n}, {trials} trial(s), {iters} iters");
    let par = &curves[0].1;
    for (label, curve) in curves.iter().skip(1) {
        let t = transient_iterations(&curve.mse, &par.mse, 1.5, 4)
            .map(|i| curve.iters[i] as i64)
            .unwrap_or(-1);
        println!("  {label:<14} transient iterations ≈ {t}");
    }
    println!("  csv: {}", ctx.csv_path("fig13").display());
    Ok(())
}
