//! Table experiments (Tables 1–10).

use super::classify_runner::{run_classify, table_dataset, ClassifySpec};
#[cfg(test)]
use super::classify_runner::simulated_imagenet_hours;
use super::logreg_runner::{global_minimizer, paper_problem, run_logreg, LogRegRun};
use super::Ctx;
use crate::coordinator::{transient_iterations, LrSchedule};
use crate::costmodel::analytic_degree;
use crate::data::classify::{generate, ClassifyConfig};
use crate::optim::AlgorithmKind;
use crate::spectral;
use crate::topology::exponential::tau;
use crate::topology::graphs;
use crate::topology::random;
use crate::topology::schedule::static_weights;
use crate::topology::weight::degree_spread;
use crate::topology::TopologyKind;
use crate::util::csv::CsvWriter;
use crate::util::table::TextTable;
use anyhow::Result;

/// Table 1 — per-iteration communication and transient-iteration
/// complexity summary for the six headline topologies (homogeneous data).
pub fn table1(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let mut t = TextTable::new(&[
        "topology", "per-iter comm", "1-rho (n=32)", "transient iters (theory)",
    ]);
    let mut csv = CsvWriter::new(&["topology", "degree", "gap", "transient_theory"]);
    for kind in TopologyKind::table1() {
        let deg = analytic_degree(kind, n);
        let (gap, gap_s) = if kind.is_time_varying() {
            (f64::NAN, "N.A. (time-varying)".to_string())
        } else {
            let g = spectral::topology_gap(kind, n, ctx.seed);
            (g, format!("{g:.4}"))
        };
        let theory = match kind {
            TopologyKind::Ring => "O(n^7)",
            TopologyKind::Grid2D => "O(n^5 log^2 n)",
            TopologyKind::HalfRandom => "O(n^3)",
            TopologyKind::RandomMatch => "N.A.",
            TopologyKind::StaticExp | TopologyKind::OnePeerExp => "O(n^3 log^2 n)",
            _ => "-",
        };
        t.row(vec![kind.name().into(), format!("{deg}"), gap_s, theory.into()]);
        csv.row(&[
            kind.name().into(),
            deg.to_string(),
            format!("{gap}"),
            theory.into(),
        ]);
    }
    csv.write(ctx.csv_path("table1"))?;
    println!("Table 1 — communication vs transient complexity (n = {n})");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table1").display());
    Ok(())
}

/// Table 2 — top-1 validation accuracy and (simulated) training time per
/// topology, n ∈ {{4, 8, 16, 32}}.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let data = table_dataset(ctx.seed);
    let sizes = [4usize, 8, 16, 32];
    let kinds = TopologyKind::table1();
    let iters = ctx.scaled(1500);
    let mut t = TextTable::new(&[
        "topology", "n=4 acc", "n=4 h", "n=8 acc", "n=8 h", "n=16 acc", "n=16 h", "n=32 acc",
        "n=32 h",
    ]);
    let mut csv = CsvWriter::new(&["topology", "nodes", "val_acc", "sim_hours", "final_loss"]);
    for kind in kinds {
        let mut row = vec![kind.name().to_string()];
        for &n in &sizes {
            let spec = ClassifySpec {
                nodes: n,
                topology: kind,
                algorithm: AlgorithmKind::DmSgd,
                hidden: 32,
                iters,
                batch: 32,
                // β = 0.9 ⇒ effective step γ/(1−β); 0.03 keeps it ≈ 0.3
                // (the Goyal-protocol momentum scaling).
                lr: 0.03,
                beta: 0.9,
                heterogeneous: false,
                seed: ctx.seed,
            };
            let r = run_classify(&data, &spec);
            row.push(format!("{:.2}", 100.0 * r.val_acc));
            row.push(format!("{:.1}", r.sim_hours));
            csv.row(&[
                kind.name().into(),
                n.to_string(),
                format!("{:.4}", r.val_acc),
                format!("{:.3}", r.sim_hours),
                format!("{:.4}", r.final_loss),
            ]);
        }
        t.row(row);
    }
    csv.write(ctx.csv_path("table2"))?;
    println!("Table 2 — DmSGD accuracy (%) and simulated 90-epoch hours per topology");
    println!("{}", t.render());
    println!("  (time column: α-β cost model with ResNet-50/ImageNet message sizes)");
    println!("  csv: {}", ctx.csv_path("table2").display());
    Ok(())
}

fn algo_grid_table(
    ctx: &Ctx,
    name: &str,
    title: &str,
    datasets: &[(&str, crate::data::classify::ClassifyData)],
    models: &[(&str, usize)],
    iters: usize,
) -> Result<()> {
    let algos = [
        AlgorithmKind::ParallelSgd,
        AlgorithmKind::VanillaDmSgd,
        AlgorithmKind::DmSgd,
        AlgorithmKind::QgDmSgd,
    ];
    let topologies = [TopologyKind::StaticExp, TopologyKind::OnePeerExp];
    let mut csv = CsvWriter::new(&[
        "dataset", "model", "algorithm", "topology", "val_acc", "sim_hours",
    ]);
    println!("{title}");
    for (dname, data) in datasets {
        for (mname, hidden) in models {
            let mut t = TextTable::new(&["algorithm", "static acc", "one-peer acc", "diff"]);
            for algo in algos {
                let mut accs = Vec::new();
                for topo in topologies {
                    // Parallel SGD ignores the topology; run it once under
                    // "static" and dash the one-peer column like the paper.
                    if algo == AlgorithmKind::ParallelSgd && topo == TopologyKind::OnePeerExp {
                        accs.push(f64::NAN);
                        continue;
                    }
                    let spec = ClassifySpec {
                        nodes: 8,
                        topology: topo,
                        algorithm: algo,
                        hidden: *hidden,
                        iters,
                        batch: 32,
                        lr: 0.03, // momentum-scaled (see table2)
                        beta: 0.9,
                        heterogeneous: false,
                        seed: ctx.seed,
                    };
                    let r = run_classify(data, &spec);
                    accs.push(r.val_acc);
                    csv.row(&[
                        dname.to_string(),
                        mname.to_string(),
                        algo.name().into(),
                        topo.name().into(),
                        format!("{:.4}", r.val_acc),
                        format!("{:.3}", r.sim_hours),
                    ]);
                }
                let diff = if accs[1].is_nan() {
                    "-".to_string()
                } else {
                    format!("{:+.2}", 100.0 * (accs[1] - accs[0]))
                };
                t.row(vec![
                    algo.name().into(),
                    format!("{:.2}", 100.0 * accs[0]),
                    if accs[1].is_nan() { "-".into() } else { format!("{:.2}", 100.0 * accs[1]) },
                    diff,
                ]);
            }
            println!("\n  dataset={dname} model={mname}");
            for line in t.render().lines() {
                println!("  {line}");
            }
        }
    }
    csv.write(ctx.csv_path(name))?;
    println!("  csv: {}", ctx.csv_path(name).display());
    Ok(())
}

/// Table 3 — static vs one-peer exponential across models and algorithms
/// (ImageNet/ResNet-MobileNet-EfficientNet substituted by MLP capacity
/// variants; see docs/DESIGN.md §Substitutions).
pub fn table3(ctx: &Ctx) -> Result<()> {
    let datasets = vec![("synth10", table_dataset(ctx.seed))];
    let models = [("mlp-64 (resnet50)", 64usize), ("mlp-16 (mobilenet)", 16), ("mlp-128 (efficientnet)", 128)];
    algo_grid_table(
        ctx,
        "table3",
        "Table 3 — models × algorithms over static/one-peer exponential graphs (n = 8)",
        &datasets,
        &models,
        ctx.scaled(1200),
    )
}

/// Table 4 — the second task family (object detection substituted by two
/// harder synthetic datasets; the claim under test is task-invariance of
/// static ≈ one-peer).
pub fn table4(ctx: &Ctx) -> Result<()> {
    let datasets = vec![
        (
            "synthVOC (easier)",
            generate(&ClassifyConfig {
                dim: 24,
                classes: 6,
                train_per_class: 500,
                val_per_class: 120,
                separation: 2.2,
                seed: ctx.seed + 40,
            }),
        ),
        (
            "synthCOCO (harder)",
            generate(&ClassifyConfig {
                dim: 48,
                classes: 16,
                train_per_class: 300,
                val_per_class: 80,
                separation: 1.6,
                seed: ctx.seed + 41,
            }),
        ),
    ];
    let models = [("mlp-48 (retinanet)", 48usize), ("mlp-96 (faster-rcnn)", 96)];
    algo_grid_table(
        ctx,
        "table4",
        "Table 4 — second task family × models × algorithms (n = 8)",
        &datasets,
        &models,
        ctx.scaled(1000),
    )
}

/// Table 5 — measured `1 − ρ` and max degree vs the theory rows of
/// Appendix A.3.2.
pub fn table5(ctx: &Ctx) -> Result<()> {
    let kinds = [
        TopologyKind::Ring,
        TopologyKind::Star,
        TopologyKind::Grid2D,
        TopologyKind::Torus2D,
        TopologyKind::HalfRandom,
        TopologyKind::RandomMatch,
        TopologyKind::StaticExp,
    ];
    let sizes = [16usize, 64, 144, 256];
    let mut csv = CsvWriter::new(&["topology", "n", "gap", "max_degree"]);
    let mut t = TextTable::new(&[
        "topology", "gap n=16", "gap n=64", "gap n=144", "gap n=256", "max deg (n=64)", "theory",
    ]);
    for kind in kinds {
        let mut row = vec![kind.name().to_string()];
        for &n in &sizes {
            if kind.is_time_varying() {
                row.push("N.A.".into());
                csv.row(&[kind.name().into(), n.to_string(), "nan".into(), "1".into()]);
                continue;
            }
            let gap = spectral::topology_gap(kind, n, ctx.seed);
            let deg = analytic_degree(kind, n);
            row.push(format!("{gap:.2e}"));
            csv.row(&[kind.name().into(), n.to_string(), format!("{gap}"), deg.to_string()]);
        }
        row.push(analytic_degree(kind, 64).to_string());
        row.push(spectral::table5_theory(kind, 64).0);
        t.row(row);
    }
    csv.write(ctx.csv_path("table5"))?;
    println!("Table 5 — spectral gap & max degree across topologies");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table5").display());
    Ok(())
}

/// Table 6 — exponential graphs vs ER / geometric random graphs:
/// connectivity, degree balance, expected communication.
pub fn table6(ctx: &Ctx) -> Result<()> {
    let n = 64;
    let trials = ctx.scaled(50);
    let mut connected_er = 0usize;
    let mut connected_geo = 0usize;
    let mut er_spread = (usize::MAX, 0usize);
    let mut geo_spread = (usize::MAX, 0usize);
    for trial in 0..trials {
        let seed = ctx.seed + trial as u64;
        let er = random::erdos_renyi_graph(n, 1.0, seed);
        let geo = random::geometric_graph(n, 1.0, seed);
        connected_er += er.is_connected() as usize;
        connected_geo += geo.is_connected() as usize;
        let ds = |g: &graphs::Graph| {
            let degs: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
            (*degs.iter().min().unwrap(), *degs.iter().max().unwrap())
        };
        let (lo, hi) = ds(&er);
        er_spread = (er_spread.0.min(lo), er_spread.1.max(hi));
        let (lo, hi) = ds(&geo);
        geo_spread = (geo_spread.0.min(lo), geo_spread.1.max(hi));
    }
    let exp_w = static_weights(TopologyKind::StaticExp, n, 0);
    let (exp_lo, exp_hi) = degree_spread(&exp_w);
    let mut t = TextTable::new(&[
        "graph", "per-iter comm", "connected (frac)", "degree min..max", "transient (theory)",
    ]);
    t.row(vec![
        "erdos_renyi".into(),
        format!("~{} (expected)", analytic_degree(TopologyKind::ErdosRenyi, n)),
        format!("{:.2}", connected_er as f64 / trials as f64),
        format!("{}..{}", er_spread.0, er_spread.1),
        "O(n^3) (if connected)".into(),
    ]);
    t.row(vec![
        "geometric".into(),
        format!("~{} (expected)", analytic_degree(TopologyKind::Geometric, n)),
        format!("{:.2}", connected_geo as f64 / trials as f64),
        format!("{}..{}", geo_spread.0, geo_spread.1),
        "O(n^5)".into(),
    ]);
    t.row(vec![
        "static_exp".into(),
        format!("{}", tau(n)),
        "1.00 (always)".into(),
        format!("{exp_lo}..{exp_hi} (balanced)"),
        "O(n^3 log^2 n)".into(),
    ]);
    t.row(vec![
        "one_peer_exp".into(),
        "1".into(),
        "exact avg each tau iters".into(),
        "1..1 (balanced)".into(),
        "O(n^3 log^2 n)".into(),
    ]);
    println!("Table 6 — exponential vs random graphs, n = {n}, {trials} trials");
    println!("{}", t.render());
    let mut csv = CsvWriter::new(&["graph", "connected_frac", "deg_min", "deg_max"]);
    csv.row(&[
        "erdos_renyi".into(),
        format!("{}", connected_er as f64 / trials as f64),
        er_spread.0.to_string(),
        er_spread.1.to_string(),
    ]);
    csv.row(&[
        "geometric".into(),
        format!("{}", connected_geo as f64 / trials as f64),
        geo_spread.0.to_string(),
        geo_spread.1.to_string(),
    ]);
    csv.row(&["static_exp".into(), "1".into(), exp_lo.to_string(), exp_hi.to_string()]);
    csv.write(ctx.csv_path("table6"))?;
    println!("  csv: {}", ctx.csv_path("table6").display());
    Ok(())
}

fn transient_table(ctx: &Ctx, name: &str, heterogeneous: bool) -> Result<()> {
    let sizes = [8usize, 16, 32];
    let kinds = [
        TopologyKind::Ring,
        TopologyKind::Grid2D,
        TopologyKind::StaticExp,
        TopologyKind::OnePeerExp,
    ];
    let iters = ctx.scaled(5000);
    let samples = ctx.scaled(4000).max(500);
    let mut t = TextTable::new(&["topology", "n=8", "n=16", "n=32"]);
    let mut csv = CsvWriter::new(&["topology", "nodes", "transient_iters"]);
    let mut measured: Vec<Vec<i64>> = Vec::new();
    for kind in kinds {
        let mut row = vec![kind.name().to_string()];
        let mut per_kind = Vec::new();
        for &n in &sizes {
            let problem = paper_problem(n, samples, heterogeneous, ctx.seed + n as u64);
            let x_star = global_minimizer(&problem, 500);
            let mk = |topology, algorithm| LogRegRun {
                topology,
                algorithm,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.1, every: iters / 4 },
                iters,
                batch: 8,
                record_every: 25,
                seed: ctx.seed + 7 * n as u64,
            };
            let dec = run_logreg(&problem, &x_star, &mk(kind, AlgorithmKind::DmSgd));
            let par = run_logreg(
                &problem,
                &x_star,
                &mk(TopologyKind::FullyConnected, AlgorithmKind::ParallelSgd),
            );
            let transient = transient_iterations(&dec.mse, &par.mse, 1.5, 4)
                .map(|i| dec.iters[i] as i64)
                .unwrap_or(-1);
            per_kind.push(transient);
            row.push(if transient < 0 { ">iters".into() } else { transient.to_string() });
            csv.row(&[kind.name().into(), n.to_string(), transient.to_string()]);
        }
        measured.push(per_kind);
        t.row(row);
    }
    csv.write(ctx.csv_path(name))?;
    let label = if heterogeneous { "heterogeneous" } else { "homogeneous" };
    println!("Table {} — measured transient iterations ({label} data)", &name[5..]);
    println!("{}", t.render());
    println!("  expected ordering per column: exp graphs < grid < ring (Tables 7/8)");
    println!("  csv: {}", ctx.csv_path(name).display());
    Ok(())
}

/// Table 7 — transient iterations, homogeneous data.
pub fn table7(ctx: &Ctx) -> Result<()> {
    transient_table(ctx, "table7", false)
}

/// Table 8 — transient iterations, heterogeneous data.
pub fn table8(ctx: &Ctx) -> Result<()> {
    transient_table(ctx, "table8", true)
}

/// Table 9 — exponential graphs when n is not a power of 2.
pub fn table9(ctx: &Ctx) -> Result<()> {
    let data = table_dataset(ctx.seed + 9);
    let sizes = [6usize, 9, 12, 15];
    let iters = ctx.scaled(1200);
    let mut t = TextTable::new(&["topology", "n=6", "n=9", "n=12", "n=15"]);
    let mut csv = CsvWriter::new(&["topology", "nodes", "val_acc"]);
    for kind in [TopologyKind::StaticExp, TopologyKind::OnePeerExp] {
        let mut row = vec![kind.name().to_string()];
        for &n in &sizes {
            let spec = ClassifySpec {
                nodes: n,
                topology: kind,
                algorithm: AlgorithmKind::DmSgd,
                hidden: 32,
                iters,
                batch: 32,
                lr: 0.03, // momentum-scaled (see table2)
                beta: 0.9,
                heterogeneous: false,
                seed: ctx.seed,
            };
            let r = run_classify(&data, &spec);
            row.push(format!("{:.2}", 100.0 * r.val_acc));
            csv.row(&[kind.name().into(), n.to_string(), format!("{:.4}", r.val_acc)]);
        }
        t.row(row);
    }
    csv.write(ctx.csv_path("table9"))?;
    println!("Table 9 — accuracy (%) with n not a power of 2 (DmSGD)");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table9").display());
    Ok(())
}

/// Table 10 — DSGD (β = 0) across topologies.
pub fn table10(ctx: &Ctx) -> Result<()> {
    let data = table_dataset(ctx.seed + 10);
    let sizes = [4usize, 8, 16];
    let iters = ctx.scaled(1200);
    let mut t = TextTable::new(&["topology", "n=4", "n=8", "n=16"]);
    let mut csv = CsvWriter::new(&["topology", "nodes", "val_acc"]);
    for kind in [TopologyKind::Ring, TopologyKind::StaticExp, TopologyKind::OnePeerExp] {
        let mut row = vec![kind.name().to_string()];
        for &n in &sizes {
            let spec = ClassifySpec {
                nodes: n,
                topology: kind,
                algorithm: AlgorithmKind::DSgd,
                hidden: 32,
                iters,
                batch: 32,
                lr: 0.1,
                beta: 0.0,
                heterogeneous: false,
                seed: ctx.seed,
            };
            let r = run_classify(&data, &spec);
            row.push(format!("{:.2}", 100.0 * r.val_acc));
            csv.row(&[kind.name().into(), n.to_string(), format!("{:.4}", r.val_acc)]);
        }
        t.row(row);
    }
    csv.write(ctx.csv_path("table10"))?;
    println!("Table 10 — DSGD (no momentum) accuracy (%)");
    println!("{}", t.render());
    println!("  (expect: lower than the DmSGD rows of Table 2 — momentum matters)");
    println!("  csv: {}", ctx.csv_path("table10").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_smoke_of_light_experiments() {
        // fig/table functions that are cheap enough for unit tests.
        let tmp = std::env::temp_dir().join(format!("expograph-exp-{}", std::process::id()));
        let ctx = Ctx { out_dir: tmp.clone(), scale: 0.02, seed: 3 };
        table1(&ctx).unwrap();
        table5(&ctx).unwrap();
        table6(&ctx).unwrap();
        assert!(tmp.join("table1.csv").exists());
        assert!(tmp.join("table5.csv").exists());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn simulated_hours_shrink_with_n_for_one_peer() {
        assert!(
            simulated_imagenet_hours(TopologyKind::OnePeerExp, 32)
                < simulated_imagenet_hours(TopologyKind::OnePeerExp, 8)
        );
    }
}
