//! Table experiments (Tables 1–10), declared as sweep grids: each table
//! is a typed cell list run by the lane-budgeted parallel scheduler
//! (docs/DESIGN.md §Sweep), with one `Record` schema streaming to
//! `results/<id>.csv` + `.json` and the paper-style pivot printed from
//! the grid-ordered results.

#[cfg(test)]
use super::classify_runner::simulated_imagenet_hours;
use super::classify_runner::{classify_record, run_classify_with, table_dataset, ClassifySpec};
use super::logreg_runner::{
    curve_records, global_minimizer, paper_problem, records_curve, run_logreg_with, LogRegRun,
};
use super::{Ctx, EXP_PAIR, GRID_ALGOS, TRANSIENT_KINDS};
use crate::coordinator::{transient_iterations, LrSchedule};
use crate::costmodel::analytic_degree;
use crate::data::classify::{generate, ClassifyConfig, ClassifyData};
use crate::data::logreg::LogRegProblem;
use crate::optim::AlgorithmKind;
use crate::spectral;
use crate::sweep::{table_num, Axis, CellResult, Col, Grid, NumFmt, Record, Sink};
use crate::topology::exponential::tau;
use crate::topology::graphs;
use crate::topology::random;
use crate::topology::schedule::static_weights;
use crate::topology::weight::degree_spread;
use crate::topology::TopologyKind;
use crate::util::table::TextTable;
use anyhow::Result;
use std::sync::OnceLock;

/// The single record of a single-record cell.
fn only(cell: &CellResult) -> &Record {
    &cell.records[0]
}

/// Table 1 — per-iteration communication and transient-iteration
/// complexity summary for the six headline topologies (homogeneous data).
pub fn table1(ctx: &Ctx) -> Result<()> {
    let n = 32;
    let seed = ctx.seed;
    let cells: Vec<TopologyKind> = TopologyKind::table1().to_vec();
    let out = ctx.runner("table1").run(
        &cells,
        |kind| format!("{kind:?} n={n}"),
        |&kind, _| {
            let gap = if kind.is_time_varying() {
                // Spectral gap of a single realization is not the right
                // object for time-varying schedules — rendered `-`/empty
                // by the sink's non-finite policy.
                f64::NAN
            } else {
                spectral::topology_gap(kind, n, seed)
            };
            let theory = match kind {
                TopologyKind::Ring => "O(n^7)",
                TopologyKind::Grid2D => "O(n^5 log^2 n)",
                TopologyKind::HalfRandom => "O(n^3)",
                TopologyKind::RandomMatch => "N.A.",
                TopologyKind::StaticExp | TopologyKind::OnePeerExp => "O(n^3 log^2 n)",
                _ => "-",
            };
            vec![Record::new()
                .with("topology", kind.name())
                .with("degree", analytic_degree(kind, n))
                .with("gap", gap)
                .with("transient_theory", theory)]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("degree"),
        Col::fixed("gap", 4),
        Col::auto("transient_theory"),
    ]);
    for cell in &out {
        sink.push(only(cell));
    }
    sink.write(&ctx.out_dir, "table1")?;
    let mut t = TextTable::new(&[
        "topology", "per-iter comm", "1-rho (n=32)", "transient iters (theory)",
    ]);
    for (cell, kind) in out.iter().zip(&cells) {
        let rec = only(cell);
        t.row(vec![
            rec.text("topology").to_string(),
            table_num(rec.num("degree"), NumFmt::Auto),
            if kind.is_time_varying() {
                "N.A. (time-varying)".to_string()
            } else {
                table_num(rec.num("gap"), NumFmt::Fixed(4))
            },
            rec.text("transient_theory").to_string(),
        ]);
    }
    println!("Table 1 — communication vs transient complexity (n = {n})");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table1").display());
    Ok(())
}

/// Table 2 — top-1 validation accuracy and (simulated) training time per
/// topology, n ∈ {{4, 8, 16, 32}}.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let seed = ctx.seed;
    // Generated lazily by the first cold cell; a fully warm (cached)
    // run never synthesizes the dataset.
    let data: OnceLock<ClassifyData> = OnceLock::new();
    let sizes = [4usize, 8, 16, 32];
    let kinds = TopologyKind::table1();
    let iters = ctx.scaled(1500);
    let grid = Grid::product2(
        &Axis::new("topology", kinds.to_vec()),
        &Axis::new("n", sizes.to_vec()),
        |&kind, &n| ClassifySpec {
            nodes: n,
            topology: kind,
            algorithm: AlgorithmKind::DmSgd,
            hidden: 32,
            iters,
            batch: 32,
            // β = 0.9 ⇒ effective step γ/(1−β); 0.03 keeps it ≈ 0.3
            // (the Goyal-protocol momentum scaling).
            lr: 0.03,
            beta: 0.9,
            heterogeneous: false,
            seed: ctx.seed,
        },
    );
    let out = ctx.runner("table2").run(
        grid.cells(),
        |spec| format!("{spec:?}"),
        |spec, cc| {
            let data = data.get_or_init(|| table_dataset(seed));
            vec![classify_record(spec, &run_classify_with(data, spec, Some(cc.lanes)))]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("nodes"),
        Col::auto("val_acc"),
        Col::auto("sim_hours"),
        Col::auto("final_loss"),
    ]);
    for cell in &out {
        sink.push(only(cell));
    }
    sink.write(&ctx.out_dir, "table2")?;

    let mut header = vec!["topology".to_string()];
    for &n in &sizes {
        header.push(format!("n={n} acc"));
        header.push(format!("n={n} h"));
    }
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (ki, kind) in kinds.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        for ni in 0..sizes.len() {
            let rec = only(&out[ki * sizes.len() + ni]);
            row.push(table_num(rec.num("val_acc"), NumFmt::Pct(2)));
            row.push(table_num(rec.num("sim_hours"), NumFmt::Fixed(1)));
        }
        t.row(row);
    }
    println!("Table 2 — DmSGD accuracy (%) and simulated 90-epoch hours per topology");
    println!("{}", t.render());
    println!("  (time column: α-β cost model with ResNet-50/ImageNet message sizes)");
    println!("  csv: {}", ctx.csv_path("table2").display());
    Ok(())
}

/// One cell of the Tables 3/4 grid: dataset × model × algorithm ×
/// topology at n = 8 (`di` indexes the experiment's dataset list).
#[derive(Clone, Debug)]
struct AlgoGridCell {
    dataset: String,
    di: usize,
    model: String,
    hidden: usize,
    algo: AlgorithmKind,
    topo: TopologyKind,
}

/// Shared Tables 3/4 runner: the static-vs-one-peer exponential pair
/// ([`EXP_PAIR`]) against the algorithm rows ([`GRID_ALGOS`]), over the
/// given datasets and model capacities. Parallel SGD ignores the
/// topology, so its one-peer cell is declared but never trained — its
/// NaN record renders as the paper's dashed column.
fn algo_grid_table(
    ctx: &Ctx,
    name: &str,
    title: &str,
    datasets: &[(&str, ClassifyData)],
    models: &[(&str, usize)],
    iters: usize,
) -> Result<()> {
    let mut cells = Vec::new();
    for (di, (dname, _)) in datasets.iter().enumerate() {
        for (mname, hidden) in models {
            for algo in GRID_ALGOS {
                for topo in EXP_PAIR {
                    cells.push(AlgoGridCell {
                        dataset: dname.to_string(),
                        di,
                        model: mname.to_string(),
                        hidden: *hidden,
                        algo,
                        topo,
                    });
                }
            }
        }
    }
    let out = ctx.runner(name).run(
        &cells,
        |cell| format!("{cell:?} iters={iters}"),
        |cell, cc| {
            if cell.algo == AlgorithmKind::ParallelSgd && cell.topo == TopologyKind::OnePeerExp {
                // Dashed in the paper: parallel SGD ran once under
                // "static"; the one-peer column has no measurement.
                return vec![Record::new()
                    .with("dataset", cell.dataset.as_str())
                    .with("model", cell.model.as_str())
                    .with("algorithm", cell.algo.name())
                    .with("topology", cell.topo.name())
                    .with("val_acc", f64::NAN)
                    .with("sim_hours", f64::NAN)];
            }
            let spec = ClassifySpec {
                nodes: 8,
                topology: cell.topo,
                algorithm: cell.algo,
                hidden: cell.hidden,
                iters,
                batch: 32,
                lr: 0.03, // momentum-scaled (see table2)
                beta: 0.9,
                heterogeneous: false,
                seed: ctx.seed,
            };
            let r = run_classify_with(&datasets[cell.di].1, &spec, Some(cc.lanes));
            vec![classify_record(&spec, &r)
                .with("dataset", cell.dataset.as_str())
                .with("model", cell.model.as_str())]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("dataset"),
        Col::auto("model"),
        Col::auto("algorithm"),
        Col::auto("topology"),
        Col::auto("val_acc"),
        Col::auto("sim_hours"),
    ]);
    for cell in &out {
        sink.push(only(cell));
    }
    sink.write(&ctx.out_dir, name)?;

    println!("{title}");
    for (di, (dname, _)) in datasets.iter().enumerate() {
        for (mi, (mname, _)) in models.iter().enumerate() {
            let mut t = TextTable::new(&["algorithm", "static acc", "one-peer acc", "diff"]);
            for (ai, algo) in GRID_ALGOS.iter().enumerate() {
                let base = ((di * models.len() + mi) * GRID_ALGOS.len() + ai) * EXP_PAIR.len();
                let stat = only(&out[base]).num("val_acc");
                let one = only(&out[base + 1]).num("val_acc");
                t.row(vec![
                    algo.name().into(),
                    table_num(stat, NumFmt::Pct(2)),
                    table_num(one, NumFmt::Pct(2)),
                    table_num(one - stat, NumFmt::PctSigned(2)),
                ]);
            }
            println!("\n  dataset={dname} model={mname}");
            for line in t.render().lines() {
                println!("  {line}");
            }
        }
    }
    println!("  csv: {}", ctx.csv_path(name).display());
    Ok(())
}

/// Table 3 — static vs one-peer exponential across models and algorithms
/// (ImageNet/ResNet-MobileNet-EfficientNet substituted by MLP capacity
/// variants; see docs/DESIGN.md §Substitutions).
pub fn table3(ctx: &Ctx) -> Result<()> {
    let datasets = vec![("synth10", table_dataset(ctx.seed))];
    let models = [("mlp-64 (resnet50)", 64usize), ("mlp-16 (mobilenet)", 16), ("mlp-128 (efficientnet)", 128)];
    algo_grid_table(
        ctx,
        "table3",
        "Table 3 — models × algorithms over static/one-peer exponential graphs (n = 8)",
        &datasets,
        &models,
        ctx.scaled(1200),
    )
}

/// Table 4 — the second task family (object detection substituted by two
/// harder synthetic datasets; the claim under test is task-invariance of
/// static ≈ one-peer).
pub fn table4(ctx: &Ctx) -> Result<()> {
    let datasets = vec![
        (
            "synthVOC (easier)",
            generate(&ClassifyConfig {
                dim: 24,
                classes: 6,
                train_per_class: 500,
                val_per_class: 120,
                separation: 2.2,
                seed: ctx.seed + 40,
            }),
        ),
        (
            "synthCOCO (harder)",
            generate(&ClassifyConfig {
                dim: 48,
                classes: 16,
                train_per_class: 300,
                val_per_class: 80,
                separation: 1.6,
                seed: ctx.seed + 41,
            }),
        ),
    ];
    let models = [("mlp-48 (retinanet)", 48usize), ("mlp-96 (faster-rcnn)", 96)];
    algo_grid_table(
        ctx,
        "table4",
        "Table 4 — second task family × models × algorithms (n = 8)",
        &datasets,
        &models,
        ctx.scaled(1000),
    )
}

/// Table 5 — measured `1 − ρ` and max degree vs the theory rows of
/// Appendix A.3.2.
pub fn table5(ctx: &Ctx) -> Result<()> {
    let kinds = [
        TopologyKind::Ring,
        TopologyKind::Star,
        TopologyKind::Grid2D,
        TopologyKind::Torus2D,
        TopologyKind::HalfRandom,
        TopologyKind::RandomMatch,
        TopologyKind::StaticExp,
    ];
    let sizes = [16usize, 64, 144, 256];
    let seed = ctx.seed;
    let grid = Grid::product2(
        &Axis::new("topology", kinds.to_vec()),
        &Axis::new("n", sizes.to_vec()),
        |&kind, &n| (kind, n),
    );
    let out = ctx.runner("table5").run(
        grid.cells(),
        |cell| format!("{cell:?}"),
        |&(kind, n), _| {
            let gap = if kind.is_time_varying() {
                f64::NAN
            } else {
                spectral::topology_gap(kind, n, seed)
            };
            vec![Record::new()
                .with("topology", kind.name())
                .with("n", n)
                .with("gap", gap)
                .with("max_degree", analytic_degree(kind, n))]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("n"),
        Col::auto("gap"),
        Col::auto("max_degree"),
    ]);
    for cell in &out {
        sink.push(only(cell));
    }
    sink.write(&ctx.out_dir, "table5")?;

    let mut header = vec!["topology".to_string()];
    header.extend(sizes.iter().map(|n| format!("gap n={n}")));
    header.push("max deg (n=64)".to_string());
    header.push("theory".to_string());
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (ki, kind) in kinds.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        for ni in 0..sizes.len() {
            row.push(table_num(only(&out[ki * sizes.len() + ni]).num("gap"), NumFmt::Sci(2)));
        }
        row.push(analytic_degree(*kind, 64).to_string());
        row.push(spectral::table5_theory(*kind, 64).0);
        t.row(row);
    }
    println!("Table 5 — spectral gap & max degree across topologies");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table5").display());
    Ok(())
}

/// Table 6 — exponential graphs vs ER / geometric random graphs:
/// connectivity, degree balance, expected communication. The grid is the
/// trial axis; connectivity fractions and degree spreads are aggregated
/// from the per-trial records.
pub fn table6(ctx: &Ctx) -> Result<()> {
    let n = 64;
    let trials = ctx.scaled(50);
    let seed = ctx.seed;
    let cells: Vec<usize> = (0..trials).collect();
    let out = ctx.runner("table6").run(
        &cells,
        |trial| format!("trial={trial} n={n}"),
        |&trial, _| {
            let trial_seed = seed + trial as u64;
            let er = random::erdos_renyi_graph(n, 1.0, trial_seed);
            let geo = random::geometric_graph(n, 1.0, trial_seed);
            let spread = |g: &graphs::Graph| {
                let degs: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
                (*degs.iter().min().unwrap(), *degs.iter().max().unwrap())
            };
            let (er_lo, er_hi) = spread(&er);
            let (geo_lo, geo_hi) = spread(&geo);
            vec![Record::new()
                .with("trial", trial)
                .with("er_connected", er.is_connected())
                .with("geo_connected", geo.is_connected())
                .with("er_deg_min", er_lo)
                .with("er_deg_max", er_hi)
                .with("geo_deg_min", geo_lo)
                .with("geo_deg_max", geo_hi)],
        },
    );
    let frac = |field: &str| {
        out.iter().map(|c| only(c).num(field)).sum::<f64>() / trials as f64
    };
    let agg = |field: &str, max: bool| {
        let it = out.iter().map(|c| only(c).num(field) as usize);
        if max { it.max().unwrap() } else { it.min().unwrap() }
    };
    let (er_lo, er_hi) = (agg("er_deg_min", false), agg("er_deg_max", true));
    let (geo_lo, geo_hi) = (agg("geo_deg_min", false), agg("geo_deg_max", true));
    let exp_w = static_weights(TopologyKind::StaticExp, n, 0);
    let (exp_lo, exp_hi) = degree_spread(&exp_w);

    let mut sink = Sink::new(vec![
        Col::auto("graph"),
        Col::auto("connected_frac"),
        Col::auto("deg_min"),
        Col::auto("deg_max"),
    ]);
    sink.push(
        &Record::new()
            .with("graph", "erdos_renyi")
            .with("connected_frac", frac("er_connected"))
            .with("deg_min", er_lo)
            .with("deg_max", er_hi),
    );
    sink.push(
        &Record::new()
            .with("graph", "geometric")
            .with("connected_frac", frac("geo_connected"))
            .with("deg_min", geo_lo)
            .with("deg_max", geo_hi),
    );
    sink.push(
        &Record::new()
            .with("graph", "static_exp")
            .with("connected_frac", 1.0)
            .with("deg_min", exp_lo)
            .with("deg_max", exp_hi),
    );
    sink.write(&ctx.out_dir, "table6")?;

    let mut t = TextTable::new(&[
        "graph", "per-iter comm", "connected (frac)", "degree min..max", "transient (theory)",
    ]);
    t.row(vec![
        "erdos_renyi".into(),
        format!("~{} (expected)", analytic_degree(TopologyKind::ErdosRenyi, n)),
        table_num(frac("er_connected"), NumFmt::Fixed(2)),
        format!("{er_lo}..{er_hi}"),
        "O(n^3) (if connected)".into(),
    ]);
    t.row(vec![
        "geometric".into(),
        format!("~{} (expected)", analytic_degree(TopologyKind::Geometric, n)),
        table_num(frac("geo_connected"), NumFmt::Fixed(2)),
        format!("{geo_lo}..{geo_hi}"),
        "O(n^5)".into(),
    ]);
    t.row(vec![
        "static_exp".into(),
        format!("{}", tau(n)),
        "1.00 (always)".into(),
        format!("{exp_lo}..{exp_hi} (balanced)"),
        "O(n^3 log^2 n)".into(),
    ]);
    t.row(vec![
        "one_peer_exp".into(),
        "1".into(),
        "exact avg each tau iters".into(),
        "1..1 (balanced)".into(),
        "O(n^3 log^2 n)".into(),
    ]);
    println!("Table 6 — exponential vs random graphs, n = {n}, {trials} trials");
    println!("{}", t.render());
    println!("  csv: {}", ctx.csv_path("table6").display());
    Ok(())
}

/// One Tables 7/8 grid cell: a full training run whose MSE curve is the
/// cell record stream (the parallel-SGD baseline is its own grid row,
/// trained **once per n** instead of once per topology × n as the old
/// hand-rolled loop did).
#[derive(Clone, Debug)]
struct TransientCell {
    kind: TopologyKind,
    algo: AlgorithmKind,
    n: usize,
}

fn transient_table(ctx: &Ctx, name: &str, heterogeneous: bool) -> Result<()> {
    let sizes = [8usize, 16, 32];
    let kinds = TRANSIENT_KINDS;
    let iters = ctx.scaled(5000);
    let samples = ctx.scaled(4000).max(500);
    let seed = ctx.seed;
    // A ragged grid: baseline rows first (one per n — trained once,
    // where the old loops re-ran it per topology), then the product.
    let mut cells: Vec<TransientCell> = sizes
        .iter()
        .map(|&n| TransientCell {
            kind: TopologyKind::FullyConnected,
            algo: AlgorithmKind::ParallelSgd,
            n,
        })
        .collect();
    for kind in kinds {
        for &n in &sizes {
            cells.push(TransientCell { kind, algo: AlgorithmKind::DmSgd, n });
        }
    }
    let grid = Grid::from_cells(cells);
    // One shared (problem, x*) per n — every topology row of a size
    // reuses it instead of re-solving the minimizer per cell; warm
    // (cached) sweeps never solve it at all.
    let setups: Vec<OnceLock<(LogRegProblem, Vec<f64>)>> =
        sizes.iter().map(|_| OnceLock::new()).collect();
    let out = ctx.runner(name).run(
        grid.cells(),
        |cell| format!("{cell:?} iters={iters} samples={samples} hetero={heterogeneous}"),
        |cell, cc| {
            let ni = sizes.iter().position(|&m| m == cell.n).expect("cell n is on the size axis");
            let (problem, x_star) = setups[ni].get_or_init(|| {
                let problem =
                    paper_problem(cell.n, samples, heterogeneous, seed + cell.n as u64);
                let x_star = global_minimizer(&problem, 500);
                (problem, x_star)
            });
            let run = LogRegRun {
                topology: cell.kind,
                algorithm: cell.algo,
                beta: 0.8,
                lr: LrSchedule::HalveEvery { init: 0.1, every: (iters / 4).max(1) },
                iters,
                batch: 8,
                record_every: 25,
                seed: seed + 7 * cell.n as u64,
            };
            curve_records(&run_logreg_with(problem, x_star, &run, Some(cc.lanes)))
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("nodes"),
        Col::auto("transient_iters"),
    ]);
    let mut header = vec!["topology".to_string()];
    header.extend(sizes.iter().map(|n| format!("n={n}")));
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (ki, kind) in kinds.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        for (ni, &n) in sizes.iter().enumerate() {
            let dec = records_curve(&out[sizes.len() + ki * sizes.len() + ni].records);
            let par = records_curve(&out[ni].records);
            let transient = transient_iterations(&dec.mse, &par.mse, 1.5, 4)
                .map(|i| dec.iters[i] as i64)
                .unwrap_or(-1);
            row.push(if transient < 0 { ">iters".into() } else { transient.to_string() });
            sink.push(
                &Record::new()
                    .with("topology", kind.name())
                    .with("nodes", n)
                    .with("transient_iters", transient),
            );
        }
        t.row(row);
    }
    sink.write(&ctx.out_dir, name)?;
    let label = if heterogeneous { "heterogeneous" } else { "homogeneous" };
    println!("Table {} — measured transient iterations ({label} data)", &name[5..]);
    println!("{}", t.render());
    println!("  expected ordering per column: exp graphs < grid < ring (Tables 7/8)");
    println!("  csv: {}", ctx.csv_path(name).display());
    Ok(())
}

/// Table 7 — transient iterations, homogeneous data.
pub fn table7(ctx: &Ctx) -> Result<()> {
    transient_table(ctx, "table7", false)
}

/// Table 8 — transient iterations, heterogeneous data.
pub fn table8(ctx: &Ctx) -> Result<()> {
    transient_table(ctx, "table8", true)
}

/// Shared Tables 9/10 declaration: a topology × n accuracy grid at one
/// algorithm, printed as the paper's pivot.
struct AccGrid<'a> {
    name: &'a str,
    title: &'a str,
    kinds: &'a [TopologyKind],
    sizes: &'a [usize],
    algorithm: AlgorithmKind,
    lr: f32,
    beta: f32,
    iters: usize,
}

fn acc_grid_table(
    ctx: &Ctx,
    make_data: impl Fn() -> ClassifyData + Sync,
    g: &AccGrid,
) -> Result<()> {
    // Generated lazily by the first cold cell; a fully warm (cached)
    // run never synthesizes the dataset.
    let data: OnceLock<ClassifyData> = OnceLock::new();
    let grid = Grid::product2(
        &Axis::new("topology", g.kinds.to_vec()),
        &Axis::new("n", g.sizes.to_vec()),
        |&kind, &n| ClassifySpec {
            nodes: n,
            topology: kind,
            algorithm: g.algorithm,
            hidden: 32,
            iters: g.iters,
            batch: 32,
            lr: g.lr,
            beta: g.beta,
            heterogeneous: false,
            seed: ctx.seed,
        },
    );
    let out = ctx.runner(g.name).run(
        grid.cells(),
        |spec| format!("{spec:?}"),
        |spec, cc| {
            let data = data.get_or_init(&make_data);
            vec![classify_record(spec, &run_classify_with(data, spec, Some(cc.lanes)))]
        },
    );
    let mut sink = Sink::new(vec![
        Col::auto("topology"),
        Col::auto("nodes"),
        Col::auto("val_acc"),
    ]);
    for cell in &out {
        sink.push(only(cell));
    }
    sink.write(&ctx.out_dir, g.name)?;

    let mut header = vec!["topology".to_string()];
    header.extend(g.sizes.iter().map(|n| format!("n={n}")));
    let mut t = TextTable::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (ki, kind) in g.kinds.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        for ni in 0..g.sizes.len() {
            row.push(table_num(
                only(&out[ki * g.sizes.len() + ni]).num("val_acc"),
                NumFmt::Pct(2),
            ));
        }
        t.row(row);
    }
    println!("{}", g.title);
    println!("{}", t.render());
    Ok(())
}

/// Table 9 — exponential graphs when n is not a power of 2.
pub fn table9(ctx: &Ctx) -> Result<()> {
    acc_grid_table(
        ctx,
        || table_dataset(ctx.seed + 9),
        &AccGrid {
            name: "table9",
            title: "Table 9 — accuracy (%) with n not a power of 2 (DmSGD)",
            kinds: &EXP_PAIR,
            sizes: &[6, 9, 12, 15],
            algorithm: AlgorithmKind::DmSgd,
            lr: 0.03, // momentum-scaled (see table2)
            beta: 0.9,
            iters: ctx.scaled(1200),
        },
    )?;
    println!("  csv: {}", ctx.csv_path("table9").display());
    Ok(())
}

/// Table 10 — DSGD (β = 0) across topologies.
pub fn table10(ctx: &Ctx) -> Result<()> {
    acc_grid_table(
        ctx,
        || table_dataset(ctx.seed + 10),
        &AccGrid {
            name: "table10",
            title: "Table 10 — DSGD (no momentum) accuracy (%)",
            kinds: &[TopologyKind::Ring, TopologyKind::StaticExp, TopologyKind::OnePeerExp],
            sizes: &[4, 8, 16],
            algorithm: AlgorithmKind::DSgd,
            lr: 0.1,
            beta: 0.0,
            iters: ctx.scaled(1200),
        },
    )?;
    println!("  (expect: lower than the DmSGD rows of Table 2 — momentum matters)");
    println!("  csv: {}", ctx.csv_path("table10").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SweepConfig;

    #[test]
    fn quick_smoke_of_light_experiments() {
        // fig/table functions that are cheap enough for unit tests.
        let tmp = std::env::temp_dir().join(format!("expograph-exp-{}", std::process::id()));
        let ctx = Ctx {
            out_dir: tmp.clone(),
            scale: 0.02,
            seed: 3,
            sweep: SweepConfig { jobs: 2, cache: true },
        };
        table1(&ctx).unwrap();
        table5(&ctx).unwrap();
        table6(&ctx).unwrap();
        assert!(tmp.join("table1.csv").exists());
        assert!(tmp.join("table1.json").exists());
        assert!(tmp.join("table5.csv").exists());
        assert!(tmp.join(".cache").is_dir(), "sweep cache populated");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn simulated_hours_shrink_with_n_for_one_peer() {
        assert!(
            simulated_imagenet_hours(TopologyKind::OnePeerExp, 32)
                < simulated_imagenet_hours(TopologyKind::OnePeerExp, 8)
        );
    }
}
