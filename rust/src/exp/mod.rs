//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see docs/DESIGN.md §Per-experiment index).
//!
//! Each experiment is a function `fn(ctx) -> Result<()>` that writes CSV
//! series to `results/` and prints a paper-style table. Invoke via
//! `expograph exp <id>` (or `expograph exp all`).

pub mod ablations;
pub mod classify_runner;
pub mod figures;
pub mod logreg_runner;
pub mod netsim_runner;
pub mod tables;

use anyhow::{bail, Result};
use std::path::PathBuf;

/// Shared experiment context.
pub struct Ctx {
    /// Output directory for CSVs (default `results/`).
    pub out_dir: PathBuf,
    /// Global scale factor for iteration counts / trials: 1.0 = paper-
    /// faithful protocol, lower = quick smoke run.
    pub scale: f64,
    pub seed: u64,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx { out_dir: PathBuf::from("results"), scale: 1.0, seed: 1 }
    }
}

impl Ctx {
    /// Scale an iteration/trial count (at least 1).
    pub fn scaled(&self, base: usize) -> usize {
        ((base as f64 * self.scale).round() as usize).max(1)
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }
}

/// All experiment ids, in run order.
pub const ALL: &[&str] = &[
    "fig3", "fig4", "fig10", "fig11", "fig12", "table1", "table5", "table6",
    "fig1", "fig13", "table7", "table8", "table2", "table3", "table4",
    "table9", "table10", "ablation_warmup", "ablation_sampling",
    "ablation_symmetric", "netsim",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "fig1" => figures::fig1(ctx),
        "fig3" => figures::fig3(ctx),
        "fig4" => figures::fig4(ctx),
        "fig10" => figures::fig10(ctx),
        "fig11" => figures::fig11(ctx),
        "fig12" => figures::fig12(ctx),
        "fig13" => figures::fig13(ctx),
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "table4" => tables::table4(ctx),
        "table5" => tables::table5(ctx),
        "table6" => tables::table6(ctx),
        "table7" => tables::table7(ctx),
        "table8" => tables::table8(ctx),
        "table9" => tables::table9(ctx),
        "table10" => tables::table10(ctx),
        "ablation_warmup" => ablations::ablation_warmup(ctx),
        "ablation_sampling" => ablations::ablation_sampling(ctx),
        "ablation_symmetric" => ablations::ablation_symmetric(ctx),
        "netsim" => {
            let base = crate::config::NetSimRunConfig::default();
            let cfg = crate::config::NetSimRunConfig {
                seed: ctx.seed,
                iters: ctx.scaled(base.iters),
                ..base
            };
            netsim_runner::netsim_table(&cfg, &ctx.out_dir).map(|_| ())
        }
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment id: {other} (see docs/DESIGN.md index)"),
    }
}
