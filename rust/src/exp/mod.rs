//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see docs/DESIGN.md §Per-experiment index).
//!
//! Every experiment is declared as a [`crate::sweep`] grid: a typed cell
//! list run by the lane-budgeted parallel scheduler (cache-aware, output
//! byte-identical for any `--jobs`), with results streamed through one
//! [`crate::sweep::Sink`] schema to `results/<id>.csv` + `.json` and a
//! paper-style text table. Invoke via `expograph exp <id>` (or
//! `expograph exp all`).

pub mod ablations;
pub mod async_runner;
pub mod classify_runner;
pub mod compression;
pub mod figures;
pub mod finite_time;
pub mod logreg_runner;
pub mod netsim_runner;
pub mod tables;

use crate::config::SweepConfig;
use crate::optim::AlgorithmKind;
use crate::sweep::Sweep;
use crate::topology::TopologyKind;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// The static-vs-one-peer exponential pair at the heart of Tables 3/4/9
/// (the paper's headline comparison).
pub const EXP_PAIR: [TopologyKind; 2] = [TopologyKind::StaticExp, TopologyKind::OnePeerExp];

/// The algorithm rows of the Tables 3/4 grids.
pub const GRID_ALGOS: [AlgorithmKind; 4] = [
    AlgorithmKind::ParallelSgd,
    AlgorithmKind::VanillaDmSgd,
    AlgorithmKind::DmSgd,
    AlgorithmKind::QgDmSgd,
];

/// The decentralized topology rows of Tables 7/8 and Fig. 13 (the
/// parallel all-reduce baseline rides along as an extra grid row).
pub const TRANSIENT_KINDS: [TopologyKind; 4] = [
    TopologyKind::Ring,
    TopologyKind::Grid2D,
    TopologyKind::StaticExp,
    TopologyKind::OnePeerExp,
];

/// Shared experiment context.
pub struct Ctx {
    /// Output directory for CSV/JSON (default `results/`).
    pub out_dir: PathBuf,
    /// Global scale factor for iteration counts / trials: 1.0 = paper-
    /// faithful protocol, lower = quick smoke run.
    pub scale: f64,
    pub seed: u64,
    /// Sweep scheduling: parallel jobs + on-disk result cache.
    pub sweep: SweepConfig,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            out_dir: PathBuf::from("results"),
            scale: 1.0,
            seed: 1,
            sweep: SweepConfig::default(),
        }
    }
}

impl Ctx {
    /// Scale an iteration/trial count (at least 1).
    pub fn scaled(&self, base: usize) -> usize {
        ((base as f64 * self.scale).round() as usize).max(1)
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }

    /// The configured sweep runner for one experiment id: seed + scale
    /// key the cache, jobs come from `--jobs`, and the cache lives under
    /// `<out_dir>/.cache/` when enabled.
    pub fn runner<'a>(&self, id: &'a str) -> Sweep<'a> {
        let sweep = Sweep::new(id, self.seed, self.scale).jobs(self.sweep.jobs);
        if self.sweep.cache {
            sweep.cache_under(&self.out_dir)
        } else {
            sweep
        }
    }
}

/// All experiment ids, in run order. This is the single source of truth
/// for dispatch **and** the `expograph exp` usage text.
pub const ALL: &[&str] = &[
    "fig3", "fig4", "fig10", "fig11", "fig12", "table1", "table5", "table6",
    "fig1", "fig13", "table7", "table8", "table2", "table3", "table4",
    "table9", "table10", "table_finite_time", "table_compression",
    "table_async", "ablation_warmup", "ablation_sampling",
    "ablation_symmetric", "netsim",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "fig1" => figures::fig1(ctx),
        "fig3" => figures::fig3(ctx),
        "fig4" => figures::fig4(ctx),
        "fig10" => figures::fig10(ctx),
        "fig11" => figures::fig11(ctx),
        "fig12" => figures::fig12(ctx),
        "fig13" => figures::fig13(ctx),
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "table4" => tables::table4(ctx),
        "table5" => tables::table5(ctx),
        "table6" => tables::table6(ctx),
        "table7" => tables::table7(ctx),
        "table8" => tables::table8(ctx),
        "table9" => tables::table9(ctx),
        "table10" => tables::table10(ctx),
        "table_finite_time" => finite_time::table_finite_time(ctx),
        "table_compression" => compression::table_compression(ctx),
        "table_async" => async_runner::table_async(ctx),
        "ablation_warmup" => ablations::ablation_warmup(ctx),
        "ablation_sampling" => ablations::ablation_sampling(ctx),
        "ablation_symmetric" => ablations::ablation_symmetric(ctx),
        "netsim" => {
            let base = crate::config::NetSimRunConfig::default();
            let cfg = crate::config::NetSimRunConfig {
                seed: ctx.seed,
                iters: ctx.scaled(base.iters),
                sweep: ctx.sweep,
                ..base
            };
            netsim_runner::netsim_table(&cfg, &ctx.out_dir).map(|_| ())
        }
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment id: {other} (see docs/DESIGN.md index)"),
    }
}
