//! `expograph` — CLI launcher for the decentralized-training framework.
//!
//! Subcommands:
//!   exp <id|all> [--scale S] [--seed N] [--out DIR]   regenerate paper tables/figures
//!   train [--config FILE] [key=value ...]             one decentralized training run
//!   netsim [--out DIR] [key=value ...]                simulated time-to-target sweep
//!   spectral <topology> <n>                           spectral gap of a topology
//!   info                                              artifact + runtime status

use anyhow::{bail, Context, Result};
use expograph::config::{parse_switch, parse_topology, NetSimRunConfig, RunConfig};
use expograph::coordinator::trainer::{TrainConfig, Trainer};
use expograph::coordinator::LrSchedule;
use expograph::costmodel::CostModel;
use expograph::exp::{self, Ctx};
use expograph::spectral;
use expograph::topology::family;
use expograph::topology::schedule::Schedule;
use expograph::topology::TopologyKind;

/// The `exp` id list, generated from [`exp::ALL`] (the dispatch table)
/// so the usage text can never omit an experiment again — wrapped to
/// readable lines.
fn exp_id_lines() -> String {
    exp::ALL
        .chunks(7)
        .map(|chunk| chunk.join(" "))
        .collect::<Vec<_>>()
        .join("\n           ")
}

/// The topology name list, generated from the open family registry so
/// the usage text tracks registered families automatically.
fn topology_name_lines() -> String {
    family::names()
        .chunks(6)
        .map(|chunk| chunk.join(" "))
        .collect::<Vec<_>>()
        .join("\n                  ")
}

fn usage() -> String {
    format!(
        "\
expograph — decentralized deep training over exponential graphs
  (reproduction of Ying et al., NeurIPS 2021)

USAGE:
  expograph exp <id|all> [--scale S] [--seed N] [--out DIR] [--jobs N] [--cache on|off]
      ids: {ids}
      --scale S   protocol scale factor (1.0 = paper protocol, 0.1 = smoke)
      --jobs N    parallel sweep cells (0 = auto, one per core; engine
                  lanes are budgeted so jobs x lanes <= cores)
      --cache     on|off: serve completed cells from <out>/.cache/ (default on)
  expograph train [--config FILE] [key=value ...]
      keys: nodes topology algorithm iters lr beta batch heterogeneous seed
            execution exec
      execution=sync | async:<staleness> — bounded-staleness gossip
      (async:0 is bitwise identical to sync)
      exec=ooo | waves — async executor: out-of-order ready batches
      (default) or the serial-wave reference (bitwise identical)
      topologies (from the registry — includes the finite-time
      arbitrary-n families):
                  {topologies}
  expograph netsim [--out DIR] [--large-n] [key=value ...]
      discrete-event network simulation: topology x n x scenario
      time-to-target table (writes netsim.json + netsim.csv)
      keys: nodes topologies scenarios iters dim tol msg_bytes compute seed
            jobs cache plan_only
      scenarios: clean straggler flaky lossy
      plan_only=on skips model training and runs scalar plan-only
      consensus (required for n > 65536); --large-n applies the preset
      n = 16384,65536,1048576 one-peer-exp clean+lossy plan-only sweep
      e.g.: nodes=8,64 topologies=ring,one_peer_exp scenarios=clean,lossy
  expograph spectral <topology> <n>
  expograph info
",
        ids = exp_id_lines(),
        topologies = topology_name_lines()
    )
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("exp") => cmd_exp(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("netsim") => cmd_netsim(&args[1..]),
        Some("spectral") => cmd_spectral(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help" | "-h" | "help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other}\n{}", usage()),
    }
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let mut ctx = Ctx::default();
    let mut id: Option<&str> = None;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--scale" => {
                ctx.scale = it.next().context("--scale needs a value")?.parse()?;
            }
            "--seed" => {
                ctx.seed = it.next().context("--seed needs a value")?.parse()?;
            }
            "--out" => {
                ctx.out_dir = it.next().context("--out needs a value")?.into();
            }
            "--jobs" => {
                ctx.sweep.jobs = it.next().context("--jobs needs a value")?.parse()?;
            }
            "--cache" => {
                ctx.sweep.cache = parse_switch(it.next().context("--cache needs on|off")?)?;
            }
            other if id.is_none() => id = Some(other),
            other => bail!("unexpected argument {other}"),
        }
    }
    let id = id.context("exp requires an experiment id (or 'all')")?;
    let t0 = std::time::Instant::now();
    exp::run(id, &ctx)?;
    eprintln!("[exp {id}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        if arg == "--config" {
            let path = it.next().context("--config needs a file")?;
            cfg = RunConfig::load(path)?;
        } else if let Some((k, v)) = arg.split_once('=') {
            cfg.set(k, v)?;
        } else {
            bail!("expected key=value, got {arg}");
        }
    }
    cfg.validate()?;
    println!("config: {cfg:?}");

    // Logistic-regression workload (the Appendix D.5 protocol) — the
    // fastest end-to-end demonstration of the full stack. For the deep
    // model see examples/transformer_e2e.rs.
    let problem = expograph::exp::logreg_runner::paper_problem(
        cfg.nodes,
        2000,
        cfg.heterogeneous,
        cfg.seed,
    );
    let provider =
        expograph::exp::logreg_runner::LogRegProvider { problem: &problem, batch: cfg.batch };
    let opt = cfg.algorithm.build(cfg.nodes, &vec![0.0f32; problem.d], cfg.beta);
    let mut trainer = Trainer::new(
        Schedule::from_family(cfg.topology, cfg.nodes, cfg.seed),
        opt,
        &provider,
        TrainConfig {
            iters: cfg.iters,
            lr: LrSchedule::HalveEvery { init: cfg.lr, every: (cfg.iters / 4).max(1) },
            warmup_allreduce: cfg.warmup_allreduce,
            record_every: (cfg.iters / 20).max(1),
            parallel_grads: false,
            lanes: None,
            seed: cfg.seed,
            msg_bytes: None,
            cost: Some(CostModel::paper_default(0.01)),
            execution: cfg.execution,
            async_exec: cfg.exec,
            ..Default::default()
        },
    );
    let hist = trainer.run_with(|k, params| {
        println!(
            "  iter {k:>6}  consensus {:.3e}",
            params.consensus_distance()
        );
    });
    println!(
        "final: loss {:.4}  sim_time {:.2}s  consensus {:.3e}",
        hist.loss.last().copied().unwrap_or(f64::NAN),
        hist.sim_time,
        hist.final_consensus()
    );
    Ok(())
}

fn cmd_netsim(args: &[String]) -> Result<()> {
    let mut cfg = NetSimRunConfig::default();
    let mut out = std::path::PathBuf::from("results");
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == "--out" {
            out = it.next().context("--out needs a value")?.into();
        } else if arg == "--large-n" {
            // Preset first, key=value after it can still override knobs.
            cfg.apply_large_n_preset();
        } else if let Some((k, v)) = arg.split_once('=') {
            cfg.set(k, v)?;
        } else {
            bail!("expected key=value, --large-n, or --out DIR, got {arg}");
        }
    }
    let t0 = std::time::Instant::now();
    expograph::exp::netsim_runner::netsim_table(&cfg, &out)?;
    eprintln!("[netsim] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_spectral(args: &[String]) -> Result<()> {
    let topo = parse_topology(args.first().context("spectral <topology> <n>")?)?;
    let n: usize = args.get(1).context("spectral <topology> <n>")?.parse()?;
    let Some(kind) = topo.kind() else {
        // Open-registry family (no closed-enum kind): report the
        // finite-time exact-averaging stats the family declares.
        println!("topology={topo} n={n} (open-registry family)");
        match topo.exact_period(n) {
            Some(tau) => {
                println!("  exact-averaging period tau = {tau}");
                println!(
                    "  residue after tau steps: {:.3e}",
                    expograph::consensus::schedule_period_error(topo, n, tau, 0)
                );
            }
            None => println!("  no finite-time exact-averaging period declared at n={n}"),
        }
        println!("  analytic per-iteration degree: {}", topo.analytic_degree(n));
        return Ok(());
    };
    if kind.is_time_varying() {
        println!("{kind} is time-varying; per-realization ‖Ŵ‖₂ and exact-averaging stats:");
        println!("  rho_max = {:.6}", expograph::consensus::one_peer_rho_max(n));
        println!(
            "  residue after tau={} steps: {:.3e}",
            expograph::topology::exponential::tau(n),
            expograph::consensus::one_peer_period_error(n, 0)
        );
        return Ok(());
    }
    let w = expograph::topology::schedule::static_weights(kind, n, 1);
    let (rho, method) = spectral::rho_with_method(&w);
    println!("topology={kind} n={n}");
    println!("  rho = {rho:.6}  (method: {method:?})");
    if let Some(closed) = topo.analytic_rho(n) {
        println!("  closed form rho = {closed:.6} (registry)");
    }
    println!("  spectral gap 1-rho = {:.6}", 1.0 - rho);
    if kind == TopologyKind::StaticExp {
        println!(
            "  Proposition 1 bound: rho <= {:.6} (equality iff n even)",
            spectral::static_exp_rho_bound(n)
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("expograph {}", env!("CARGO_PKG_VERSION"));
    let dir = expograph::runtime::Manifest::default_dir();
    println!("artifacts dir: {}", dir.display());
    match expograph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("manifest: {} artifacts", m.artifacts.len());
            for a in &m.artifacts {
                let ins: Vec<String> =
                    a.inputs.iter().map(|i| format!("{:?}", i.shape)).collect();
                println!("  {:<26} inputs {}", a.name, ins.join(" "));
            }
            match expograph::runtime::Runtime::new(&dir) {
                Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                Err(e) => println!("PJRT unavailable: {e}"),
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    Ok(())
}
