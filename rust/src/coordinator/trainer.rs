//! The training loop: topology schedule × optimizer × gradient provider.
//!
//! Mirrors the paper's experimental protocol:
//! * optional warm-up all-reduce so the first `τ` iterations start from
//!   exact consensus (Corollary 3),
//! * per-iteration: borrow this iteration's cached [`MixingPlan`] from
//!   the schedule (`O(1)` amortized, zero allocation for deterministic
//!   topologies — see docs/DESIGN.md §Plan cache), compute per-node
//!   stochastic gradients, apply the optimizer's fused shard kernel,
//! * metrics: mean training loss, consensus distance, simulated
//!   communication time from the [`crate::costmodel`].
//!
//! All O(nP) work — gradients, the optimizer step, and the consensus
//! probe — is driven through one persistent [`Engine`] pool created at
//! the top of [`Trainer::run_with`]: **zero thread spawns per
//! iteration** (docs/DESIGN.md §Engine). Results are bitwise-identical
//! for any lane count.

use super::schedule_lr::LrSchedule;
use super::state::StackedParams;
use crate::compress::{CompressorKind, GossipCompression};
use crate::costmodel::CostModel;
use crate::engine::{auto_lanes, Engine};
use crate::netsim::NetSim;
use crate::optim::{Optimizer, StepScratch};
use crate::topology::schedule::Schedule;
use crate::util::rng::Pcg;

/// Computes per-node stochastic gradients. Implementations exist for the
/// Rust-native models and for the PJRT-artifact path; both present the
/// same flat-vector contract.
pub trait GradProvider: Sync {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Number of nodes.
    fn nodes(&self) -> usize;

    /// Compute node `i`'s stochastic gradient at `params` into `out`;
    /// returns the minibatch loss. `iter` and `seed` determinize the
    /// minibatch choice.
    fn grad(&self, node: usize, params: &[f32], iter: usize, seed: u64, out: &mut [f32]) -> f32;
}

/// How the fleet advances through iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecutionMode {
    /// Bulk-synchronous: every node waits on the slowest each round.
    #[default]
    Sync,
    /// Bounded-staleness gossip (docs/DESIGN.md §Async runtime): nodes
    /// advance on local clocks and pull whichever committed payload
    /// version of each partner is ready, at most `tau` iterations
    /// behind. `tau = 0` forces fresh payloads everywhere and is
    /// bitwise-identical to [`ExecutionMode::Sync`] (pinned by
    /// `tests/engine_determinism.rs`).
    Async { tau: usize },
}

impl ExecutionMode {
    /// Parse `"sync"` / `"async:<tau>"` (the config/CLI surface).
    pub fn parse(s: &str) -> Option<ExecutionMode> {
        if s == "sync" {
            return Some(ExecutionMode::Sync);
        }
        if let Some(t) = s.strip_prefix("async:") {
            return t.parse::<usize>().ok().map(|tau| ExecutionMode::Async { tau });
        }
        None
    }

    /// Round-trippable name (`parse(label()) == self`).
    pub fn label(&self) -> String {
        match self {
            ExecutionMode::Sync => "sync".into(),
            ExecutionMode::Async { tau } => format!("async:{tau}"),
        }
    }
}

impl std::fmt::Display for ExecutionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Which executor drives `execution=async:<τ>` (docs/DESIGN.md §Async
/// runtime). Both produce bitwise-identical trajectories (pinned by
/// `tests/engine_determinism.rs`); they differ only in dispatch economy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AsyncExec {
    /// The serial-wave reference: every wave pays two engine barrier
    /// crossings, fleet-wide. Kept as the escape hatch and the pinning
    /// oracle (`run_waves_reference`), mirroring `fused_probe`.
    Waves,
    /// Out-of-order ready batches over the engine's work queue:
    /// amortized O(1) dispatches per ready batch (default).
    #[default]
    Ooo,
}

impl AsyncExec {
    /// Parse `"waves"` / `"ooo"` (the config/CLI surface).
    pub fn parse(s: &str) -> Option<AsyncExec> {
        match s {
            "waves" => Some(AsyncExec::Waves),
            "ooo" => Some(AsyncExec::Ooo),
            _ => None,
        }
    }

    /// Round-trippable name (`parse(label()) == self`).
    pub fn label(&self) -> &'static str {
        match self {
            AsyncExec::Waves => "waves",
            AsyncExec::Ooo => "ooo",
        }
    }
}

impl std::fmt::Display for AsyncExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub iters: usize,
    pub lr: LrSchedule,
    /// Warm-up all-reduce before training (Corollary 3).
    pub warmup_allreduce: bool,
    /// Record metrics every `record_every` iterations (loss is recorded
    /// every iteration; consensus distance is O(nP) so it is throttled).
    pub record_every: usize,
    /// Force a multi-lane engine even for small states (gradient compute
    /// may dominate long before the mixing threshold). With `false` the
    /// lane count is sized automatically from `n·P`.
    pub parallel_grads: bool,
    /// Explicit engine lane count (overrides `parallel_grads` and the
    /// automatic sizing). `Some(1)` pins the single-threaded path —
    /// bitwise-identical to any other lane count by construction.
    pub lanes: Option<usize>,
    pub seed: u64,
    /// Message bytes per gossip round (for the simulated clock); default
    /// = 4·P.
    pub msg_bytes: Option<f64>,
    /// Cost model for the simulated communication clock.
    pub cost: Option<CostModel>,
    /// Gossip payload compressor (docs/DESIGN.md §Compression). Every
    /// wire-size computation — netsim ledger and closed-form cost alike —
    /// prices gossip rounds at `compressor.wire_bytes(msg_bytes)`;
    /// all-reduce rounds stay dense (the parallel baseline does not
    /// compress). `Identity` is byte-for-byte the pre-compression path.
    pub compressor: CompressorKind,
    /// Execution mode: bulk-synchronous (default) or bounded-staleness
    /// async gossip (docs/DESIGN.md §Async runtime).
    pub execution: ExecutionMode,
    /// Which async executor drives `execution=async:<τ>`: out-of-order
    /// ready batches (default) or the serial-wave reference. Ignored
    /// under [`ExecutionMode::Sync`].
    pub async_exec: AsyncExec,
    /// Fold the consensus probe of record iterations into the *next*
    /// iteration's gradient dispatch ([`Engine::compute_grads_probed`]),
    /// cutting a record round's barrier crossings from 3 to 2. The
    /// parameters a deferred probe reads are untouched between the two
    /// points, so every recorded value is bitwise identical; `false`
    /// keeps the standalone probe dispatch.
    pub fused_probe: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 1000,
            lr: LrSchedule::Const(0.05),
            warmup_allreduce: false,
            record_every: 10,
            parallel_grads: false,
            lanes: None,
            seed: 0,
            msg_bytes: None,
            cost: None,
            compressor: CompressorKind::Identity,
            execution: ExecutionMode::Sync,
            async_exec: AsyncExec::Ooo,
            fused_probe: true,
        }
    }
}

/// Recorded training curves.
#[derive(Clone, Debug, Default)]
pub struct TrainingHistory {
    /// Mean (across nodes) minibatch loss per iteration.
    pub loss: Vec<f64>,
    /// (iter, consensus distance) samples.
    pub consensus: Vec<(usize, f64)>,
    /// Simulated wall-clock seconds accumulated over iterations (compute +
    /// non-overlapped communication), if a cost model or [`NetSim`] was
    /// supplied.
    pub sim_time: f64,
    /// Per-iteration simulated seconds (empty unless a cost model or
    /// [`NetSim`] was supplied) — `sim_time` is its running total.
    pub round_times: Vec<f64>,
    /// Per-iteration bytes put on the wire (empty unless a cost model or
    /// [`NetSim`] was supplied). Sourced from the netsim ledger when one
    /// is attached, else from the same closed-form slot count the cost
    /// model charges — both priced through
    /// [`CompressorKind::wire_bytes`] for gossip rounds.
    pub round_bytes: Vec<f64>,
    /// Learning rate trace at `record_every` granularity.
    pub lr: Vec<(usize, f32)>,
    /// Total engine broadcast dispatches (barrier crossings) over the
    /// run — the denominator of steps-per-crossing in `bench_async`.
    pub dispatches: u64,
}

impl TrainingHistory {
    /// Last recorded consensus distance, `NaN` when none was recorded
    /// (`iters == 0` runs record no samples) — a NaN-safe summary for
    /// callers that previously unwrapped `consensus.last()`.
    pub fn final_consensus(&self) -> f64 {
        self.consensus.last().map(|&(_, d)| d).unwrap_or(f64::NAN)
    }
}

/// Orchestrates one training run.
pub struct Trainer<'a> {
    pub topology: Schedule,
    pub optimizer: Box<dyn Optimizer>,
    pub provider: &'a dyn GradProvider,
    pub cfg: TrainConfig,
    /// Optional network simulator (docs/DESIGN.md §NetSim). When set,
    /// every iteration is priced by a discrete-event simulation of the
    /// exchanges instead of the closed-form cost model, and a round
    /// whose faults fired mixes through the *degraded* plan the
    /// simulator returns. With faults disabled the trajectory is
    /// bitwise identical to the plain path — only the clock changes.
    pub netsim: Option<NetSim>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        topology: Schedule,
        optimizer: Box<dyn Optimizer>,
        provider: &'a dyn GradProvider,
        cfg: TrainConfig,
    ) -> Self {
        Trainer { topology, optimizer, provider, cfg, netsim: None }
    }

    /// Attach a network simulator (builder style).
    pub fn with_netsim(mut self, sim: NetSim) -> Self {
        self.netsim = Some(sim);
        self
    }

    /// Run to completion, calling `probe(iter, params)` every
    /// `record_every` iterations (and once at the end).
    pub fn run_with(
        &mut self,
        mut probe: impl FnMut(usize, &StackedParams),
    ) -> TrainingHistory {
        if let ExecutionMode::Async { tau } = self.cfg.execution {
            return super::async_exec::run_async(self, tau, &mut probe);
        }
        let n = self.provider.nodes();
        let dim = self.provider.dim();
        assert_eq!(self.optimizer.params().n, n, "optimizer/provider node mismatch");
        assert_eq!(self.optimizer.params().dim, dim, "optimizer/provider dim mismatch");
        let mut grads = StackedParams::zeros(n, dim);
        let mut losses = vec![0.0f64; n];
        let mut scratch = StepScratch::default();
        let mut history = TrainingHistory::default();
        let msg_bytes = self.cfg.msg_bytes.unwrap_or(4.0 * dim as f64);
        // Single pricing point for compressed gossip payloads: both the
        // netsim ledger and the closed-form cost model see this number,
        // so the two wire ledgers cannot drift apart.
        let gossip_bytes = self.cfg.compressor.wire_bytes(msg_bytes);
        let mut gz = GossipCompression::new(self.cfg.compressor, self.cfg.seed);

        // The persistent worker pool: created once here, reused by every
        // iteration's gradients, optimizer step, and consensus probe —
        // zero thread spawns inside the loop.
        let lanes = self.cfg.lanes.unwrap_or_else(|| {
            if self.cfg.parallel_grads {
                std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
            } else {
                auto_lanes(n, n * dim)
            }
        });
        let engine = Engine::new(lanes.clamp(1, n.max(1)));

        if self.cfg.warmup_allreduce {
            self.optimizer.params_mut().allreduce();
        }

        // Deferred consensus probe (`cfg.fused_probe`): a record
        // iteration's probe rides in the *next* iteration's gradient
        // dispatch — the parameters are untouched in between, so the
        // recorded values are bitwise identical at one less barrier
        // crossing per record round.
        let mut pending: Option<(usize, f32)> = None;

        for k in 0..self.cfg.iters {
            // Borrowed, cached sparse plan: no dense matrix, no O(n²)
            // scan, no allocation for deterministic topologies.
            let plan = self.topology.plan_at(k);
            let lr = self.cfg.lr.at(k);

            // Per-node stochastic gradients, sharded over the pool. The
            // per-node losses land in node order, so the mean below is
            // lane-count-independent bit for bit.
            if let Some((pk, plr)) = pending.take() {
                let d = engine.compute_grads_probed(
                    self.provider,
                    self.optimizer.params(),
                    &mut grads,
                    &mut losses,
                    k,
                    self.cfg.seed,
                );
                history.consensus.push((pk, d));
                history.lr.push((pk, plr));
                probe(pk, self.optimizer.params());
            } else {
                engine.compute_grads(
                    self.provider,
                    self.optimizer.params(),
                    &mut grads,
                    &mut losses,
                    k,
                    self.cfg.seed,
                );
            }
            let mean_loss: f64 = losses.iter().sum::<f64>() / n as f64;

            // Network simulation (when attached): price the round by
            // discrete events and pick up the degraded plan if a fault
            // fired. `degraded = None` keeps the borrowed plan, so
            // fault-free instrumented runs stay bitwise identical.
            let parallel = self.optimizer.is_parallel();
            let outcome = self.netsim.as_mut().map(|sim| {
                if parallel {
                    sim.simulate_allreduce(k, n, msg_bytes)
                } else {
                    sim.simulate_round(k, plan, gossip_bytes)
                }
            });
            let step_plan = outcome
                .as_ref()
                .and_then(|o| o.degraded.as_ref())
                .unwrap_or(plan);

            // Fused shard-local optimizer step on the same pool. With the
            // identity compressor this delegates to the plain dense
            // kernels (byte-identical to the pre-compression path).
            self.optimizer
                .step_engine_compressed(&engine, step_plan, &grads, lr, &mut scratch, &mut gz);

            history.loss.push(mean_loss);
            if let Some(outcome) = &outcome {
                let overlap = self.netsim.as_ref().map(|s| s.cost.overlap).unwrap_or(0.0);
                let t = outcome.iteration_time(overlap);
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(outcome.bytes_on_wire);
            } else if let Some(cost) = &self.cfg.cost {
                let (comm, bytes) = if parallel {
                    // Ring all-reduce: 2(n−1) phases of n chunks of
                    // msg_bytes/n — total 2(n−1)·msg_bytes on the wire.
                    (
                        cost.allreduce_time(n, msg_bytes),
                        2.0 * (n as f64 - 1.0) * msg_bytes,
                    )
                } else {
                    // Same directed-slot count netsim bills in the clean
                    // case: one compressed payload per both-online pull.
                    let slots: usize = (0..n).map(|u| step_plan.partners(u).len()).sum();
                    (
                        cost.partial_averaging_time(plan, gossip_bytes),
                        slots as f64 * gossip_bytes,
                    )
                };
                let hidden = cost.compute.min(comm) * cost.overlap;
                let t = cost.compute + comm - hidden;
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(bytes);
            }
            if k % self.cfg.record_every == 0 || k + 1 == self.cfg.iters {
                if self.cfg.fused_probe && k + 1 != self.cfg.iters {
                    pending = Some((k, lr));
                } else {
                    history
                        .consensus
                        .push((k, engine.consensus_distance(self.optimizer.params())));
                    history.lr.push((k, lr));
                    probe(k, self.optimizer.params());
                }
            }
        }
        history.dispatches = engine.dispatches();
        history
    }

    /// Run without a probe.
    pub fn run(&mut self) -> TrainingHistory {
        self.run_with(|_, _| {})
    }
}

/// A trivial quadratic provider used in tests and benches:
/// `f_i(x) = ½‖x − c_i‖²` with optional gradient noise.
pub struct QuadraticProvider {
    pub targets: StackedParams,
    pub noise: f32,
}

impl QuadraticProvider {
    /// Heterogeneous: each node has its own random target `c_i`.
    pub fn random(n: usize, dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Pcg::seeded(seed);
        let mut targets = StackedParams::zeros(n, dim);
        for v in targets.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        QuadraticProvider { targets, noise }
    }

    /// Homogeneous: all nodes share one target (optimal loss is the noise
    /// floor — convenient for "loss goes to ~0" assertions).
    pub fn shared(n: usize, dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Pcg::seeded(seed);
        let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        QuadraticProvider { targets: StackedParams::replicate(n, &row), noise }
    }
}

impl GradProvider for QuadraticProvider {
    fn dim(&self) -> usize {
        self.targets.dim
    }

    fn nodes(&self) -> usize {
        self.targets.n
    }

    fn grad(&self, node: usize, params: &[f32], iter: usize, seed: u64, out: &mut [f32]) -> f32 {
        // Parenthesized on purpose: `<<` binds tighter than `^` in Rust,
        // so this is the grouping the bare expression already had — made
        // explicit so the intent (node in the high bits, iter in the low
        // bits) is unambiguous.
        let mut rng = Pcg::new(
            seed ^ ((node as u64) << 32) ^ (iter as u64),
            0x9AD,
        );
        let mut loss = 0.0f32;
        for (o, (p, t)) in out
            .iter_mut()
            .zip(params.iter().zip(self.targets.row(node).iter()))
        {
            let d = p - t;
            loss += 0.5 * d * d;
            *o = d + self.noise * rng.normal() as f32;
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AlgorithmKind;
    use crate::topology::TopologyKind;

    fn run(
        kind: TopologyKind,
        algo: AlgorithmKind,
        parallel_grads: bool,
    ) -> (TrainingHistory, f64) {
        let n = 8;
        let dim = 16;
        let provider = QuadraticProvider::shared(n, dim, 0.1, 3);
        let opt = algo.build(n, &vec![0.0; dim], 0.9);
        let mut trainer = Trainer::new(
            Schedule::new(kind, n, 1),
            opt,
            &provider,
            TrainConfig {
                iters: 400,
                lr: LrSchedule::Const(0.05),
                warmup_allreduce: true,
                record_every: 50,
                parallel_grads,
                lanes: None,
                seed: 7,
                msg_bytes: None,
                cost: Some(CostModel::paper_default(0.01)),
                compressor: CompressorKind::Identity,
                ..Default::default()
            },
        );
        let hist = trainer.run();
        let final_consensus = hist.final_consensus();
        (hist, final_consensus)
    }

    #[test]
    fn loss_decreases_across_algorithms_and_topologies() {
        for algo in [
            AlgorithmKind::DSgd,
            AlgorithmKind::DmSgd,
            AlgorithmKind::VanillaDmSgd,
            AlgorithmKind::QgDmSgd,
            AlgorithmKind::ParallelSgd,
        ] {
            for kind in [TopologyKind::OnePeerExp, TopologyKind::StaticExp, TopologyKind::Ring] {
                let (hist, _) = run(kind, algo, false);
                let early: f64 = hist.loss[..20].iter().sum::<f64>() / 20.0;
                let late: f64 = hist.loss[380..].iter().sum::<f64>() / 20.0;
                assert!(
                    late < early * 0.3,
                    "{algo}/{kind}: loss {early} -> {late}"
                );
            }
        }
    }

    #[test]
    fn consensus_stays_bounded() {
        let (_, consensus) = run(TopologyKind::OnePeerExp, AlgorithmKind::DmSgd, false);
        assert!(consensus < 1.0, "consensus distance {consensus}");
    }

    #[test]
    fn parallel_grad_computation_matches_sequential() {
        let (a, _) = run(TopologyKind::StaticExp, AlgorithmKind::DmSgd, false);
        let (b, _) = run(TopologyKind::StaticExp, AlgorithmKind::DmSgd, true);
        for (x, y) in a.loss.iter().zip(b.loss.iter()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn sim_time_ordering_one_peer_cheaper_than_static_exp() {
        let (a, _) = run(TopologyKind::OnePeerExp, AlgorithmKind::DmSgd, false);
        let (b, _) = run(TopologyKind::StaticExp, AlgorithmKind::DmSgd, false);
        assert!(a.sim_time < b.sim_time, "{} vs {}", a.sim_time, b.sim_time);
    }

    #[test]
    fn warmup_allreduce_zeroes_initial_consensus() {
        let n = 4;
        let dim = 3;
        let provider = QuadraticProvider::random(n, dim, 0.0, 1);
        // Start from *different* rows on purpose.
        let mut x = StackedParams::zeros(n, dim);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let opt = Box::new(crate::optim::DmSgd::new(x, 0.9));
        let mut t = Trainer::new(
            Schedule::new(TopologyKind::OnePeerExp, n, 0),
            opt,
            &provider,
            TrainConfig {
                iters: 1,
                warmup_allreduce: true,
                record_every: 1,
                ..Default::default()
            },
        );
        let hist = t.run();
        // After warm-up + 1 one-peer step consensus is still tiny (grads
        // are noiseless and equal-target here? targets differ, so allow a
        // loose bound).
        assert!(hist.consensus[0].1 < 10.0);
    }

    #[test]
    fn zero_iteration_run_yields_nan_safe_summary() {
        let n = 4;
        let dim = 3;
        let provider = QuadraticProvider::random(n, dim, 0.0, 2);
        let opt = AlgorithmKind::DmSgd.build(n, &vec![0.0; dim], 0.9);
        let mut t = Trainer::new(
            Schedule::new(TopologyKind::OnePeerExp, n, 0),
            opt,
            &provider,
            TrainConfig { iters: 0, ..Default::default() },
        );
        let hist = t.run();
        assert!(hist.consensus.is_empty());
        assert!(hist.loss.is_empty());
        // The old `consensus.last().unwrap()` panicked here; the summary
        // must instead be a quiet NaN.
        assert!(hist.final_consensus().is_nan());
    }

    #[test]
    fn fused_probe_is_bitwise_identical_to_standalone() {
        let n = 8;
        let dim = 16;
        let provider = QuadraticProvider::random(n, dim, 0.1, 9);
        let histories: Vec<TrainingHistory> = [false, true]
            .iter()
            .map(|&fused| {
                let opt = AlgorithmKind::DmSgd.build(n, &vec![0.25; dim], 0.9);
                let mut t = Trainer::new(
                    Schedule::new(TopologyKind::OnePeerExp, n, 1),
                    opt,
                    &provider,
                    TrainConfig {
                        iters: 37,
                        record_every: 5,
                        seed: 11,
                        fused_probe: fused,
                        ..Default::default()
                    },
                );
                t.run()
            })
            .collect();
        let (a, b) = (&histories[0], &histories[1]);
        assert_eq!(a.consensus.len(), b.consensus.len());
        for (x, y) in a.consensus.iter().zip(b.consensus.iter()) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "iter {}", x.0);
        }
        for (x, y) in a.loss.iter().zip(b.loss.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.lr, b.lr);
    }

    #[test]
    fn execution_mode_parses_and_round_trips() {
        assert_eq!(ExecutionMode::parse("sync"), Some(ExecutionMode::Sync));
        assert_eq!(ExecutionMode::parse("async:0"), Some(ExecutionMode::Async { tau: 0 }));
        assert_eq!(ExecutionMode::parse("async:3"), Some(ExecutionMode::Async { tau: 3 }));
        assert_eq!(ExecutionMode::parse("async"), None);
        assert_eq!(ExecutionMode::parse("async:x"), None);
        assert_eq!(ExecutionMode::parse("bulk"), None);
        for mode in [ExecutionMode::Sync, ExecutionMode::Async { tau: 2 }] {
            assert_eq!(ExecutionMode::parse(&mode.label()), Some(mode));
        }
    }
}
