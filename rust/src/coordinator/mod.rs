//! The Layer-3 coordinator: node state, the partial-averaging hot path,
//! the training loop, learning-rate schedules, metrics, and
//! transient-iteration detection.
//!
//! This is the BlueFog-analogue system layer of the reproduction — the
//! part of the paper's stack that owns topology scheduling, the DmSGD
//! update, and experiment orchestration. Gradients come from either the
//! pure-Rust models ([`crate::models`]) or the PJRT runtime
//! ([`crate::runtime`]); the coordinator is agnostic.

pub mod async_exec;
pub mod mixing;
pub mod schedule_lr;
pub mod state;
pub mod trainer;
pub mod transient;

pub use mixing::MixingPlan;
pub use schedule_lr::LrSchedule;
pub use state::StackedParams;
pub use trainer::{AsyncExec, ExecutionMode, GradProvider, TrainConfig, Trainer, TrainingHistory};
pub use transient::transient_iterations;
