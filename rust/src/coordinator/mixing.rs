//! Partial-averaging (neighbor all-reduce) over stacked node state — the
//! coordinator's hot path.
//!
//! The mixing kernels consume a [`MixingPlan`] (the sparse-first CSR
//! representation owned by [`crate::topology::plan`]; `Schedule::plan_at`
//! hands out cached borrows, so no dense `n × n` matrix and no per-
//! iteration `O(n²)` conversion exist anywhere on the training path).
//! Mixing an `n × P` state stack costs `O(nnz(W) · P)` streaming flops.
//! [`MixingPlan::mix_dmsgd`] fuses Algorithm 1's two mixes —
//! `m⁺ = W(βm + g)` and `x⁺ = W(x − γm)` — into a single pass over the
//! parameter dimension so each of `x`, `m`, `g` is read exactly once per
//! nonzero (see docs/DESIGN.md §Perf).
//!
//! # Kernel structure
//!
//! A step kernel is `mix_fused_rows` (one output stack) or
//! `mix_fused_rows2` (the fused dual-output DmSGD form) over a
//! [`RowSource`]: a per-element view `src.at(j, k)` of the pre-mixed
//! source row `j` (e.g. `x_j − γ g_j` produced on the fly — this is what
//! fuses an algorithm's pre-mix element loop into the accumulation).
//! Each output row dispatches on its nonzero count (1 / 2 / general —
//! the 2-nonzero case is the paper's recommended one-peer deployment,
//! Table 1) into fixed-8-lane blocked loops with register accumulators
//! and [`crate::simd::fmaf`] folds. Per output element the accumulation
//! is the ascending-`j` fold `acc = fmaf(w_t, src_t, acc)` seeded with
//! `w_0 · src_0`; blocking is across the parameter dimension only, so
//! the fold per element is identical for every specialization, for the
//! retained scalar reference twins ([`crate::simd::scalar_kernels`]),
//! and for any row sharding — bitwise (docs/DESIGN.md §Perf).

use std::ops::Range;

use super::state::StackedParams;
use crate::simd::{fmaf, LANES};
use crate::topology::plan::PlanRow;
pub use crate::topology::plan::MixingPlan;

/// Per-element view of the pre-mixed source rows: `at(j, k)` is element
/// `k` of source row `j`, computed on the fly. Implemented for any
/// `Fn(usize, usize) -> f32` closure, which is how the optimizer kernels
/// fold their pre-mix element math into the accumulation.
pub(crate) trait RowSource {
    /// Element `k` of pre-mixed source row `j`.
    fn at(&self, j: usize, k: usize) -> f32;
}

impl<F: Fn(usize, usize) -> f32> RowSource for F {
    #[inline(always)]
    fn at(&self, j: usize, k: usize) -> f32 {
        self(j, k)
    }
}

/// Vectorized single-output row kernel: `orow[k] = Σ_t w_t · src(j_t, k)`
/// with the ascending-`t` `fmaf` fold, 8-lane blocked, specialized by
/// nonzero count. Caller handles the empty row.
#[inline]
fn mix_row_vectorized<S: RowSource>(row: PlanRow<'_>, orow: &mut [f32], src: &S) {
    let nnz = row.len();
    let dim = orow.len();
    let j0 = row.cols[0] as usize;
    let w0 = row.w32[0];
    let blocks = dim / LANES;
    match nnz {
        1 => {
            for blk in 0..blocks {
                let k0 = blk * LANES;
                let o = &mut orow[k0..k0 + LANES];
                for (l, ov) in o.iter_mut().enumerate() {
                    *ov = w0 * src.at(j0, k0 + l);
                }
            }
            for (k, ov) in orow.iter_mut().enumerate().skip(blocks * LANES) {
                *ov = w0 * src.at(j0, k);
            }
        }
        2 => {
            let j1 = row.cols[1] as usize;
            let w1 = row.w32[1];
            for blk in 0..blocks {
                let k0 = blk * LANES;
                let o = &mut orow[k0..k0 + LANES];
                for (l, ov) in o.iter_mut().enumerate() {
                    let k = k0 + l;
                    *ov = fmaf(w1, src.at(j1, k), w0 * src.at(j0, k));
                }
            }
            for (k, ov) in orow.iter_mut().enumerate().skip(blocks * LANES) {
                *ov = fmaf(w1, src.at(j1, k), w0 * src.at(j0, k));
            }
        }
        _ => {
            for blk in 0..blocks {
                let k0 = blk * LANES;
                let mut acc = [0.0f32; LANES];
                for (l, a) in acc.iter_mut().enumerate() {
                    *a = w0 * src.at(j0, k0 + l);
                }
                for t in 1..nnz {
                    let j = row.cols[t] as usize;
                    let w = row.w32[t];
                    for (l, a) in acc.iter_mut().enumerate() {
                        *a = fmaf(w, src.at(j, k0 + l), *a);
                    }
                }
                orow[k0..k0 + LANES].copy_from_slice(&acc);
            }
            for (k, ov) in orow.iter_mut().enumerate().skip(blocks * LANES) {
                let mut acc = w0 * src.at(j0, k);
                for t in 1..nnz {
                    acc = fmaf(row.w32[t], src.at(row.cols[t] as usize, k), acc);
                }
                *ov = acc;
            }
        }
    }
}

/// Retained scalar reference twin of [`mix_row_vectorized`]: the
/// identical per-element `fmaf` fold evaluated one element at a time —
/// bitwise-equal output by construction (tests/kernels.rs pins this),
/// and the honest "before" side of the bench comparator.
#[inline]
fn mix_row_scalar<S: RowSource>(row: PlanRow<'_>, orow: &mut [f32], src: &S) {
    let nnz = row.len();
    let j0 = row.cols[0] as usize;
    let w0 = row.w32[0];
    for (k, ov) in orow.iter_mut().enumerate() {
        let mut acc = w0 * src.at(j0, k);
        for t in 1..nnz {
            acc = fmaf(row.w32[t], src.at(row.cols[t] as usize, k), acc);
        }
        *ov = acc;
    }
}

/// Vectorized dual-output row kernel: the two accumulations share one
/// pass over the nonzeros (each source row is visited once per nonzero —
/// the fusion `mix_dmsgd` is built on). Same fold discipline as
/// [`mix_row_vectorized`] per output.
#[inline]
fn mix_row2_vectorized<A: RowSource, B: RowSource>(
    row: PlanRow<'_>,
    oa: &mut [f32],
    ob: &mut [f32],
    sa: &A,
    sb: &B,
) {
    let nnz = row.len();
    let dim = oa.len();
    let j0 = row.cols[0] as usize;
    let w0 = row.w32[0];
    let blocks = dim / LANES;
    match nnz {
        1 => {
            for blk in 0..blocks {
                let k0 = blk * LANES;
                for l in 0..LANES {
                    let k = k0 + l;
                    oa[k] = w0 * sa.at(j0, k);
                    ob[k] = w0 * sb.at(j0, k);
                }
            }
            for k in blocks * LANES..dim {
                oa[k] = w0 * sa.at(j0, k);
                ob[k] = w0 * sb.at(j0, k);
            }
        }
        2 => {
            let j1 = row.cols[1] as usize;
            let w1 = row.w32[1];
            for blk in 0..blocks {
                let k0 = blk * LANES;
                for l in 0..LANES {
                    let k = k0 + l;
                    oa[k] = fmaf(w1, sa.at(j1, k), w0 * sa.at(j0, k));
                    ob[k] = fmaf(w1, sb.at(j1, k), w0 * sb.at(j0, k));
                }
            }
            for k in blocks * LANES..dim {
                oa[k] = fmaf(w1, sa.at(j1, k), w0 * sa.at(j0, k));
                ob[k] = fmaf(w1, sb.at(j1, k), w0 * sb.at(j0, k));
            }
        }
        _ => {
            for blk in 0..blocks {
                let k0 = blk * LANES;
                let mut acc_a = [0.0f32; LANES];
                let mut acc_b = [0.0f32; LANES];
                for l in 0..LANES {
                    let k = k0 + l;
                    acc_a[l] = w0 * sa.at(j0, k);
                    acc_b[l] = w0 * sb.at(j0, k);
                }
                for t in 1..nnz {
                    let j = row.cols[t] as usize;
                    let w = row.w32[t];
                    for l in 0..LANES {
                        let k = k0 + l;
                        acc_a[l] = fmaf(w, sa.at(j, k), acc_a[l]);
                        acc_b[l] = fmaf(w, sb.at(j, k), acc_b[l]);
                    }
                }
                oa[k0..k0 + LANES].copy_from_slice(&acc_a);
                ob[k0..k0 + LANES].copy_from_slice(&acc_b);
            }
            for k in blocks * LANES..dim {
                let mut acc_a = w0 * sa.at(j0, k);
                let mut acc_b = w0 * sb.at(j0, k);
                for t in 1..nnz {
                    let j = row.cols[t] as usize;
                    let w = row.w32[t];
                    acc_a = fmaf(w, sa.at(j, k), acc_a);
                    acc_b = fmaf(w, sb.at(j, k), acc_b);
                }
                oa[k] = acc_a;
                ob[k] = acc_b;
            }
        }
    }
}

/// Retained scalar reference twin of [`mix_row2_vectorized`].
#[inline]
fn mix_row2_scalar<A: RowSource, B: RowSource>(
    row: PlanRow<'_>,
    oa: &mut [f32],
    ob: &mut [f32],
    sa: &A,
    sb: &B,
) {
    let nnz = row.len();
    let dim = oa.len();
    let j0 = row.cols[0] as usize;
    let w0 = row.w32[0];
    for k in 0..dim {
        let mut acc_a = w0 * sa.at(j0, k);
        let mut acc_b = w0 * sb.at(j0, k);
        for t in 1..nnz {
            let j = row.cols[t] as usize;
            let w = row.w32[t];
            acc_a = fmaf(w, sa.at(j, k), acc_a);
            acc_b = fmaf(w, sb.at(j, k), acc_b);
        }
        oa[k] = acc_a;
        ob[k] = acc_b;
    }
}

impl MixingPlan {
    /// Fused sparse mix over output rows `rows`: accumulate `W·v` into
    /// the shard view `out` (row `rows.start` at offset 0), where source
    /// element `v_j[k]` is produced **on the fly** by `src.at(j, k)`.
    /// Nonzeros accumulate in ascending-`j` order per element, so the
    /// result is identical for any sharding (docs/DESIGN.md §Perf). This
    /// is the single kernel behind `mix` and every non-DmSGD
    /// `Optimizer::step_shard`.
    #[inline]
    pub(crate) fn mix_fused_rows<S: RowSource>(
        &self,
        rows: Range<usize>,
        dim: usize,
        out: &mut [f32],
        src: S,
    ) {
        let base = rows.start;
        let scalar = crate::simd::scalar_kernels();
        for i in rows {
            let off = (i - base) * dim;
            let orow = &mut out[off..off + dim];
            let row = self.row(i);
            if row.is_empty() {
                orow.fill(0.0);
                continue;
            }
            if scalar {
                mix_row_scalar(row, orow, &src);
            } else {
                mix_row_vectorized(row, orow, &src);
            }
        }
    }

    /// Dual-output variant of [`MixingPlan::mix_fused_rows`]: both
    /// accumulations share one pass over the nonzeros, so each source
    /// row is visited exactly once per nonzero (DmSGD's fusion).
    #[inline]
    pub(crate) fn mix_fused_rows2<A: RowSource, B: RowSource>(
        &self,
        rows: Range<usize>,
        dim: usize,
        out_a: &mut [f32],
        out_b: &mut [f32],
        src_a: A,
        src_b: B,
    ) {
        let base = rows.start;
        let scalar = crate::simd::scalar_kernels();
        for i in rows {
            let off = (i - base) * dim;
            let oa = &mut out_a[off..off + dim];
            let ob = &mut out_b[off..off + dim];
            let row = self.row(i);
            if row.is_empty() {
                oa.fill(0.0);
                ob.fill(0.0);
                continue;
            }
            if scalar {
                mix_row2_scalar(row, oa, ob, &src_a, &src_b);
            } else {
                mix_row2_vectorized(row, oa, ob, &src_a, &src_b);
            }
        }
    }

    /// Compute `out` rows in `range` of `W · input` — the single-source
    /// case reads straight from the input slice (no staging buffer, no
    /// copy; the closure is just an index map).
    #[inline]
    fn mix_rows(&self, range: Range<usize>, input: &[f32], dim: usize, out: &mut [f32]) {
        self.mix_fused_rows(range, dim, out, |j: usize, k: usize| input[j * dim + k]);
    }

    /// Single-threaded `out = W · input` on the calling thread — the
    /// comparator entry the benches time (no spawn threshold, so the
    /// scalar-vs-vectorized ratio measures the kernel, not threading)
    /// and a direct kernel hook for tests. Bitwise identical to
    /// [`MixingPlan::mix`].
    pub fn mix_serial(&self, input: &StackedParams, out: &mut StackedParams) {
        assert_eq!(input.n, self.n);
        assert_eq!(out.n, self.n);
        assert_eq!(input.dim, out.dim);
        self.mix_rows(0..self.n, &input.data, input.dim, &mut out.data);
    }

    /// `out = W · input` over the stack (row i of out = Σ_j w_ij · row j).
    /// Legacy spawn-per-call wrapper: row-parallel on freshly spawned
    /// threads for large states. The training loop instead drives the
    /// row-range kernels through the persistent [`crate::engine::Engine`]
    /// pool (zero per-call spawns); this wrapper survives for ad-hoc
    /// callers, tests, and the engine-vs-legacy benchmark.
    pub fn mix(&self, input: &StackedParams, out: &mut StackedParams) {
        assert_eq!(input.n, self.n);
        assert_eq!(out.n, self.n);
        assert_eq!(input.dim, out.dim);
        let n = self.n;
        let dim = input.dim;
        let threads = crate::engine::auto_lanes(n, n * dim);
        if threads <= 1 {
            self.mix_rows(0..n, &input.data, dim, &mut out.data);
            return;
        }
        let rows_per = n.div_ceil(threads);
        let inp = &input.data;
        std::thread::scope(|scope| {
            let mut rest = out.data.as_mut_slice();
            let mut start = 0usize;
            while start < n {
                let end = (start + rows_per).min(n);
                let take = (end - start) * dim;
                let (chunk, tail) = rest.split_at_mut(take);
                rest = tail;
                let range = start..end;
                scope.spawn(move || self.mix_rows(range, inp, dim, chunk));
                start = end;
            }
        });
    }

    /// Compute fused output rows `i ∈ rows_range` into `xo`/`mo` slices
    /// covering exactly those rows. This is DmSGD's shard-local fused
    /// kernel — `DmSgd::step_shard` calls it directly with the engine's
    /// row shards:
    ///
    /// ```text
    /// xo_i = Σ_j w_ij (x_j − γ m_j)
    /// mo_i = Σ_j w_ij (β m_j + g_j)
    /// ```
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn mix_dmsgd_rows(
        &self,
        rows_range: Range<usize>,
        x: &[f32],
        m: &[f32],
        g: &[f32],
        beta: f32,
        gamma: f32,
        dim: usize,
        xo_rows: &mut [f32],
        mo_rows: &mut [f32],
    ) {
        self.mix_fused_rows2(
            rows_range,
            dim,
            xo_rows,
            mo_rows,
            |j: usize, k: usize| {
                let s = j * dim + k;
                fmaf(-gamma, m[s], x[s])
            },
            |j: usize, k: usize| {
                let s = j * dim + k;
                fmaf(beta, m[s], g[s])
            },
        );
    }

    /// The fused DmSGD mixing update (Algorithm 1):
    ///
    /// ```text
    /// x⁺_i = Σ_j w_ij (x_j − γ m_j)
    /// m⁺_i = Σ_j w_ij (β m_j + g_j)
    /// ```
    ///
    /// `x`/`m` are updated in place through double buffers owned here.
    /// Legacy spawn-per-call wrapper: large states are processed on
    /// freshly spawned threads with output rows partitioned per thread.
    /// The training loop instead shards [`MixingPlan::mix_dmsgd_rows`]
    /// over the persistent engine pool (docs/DESIGN.md §Engine).
    #[allow(clippy::too_many_arguments)]
    pub fn mix_dmsgd(
        &self,
        x: &mut StackedParams,
        m: &mut StackedParams,
        g: &StackedParams,
        beta: f32,
        gamma: f32,
        x_buf: &mut StackedParams,
        m_buf: &mut StackedParams,
    ) {
        let n = self.n;
        let dim = x.dim;
        assert!(x.n == n && m.n == n && g.n == n && x_buf.n == n && m_buf.n == n);
        // Threading threshold: one shared constant with the engine
        // (`engine::PARALLEL_MIN_ELEMS`) so legacy and pooled paths
        // cannot drift — see docs/DESIGN.md §Engine.
        let threads = crate::engine::auto_lanes(n, n * dim);
        if threads <= 1 {
            let (xd, md, gd) = (&x.data, &m.data, &g.data);
            self.mix_dmsgd_rows(0..n, xd, md, gd, beta, gamma, dim, &mut x_buf.data, &mut m_buf.data);
        } else {
            let rows_per = n.div_ceil(threads);
            let (xd, md, gd) = (&x.data, &m.data, &g.data);
            std::thread::scope(|scope| {
                let mut xo_rest = x_buf.data.as_mut_slice();
                let mut mo_rest = m_buf.data.as_mut_slice();
                let mut start = 0usize;
                while start < n {
                    let end = (start + rows_per).min(n);
                    let take = (end - start) * dim;
                    let (xo, xr) = xo_rest.split_at_mut(take);
                    let (mo, mr) = mo_rest.split_at_mut(take);
                    xo_rest = xr;
                    mo_rest = mr;
                    let range = start..end;
                    scope.spawn(move || {
                        self.mix_dmsgd_rows(range, xd, md, gd, beta, gamma, dim, xo, mo);
                    });
                    start = end;
                }
            });
        }
        std::mem::swap(&mut x.data, &mut x_buf.data);
        std::mem::swap(&mut m.data, &mut m_buf.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::topology::exponential::{
        one_peer_exp_plan, one_peer_exp_weights, static_exp_plan, static_exp_weights,
    };

    fn stack(n: usize, dim: usize, seed: u64) -> StackedParams {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let mut s = StackedParams::zeros(n, dim);
        for v in s.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        s
    }

    #[test]
    fn sparse_matches_dense_matvec() {
        let w = static_exp_weights(8);
        let sw = static_exp_plan(8);
        let input = stack(8, 5, 1);
        let mut out = StackedParams::zeros(8, 5);
        sw.mix(&input, &mut out);
        // Compare per column against dense matvec.
        for col in 0..5 {
            let v: Vec<f64> = (0..8).map(|i| input.row(i)[col] as f64).collect();
            let dense = w.matvec(&v);
            for i in 0..8 {
                assert!((out.row(i)[col] as f64 - dense[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn mixing_preserves_mean() {
        // Doubly-stochastic W: column sums 1 → the node-mean is invariant.
        let sw = one_peer_exp_plan(16, 2);
        let input = stack(16, 7, 2);
        let before = input.mean();
        let mut out = StackedParams::zeros(16, 7);
        sw.mix(&input, &mut out);
        let after = out.mean();
        for (b, a) in before.iter().zip(after.iter()) {
            assert!((b - a).abs() < 1e-5, "mean not preserved: {b} vs {a}");
        }
    }

    #[test]
    fn fused_dmsgd_matches_two_separate_mixes() {
        let n = 8;
        let dim = 6;
        let sw = static_exp_plan(n);
        let (beta, gamma) = (0.9f32, 0.05f32);
        let x0 = stack(n, dim, 3);
        let m0 = stack(n, dim, 4);
        let g = stack(n, dim, 5);
        // Reference: explicit temporaries.
        let mut pre_x = StackedParams::zeros(n, dim);
        let mut pre_m = StackedParams::zeros(n, dim);
        for i in 0..n {
            for k in 0..dim {
                pre_x.row_mut(i)[k] = x0.row(i)[k] - gamma * m0.row(i)[k];
                pre_m.row_mut(i)[k] = beta * m0.row(i)[k] + g.row(i)[k];
            }
        }
        let mut want_x = StackedParams::zeros(n, dim);
        let mut want_m = StackedParams::zeros(n, dim);
        sw.mix(&pre_x, &mut want_x);
        sw.mix(&pre_m, &mut want_m);
        // Fused.
        let mut x = x0.clone();
        let mut m = m0.clone();
        let mut xb = StackedParams::zeros(n, dim);
        let mut mb = StackedParams::zeros(n, dim);
        sw.mix_dmsgd(&mut x, &mut m, &g, beta, gamma, &mut xb, &mut mb);
        for i in 0..n {
            for k in 0..dim {
                assert!((x.row(i)[k] - want_x.row(i)[k]).abs() < 1e-5);
                assert!((m.row(i)[k] - want_m.row(i)[k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn from_dense_escape_hatch_still_mixes() {
        // The from_dense escape hatch behaves exactly like the direct
        // plan constructors.
        let sw = MixingPlan::from_dense(&one_peer_exp_weights(16, 0));
        let plan = one_peer_exp_plan(16, 0);
        let input = stack(16, 3, 9);
        let mut out_a = StackedParams::zeros(16, 3);
        let mut out_b = StackedParams::zeros(16, 3);
        sw.mix(&input, &mut out_a);
        plan.mix(&input, &mut out_b);
        assert_eq!(out_a.data, out_b.data);
    }

    #[test]
    fn sparse_degree_matches_topology() {
        let sw = one_peer_exp_plan(16, 0);
        assert_eq!(sw.max_degree, 2); // sends to one, receives from one
        let sw2 = MixingPlan::from_dense(&Matrix::averaging(16));
        assert_eq!(sw2.max_degree, 15);
    }

    #[test]
    fn specializations_agree_with_general_fold() {
        // The 1- and 2-nonzero fast arms must produce the exact fold the
        // general arm would: mix against hand-built plans whose rows have
        // 1, 2, and k nonzeros, comparing with a naive per-element fold.
        let n = 5;
        let rows = vec![
            vec![(0usize, 1.0f64)],
            vec![(0, 0.5), (2, 0.5)],
            vec![(1, 0.25), (2, 0.5), (3, 0.25)],
            vec![],
            vec![(0, 0.2), (1, 0.2), (2, 0.2), (3, 0.2), (4, 0.2)],
        ];
        let plan = MixingPlan::from_rows(rows.clone(), None);
        for dim in [1usize, 7, 8, 9, 17] {
            let input = stack(n, dim, 42);
            let mut out = StackedParams::zeros(n, dim);
            plan.mix(&input, &mut out);
            for (i, row) in rows.iter().enumerate() {
                for k in 0..dim {
                    let want = if row.is_empty() {
                        0.0f32
                    } else {
                        let mut acc = row[0].1 as f32 * input.row(row[0].0)[k];
                        for &(j, w) in &row[1..] {
                            acc = fmaf(w as f32, input.row(j)[k], acc);
                        }
                        acc
                    };
                    assert_eq!(
                        out.row(i)[k].to_bits(),
                        want.to_bits(),
                        "dim={dim} row={i} k={k}"
                    );
                }
            }
        }
    }
}
