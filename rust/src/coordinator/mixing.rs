//! Partial-averaging (neighbor all-reduce) over stacked node state — the
//! coordinator's hot path.
//!
//! The mixing kernels consume a [`MixingPlan`] (the sparse-first
//! representation owned by [`crate::topology::plan`]; `Schedule::plan_at`
//! hands out cached borrows, so no dense `n × n` matrix and no per-
//! iteration `O(n²)` conversion exist anywhere on the training path).
//! Mixing an `n × P` state stack costs `O(nnz(W) · P)` streaming flops.
//! [`MixingPlan::mix_dmsgd`] fuses Algorithm 1's two mixes —
//! `m⁺ = W(βm + g)` and `x⁺ = W(x − γm)` — into a single pass over the
//! parameter dimension so each of `x`, `m`, `g` is read exactly once per
//! nonzero (see docs/DESIGN.md §Perf).

use super::state::StackedParams;
pub use crate::topology::plan::MixingPlan;

impl MixingPlan {
    /// Fused sparse mix over output rows `rows`: accumulate `W·v` into
    /// the shard view `out` (row `rows.start` at offset 0), where the
    /// chunk `v_j[c0 .. c0+dst.len()]` is produced **on the fly** by
    /// `src(j, c0, dst)` — this is what fuses an algorithm's pre-mix
    /// element loop into the accumulation (one streaming pass per
    /// nonzero). The source chunk lands in a stack buffer that stays
    /// L1-resident, and both the fill and the accumulation are plain
    /// slice zips (no per-element indexing in the hot loop). Nonzeros
    /// accumulate in ascending-`j` order, so the result is identical for
    /// any sharding (docs/DESIGN.md §Perf). This is the single kernel
    /// behind `mix` and every non-DmSGD `Optimizer::step_shard`.
    #[inline]
    pub(crate) fn mix_fused_rows(
        &self,
        rows: std::ops::Range<usize>,
        dim: usize,
        out: &mut [f32],
        src: impl Fn(usize, usize, &mut [f32]),
    ) {
        let base = rows.start;
        const CHUNK: usize = 4096;
        let mut buf = [0.0f32; CHUNK];
        for i in rows {
            let off = (i - base) * dim;
            let row = &self.rows[i];
            if row.is_empty() {
                out[off..off + dim].iter_mut().for_each(|v| *v = 0.0);
                continue;
            }
            let mut c0 = 0usize;
            while c0 < dim {
                let c1 = (c0 + CHUNK).min(dim);
                let orow = &mut out[off + c0..off + c1];
                for (idx, &(j, wij)) in row.iter().enumerate() {
                    let wij = wij as f32;
                    src(j, c0, &mut buf[..c1 - c0]);
                    let chunk = &buf[..c1 - c0];
                    if idx == 0 {
                        for (o, v) in orow.iter_mut().zip(chunk.iter()) {
                            *o = wij * v;
                        }
                    } else {
                        for (o, v) in orow.iter_mut().zip(chunk.iter()) {
                            *o += wij * v;
                        }
                    }
                }
                c0 = c1;
            }
        }
    }

    /// Compute `out` rows in `range` of `W · input`.
    #[inline]
    fn mix_rows(&self, range: std::ops::Range<usize>, input: &[f32], dim: usize, out: &mut [f32]) {
        self.mix_fused_rows(range, dim, out, |j, c0, dst| {
            let s = j * dim + c0;
            dst.copy_from_slice(&input[s..s + dst.len()]);
        });
    }

    /// `out = W · input` over the stack (row i of out = Σ_j w_ij · row j).
    /// Legacy spawn-per-call wrapper: row-parallel on freshly spawned
    /// threads for large states. The training loop instead drives the
    /// row-range kernels through the persistent [`crate::engine::Engine`]
    /// pool (zero per-call spawns); this wrapper survives for ad-hoc
    /// callers, tests, and the engine-vs-legacy benchmark.
    pub fn mix(&self, input: &StackedParams, out: &mut StackedParams) {
        assert_eq!(input.n, self.n);
        assert_eq!(out.n, self.n);
        assert_eq!(input.dim, out.dim);
        let n = self.n;
        let dim = input.dim;
        let threads = crate::engine::auto_lanes(n, n * dim);
        if threads <= 1 {
            self.mix_rows(0..n, &input.data, dim, &mut out.data);
            return;
        }
        let rows_per = n.div_ceil(threads);
        let inp = &input.data;
        std::thread::scope(|scope| {
            let mut rest = out.data.as_mut_slice();
            let mut start = 0usize;
            while start < n {
                let end = (start + rows_per).min(n);
                let take = (end - start) * dim;
                let (chunk, tail) = rest.split_at_mut(take);
                rest = tail;
                let range = start..end;
                scope.spawn(move || self.mix_rows(range, inp, dim, chunk));
                start = end;
            }
        });
    }

    /// Compute fused output rows `i ∈ rows_range` into `xo`/`mo` slices
    /// covering exactly those rows. This is DmSGD's shard-local fused
    /// kernel — `DmSgd::step_shard` calls it directly with the engine's
    /// row shards.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn mix_dmsgd_rows(
        &self,
        rows_range: std::ops::Range<usize>,
        x: &[f32],
        m: &[f32],
        g: &[f32],
        beta: f32,
        gamma: f32,
        dim: usize,
        xo_rows: &mut [f32],
        mo_rows: &mut [f32],
    ) {
        let base = rows_range.start;
        // Chunk the parameter dimension so the output chunk stays resident
        // in L1 across the nonzero accumulation (otherwise every extra
        // nonzero costs a full read-modify-write pass over DRAM — measured
        // −40% throughput for the 6-nonzero static-exp rows; see
        // docs/DESIGN.md §Perf).
        const CHUNK: usize = 4096;
        for i in rows_range {
            let off = (i - base) * dim;
            let row = &self.rows[i];
            if row.is_empty() {
                xo_rows[off..off + dim].iter_mut().for_each(|v| *v = 0.0);
                mo_rows[off..off + dim].iter_mut().for_each(|v| *v = 0.0);
                continue;
            }
            // One-peer / matching rows have exactly two nonzeros — the
            // recommended deployment (Table 1) — worth a fused two-source
            // loop: one write per output element, no accumulation pass.
            if row.len() == 2 {
                let (j0, w0) = row[0];
                let (j1, w1) = row[1];
                let (w0, w1) = (w0 as f32, w1 as f32);
                let (x0, x1) = (&x[j0 * dim..(j0 + 1) * dim], &x[j1 * dim..(j1 + 1) * dim]);
                let (m0, m1) = (&m[j0 * dim..(j0 + 1) * dim], &m[j1 * dim..(j1 + 1) * dim]);
                let (g0, g1) = (&g[j0 * dim..(j0 + 1) * dim], &g[j1 * dim..(j1 + 1) * dim]);
                let xo = &mut xo_rows[off..off + dim];
                let mo = &mut mo_rows[off..off + dim];
                for k in 0..dim {
                    let (m0k, m1k) = (m0[k], m1[k]);
                    xo[k] = w0 * (x0[k] - gamma * m0k) + w1 * (x1[k] - gamma * m1k);
                    mo[k] = w0 * (beta * m0k + g0[k]) + w1 * (beta * m1k + g1[k]);
                }
                continue;
            }
            let mut c0 = 0usize;
            while c0 < dim {
                let c1 = (c0 + CHUNK).min(dim);
                let xo = &mut xo_rows[off + c0..off + c1];
                let mo = &mut mo_rows[off + c0..off + c1];
                for (idx, &(j, wij)) in row.iter().enumerate() {
                    let wij = wij as f32;
                    let xj = &x[j * dim + c0..j * dim + c1];
                    let mj = &m[j * dim + c0..j * dim + c1];
                    let gj = &g[j * dim + c0..j * dim + c1];
                    if idx == 0 {
                        for k in 0..xo.len() {
                            let mjk = mj[k];
                            xo[k] = wij * (xj[k] - gamma * mjk);
                            mo[k] = wij * (beta * mjk + gj[k]);
                        }
                    } else {
                        for k in 0..xo.len() {
                            let mjk = mj[k];
                            xo[k] += wij * (xj[k] - gamma * mjk);
                            mo[k] += wij * (beta * mjk + gj[k]);
                        }
                    }
                }
                c0 = c1;
            }
        }
    }

    /// The fused DmSGD mixing update (Algorithm 1):
    ///
    /// ```text
    /// x⁺_i = Σ_j w_ij (x_j − γ m_j)
    /// m⁺_i = Σ_j w_ij (β m_j + g_j)
    /// ```
    ///
    /// `x`/`m` are updated in place through double buffers owned here.
    /// Legacy spawn-per-call wrapper: large states are processed on
    /// freshly spawned threads with output rows partitioned per thread.
    /// The training loop instead shards [`MixingPlan::mix_dmsgd_rows`]
    /// over the persistent engine pool (docs/DESIGN.md §Engine).
    #[allow(clippy::too_many_arguments)]
    pub fn mix_dmsgd(
        &self,
        x: &mut StackedParams,
        m: &mut StackedParams,
        g: &StackedParams,
        beta: f32,
        gamma: f32,
        x_buf: &mut StackedParams,
        m_buf: &mut StackedParams,
    ) {
        let n = self.n;
        let dim = x.dim;
        assert!(x.n == n && m.n == n && g.n == n && x_buf.n == n && m_buf.n == n);
        // Threading threshold: one shared constant with the engine
        // (`engine::PARALLEL_MIN_ELEMS`) so legacy and pooled paths
        // cannot drift — see docs/DESIGN.md §Engine.
        let threads = crate::engine::auto_lanes(n, n * dim);
        if threads <= 1 {
            let (xd, md, gd) = (&x.data, &m.data, &g.data);
            self.mix_dmsgd_rows(0..n, xd, md, gd, beta, gamma, dim, &mut x_buf.data, &mut m_buf.data);
        } else {
            let rows_per = n.div_ceil(threads);
            let (xd, md, gd) = (&x.data, &m.data, &g.data);
            std::thread::scope(|scope| {
                let mut xo_rest = x_buf.data.as_mut_slice();
                let mut mo_rest = m_buf.data.as_mut_slice();
                let mut start = 0usize;
                while start < n {
                    let end = (start + rows_per).min(n);
                    let take = (end - start) * dim;
                    let (xo, xr) = xo_rest.split_at_mut(take);
                    let (mo, mr) = mo_rest.split_at_mut(take);
                    xo_rest = xr;
                    mo_rest = mr;
                    let range = start..end;
                    scope.spawn(move || {
                        self.mix_dmsgd_rows(range, xd, md, gd, beta, gamma, dim, xo, mo);
                    });
                    start = end;
                }
            });
        }
        std::mem::swap(&mut x.data, &mut x_buf.data);
        std::mem::swap(&mut m.data, &mut m_buf.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::topology::exponential::{
        one_peer_exp_plan, one_peer_exp_weights, static_exp_plan, static_exp_weights,
    };

    fn stack(n: usize, dim: usize, seed: u64) -> StackedParams {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let mut s = StackedParams::zeros(n, dim);
        for v in s.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        s
    }

    #[test]
    fn sparse_matches_dense_matvec() {
        let w = static_exp_weights(8);
        let sw = static_exp_plan(8);
        let input = stack(8, 5, 1);
        let mut out = StackedParams::zeros(8, 5);
        sw.mix(&input, &mut out);
        // Compare per column against dense matvec.
        for col in 0..5 {
            let v: Vec<f64> = (0..8).map(|i| input.row(i)[col] as f64).collect();
            let dense = w.matvec(&v);
            for i in 0..8 {
                assert!((out.row(i)[col] as f64 - dense[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn mixing_preserves_mean() {
        // Doubly-stochastic W: column sums 1 → the node-mean is invariant.
        let sw = one_peer_exp_plan(16, 2);
        let input = stack(16, 7, 2);
        let before = input.mean();
        let mut out = StackedParams::zeros(16, 7);
        sw.mix(&input, &mut out);
        let after = out.mean();
        for (b, a) in before.iter().zip(after.iter()) {
            assert!((b - a).abs() < 1e-5, "mean not preserved: {b} vs {a}");
        }
    }

    #[test]
    fn fused_dmsgd_matches_two_separate_mixes() {
        let n = 8;
        let dim = 6;
        let sw = static_exp_plan(n);
        let (beta, gamma) = (0.9f32, 0.05f32);
        let x0 = stack(n, dim, 3);
        let m0 = stack(n, dim, 4);
        let g = stack(n, dim, 5);
        // Reference: explicit temporaries.
        let mut pre_x = StackedParams::zeros(n, dim);
        let mut pre_m = StackedParams::zeros(n, dim);
        for i in 0..n {
            for k in 0..dim {
                pre_x.row_mut(i)[k] = x0.row(i)[k] - gamma * m0.row(i)[k];
                pre_m.row_mut(i)[k] = beta * m0.row(i)[k] + g.row(i)[k];
            }
        }
        let mut want_x = StackedParams::zeros(n, dim);
        let mut want_m = StackedParams::zeros(n, dim);
        sw.mix(&pre_x, &mut want_x);
        sw.mix(&pre_m, &mut want_m);
        // Fused.
        let mut x = x0.clone();
        let mut m = m0.clone();
        let mut xb = StackedParams::zeros(n, dim);
        let mut mb = StackedParams::zeros(n, dim);
        sw.mix_dmsgd(&mut x, &mut m, &g, beta, gamma, &mut xb, &mut mb);
        for i in 0..n {
            for k in 0..dim {
                assert!((x.row(i)[k] - want_x.row(i)[k]).abs() < 1e-6);
                assert!((m.row(i)[k] - want_m.row(i)[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn from_dense_escape_hatch_still_mixes() {
        // The from_dense escape hatch behaves exactly like the direct
        // plan constructors.
        let sw = MixingPlan::from_dense(&one_peer_exp_weights(16, 0));
        let plan = one_peer_exp_plan(16, 0);
        let input = stack(16, 3, 9);
        let mut out_a = StackedParams::zeros(16, 3);
        let mut out_b = StackedParams::zeros(16, 3);
        sw.mix(&input, &mut out_a);
        plan.mix(&input, &mut out_b);
        assert_eq!(out_a.data, out_b.data);
    }

    #[test]
    fn sparse_degree_matches_topology() {
        let sw = one_peer_exp_plan(16, 0);
        assert_eq!(sw.max_degree, 2); // sends to one, receives from one
        let sw2 = MixingPlan::from_dense(&Matrix::averaging(16));
        assert_eq!(sw2.max_degree, 15);
    }
}
