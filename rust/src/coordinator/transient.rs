//! Transient-iteration detection (Sec. 2 / Fig. 1).
//!
//! The paper defines transient iterations as those before a decentralized
//! algorithm reaches the linear-speedup stage — operationally (Fig. 1),
//! the iterations before its error curve merges with parallel SGD's.
//! We detect the merge point on smoothed curves: the smallest `K` such
//! that for all recorded `k ≥ K`, `err_dec[k] ≤ ratio · err_par[k]`.

/// Moving-average smoothing (window `w`, causal).
pub fn smooth(xs: &[f64], w: usize) -> Vec<f64> {
    if w <= 1 {
        return xs.to_vec();
    }
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        acc += x;
        if i >= w {
            acc -= xs[i - w];
        }
        out.push(acc / (i.min(w - 1) + 1) as f64);
    }
    out
}

/// Transient iterations: first index `K` after which the decentralized
/// error stays within `ratio ×` the parallel error. Returns `None` if the
/// curves never merge. Both curves must be sampled at the same iterations.
pub fn transient_iterations(dec: &[f64], par: &[f64], ratio: f64, window: usize) -> Option<usize> {
    assert_eq!(dec.len(), par.len());
    let d = smooth(dec, window);
    let p = smooth(par, window);
    let mut k_merge = None;
    for k in 0..d.len() {
        if d[k] <= ratio * p[k] {
            if k_merge.is_none() {
                k_merge = Some(k);
            }
        } else {
            k_merge = None;
        }
    }
    k_merge
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smooth_is_mean_preserving_on_constants() {
        let s = smooth(&[2.0; 10], 4);
        assert!(s.iter().all(|&v| (v - 2.0).abs() < 1e-12));
    }

    #[test]
    fn detects_merge_point() {
        // Parallel: 1/k decay. Decentralized: 10/k until k=50, then equal.
        let par: Vec<f64> = (1..=100).map(|k| 1.0 / k as f64).collect();
        let dec: Vec<f64> = (1..=100)
            .map(|k| if k < 50 { 10.0 / k as f64 } else { 1.0 / k as f64 })
            .collect();
        let t = transient_iterations(&dec, &par, 1.5, 1).unwrap();
        assert!((45..=52).contains(&t), "t={t}");
    }

    #[test]
    fn no_merge_returns_none() {
        let par = vec![1.0; 50];
        let dec = vec![10.0; 50];
        assert_eq!(transient_iterations(&dec, &par, 1.5, 1), None);
    }

    #[test]
    fn transient_resets_on_recross() {
        // Merges at 10 but diverges again at 30, then re-merges at 60.
        let par = vec![1.0; 100];
        let mut dec = vec![5.0; 100];
        for v in dec.iter_mut().take(30).skip(10) {
            *v = 1.0;
        }
        for v in dec.iter_mut().skip(60) {
            *v = 1.0;
        }
        let t = transient_iterations(&dec, &par, 1.5, 1).unwrap();
        assert_eq!(t, 60);
    }
}
