//! Learning-rate schedules.
//!
//! The paper's protocols: linear warm-up for the first 5 epochs then ×0.1
//! decay at epochs 30/60/80 (ImageNet, Sec. 6.1); γ halved every 1000
//! iterations (logistic regression, Appendix D.5); and the theory rate
//! `γ = √(n(1−β)³/T)` (Theorem 1).

/// A learning-rate schedule evaluated per iteration.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    /// Constant γ.
    Const(f32),
    /// γ halved every `every` iterations (Appendix D.5 protocol).
    HalveEvery { init: f32, every: usize },
    /// Step decay by `factor` at each milestone iteration, with optional
    /// linear warm-up over the first `warmup` iterations (Goyal et al.
    /// protocol used in Sec. 6).
    Milestones { init: f32, factor: f32, milestones: Vec<usize>, warmup: usize },
}

impl LrSchedule {
    /// γ_k.
    pub fn at(&self, k: usize) -> f32 {
        match self {
            LrSchedule::Const(g) => *g,
            LrSchedule::HalveEvery { init, every } => init * 0.5f32.powi((k / every) as i32),
            LrSchedule::Milestones { init, factor, milestones, warmup } => {
                let base = if *warmup > 0 && k < *warmup {
                    init * (k + 1) as f32 / *warmup as f32
                } else {
                    *init
                };
                let hits = milestones.iter().filter(|&&m| k >= m).count() as i32;
                base * factor.powi(hits)
            }
        }
    }

    /// The theory step size of Theorem 1: `γ = √(n(1−β)³) / √T`, clipped
    /// to `max_lr` for stability at small T.
    pub fn theory(n: usize, beta: f32, total_iters: usize, max_lr: f32) -> LrSchedule {
        let g = ((n as f32) * (1.0 - beta).powi(3)).sqrt() / (total_iters as f32).sqrt();
        LrSchedule::Const(g.min(max_lr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halve_every() {
        let s = LrSchedule::HalveEvery { init: 0.2, every: 1000 };
        assert_eq!(s.at(0), 0.2);
        assert_eq!(s.at(999), 0.2);
        assert_eq!(s.at(1000), 0.1);
        assert_eq!(s.at(2500), 0.05);
    }

    #[test]
    fn milestones_with_warmup() {
        let s = LrSchedule::Milestones {
            init: 1.0,
            factor: 0.1,
            milestones: vec![100, 200],
            warmup: 10,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-6); // warming up
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!((s.at(50) - 1.0).abs() < 1e-6);
        assert!((s.at(150) - 0.1).abs() < 1e-6);
        assert!((s.at(250) - 0.01).abs() < 1e-7);
    }

    #[test]
    fn theory_rate_shrinks_with_t() {
        let a = LrSchedule::theory(16, 0.9, 1_000, 1.0).at(0);
        let b = LrSchedule::theory(16, 0.9, 100_000, 1.0).at(0);
        assert!(a > b);
        // γ = √(16·0.001)/√1000 = 0.1265.../31.6 ≈ 0.004
        assert!((a - (16.0f32 * 0.001f32).sqrt() / 1000f32.sqrt()).abs() < 1e-6);
    }
}
