//! Bounded-staleness asynchronous gossip executor
//! (docs/DESIGN.md §Async runtime).
//!
//! `execution = async:<τ>` replaces the bulk-synchronous round with a
//! **serial-wave** event model: every node still executes step `k`
//! during wave `k`, but each node advances on its own simulated clock —
//! netsim's deterministic hash-derived compute/link times decide *when*
//! a node's wave-`k` payload commits, and a node gossip-pulls whichever
//! committed payload **version** of each partner is ready when its own
//! clock gets there, at most `τ` iterations behind. Asynchrony
//! therefore lives in two places only:
//!
//! * the **clock** — a node never waits for the global slowest node,
//!   only for version `k − τ` of its partners (the staleness floor) and
//!   for the fleet to have released wave `k − τ − 1` (the progress
//!   gate); `sim_time` is the release envelope, not a sum of global
//!   barriers, which is where straggler resilience shows up;
//! * the **resolved versions** — the per-`(reader, partner)` payload
//!   version fed to the mixing fold.
//!
//! Numerically, a wave is two engine dispatches — (A) gradients fused
//! with payload staging into a `τ + 2`-slot version ring, (B) the
//! pull-based mix [`Optimizer::step_shard_async`] — plus the ordinary
//! serial `commit`. All kernels are row-local with fixed fold order and
//! every timing/resolution decision is a pure function of
//! `(seed, iter, endpoints)`, so async runs are reproducible and
//! bitwise lane-count-invariant, like every other subsystem.
//!
//! At `τ = 0` every resolution is forced fresh and the round is priced
//! by the exact synchronous code (netsim `simulate_round` or the
//! closed-form cost model), so `async:0` is **bitwise identical** to
//! `execution = sync` — pinned by `tests/engine_determinism.rs`.
//!
//! Scope: single-phase algorithms with an async gossip form
//! ([`Optimizer::async_streams`] > 0) and timing-only (faultless)
//! scenarios; anything else is rejected with a clear panic. With τ ≥ 1
//! an attached netsim is used as the timing oracle only — its round
//! counters do not advance.

use super::state::StackedParams;
use super::trainer::{Trainer, TrainingHistory};
use crate::compress::{stream_seed, Compressor};
use crate::costmodel::CostModel;
use crate::engine::{auto_lanes, shard_range, Engine, Lanes};
use crate::netsim::{NetSim, Scenario};
use crate::optim::{Optimizer, StepScratch};

/// Borrow ring slot `cur` mutably and slot `prev` immutably out of one
/// stream's version ring (slot-major, `nd` elements per slot).
fn split_ring_slot(ring: &mut [f32], cur: usize, prev: usize, nd: usize) -> (&mut [f32], &[f32]) {
    assert_ne!(cur, prev, "version ring needs at least 2 slots");
    if prev < cur {
        let (head, tail) = ring.split_at_mut(cur * nd);
        (&mut tail[..nd], &head[prev * nd..(prev + 1) * nd])
    } else {
        let (head, tail) = ring.split_at_mut(prev * nd);
        (&mut head[cur * nd..(cur + 1) * nd], &tail[..nd])
    }
}

/// Drive one full training run in bounded-staleness mode. Called by
/// [`Trainer::run_with`] when `cfg.execution = Async { tau }`.
pub(crate) fn run_async(
    tr: &mut Trainer<'_>,
    tau: usize,
    probe: &mut dyn FnMut(usize, &StackedParams),
) -> TrainingHistory {
    let Trainer { topology, optimizer, provider, cfg, netsim } = tr;
    let provider = *provider;
    let n = provider.nodes();
    let dim = provider.dim();
    assert_eq!(optimizer.params().n, n, "optimizer/provider node mismatch");
    assert_eq!(optimizer.params().dim, dim, "optimizer/provider dim mismatch");
    assert!(tau <= 1 << 16, "execution=async:{tau}: staleness bound is unreasonably large");

    let streams = optimizer.async_streams();
    assert!(
        streams > 0,
        "execution=async:{tau}: algorithm '{}' has no async gossip form; use execution=sync",
        optimizer.name()
    );
    assert_eq!(
        optimizer.phases(),
        1,
        "async execution supports single-phase algorithms only"
    );
    if let Some(sim) = netsim.as_ref() {
        assert!(
            sim.scenario.is_faultless(),
            "execution=async:{tau}: scenario '{}' drops messages or partitions nodes; \
             the bounded-staleness executor models timing faults only",
            sim.scenario.name
        );
    }

    let msg_bytes = cfg.msg_bytes.unwrap_or(4.0 * dim as f64);
    let gossip_bytes = cfg.compressor.wire_bytes(msg_bytes);
    let comp: Option<Box<dyn Compressor>> =
        if cfg.compressor.is_identity() { None } else { Some(cfg.compressor.build()) };
    let gamma = comp.as_ref().map(|c| c.gamma()).unwrap_or(1.0);
    let sseeds: Vec<u64> = (0..streams).map(|s| stream_seed(cfg.seed, s)).collect();

    // Same engine sizing as the synchronous path.
    let lanes = cfg.lanes.unwrap_or_else(|| {
        if cfg.parallel_grads {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
        } else {
            auto_lanes(n, n * dim)
        }
    });
    let engine = Engine::new(lanes.clamp(1, n.max(1)));
    let lanes_n = engine.lanes();

    if cfg.warmup_allreduce {
        optimizer.params_mut().allreduce();
    }

    // Timing oracle for τ ≥ 1: the attached netsim when present (used
    // read-only — counters do not advance), else an internal clean-
    // scenario simulator over `cfg.cost` (or the paper default, for
    // ordering only — times are emitted iff a netsim or cost model was
    // actually supplied, matching the sync path's contract).
    let owned_oracle: Option<NetSim> = if tau > 0 && netsim.is_none() {
        let cm = cfg.cost.unwrap_or_else(|| CostModel::paper_default(0.01));
        Some(NetSim::new(&cm, Scenario::clean(), cfg.seed))
    } else {
        None
    };
    let emit_times = netsim.is_some() || cfg.cost.is_some();

    // The payload version ring: `S = τ + 2` slots per stream, slot-major
    // `[slot][node][dim]`, slot = version mod S. Wave k reads versions
    // in `[k − τ, k]` (τ + 1 slots) while overwriting slot `k mod S`,
    // which leaves exactly one slot of headroom — no wave can clobber a
    // version still in another node's staleness window. Rings start at
    // zero, which is also the error-feedback reconstruction's initial
    // state, so the compressed chain matches sync's from wave 0.
    let s_slots = tau + 2;
    let nd = n * dim;
    let mut rings: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; s_slots * nd]).collect();
    // Raw (pre-compression) payloads of the current wave — the damped
    // consensus step's base. Unused (empty) under identity compression.
    let praw_len = if comp.is_some() { nd } else { 0 };
    let mut praw: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; praw_len]).collect();

    let mut grads = StackedParams::zeros(n, dim);
    let mut losses = vec![0.0f64; n];
    let mut scratch = StepScratch::default();
    let mut history = TrainingHistory::default();

    // Event-clock state (τ ≥ 1 only).
    let mut clock = vec![0.0f64; n];
    let mut start_of = vec![0.0f64; n];
    let mut t_comp = vec![0.0f64; n];
    let mut ready = vec![0.0f64; n * s_slots];
    let mut release_hist: Vec<f64> = Vec::with_capacity(cfg.iters);
    // Per-wave resolved version slots, CSR-aligned with
    // `plan.partners(u)` (ascending — the mix closure binary-searches).
    let mut res_off = vec![0usize; n + 1];
    let mut res_slot: Vec<u32> = Vec::new();

    for k in 0..cfg.iters {
        let lr = cfg.lr.at(k);
        let plan = topology.plan_at(k);
        let cur = k % s_slots;
        let prev = (cur + s_slots - 1) % s_slots;

        // ---- Dispatch A: gradients fused with payload staging. Each
        // lane computes its gradient rows, stages its raw payload rows
        // from them, and commits its rows of ring slot `k mod S` (for
        // compressed gossip: copy the node's previous reconstruction,
        // then advance it through the compressor — the same per-row
        // error-feedback chain as the sync path).
        {
            let opt: &dyn Optimizer = &**optimizer;
            let g = grads.lane_shards(lanes_n);
            let l = Lanes::split(&mut losses, n, 1, lanes_n);
            let mut cur_lanes = Vec::with_capacity(streams);
            let mut prev_views: Vec<&[f32]> = Vec::with_capacity(streams);
            for r in rings.iter_mut() {
                let (c, p) = split_ring_slot(r, cur, prev, nd);
                cur_lanes.push(Lanes::split(c, n, dim, lanes_n));
                prev_views.push(p);
            }
            let praw_lanes: Vec<Lanes<'_, f32>> =
                praw.iter_mut().map(|p| Lanes::split(p, n, dim, lanes_n)).collect();
            let comp_ref = comp.as_deref();
            let seed = cfg.seed;
            engine.run(&|lane| {
                let rows = shard_range(n, lanes_n, lane);
                if rows.is_empty() {
                    return;
                }
                let mut gs = g.lock(lane);
                let mut ls = l.lock(lane);
                let params = opt.params();
                for (off, i) in rows.clone().enumerate() {
                    let out = &mut gs[off * dim..(off + 1) * dim];
                    ls[off] = provider.grad(i, params.row(i), k, seed, out) as f64;
                }
                for s in 0..streams {
                    let mut cs = cur_lanes[s].lock(lane);
                    match comp_ref {
                        None => {
                            // Identity: the staged payload *is* the
                            // committed version.
                            opt.stage_shard_async(s, rows.clone(), &gs[..], lr, &mut cs[..]);
                        }
                        Some(c) => {
                            let mut ps = praw_lanes[s].lock(lane);
                            opt.stage_shard_async(s, rows.clone(), &gs[..], lr, &mut ps[..]);
                            let pv = prev_views[s];
                            for (off, i) in rows.clone().enumerate() {
                                let o = off * dim;
                                cs[o..o + dim].copy_from_slice(&pv[i * dim..(i + 1) * dim]);
                                c.compress_row(&ps[o..o + dim], &mut cs[o..o + dim], i, k, sseeds[s]);
                            }
                        }
                    }
                }
            });
        }
        history.loss.push(losses.iter().sum::<f64>() / n as f64);

        // ---- Serial: event clock + per-(reader, partner) version
        // resolution, and round pricing.
        res_slot.clear();
        if tau == 0 {
            // Degenerate staleness: every read is fresh. Pricing is the
            // exact synchronous code, so async:0 == sync bit for bit.
            for u in 0..n {
                for _ in plan.partners(u) {
                    res_slot.push(cur as u32);
                }
                res_off[u + 1] = res_slot.len();
            }
            if let Some(sim) = netsim.as_mut() {
                let outcome = sim.simulate_round(k, plan, gossip_bytes);
                let overlap = sim.cost.overlap;
                let t = outcome.iteration_time(overlap);
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(outcome.bytes_on_wire);
            } else if let Some(cost) = &cfg.cost {
                let slots: usize = (0..n).map(|u| plan.partners(u).len()).sum();
                let comm = cost.partial_averaging_time(plan, gossip_bytes);
                let bytes = slots as f64 * gossip_bytes;
                let hidden = cost.compute.min(comm) * cost.overlap;
                let t = cost.compute + comm - hidden;
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(bytes);
            }
        } else {
            let oracle: &NetSim =
                netsim.as_ref().or(owned_oracle.as_ref()).expect("async timing oracle");
            let overlap = oracle.cost.overlap;
            // Progress gate: wave k may start only once every node has
            // finished wave k − τ − 1 (bounded staleness is two-sided —
            // no node runs ahead of the floor it must serve).
            let gate = if k > tau { release_hist[k - tau - 1] } else { 0.0 };
            for u in 0..n {
                let start = clock[u].max(gate);
                start_of[u] = start;
                let tc = start + oracle.compute_time(k, u, n);
                t_comp[u] = tc;
                ready[u * s_slots + cur] = tc;
            }
            let lo = k.saturating_sub(tau);
            let prev_release = release_hist.last().copied().unwrap_or(0.0);
            let mut release = prev_release;
            for u in 0..n {
                let mut t = t_comp[u];
                for &v in plan.partners(u) {
                    let v = v as usize;
                    // Newest version in [k − τ, k] already committed by
                    // v when u's chain clock gets there; if even the
                    // floor is not ready, u blocks until it is.
                    let mut chosen = usize::MAX;
                    let mut j = k;
                    loop {
                        if ready[v * s_slots + j % s_slots] <= t {
                            chosen = j;
                            break;
                        }
                        if j == lo {
                            break;
                        }
                        j -= 1;
                    }
                    let slot_start = if chosen == usize::MAX {
                        chosen = lo;
                        t.max(ready[v * s_slots + lo % s_slots])
                    } else {
                        t
                    };
                    t = slot_start + oracle.slot_time(k, u, v, gossip_bytes);
                    res_slot.push((chosen % s_slots) as u32);
                }
                res_off[u + 1] = res_slot.len();
                let comp_t = t_comp[u] - start_of[u];
                let comm_t = t - t_comp[u];
                let hidden = comp_t.min(comm_t) * overlap;
                let finish = start_of[u] + comp_t + comm_t - hidden;
                clock[u] = finish;
                release = release.max(finish);
            }
            release_hist.push(release);
            if emit_times {
                let rt = release - prev_release;
                history.sim_time += rt;
                history.round_times.push(rt);
                let slots: usize = (0..n).map(|u| plan.partners(u).len()).sum();
                history.round_bytes.push(slots as f64 * gossip_bytes);
            }
        }

        // ---- Dispatch B: the pull-based mix. Every payload element is
        // read through the resolved-version closure; rows land in the
        // ordinary step scratch and the ordinary serial commit adopts
        // them.
        scratch.ensure(n, dim, optimizer.needs_secondary());
        optimizer.prepare(plan, &grads, lr);
        {
            let opt: &dyn Optimizer = &**optimizer;
            let ring_views: Vec<&[f32]> = rings.iter().map(|r| &r[..]).collect();
            let praw_views: Vec<&[f32]> = praw.iter().map(|p| &p[..]).collect();
            let res_off_ref = &res_off;
            let res_slot_ref = &res_slot;
            let src = |i: usize, s: usize, j: usize, e: usize| -> f32 {
                let slot = if j == i {
                    cur
                } else {
                    let ps = plan.partners(i);
                    let pos = ps.partition_point(|&c| (c as usize) < j);
                    debug_assert!(
                        pos < ps.len() && ps[pos] as usize == j,
                        "mix column {j} not among partners of {i}"
                    );
                    res_slot_ref[res_off_ref[i] + pos] as usize
                };
                ring_views[s][slot * nd + j * dim + e]
            };
            let damp_opt: Option<(f32, &[&[f32]])> =
                if comp.is_some() { Some((gamma, &praw_views[..])) } else { None };
            let a = Lanes::split(&mut scratch.a.data, n, dim, lanes_n);
            let b = Lanes::split(&mut scratch.b.data, n, dim, lanes_n);
            engine.run(&|lane| {
                let rows = shard_range(n, lanes_n, lane);
                if rows.is_empty() {
                    return;
                }
                let mut ga = a.lock(lane);
                let mut gb = b.lock(lane);
                opt.step_shard_async(rows, plan, &grads, lr, &src, damp_opt, &mut ga[..], &mut gb[..]);
            });
        }
        optimizer.commit(0, plan, &grads, lr, &mut scratch);

        if k % cfg.record_every == 0 || k + 1 == cfg.iters {
            history.consensus.push((k, engine.consensus_distance(optimizer.params())));
            history.lr.push((k, lr));
            probe(k, optimizer.params());
        }
    }
    history.dispatches = engine.dispatches();
    history
}
