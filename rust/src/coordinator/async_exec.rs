//! Bounded-staleness asynchronous gossip executors
//! (docs/DESIGN.md §Async runtime).
//!
//! `execution = async:<τ>` replaces the bulk-synchronous round with a
//! wave model: every node still executes step `k` during wave `k`, but
//! each node advances on its own simulated clock — netsim's
//! deterministic hash-derived compute/link times decide *when* a node's
//! wave-`k` payload commits, and a node gossip-pulls whichever
//! committed payload **version** of each partner is ready when its own
//! clock gets there, at most `τ` iterations behind. Asynchrony
//! therefore lives in two places only:
//!
//! * the **clock** ([`WaveClock`]) — a node never waits for the global
//!   slowest node, only for version `k − τ` of its partners (the
//!   staleness floor) and for the fleet to have released wave
//!   `k − τ − 1` (the progress gate); `sim_time` is the release
//!   envelope, not a sum of global barriers, which is where straggler
//!   resilience shows up;
//! * the **resolved versions** — the per-`(reader, partner)` payload
//!   version fed to the mixing fold.
//!
//! Two executors drive the numerics, selected by
//! [`TrainConfig::async_exec`](super::trainer::TrainConfig::async_exec):
//!
//! * [`run_waves_reference`] (`exec=waves`) — the serial-wave
//!   reference: wave `k` is two engine broadcast dispatches — (A)
//!   gradients fused with payload staging into the version ring, (B)
//!   the pull-based mix [`Optimizer::step_shard_async`] — plus the
//!   ordinary serial `commit`. Simple, and the pinning oracle.
//! * [`run_ready_batches`] (`exec=ooo`, default) — the out-of-order
//!   executor: the same wave is split into per-node tasks
//!   `A(i, w)` (gradient + stage + publish) and `B(i, w)` (pull-mix +
//!   commit in place), threaded through the engine's persistent
//!   [`WorkQueue`]. A task unlocks the moment its *own* inputs exist —
//!   `A(i, w)` after `B(i, w − 1)`, `B(i, w)` after `A(i, w)` and
//!   `A(j, v)` for each resolved partner version `v` — so a fast node
//!   runs up to `τ + 1` waves ahead of a straggler instead of parking
//!   on a fleet-wide barrier. Engine dispatches collapse from two
//!   barrier crossings per wave to **amortized O(1) per ready batch**:
//!   one queue session for the whole run plus at most one
//!   [`Engine::submit_batch`] per wave created (follow-on tasks ride
//!   the completion pushes for free), i.e. dispatches/iter
//!   ≤ 1 + 1/iters — strictly below 2 (pinned by `tests/async_exec.rs`
//!   and tracked in `BENCH_async.json`).
//!
//! **Determinism.** Both executors are bitwise identical for any lane
//! count and to each other (pinned by `tests/engine_determinism.rs`):
//! the freshest-ready down-scan with the `k − τ` floor is a pure
//! function of `(seed, iter, endpoints)` and is resolved *serially* by
//! the coordinator in [`WaveClock::advance`] before any task of the
//! wave is created, so the out-of-order schedule decides only *when*
//! a row kernel runs, never *what* it reads — every task consumes
//! exactly the version indices the serial reference would.
//!
//! At `τ = 0` every resolution is forced fresh and the round is priced
//! by the exact synchronous code (netsim `simulate_round` or the
//! closed-form cost model), so `async:0` is **bitwise identical** to
//! `execution = sync` — pinned by `tests/engine_determinism.rs`.
//!
//! Scope: single-phase algorithms with an async gossip form
//! ([`Optimizer::async_streams`] > 0) and timing-only (faultless)
//! scenarios; anything else is rejected with a clear panic. With τ ≥ 1
//! an attached netsim is used as the timing oracle only
//! ([`NetSim::ready_oracle`]) — its round counters do not advance.

use std::cell::UnsafeCell;
use std::sync::Mutex;

use super::state::StackedParams;
use super::trainer::{AsyncExec, TrainConfig, Trainer, TrainingHistory};
use crate::compress::{stream_seed, Compressor};
use crate::costmodel::CostModel;
use crate::engine::{auto_lanes, shard_range, Engine, Lanes, QueueTask, RowTable, WorkQueue};
use crate::netsim::{NetSim, Scenario};
use crate::optim::{Optimizer, StepScratch};
use crate::topology::plan::MixingPlan;

/// Borrow ring slot `cur` mutably and slot `prev` immutably out of one
/// stream's version ring (slot-major, `nd` elements per slot).
fn split_ring_slot(ring: &mut [f32], cur: usize, prev: usize, nd: usize) -> (&mut [f32], &[f32]) {
    assert_ne!(cur, prev, "version ring needs at least 2 slots");
    if prev < cur {
        let (head, tail) = ring.split_at_mut(cur * nd);
        (&mut tail[..nd], &head[prev * nd..(prev + 1) * nd])
    } else {
        let (head, tail) = ring.split_at_mut(prev * nd);
        (&mut head[cur * nd..(cur + 1) * nd], &tail[..nd])
    }
}

/// Everything both executors share: the validated run parameters, the
/// compression chain, the engine pool, and the timing oracle. Building
/// it also performs the optional warm-up all-reduce — state after
/// `setup` is "wave 0 may start".
struct Setup {
    streams: usize,
    gossip_bytes: f64,
    comp: Option<Box<dyn Compressor>>,
    gamma: f32,
    sseeds: Vec<u64>,
    engine: Engine,
    /// Internal clean-scenario oracle for τ ≥ 1 runs without an
    /// attached netsim (ordering only — see `emit_times`).
    owned_oracle: Option<NetSim>,
    /// Emit `sim_time`/`round_times`/`round_bytes` — true iff a netsim
    /// or cost model was actually supplied, matching the sync path.
    emit_times: bool,
}

fn setup(
    optimizer: &mut Box<dyn Optimizer>,
    provider: &dyn super::trainer::GradProvider,
    cfg: &TrainConfig,
    netsim: &Option<NetSim>,
    tau: usize,
) -> Setup {
    let n = provider.nodes();
    let dim = provider.dim();
    assert_eq!(optimizer.params().n, n, "optimizer/provider node mismatch");
    assert_eq!(optimizer.params().dim, dim, "optimizer/provider dim mismatch");
    assert!(tau <= 1 << 16, "execution=async:{tau}: staleness bound is unreasonably large");

    let streams = optimizer.async_streams();
    assert!(
        streams > 0,
        "execution=async:{tau}: algorithm '{}' has no async gossip form; use execution=sync",
        optimizer.name()
    );
    assert_eq!(
        optimizer.phases(),
        1,
        "async execution supports single-phase algorithms only"
    );
    if let Some(sim) = netsim.as_ref() {
        assert!(
            sim.scenario.is_faultless(),
            "execution=async:{tau}: scenario '{}' drops messages or partitions nodes; \
             the bounded-staleness executor models timing faults only",
            sim.scenario.name
        );
    }

    let msg_bytes = cfg.msg_bytes.unwrap_or(4.0 * dim as f64);
    let gossip_bytes = cfg.compressor.wire_bytes(msg_bytes);
    let comp: Option<Box<dyn Compressor>> =
        if cfg.compressor.is_identity() { None } else { Some(cfg.compressor.build()) };
    let gamma = comp.as_ref().map(|c| c.gamma()).unwrap_or(1.0);
    let sseeds: Vec<u64> = (0..streams).map(|s| stream_seed(cfg.seed, s)).collect();

    // Same engine sizing as the synchronous path.
    let lanes = cfg.lanes.unwrap_or_else(|| {
        if cfg.parallel_grads {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
        } else {
            auto_lanes(n, n * dim)
        }
    });
    let engine = Engine::new(lanes.clamp(1, n.max(1)));

    if cfg.warmup_allreduce {
        optimizer.params_mut().allreduce();
    }

    // Timing oracle for τ ≥ 1: the attached netsim when present (used
    // read-only — counters do not advance), else an internal clean-
    // scenario simulator over `cfg.cost` (or the paper default, for
    // ordering only — times are emitted iff a netsim or cost model was
    // actually supplied, matching the sync path's contract).
    let owned_oracle: Option<NetSim> = if tau > 0 && netsim.is_none() {
        let cm = cfg.cost.unwrap_or_else(|| CostModel::paper_default(0.01));
        Some(NetSim::new(&cm, Scenario::clean(), cfg.seed))
    } else {
        None
    };
    let emit_times = netsim.is_some() || cfg.cost.is_some();

    Setup { streams, gossip_bytes, comp, gamma, sseeds, engine, owned_oracle, emit_times }
}

/// The serial event clock: per-node chain clocks, the per-version
/// ready-time ring, the fleet release envelope, and the per-wave
/// resolved versions. [`WaveClock::advance`] is the *only* place
/// staleness is resolved — both executors call it from their (serial)
/// coordinator, so resolved versions are a pure function of
/// `(seed, wave)` regardless of how tasks are later scheduled.
struct WaveClock {
    tau: usize,
    n: usize,
    /// Ready-ring slots: `τ + 2` (wave `k` writes slot `k mod cs` while
    /// reading the `τ + 1` versions in `[k − τ, k]`).
    cs: usize,
    clock: Vec<f64>,
    start_of: Vec<f64>,
    t_comp: Vec<f64>,
    ready: Vec<f64>,
    release_hist: Vec<f64>,
    /// CSR offsets of `res_ver`, aligned with `plan.partners(u)`
    /// (ascending — the mix closure binary-searches).
    res_off: Vec<usize>,
    /// Resolved payload **versions** (wave indices, not ring slots — the
    /// executor maps them onto its own ring size).
    res_ver: Vec<u32>,
}

impl WaveClock {
    fn new(tau: usize, n: usize, iters: usize) -> WaveClock {
        let cs = tau + 2;
        WaveClock {
            tau,
            n,
            cs,
            clock: vec![0.0; n],
            start_of: vec![0.0; n],
            t_comp: vec![0.0; n],
            ready: vec![0.0; n * cs],
            release_hist: Vec::with_capacity(iters),
            res_off: vec![0; n + 1],
            res_ver: Vec::new(),
        }
    }

    /// Resolve wave `k`: fill `res_off`/`res_ver` with the freshest
    /// ready version of each `(reader, partner)` pair and price the
    /// round into `history`. At `τ = 0` pricing is the exact
    /// synchronous code (so `async:0` == sync bit for bit); at `τ ≥ 1`
    /// the round time is the growth of the fleet release envelope.
    #[allow(clippy::too_many_arguments)]
    fn advance(
        &mut self,
        k: usize,
        plan: &MixingPlan,
        netsim: &mut Option<NetSim>,
        owned_oracle: &Option<NetSim>,
        cost: &Option<CostModel>,
        gossip_bytes: f64,
        emit_times: bool,
        history: &mut TrainingHistory,
    ) {
        let (n, tau, cs) = (self.n, self.tau, self.cs);
        self.res_ver.clear();
        if tau == 0 {
            // Degenerate staleness: every read is fresh. Pricing is the
            // exact synchronous code, so async:0 == sync bit for bit.
            for u in 0..n {
                for _ in plan.partners(u) {
                    self.res_ver.push(k as u32);
                }
                self.res_off[u + 1] = self.res_ver.len();
            }
            if let Some(sim) = netsim.as_mut() {
                let outcome = sim.simulate_round(k, plan, gossip_bytes);
                let overlap = sim.cost.overlap;
                let t = outcome.iteration_time(overlap);
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(outcome.bytes_on_wire);
            } else if let Some(cost) = cost {
                let slots: usize = (0..n).map(|u| plan.partners(u).len()).sum();
                let comm = cost.partial_averaging_time(plan, gossip_bytes);
                let bytes = slots as f64 * gossip_bytes;
                let hidden = cost.compute.min(comm) * cost.overlap;
                let t = cost.compute + comm - hidden;
                history.sim_time += t;
                history.round_times.push(t);
                history.round_bytes.push(bytes);
            }
            return;
        }
        let oracle = netsim
            .as_ref()
            .or(owned_oracle.as_ref())
            .expect("async timing oracle")
            .ready_oracle();
        let overlap = oracle.overlap();
        // Progress gate: wave k may start only once every node has
        // finished wave k − τ − 1 (bounded staleness is two-sided —
        // no node runs ahead of the floor it must serve).
        let gate = if k > tau { self.release_hist[k - tau - 1] } else { 0.0 };
        for u in 0..n {
            let start = self.clock[u].max(gate);
            self.start_of[u] = start;
            let tc = oracle.compute_done(k, u, n, start);
            self.t_comp[u] = tc;
            self.ready[u * cs + k % cs] = tc;
        }
        let lo = k.saturating_sub(tau);
        let prev_release = self.release_hist.last().copied().unwrap_or(0.0);
        let mut release = prev_release;
        for u in 0..n {
            let mut t = self.t_comp[u];
            for &v in plan.partners(u) {
                let v = v as usize;
                // Newest version in [k − τ, k] already committed by
                // v when u's chain clock gets there; if even the
                // floor is not ready, u blocks until it is.
                let mut chosen = usize::MAX;
                let mut j = k;
                loop {
                    if self.ready[v * cs + j % cs] <= t {
                        chosen = j;
                        break;
                    }
                    if j == lo {
                        break;
                    }
                    j -= 1;
                }
                let slot_start = if chosen == usize::MAX {
                    chosen = lo;
                    t.max(self.ready[v * cs + lo % cs])
                } else {
                    t
                };
                t = oracle.pull_done(k, u, v, slot_start, gossip_bytes);
                self.res_ver.push(chosen as u32);
            }
            self.res_off[u + 1] = self.res_ver.len();
            let comp_t = self.t_comp[u] - self.start_of[u];
            let comm_t = t - self.t_comp[u];
            let hidden = comp_t.min(comm_t) * overlap;
            let finish = self.start_of[u] + comp_t + comm_t - hidden;
            self.clock[u] = finish;
            release = release.max(finish);
        }
        self.release_hist.push(release);
        if emit_times {
            let rt = release - prev_release;
            history.sim_time += rt;
            history.round_times.push(rt);
            let slots: usize = (0..n).map(|u| plan.partners(u).len()).sum();
            history.round_bytes.push(slots as f64 * gossip_bytes);
        }
    }
}

/// Drive one full training run in bounded-staleness mode. Called by
/// [`Trainer::run_with`] when `cfg.execution = Async { tau }`; picks
/// the executor from `cfg.async_exec`.
pub(crate) fn run_async(
    tr: &mut Trainer<'_>,
    tau: usize,
    probe: &mut dyn FnMut(usize, &StackedParams),
) -> TrainingHistory {
    match tr.cfg.async_exec {
        AsyncExec::Waves => run_waves_reference(tr, tau, probe),
        AsyncExec::Ooo => run_ready_batches(tr, tau, probe),
    }
}

/// The serial-wave reference executor (`exec=waves`): two engine
/// broadcast dispatches per wave, fleet-wide. Kept as the escape hatch
/// and the pinning oracle for [`run_ready_batches`].
fn run_waves_reference(
    tr: &mut Trainer<'_>,
    tau: usize,
    probe: &mut dyn FnMut(usize, &StackedParams),
) -> TrainingHistory {
    let Trainer { topology, optimizer, provider, cfg, netsim } = tr;
    let provider = *provider;
    let n = provider.nodes();
    let dim = provider.dim();
    let Setup { streams, gossip_bytes, comp, gamma, sseeds, engine, owned_oracle, emit_times } =
        setup(optimizer, provider, cfg, netsim, tau);
    let lanes_n = engine.lanes();

    // The payload version ring: `S = τ + 2` slots per stream, slot-major
    // `[slot][node][dim]`, slot = version mod S. Wave k reads versions
    // in `[k − τ, k]` (τ + 1 slots) while overwriting slot `k mod S`,
    // which leaves exactly one slot of headroom — no wave can clobber a
    // version still in another node's staleness window. Rings start at
    // zero, which is also the error-feedback reconstruction's initial
    // state, so the compressed chain matches sync's from wave 0.
    let s_slots = tau + 2;
    let nd = n * dim;
    let mut rings: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; s_slots * nd]).collect();
    // Raw (pre-compression) payloads of the current wave — the damped
    // consensus step's base. Unused (empty) under identity compression.
    let praw_len = if comp.is_some() { nd } else { 0 };
    let mut praw: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; praw_len]).collect();

    let mut grads = StackedParams::zeros(n, dim);
    let mut losses = vec![0.0f64; n];
    let mut scratch = StepScratch::default();
    let mut history = TrainingHistory::default();
    let mut clock = WaveClock::new(tau, n, cfg.iters);

    for k in 0..cfg.iters {
        let lr = cfg.lr.at(k);
        let plan = topology.plan_at(k);
        let cur = k % s_slots;
        let prev = (cur + s_slots - 1) % s_slots;

        // ---- Dispatch A: gradients fused with payload staging. Each
        // lane computes its gradient rows, stages its raw payload rows
        // from them, and commits its rows of ring slot `k mod S` (for
        // compressed gossip: copy the node's previous reconstruction,
        // then advance it through the compressor — the same per-row
        // error-feedback chain as the sync path).
        {
            let opt: &dyn Optimizer = &**optimizer;
            let g = grads.lane_shards(lanes_n);
            let l = Lanes::split(&mut losses, n, 1, lanes_n);
            let mut cur_lanes = Vec::with_capacity(streams);
            let mut prev_views: Vec<&[f32]> = Vec::with_capacity(streams);
            for r in rings.iter_mut() {
                let (c, p) = split_ring_slot(r, cur, prev, nd);
                cur_lanes.push(Lanes::split(c, n, dim, lanes_n));
                prev_views.push(p);
            }
            let praw_lanes: Vec<Lanes<'_, f32>> =
                praw.iter_mut().map(|p| Lanes::split(p, n, dim, lanes_n)).collect();
            let comp_ref = comp.as_deref();
            let seed = cfg.seed;
            engine.run(&|lane| {
                let rows = shard_range(n, lanes_n, lane);
                if rows.is_empty() {
                    return;
                }
                let mut gs = g.lock(lane);
                let mut ls = l.lock(lane);
                let params = opt.params();
                for (off, i) in rows.clone().enumerate() {
                    let out = &mut gs[off * dim..(off + 1) * dim];
                    ls[off] = provider.grad(i, params.row(i), k, seed, out) as f64;
                }
                for s in 0..streams {
                    let mut cs = cur_lanes[s].lock(lane);
                    match comp_ref {
                        None => {
                            // Identity: the staged payload *is* the
                            // committed version.
                            opt.stage_shard_async(s, rows.clone(), &gs[..], lr, &mut cs[..]);
                        }
                        Some(c) => {
                            let mut ps = praw_lanes[s].lock(lane);
                            opt.stage_shard_async(s, rows.clone(), &gs[..], lr, &mut ps[..]);
                            let pv = prev_views[s];
                            for (off, i) in rows.clone().enumerate() {
                                let o = off * dim;
                                cs[o..o + dim].copy_from_slice(&pv[i * dim..(i + 1) * dim]);
                                c.compress_row(&ps[o..o + dim], &mut cs[o..o + dim], i, k, sseeds[s]);
                            }
                        }
                    }
                }
            });
        }
        history.loss.push(losses.iter().sum::<f64>() / n as f64);

        // ---- Serial: event clock + per-(reader, partner) version
        // resolution, and round pricing.
        clock.advance(
            k,
            plan,
            netsim,
            &owned_oracle,
            &cfg.cost,
            gossip_bytes,
            emit_times,
            &mut history,
        );

        // ---- Dispatch B: the pull-based mix. Every payload element is
        // read through the resolved-version closure; rows land in the
        // ordinary step scratch and the ordinary serial commit adopts
        // them.
        scratch.ensure(n, dim, optimizer.needs_secondary());
        optimizer.prepare(plan, &grads, lr);
        {
            let opt: &dyn Optimizer = &**optimizer;
            let ring_views: Vec<&[f32]> = rings.iter().map(|r| &r[..]).collect();
            let praw_views: Vec<&[f32]> = praw.iter().map(|p| &p[..]).collect();
            let res_off_ref = &clock.res_off;
            let res_ver_ref = &clock.res_ver;
            let src = |i: usize, s: usize, j: usize, e: usize| -> f32 {
                let slot = if j == i {
                    cur
                } else {
                    let ps = plan.partners(i);
                    let pos = ps.partition_point(|&c| (c as usize) < j);
                    debug_assert!(
                        pos < ps.len() && ps[pos] as usize == j,
                        "mix column {j} not among partners of {i}"
                    );
                    res_ver_ref[res_off_ref[i] + pos] as usize % s_slots
                };
                ring_views[s][slot * nd + j * dim + e]
            };
            let damp_opt: Option<(f32, &[&[f32]])> =
                if comp.is_some() { Some((gamma, &praw_views[..])) } else { None };
            let a = Lanes::split(&mut scratch.a.data, n, dim, lanes_n);
            let b = Lanes::split(&mut scratch.b.data, n, dim, lanes_n);
            engine.run(&|lane| {
                let rows = shard_range(n, lanes_n, lane);
                if rows.is_empty() {
                    return;
                }
                let mut ga = a.lock(lane);
                let mut gb = b.lock(lane);
                opt.step_shard_async(rows, plan, &grads, lr, &src, damp_opt, &mut ga[..], &mut gb[..]);
            });
        }
        optimizer.commit(0, plan, &grads, lr, &mut scratch);

        if k % cfg.record_every == 0 || k + 1 == cfg.iters {
            history.consensus.push((k, engine.consensus_distance(optimizer.params())));
            history.lr.push((k, lr));
            probe(k, optimizer.params());
        }
    }
    history.dispatches = engine.dispatches();
    history
}

/// Interior-mutable cell for the wave-slot ring: the coordinator fills
/// slot `w mod W` strictly before registering wave `w` (at which point
/// no task of waves `w − W` and earlier is live — finalize waited for
/// them — and no task of wave `w` exists yet), and tasks only read it.
struct SlotCell<T>(UnsafeCell<T>);

// Safety: accesses are ordered by the DAG/queue mutexes — the slot is
// never written while a reader is live (see struct docs).
unsafe impl<T: Send> Sync for SlotCell<T> {}

/// Per-wave immutable inputs, published to tasks through the slot ring:
/// the mixing plan, the serially-resolved partner versions (CSR over
/// `plan.partners`), the learning rate, and the record flag.
struct WaveSlot {
    plan: MixingPlan,
    res_off: Vec<usize>,
    res_ver: Vec<u32>,
    lr: f32,
    record: bool,
}

/// A pending wake-up: reader `reader`'s `B(reader, wave)` needs
/// publisher version `needed` (i.e. `A(publisher, needed)` complete).
struct Awaiter {
    reader: u32,
    wave: u32,
    needed: u32,
}

/// The ready-set dependency tracker. All transitions run under one
/// mutex, which both linearizes the single-push invariant (exactly one
/// of `register_wave`/`complete_b` enqueues a given `A`, exactly one
/// `complete_a` enqueues a given `B`) and provides the happens-before
/// edges that make the [`RowTable`] row hand-offs sound.
///
/// Unlock rules:
/// * `A(i, w)` — ready when `B(i, w − 1)` is done (a node's tasks form
///   a serial chain; `A` reads the `x`/`m` rows `B` last wrote).
/// * `B(i, w)` — ready when `A(i, w)` is done *and*, for every partner
///   `j` of wave `w`, the resolved version `A(j, res_ver)` is done.
struct Dag {
    n: usize,
    w_slots: usize,
    /// Per node: number of completed `A` tasks (== first wave whose `A`
    /// is still pending). Version `v` of node `j` exists iff
    /// `a_done[j] > v`.
    a_done: Vec<u32>,
    /// Per node: number of completed `B` tasks.
    b_done: Vec<u32>,
    /// Outstanding input count of `B(i, w)` at `[(w mod W)·n + i]`.
    b_missing: Vec<u32>,
    /// Unfinished `B` tasks of wave `w` at `[w mod W]` — the
    /// coordinator's finalization condition.
    b_remaining: Vec<u32>,
    /// Per publisher node: readers waiting on one of its versions.
    awaiters: Vec<Vec<Awaiter>>,
    /// Number of waves registered so far (`A(i, w)` may only be pushed
    /// for `w < created`).
    created: u32,
}

impl Dag {
    fn new(n: usize, w_slots: usize) -> Dag {
        Dag {
            n,
            w_slots,
            a_done: vec![0; n],
            b_done: vec![0; n],
            b_missing: vec![0; w_slots * n],
            b_remaining: vec![0; w_slots],
            awaiters: (0..n).map(|_| Vec::new()).collect(),
            created: 0,
        }
    }

    /// Publish wave `w`'s dependency rows and push every task of it
    /// that is ready right now onto `ready`.
    fn register_wave(
        &mut self,
        w: usize,
        plan: &MixingPlan,
        res_off: &[usize],
        res_ver: &[u32],
        ready: &mut Vec<QueueTask>,
    ) {
        let n = self.n;
        let base = (w % self.w_slots) * n;
        self.created = w as u32 + 1;
        self.b_remaining[w % self.w_slots] = n as u32;
        for i in 0..n {
            // Own publish: A(i, w) cannot have completed before its wave
            // was registered, so it is always an outstanding input.
            let mut missing = 1u32;
            self.awaiters[i].push(Awaiter { reader: i as u32, wave: w as u32, needed: w as u32 });
            for (idx, &j) in plan.partners(i).iter().enumerate() {
                let j = j as usize;
                let ver = res_ver[res_off[i] + idx];
                if self.a_done[j] <= ver {
                    missing += 1;
                    self.awaiters[j].push(Awaiter {
                        reader: i as u32,
                        wave: w as u32,
                        needed: ver,
                    });
                }
            }
            self.b_missing[base + i] = missing;
            // A(i, w) unlocks off B(i, w − 1); if that already happened
            // (or w == 0) the registration itself pushes it.
            if self.b_done[i] >= w as u32 {
                ready.push(QueueTask { node: i as u32, wave: w as u32, stage: 0 });
            }
        }
    }

    /// `A(i, w)` finished: version `w` of node `i` now exists. Satisfy
    /// every awaiter whose needed version is covered and push each `B`
    /// whose input count hits zero.
    fn complete_a(&mut self, i: usize, w: usize, ready: &mut Vec<QueueTask>) {
        self.a_done[i] = w as u32 + 1;
        // Temporarily move the list out so the scan can mutate
        // `b_missing` without aliasing `self.awaiters`.
        let mut aws = std::mem::take(&mut self.awaiters[i]);
        let mut idx = 0;
        while idx < aws.len() {
            if aws[idx].needed < self.a_done[i] {
                let aw = aws.swap_remove(idx);
                let slot = (aw.wave as usize % self.w_slots) * self.n + aw.reader as usize;
                self.b_missing[slot] -= 1;
                if self.b_missing[slot] == 0 {
                    ready.push(QueueTask { node: aw.reader, wave: aw.wave, stage: 1 });
                }
            } else {
                idx += 1;
            }
        }
        self.awaiters[i] = aws;
    }

    /// `B(i, w)` finished: node `i`'s state rows are committed for wave
    /// `w`; its next `A` unlocks if that wave is already registered, and
    /// wave `w` moves one node closer to finalization.
    fn complete_b(&mut self, i: usize, w: usize, ready: &mut Vec<QueueTask>) {
        self.b_done[i] = w as u32 + 1;
        if self.b_done[i] < self.created {
            ready.push(QueueTask { node: i as u32, wave: w as u32 + 1, stage: 0 });
        }
        self.b_remaining[w % self.w_slots] -= 1;
    }
}

/// The out-of-order ready-batch executor (`exec=ooo`, default): per-node
/// tasks over the engine's work queue, unlocked the moment their inputs
/// exist. Bitwise identical to [`run_waves_reference`] (see module
/// docs) at amortized O(1) engine dispatches per ready batch.
fn run_ready_batches(
    tr: &mut Trainer<'_>,
    tau: usize,
    probe: &mut dyn FnMut(usize, &StackedParams),
) -> TrainingHistory {
    let Trainer { topology, optimizer, provider, cfg, netsim } = tr;
    let provider = *provider;
    let n = provider.nodes();
    let dim = provider.dim();
    let Setup { streams, gossip_bytes, comp, gamma, sseeds, engine, owned_oracle, emit_times } =
        setup(optimizer, provider, cfg, netsim, tau);
    let lanes_n = engine.lanes();
    let iters = cfg.iters;

    let mut history = TrainingHistory::default();
    if iters == 0 {
        return history;
    }

    // In-flight window W = τ + 2 waves: wave w is created once wave
    // w − W is finalized, so per-wave rows (loss, snapshots, wave
    // slots) ride a W-slot ring. The payload ring is *wider* than the
    // reference executor's: S = 2τ + 2 slots. Out of order, a reader
    // B(j, w') may consume version v as late as wave w' = v + τ, and
    // the writer A(i, v + S) exists no earlier than the creation of
    // wave v + S = (v + τ) + (τ + 2) — strictly after wave v + τ
    // finalized, so with S ≥ 2τ + 2 no live version is ever clobbered.
    let w_slots = tau + 2;
    let s_ring = 2 * tau + 2;
    let nd = n * dim;

    let mut clock = WaveClock::new(tau, n, iters);
    // The optimizer's state stacks, taken so per-node tasks can write
    // x/m rows in place through RowTables (no scratch, no commit — the
    // supported single-phase algorithms' commits are pure swaps, so the
    // in-place per-node form is bitwise identical; restored below).
    let (mut x_stack, mut m_stack) = optimizer.take_async_state();

    let mut grads_buf = vec![0.0f32; nd];
    let mut loss_buf = vec![0.0f64; w_slots * n];
    let mut ring_bufs: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; s_ring * nd]).collect();
    let praw_len = if comp.is_some() { nd } else { 0 };
    let mut praw_bufs: Vec<Vec<f32>> = (0..streams).map(|_| vec![0.0f32; praw_len]).collect();
    let mut snap_buf = vec![0.0f32; w_slots * nd];
    let mut tmp_buf = vec![0.0f32; lanes_n * dim];
    let mut probe_buf = StackedParams::zeros(n, dim);

    let init_plan = topology.plan_at(0).clone();
    let slots: Vec<SlotCell<WaveSlot>> = (0..w_slots)
        .map(|_| {
            SlotCell(UnsafeCell::new(WaveSlot {
                plan: init_plan.clone(),
                res_off: Vec::new(),
                res_ver: Vec::new(),
                lr: 0.0,
                record: false,
            }))
        })
        .collect();

    let dag = Mutex::new(Dag::new(n, w_slots));
    let queue = WorkQueue::new();
    let lock_dag = || dag.lock().unwrap_or_else(|p| p.into_inner());

    {
        let x_tab = RowTable::new(&mut x_stack.data, dim);
        let m_tab = RowTable::new(&mut m_stack.data, dim);
        let grads_tab = RowTable::new(&mut grads_buf, dim);
        let loss_tab = RowTable::new(&mut loss_buf, 1);
        let ring_tabs: Vec<RowTable<'_, f32>> =
            ring_bufs.iter_mut().map(|r| RowTable::new(r, dim)).collect();
        let praw_tabs: Vec<RowTable<'_, f32>> =
            praw_bufs.iter_mut().map(|p| RowTable::new(p, dim)).collect();
        let snap_tab = RowTable::new(&mut snap_buf, dim);
        let tmp_tab = RowTable::new(&mut tmp_buf, dim);
        let opt: &dyn Optimizer = &**optimizer;
        let comp_ref = comp.as_deref();
        let seed = cfg.seed;
        let slots_ref = &slots;
        let sseeds_ref = &sseeds;

        // One task body for both stages; `lane` picks the scratch row.
        // Safety of every `RowTable` access: the DAG's unlock rules make
        // each row single-writer with mutex-ordered hand-offs — see the
        // per-line comments and docs/DESIGN.md §Async runtime.
        let run_task = |lane: usize, t: QueueTask| {
            let i = t.node as usize;
            let w = t.wave as usize;
            // Slot w mod W is immutable while any task of wave w is
            // live (rewritten only at wave w + W's creation, after
            // wave w finalized).
            let slot = unsafe { &*slots_ref[w % w_slots].0.get() };
            if t.stage == 0 {
                // ---- A(i, w): gradient, stage, publish. Row chain
                // A(i,w) → B(i,w) → A(i,w+1) makes grads/x/m/praw rows
                // single-writer; the ring row (w mod S, i) has no live
                // readers (window proof above).
                let x_row = unsafe { x_tab.row(i) };
                let m_row = unsafe { m_tab.row(i) };
                let g_row = unsafe { grads_tab.row_mut(i) };
                let loss = provider.grad(i, x_row, w, seed, g_row);
                unsafe { loss_tab.row_mut((w % w_slots) * n + i) }[0] = loss as f64;
                for (s, ring_tab) in ring_tabs.iter().enumerate() {
                    let cur_row = unsafe { ring_tab.row_mut((w % s_ring) * n + i) };
                    match comp_ref {
                        None => {
                            opt.stage_node_async(s, x_row, m_row, g_row, slot.lr, cur_row);
                        }
                        Some(c) => {
                            let p_row = unsafe { praw_tabs[s].row_mut(i) };
                            opt.stage_node_async(s, x_row, m_row, g_row, slot.lr, p_row);
                            // Previous reconstruction: version w − 1
                            // (slot S − 1 at w = 0 — still all zeros,
                            // the chain's initial state).
                            let prev_row =
                                unsafe { ring_tab.row(((w + s_ring - 1) % s_ring) * n + i) };
                            cur_row.copy_from_slice(prev_row);
                            c.compress_row(p_row, cur_row, i, w, sseeds_ref[s]);
                        }
                    }
                }
                let mut ready = Vec::new();
                lock_dag().complete_a(i, w, &mut ready);
                // Follow-on tasks ride the completion push — no engine
                // dispatch charged (the amortized-O(1) economy).
                queue.push_many(&ready);
                queue.nudge();
            } else {
                // ---- B(i, w): pull-mix + in-place commit. Reads only
                // published ring versions (complete by the unlock rule)
                // and its own grads/praw rows; writes its own x/m rows.
                let g_row = unsafe { grads_tab.row(i) };
                let x_row = unsafe { x_tab.row_mut(i) };
                let m_row = unsafe { m_tab.row_mut(i) };
                let tmp = unsafe { tmp_tab.row_mut(lane) };
                let src = |s: usize, j: usize, e: usize| -> f32 {
                    let ver = if j == i {
                        w
                    } else {
                        let ps = slot.plan.partners(i);
                        let pos = ps.partition_point(|&c| (c as usize) < j);
                        debug_assert!(
                            pos < ps.len() && ps[pos] as usize == j,
                            "mix column {j} not among partners of {i}"
                        );
                        slot.res_ver[slot.res_off[i] + pos] as usize
                    };
                    unsafe { ring_tabs[s].row((ver % s_ring) * n + j) }[e]
                };
                let praw_rows: Vec<&[f32]> =
                    praw_tabs.iter().map(|p| unsafe { p.row(i) }).collect();
                let damp: Option<(f32, &[&[f32]])> =
                    if comp_ref.is_some() { Some((gamma, &praw_rows[..])) } else { None };
                opt.step_node_async(i, &slot.plan, g_row, slot.lr, &src, damp, x_row, m_row, tmp);
                if slot.record {
                    unsafe { snap_tab.row_mut((w % w_slots) * n + i) }.copy_from_slice(x_row);
                }
                let mut ready = Vec::new();
                lock_dag().complete_b(i, w, &mut ready);
                queue.push_many(&ready);
                queue.nudge();
            }
        };

        let mut coordinator = || {
            let mut created = 0usize;
            let mut batch: Vec<QueueTask> = Vec::new();
            for f in 0..iters {
                // Create every wave the window allows: wave w needs
                // wave w − W finalized (its per-wave ring rows free).
                while created < iters && created < f + w_slots {
                    let w = created;
                    let plan = topology.plan_at(w);
                    clock.advance(
                        w,
                        plan,
                        netsim,
                        &owned_oracle,
                        &cfg.cost,
                        gossip_bytes,
                        emit_times,
                        &mut history,
                    );
                    // Safety: no task of wave w exists yet and every
                    // task of wave w − W finished (finalized) — the
                    // slot has no concurrent reader.
                    let slot = unsafe { &mut *slots_ref[w % w_slots].0.get() };
                    slot.plan.clone_from(plan);
                    slot.res_off.clone_from(&clock.res_off);
                    slot.res_ver.clone_from(&clock.res_ver);
                    slot.lr = cfg.lr.at(w);
                    slot.record = w % cfg.record_every == 0 || w + 1 == iters;
                    batch.clear();
                    lock_dag().register_wave(w, &slot.plan, &slot.res_off, &slot.res_ver, &mut batch);
                    if !batch.is_empty() {
                        engine.submit_batch(&queue, &batch);
                    }
                    created += 1;
                }
                // Help drain until wave f is fully mixed, parking only
                // when the queue is empty (every completion nudges).
                loop {
                    if lock_dag().b_remaining[f % w_slots] == 0 {
                        break;
                    }
                    if let Some(t) = queue.try_pop() {
                        run_task(0, t);
                        continue;
                    }
                    let seen = queue.epoch();
                    if lock_dag().b_remaining[f % w_slots] == 0 {
                        break;
                    }
                    if queue.closed() {
                        panic!("async executor: a worker lane failed");
                    }
                    queue.wait_event(seen);
                }
                // ---- Finalize wave f: mean loss in node order (the
                // exact f64 sum the reference takes) and the throttled
                // consensus probe from the wave's snapshot rows.
                let base = (f % w_slots) * n;
                let mut loss_sum = 0.0f64;
                for i in 0..n {
                    loss_sum += unsafe { loss_tab.row(base + i) }[0];
                }
                history.loss.push(loss_sum / n as f64);
                let slot = unsafe { &*slots_ref[f % w_slots].0.get() };
                if slot.record {
                    for i in 0..n {
                        probe_buf.row_mut(i).copy_from_slice(unsafe { snap_tab.row(base + i) });
                    }
                    history.consensus.push((f, probe_buf.consensus_distance()));
                    history.lr.push((f, slot.lr));
                    probe(f, &probe_buf);
                }
            }
        };

        engine.run_queue(&queue, &run_task, &mut coordinator);
    }

    history.dispatches = engine.dispatches();
    optimizer.restore_async_state(x_stack, m_stack);
    history
}
