//! Stacked per-node training state.
//!
//! `StackedParams` is the `n × P` matrix `𝐱^{(k)}` of Appendix D.1: row `i`
//! is node `i`'s flat parameter (or momentum, or gradient) vector in f32.
//! All decentralized updates are linear maps over this stacking.

/// Row-major `n × dim` stack of per-node vectors.
#[derive(Clone, Debug, PartialEq)]
pub struct StackedParams {
    pub n: usize,
    pub dim: usize,
    pub data: Vec<f32>,
}

impl StackedParams {
    /// All-zero stack.
    pub fn zeros(n: usize, dim: usize) -> Self {
        StackedParams { n, dim, data: vec![0.0; n * dim] }
    }

    /// Every node starts from the same vector (paper's experiments
    /// broadcast an identical initialization).
    pub fn replicate(n: usize, row: &[f32]) -> Self {
        let dim = row.len();
        let mut data = Vec::with_capacity(n * dim);
        for _ in 0..n {
            data.extend_from_slice(row);
        }
        StackedParams { n, dim, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Disjoint per-lane row-shard views for the engine's workers: lane
    /// `t` covers rows [`crate::engine::shard_range`]`(n, lanes, t)`,
    /// each shard behind its own (uncontended) mutex so a broadcast
    /// closure can claim exactly its lane's rows in safe Rust.
    pub fn lane_shards(&mut self, lanes: usize) -> crate::engine::Lanes<'_, f32> {
        crate::engine::Lanes::split(&mut self.data, self.n, self.dim, lanes)
    }

    /// Mean across nodes: `x̄ = (1/n) Σ_i x_i` into `out`. Rows accumulate
    /// in ascending node order (each element's fold order is fixed —
    /// the 8-lane blocking inside [`crate::simd::accumulate_scaled`] is
    /// across the parameter dimension only).
    pub fn mean_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        let scale = 1.0 / self.n as f32;
        for i in 0..self.n {
            crate::simd::accumulate_scaled(out, self.row(i), scale);
        }
    }

    /// Mean across nodes (allocating).
    pub fn mean(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.mean_into(&mut out);
        out
    }

    /// Consensus distance `‖𝐱 − 1x̄ᵀ‖²_F = Σ_i ‖x_i − x̄‖²` (f64
    /// accumulate): one ordered per-row reduction
    /// ([`crate::simd::sum_sq_diff`]) per node, summed in node order —
    /// the same per-row values the engine's sharded probe computes, so
    /// the two probes agree bitwise.
    pub fn consensus_distance(&self) -> f64 {
        let mean = self.mean();
        let mut total = 0.0f64;
        for i in 0..self.n {
            total += crate::simd::sum_sq_diff(self.row(i), &mean);
        }
        total
    }

    /// Replace every row by the global mean (the warm-up all-reduce of
    /// Corollary 3, and parallel SGD's exact averaging).
    pub fn allreduce(&mut self) {
        let mean = self.mean();
        for i in 0..self.n {
            self.row_mut(i).copy_from_slice(&mean);
        }
    }

    /// Mean squared distance to a reference vector:
    /// `(1/n) Σ_i ‖x_i − r‖²` (Fig. 13's y-axis with `r = x*`).
    pub fn mean_sq_error_to(&self, reference: &[f32]) -> f64 {
        assert_eq!(reference.len(), self.dim);
        let mut total = 0.0f64;
        for i in 0..self.n {
            total += crate::simd::sum_sq_diff(self.row(i), reference);
        }
        total / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicate_and_mean() {
        let s = StackedParams::replicate(4, &[1.0, 2.0, 3.0]);
        assert_eq!(s.mean(), vec![1.0, 2.0, 3.0]);
        assert!(s.consensus_distance() < 1e-12);
    }

    #[test]
    fn consensus_distance_known() {
        let mut s = StackedParams::zeros(2, 1);
        s.row_mut(0)[0] = 1.0;
        s.row_mut(1)[0] = -1.0;
        // mean 0 → distance 1 + 1 = 2.
        assert!((s.consensus_distance() - 2.0).abs() < 1e-12);
        s.allreduce();
        assert!(s.consensus_distance() < 1e-15);
        assert_eq!(s.row(0)[0], 0.0);
    }

    #[test]
    fn lane_shards_cover_rows_disjointly() {
        let mut s = StackedParams::zeros(5, 3);
        let shards = s.lane_shards(2);
        for lane in 0..2usize {
            let mut view = shards.lock(lane);
            for v in view.iter_mut() {
                *v = (lane + 1) as f32;
            }
        }
        drop(shards);
        for i in 0..5usize {
            let r = crate::engine::shard_range(5, 2, 1);
            let want = if r.contains(&i) { 2.0 } else { 1.0 };
            assert_eq!(s.row(i)[0], want, "row {i}");
        }
    }

    #[test]
    fn mse_to_reference() {
        let s = StackedParams::replicate(3, &[1.0, 1.0]);
        let mse = s.mean_sq_error_to(&[0.0, 0.0]);
        assert!((mse - 2.0).abs() < 1e-12);
    }
}
