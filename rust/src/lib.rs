//! # expograph
//!
//! Decentralized deep training over **exponential graphs** — a
//! production-oriented reproduction of *"Exponential Graph is Provably
//! Efficient for Decentralized Deep Training"* (Ying, Yuan, Chen, Hu, Pan,
//! Yin — NeurIPS 2021).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * [`topology`] — the full topology zoo of the paper (ring, star, grid,
//!   torus, hypercube, random graphs, bipartite random match, static and
//!   one-peer exponential graphs) with doubly-stochastic weight-matrix
//!   generation, behind an **open family registry**
//!   ([`topology::family`]): per-family plan construction, analytic
//!   degree/ρ, and exact-averaging periods are declared once per
//!   [`topology::TopologyFamily`], and the finite-time families
//!   ([`topology::finite_time`]: base-(k+1) after Takezawa et al.,
//!   CECA-style one/two-peer after Ding et al.) extend the paper's
//!   log₂(n)-step exact averaging to **arbitrary n** — not just powers
//!   of two.
//! * [`spectral`] — spectral-gap analysis (Proposition 1) built on the
//!   in-crate [`linalg`] substrate (DFT over circulants, Jacobi symmetric
//!   eigensolver, power iteration).
//! * [`consensus`] — gossip/partial-averaging simulation and the periodic
//!   exact-averaging property (Lemma 1).
//! * [`optim`] — decentralized optimizers: DSGD, DmSGD (Algorithm 1),
//!   vanilla DmSGD, QG-DmSGD, and the parallel (all-reduce) SGD baseline.
//! * [`coordinator`] — the training orchestrator: node state, topology
//!   schedule, warm-up all-reduce, metrics, transient-iteration detection.
//! * [`engine`] — the sharded execution engine: a persistent worker pool
//!   (created once per run, reusable barriers, zero per-iteration thread
//!   spawns) that drives gradients, fused optimizer steps, consensus
//!   probes, and gossip over contiguous row shards.
//! * [`costmodel`] — the α-β per-iteration communication-time model used to
//!   reproduce the wall-clock columns of Tables 2–3.
//! * [`netsim`] — deterministic discrete-event simulator of training rounds
//!   over heterogeneous / faulty networks (stragglers, link jitter, message
//!   drop, node dropout); collapses onto [`costmodel`]'s closed forms on a
//!   clean uniform network.
//! * [`compress`] — gossip payload compression (identity, top-k
//!   sparsification, stochastic int8) with per-stream lag-as-memory error
//!   feedback; `Compressor::wire_bytes` is the single source of payload
//!   size for both [`costmodel`] and [`netsim`], and compressed steps stay
//!   bitwise lane-count-invariant.
//! * [`runtime`] — PJRT CPU client that loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`) produced by the build-time JAX/Pallas layers.
//! * [`data`], [`models`] — synthetic workloads (logistic regression per
//!   Appendix D.5, classification, tiny-corpus LM) and pure-Rust reference
//!   models for laptop-scale sweeps.
//! * [`sweep`] — the declarative sweep harness: `Axis`/`Grid` experiment
//!   grids, a lane-budgeted parallel cell scheduler with deterministic
//!   grid-order collection, a `Record`/`Sink` output schema (CSV + JSON +
//!   text table from one definition), and an on-disk result cache.
//! * [`exp`] — the experiment harness regenerating every table and figure
//!   of the paper's evaluation, declared as [`sweep`] grids.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request/training path is pure Rust.

pub mod bench;
pub mod compress;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod engine;
pub mod exp;
pub mod linalg;
pub mod models;
pub mod netsim;
pub mod optim;
pub mod runtime;
pub mod simd;
pub mod spectral;
pub mod sweep;
pub mod topology;
pub mod util;
