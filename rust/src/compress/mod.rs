//! Wire compression for gossip exchanges, with error feedback.
//!
//! Decentralized training buys cheap averaging twice over: the topology
//! bounds *how many* messages a round needs (the paper's thesis), and a
//! [`Compressor`] bounds *how large* each message is. This module adds
//! the second axis without giving up the repo's determinism discipline:
//! compressed trajectories stay bitwise identical for any engine lane
//! count, because every piece of compression state is row-local.
//!
//! # Scheme: lag-as-memory reconstruction (CHOCO / EF21 style)
//!
//! Each wire stream keeps a *reconstruction stack* `h` alongside the raw
//! payload stack `p`. The simulator has global memory, so the copy of
//! `h_i` a sender holds and the copy its receivers hold are one and the
//! same array — exactly the invariant real implementations maintain by
//! applying identical compressed updates on both ends. Per round:
//!
//! ```text
//! q_i = C(p_i − h_i)        // compress the reconstruction lag
//! h_i ← h_i + q_i           // sender and receivers apply the same q_i
//! x⁺_i = p_i + γ·(Σ_j w_ij h_j − h_i)   // damped gossip on reconstructions
//! ```
//!
//! The *lag* `p − h` is the error memory: coordinates a sparsifier drops
//! simply stay in the next round's difference. (A separate accumulated
//! residual à la classic error feedback double-counts the dropped
//! coordinates — the lag already contains them — and measurably
//! diverges; this was checked numerically before the scheme was chosen.)
//! The consensus step size `γ` damps the pull toward lagged
//! reconstructions; `γ = 1` recovers plain mixing and is only stable for
//! mild compression, so each compressor picks its own `γ`
//! ([`Compressor::gamma`], `min(1, 3·frac)` for top-k per the CHOCO
//! `γ ∝ δ` rule).
//!
//! The identity compressor copies `p` into `h` bitwise and the trainer
//! routes identity runs through the uncompressed kernels, so
//! `CompressorKind::Identity` is byte-identical — outputs *and* wire
//! ledger — to a build without this module.
//!
//! # Determinism
//!
//! [`Compressor::compress_row`] sees one row (one node's payload) plus
//! `(node, iter, seed)`; it never reads another row or any lane-indexed
//! state. Top-k selection is a total order (`f32::total_cmp` on
//! magnitudes, ascending index tie-break); int8 stochastic rounding
//! draws from the same splitmix-style [`coin`](crate::netsim::coin)
//! hash netsim uses, keyed by `(seed, iter, node, element)`. Sharding
//! rows across lanes therefore cannot change a single bit.
//!
//! # Wire pricing
//!
//! [`CompressorKind::wire_bytes`] is the *single* source of payload
//! size: the trainer prices both the closed-form cost model and netsim
//! rounds through it, so the `bytes_on_wire` ledger and the time ledger
//! can never disagree about what a compressed round weighs.

use crate::coordinator::state::StackedParams;
use crate::netsim::coin;

/// Salt for int8 stochastic-rounding draws (disjoint from netsim's
/// fault/jitter salts).
const SALT_QUANT: u64 = 0x08B1;

/// Default kept fraction for [`CompressorKind::TopK`].
pub const DEFAULT_TOPK_FRAC: f32 = 0.125;

/// A per-row wire compressor with reconstruction-based error feedback.
///
/// Implementations advance the shared reconstruction `h` toward the raw
/// payload `p` using only information that fits in the compressed
/// message; the un-transmitted lag `p − h` is the error-feedback state.
/// The update must be row-local and a pure function of
/// `(p, h, node, iter, seed)`.
pub trait Compressor: Send + Sync {
    /// Compressor family name (stable identifier, no parameters).
    fn name(&self) -> &'static str;

    /// Bytes one node's compressed message puts on the wire, given the
    /// dense message would be `dense_bytes`.
    fn wire_bytes(&self, dense_bytes: f64) -> f64;

    /// Consensus step size for mixing from reconstructions
    /// (`x⁺ = p + γ(Wh − h)`). `1.0` recovers undamped gossip.
    fn gamma(&self) -> f32 {
        1.0
    }

    /// Transmit `C(p − h)` for one node's row and apply it to `h`.
    fn compress_row(&self, p: &[f32], h: &mut [f32], node: usize, iter: usize, seed: u64);
}

/// No-op compressor: the reconstruction is the payload, bit for bit.
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn wire_bytes(&self, dense_bytes: f64) -> f64 {
        dense_bytes
    }

    fn compress_row(&self, p: &[f32], h: &mut [f32], _node: usize, _iter: usize, _seed: u64) {
        h.copy_from_slice(p);
    }
}

/// Top-k sparsification of the reconstruction lag: transmit the `k =
/// ceil(frac·dim)` coordinates of `p − h` with the largest magnitude
/// (index + fresh value pairs), leave the rest lagging.
pub struct TopK {
    pub frac: f32,
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn wire_bytes(&self, dense_bytes: f64) -> f64 {
        // Each kept coordinate ships a u32 index + f32 value: 8 bytes
        // against 4 dense, hence the factor 2 on the kept fraction.
        (2.0 * self.frac as f64 * dense_bytes).min(dense_bytes)
    }

    fn gamma(&self) -> f32 {
        // CHOCO rule γ ∝ δ: aggressive sparsification needs a gentler
        // consensus step. Calibrated on the heterogeneous quadratic —
        // 4·frac sits on the stability boundary, 3·frac inside it.
        (3.0 * self.frac).min(1.0)
    }

    fn compress_row(&self, p: &[f32], h: &mut [f32], _node: usize, _iter: usize, _seed: u64) {
        let dim = p.len();
        let k = ((self.frac * dim as f32).ceil() as usize).clamp(1, dim);
        if k == dim {
            h.copy_from_slice(p);
            return;
        }
        let mut idx: Vec<u32> = (0..dim as u32).collect();
        idx.sort_unstable_by(|&a, &b| {
            let da = (p[a as usize] - h[a as usize]).abs();
            let db = (p[b as usize] - h[b as usize]).abs();
            // Largest lag first; ascending index breaks ties (and
            // total_cmp totalizes NaN), so selection is a total order.
            db.total_cmp(&da).then(a.cmp(&b))
        });
        for &i in &idx[..k] {
            h[i as usize] = p[i as usize];
        }
    }
}

/// Int8 stochastic quantization of the reconstruction lag: one shared
/// absmax scale per row, each coordinate rounded to an integer level
/// with probability proportional to its remainder (unbiased).
pub struct Int8;

impl Compressor for Int8 {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn wire_bytes(&self, dense_bytes: f64) -> f64 {
        // One byte per f32 coordinate plus a 4-byte row scale.
        dense_bytes / 4.0 + 4.0
    }

    fn compress_row(&self, p: &[f32], h: &mut [f32], node: usize, iter: usize, seed: u64) {
        let dim = p.len();
        let mut max_abs = 0.0f32;
        for i in 0..dim {
            max_abs = max_abs.max((p[i] - h[i]).abs());
        }
        if max_abs == 0.0 || !max_abs.is_finite() {
            // Zero lag transmits nothing; a non-finite lag has no
            // representable scale, so hold the reconstruction still
            // rather than poison it.
            return;
        }
        let scale = max_abs / 127.0;
        for i in 0..dim {
            let t = p[i] - h[i];
            let x = t / scale; // in [-127, 127]
            let fl = x.floor();
            let up = coin(seed, iter, node, i, SALT_QUANT) < (x - fl) as f64;
            let level = if up { fl + 1.0 } else { fl };
            h[i] += level * scale;
        }
    }
}

/// Which compressor a run uses — the config/CLI-facing value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressorKind {
    Identity,
    TopK { frac: f32 },
    Int8,
}

impl Default for CompressorKind {
    fn default() -> Self {
        CompressorKind::Identity
    }
}

impl CompressorKind {
    /// Parse a CLI/config spelling: `identity` (aliases `dense`,
    /// `none`), `topk` (default fraction), `topk:<frac>`, `int8`.
    pub fn parse(s: &str) -> Option<CompressorKind> {
        match s {
            "identity" | "dense" | "none" => Some(CompressorKind::Identity),
            "int8" => Some(CompressorKind::Int8),
            "topk" => Some(CompressorKind::TopK { frac: DEFAULT_TOPK_FRAC }),
            _ => {
                let frac: f32 = s.strip_prefix("topk:")?.parse().ok()?;
                (frac > 0.0 && frac <= 1.0).then_some(CompressorKind::TopK { frac })
            }
        }
    }

    /// Display/record label; round-trips through [`CompressorKind::parse`].
    pub fn label(&self) -> String {
        match self {
            CompressorKind::Identity => "identity".to_string(),
            CompressorKind::TopK { frac } => format!("topk:{frac}"),
            CompressorKind::Int8 => "int8".to_string(),
        }
    }

    pub fn is_identity(&self) -> bool {
        matches!(self, CompressorKind::Identity)
    }

    /// Wire size of one node's message — the single pricing point both
    /// the cost model and netsim consume (satellite: no call site may
    /// scale `msg_bytes` on its own).
    pub fn wire_bytes(&self, dense_bytes: f64) -> f64 {
        match self {
            CompressorKind::Identity => dense_bytes,
            CompressorKind::TopK { frac } => TopK { frac: *frac }.wire_bytes(dense_bytes),
            CompressorKind::Int8 => Int8.wire_bytes(dense_bytes),
        }
    }

    pub fn build(&self) -> Box<dyn Compressor> {
        match self {
            CompressorKind::Identity => Box::new(Identity),
            CompressorKind::TopK { frac } => Box::new(TopK { frac: *frac }),
            CompressorKind::Int8 => Box::new(Int8),
        }
    }
}

/// One wire stream's state: the raw payload staged this round and the
/// shared reconstruction the network actually mixes.
pub struct StreamState {
    /// Raw pre-mix payload, staged by `Optimizer::payload_shard`.
    pub p: StackedParams,
    /// Shared reconstruction `h` (sender and receivers hold the same
    /// array — global-memory simulation of both ends applying `q`).
    pub h: StackedParams,
}

/// All compression state for one training run: the compressor, the
/// per-stream reconstruction stacks, and the round counter that keys
/// stochastic rounding. Owned by the step driver, advanced once per
/// optimizer step regardless of lane count.
pub struct GossipCompression {
    kind: CompressorKind,
    comp: Box<dyn Compressor>,
    seed: u64,
    iter: usize,
    streams: Vec<StreamState>,
}

/// Per-stream seed separation, so two streams of the same round draw
/// independent stochastic-rounding coins.
pub fn stream_seed(seed: u64, stream: usize) -> u64 {
    seed ^ ((stream as u64 + 1) << 56)
}

impl GossipCompression {
    pub fn new(kind: CompressorKind, seed: u64) -> Self {
        GossipCompression { kind, comp: kind.build(), seed, iter: 0, streams: Vec::new() }
    }

    pub fn kind(&self) -> CompressorKind {
        self.kind
    }

    pub fn is_identity(&self) -> bool {
        self.kind.is_identity()
    }

    pub fn gamma(&self) -> f32 {
        self.comp.gamma()
    }

    pub fn iter(&self) -> usize {
        self.iter
    }

    /// Size the stream stacks (idempotent; reconstructions start at 0,
    /// the shared initial value both ends agree on).
    pub fn ensure(&mut self, total_streams: usize, n: usize, dim: usize) {
        while self.streams.len() < total_streams {
            self.streams.push(StreamState {
                p: StackedParams::zeros(n, dim),
                h: StackedParams::zeros(n, dim),
            });
        }
    }

    /// Split borrows for the staging pass: the compressor, the round
    /// counter, the base seed, and the mutable stream states.
    pub fn parts_mut(&mut self) -> (&dyn Compressor, usize, u64, &mut [StreamState]) {
        (self.comp.as_ref(), self.iter, self.seed, &mut self.streams[..])
    }

    /// Borrow `count` streams starting at `start` (one phase's worth)
    /// for the mixing pass.
    pub fn phase_states(&self, start: usize, count: usize) -> Vec<&StreamState> {
        self.streams[start..start + count].iter().collect()
    }

    /// Advance the round counter — exactly once per optimizer step.
    pub fn advance(&mut self) {
        self.iter += 1;
    }

    /// Σ‖p − h‖² over all streams: the live error-feedback residual.
    /// Bounded along a stable trajectory; diverges when γ is too hot.
    pub fn residual_sq(&self) -> f64 {
        self.streams
            .iter()
            .map(|st| {
                st.p
                    .data
                    .iter()
                    .zip(st.h.data.iter())
                    .map(|(&p, &h)| {
                        let d = (p - h) as f64;
                        d * d
                    })
                    .sum::<f64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_reconstruction_is_bitwise_payload() {
        let p: Vec<f32> = (0..17).map(|i| (i as f32 - 8.0) * 0.37).collect();
        let mut h = vec![f32::NAN; 17];
        Identity.compress_row(&p, &mut h, 3, 11, 42);
        for (a, b) in p.iter().zip(h.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn topk_transmits_exactly_k_coordinates() {
        let dim = 16;
        let p: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let mut h = vec![0.0f32; dim];
        let c = TopK { frac: 0.25 }; // k = 4
        c.compress_row(&p, &mut h, 0, 0, 1);
        let touched = h.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(touched, 4, "k = ceil(0.25·16) coordinates move");
        // The largest lags win: coordinates 12..16.
        for i in 12..dim {
            assert_eq!(h[i], p[i]);
        }
        for i in 1..12 {
            assert_eq!(h[i], 0.0);
        }
    }

    #[test]
    fn topk_selection_breaks_ties_by_index() {
        let p = [1.0f32, 1.0, 1.0, 1.0];
        let mut h = vec![0.0f32; 4];
        TopK { frac: 0.25 }.compress_row(&p, &mut h, 0, 0, 1); // k = 1
        assert_eq!(h, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_lag_drains_over_rounds() {
        let dim = 32;
        let p: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.73).sin()).collect();
        let mut h = vec![0.0f32; dim];
        let c = TopK { frac: DEFAULT_TOPK_FRAC }; // k = 4
        for it in 0..(dim / 4) {
            c.compress_row(&p, &mut h, 0, it, 1);
        }
        // A static payload is fully reconstructed in dim/k rounds.
        for (a, b) in p.iter().zip(h.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn int8_is_deterministic_and_contracts_the_lag() {
        let dim = 64;
        let p: Vec<f32> = (0..dim).map(|i| (i as f32 * 1.13).cos() * 3.0).collect();
        let mut h1 = vec![0.0f32; dim];
        let mut h2 = vec![0.0f32; dim];
        Int8.compress_row(&p, &mut h1, 5, 9, 77);
        Int8.compress_row(&p, &mut h2, 5, 9, 77);
        assert_eq!(h1, h2, "same (node, iter, seed) → same quantization");
        let lag: f32 = p.iter().zip(h1.iter()).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        // One round leaves at most one quantization bin of lag.
        let scale = p.iter().fold(0.0f32, |m, v| m.max(v.abs())) / 127.0;
        assert!(lag <= scale * 1.0001, "lag {lag} exceeds one bin {scale}");
        let mut h3 = vec![0.0f32; dim];
        Int8.compress_row(&p, &mut h3, 5, 10, 77);
        assert_ne!(h1, h3, "different iter draws different rounding coins");
    }

    #[test]
    fn int8_zero_and_nonfinite_lag_hold_still() {
        let mut h = vec![1.0f32, -2.0];
        let p = h.clone();
        Int8.compress_row(&p, &mut h, 0, 0, 1);
        assert_eq!(h, vec![1.0, -2.0]);
        let bad = [f32::INFINITY, 0.0];
        Int8.compress_row(&bad, &mut h, 0, 0, 1);
        assert!(h.iter().all(|v| v.is_finite()), "non-finite lag must not poison h");
    }

    #[test]
    fn kind_parse_label_round_trip() {
        for s in ["identity", "topk", "topk:0.25", "int8"] {
            let k = CompressorKind::parse(s).unwrap();
            assert_eq!(CompressorKind::parse(&k.label()), Some(k));
        }
        assert_eq!(CompressorKind::parse("dense"), Some(CompressorKind::Identity));
        assert_eq!(CompressorKind::parse("topk:0"), None);
        assert_eq!(CompressorKind::parse("topk:1.5"), None);
        assert_eq!(CompressorKind::parse("gzip"), None);
    }

    #[test]
    fn wire_bytes_pricing() {
        let dense = 4.0 * 32.0;
        assert_eq!(CompressorKind::Identity.wire_bytes(dense), dense);
        assert_eq!(
            CompressorKind::TopK { frac: 0.125 }.wire_bytes(dense),
            2.0 * 0.125 * dense
        );
        // Index+value pairs can never exceed the dense message.
        assert_eq!(CompressorKind::TopK { frac: 0.9 }.wire_bytes(dense), dense);
        assert_eq!(CompressorKind::Int8.wire_bytes(dense), dense / 4.0 + 4.0);
    }

    #[test]
    fn gossip_compression_state_machine() {
        let mut gz = GossipCompression::new(
            CompressorKind::TopK { frac: DEFAULT_TOPK_FRAC },
            7,
        );
        gz.ensure(2, 4, 8);
        gz.ensure(2, 4, 8); // idempotent
        assert_eq!(gz.iter(), 0);
        {
            let (comp, iter, seed, streams) = gz.parts_mut();
            assert_eq!(streams.len(), 2);
            let p: Vec<f32> = (0..8).map(|i| i as f32).collect();
            let StreamState { h, .. } = &mut streams[0];
            comp.compress_row(&p, &mut h.data[0..8], 0, iter, stream_seed(seed, 0));
        }
        assert!(gz.residual_sq() >= 0.0);
        gz.advance();
        assert_eq!(gz.iter(), 1);
        assert!((gz.gamma() - 3.0 * DEFAULT_TOPK_FRAC).abs() < 1e-6);
    }
}
