//! Deterministic discrete-event simulator of decentralized training
//! rounds over an explicit network model (docs/DESIGN.md §NetSim).
//!
//! The closed-form α-β [`crate::costmodel`] prices a round under a
//! uniform, failure-free network. This module generalizes it to the
//! clusters that motivate topology choice in practice: heterogeneous
//! links (per-edge α-β multipliers and jitter), stragglers (per-node
//! compute-time distributions), and faults (transient message drop,
//! node dropout for an iteration window). A [`NetSim`] consumes each
//! iteration's [`MixingPlan`] from the schedule, simulates the
//! point-to-point exchanges, and returns the simulated round time,
//! bytes-on-wire accounting, plus — when a fault fired — a *degraded*
//! plan ([`MixingPlan::degrade_if`]): rows renormalized so the
//! self-weight absorbs the mass of every lost message, keeping each row
//! stochastic.
//!
//! **Hot-path layout.** The paper's argument is asymptotic in `n`, so
//! the simulator must price a round at `n = 10⁵–10⁶`. One round is
//! allocation-free: all per-node state (compute-ready times, per-node
//! slot clocks, offline/lost flags as bitsets) and the recorded event
//! queue live in a [`RoundArena`] owned by the `NetSim` and reused
//! across rounds — flat SoA arrays, no `BinaryHeap`, no per-round
//! `Vec`s. The heap is unnecessary because the event graph is a forest
//! of per-node chains: node `u`'s slot `s+1` starts when slot `s` ends
//! (waiting on the partner's *compute* time, never on the partner's
//! slots), so every node's finish time folds left-to-right in
//! `O(degree)` with exactly the fp ops the heap replay performed. When
//! a trace is recorded, the events are re-ordered through a
//! bucket/calendar queue (bucket by time over the round's bounded
//! horizon, full `(t, kind, node, slot)` comparator within a bucket) —
//! since each chain's keys are non-decreasing, heap pop order *is*
//! globally sorted order, and the comparator is a strict total order
//! (no two distinct events tie), so the emitted trace is
//! bitwise-identical to the retired heap's. The pre-arena
//! implementation survives as [`NetSim::simulate_round_reference`], the
//! pin for `tests/netsim_scale.rs` and the "before" side of
//! `bench_netsim`'s comparator.
//!
//! Three contracts, all pinned by tests:
//!
//! * **Conformance** (`tests/netsim.rs`): on a uniform fault-free
//!   network the simulated round time reproduces
//!   [`CostModel::partial_averaging_time`] (and the ring-allreduce
//!   closed form for the parallel baseline) to f64 round-off — the
//!   closed forms remain the fast path, the simulator is their general
//!   case.
//! * **Non-intrusiveness**: a fault cannot fire ⇒ the degraded plan is
//!   `None` ⇒ a `NetSim`-instrumented training run is bitwise identical
//!   to the plain engine path (only the clock differs).
//! * **Determinism** (`tests/proptests.rs`, `tests/netsim_scale.rs`):
//!   every random draw is a pure hash of `(seed, iteration, endpoints,
//!   salt)` — no sequential RNG state — so the event trace and the
//!   degraded plans are identical for any lane count, replay order, or
//!   re-query, and the arena path is bitwise-equal to the reference.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::costmodel::CostModel;
use crate::topology::plan::MixingPlan;

/// Hash-coin salts: one label per independent random stream.
const SALT_DROP: u64 = 0xD201;
const SALT_DROP_AR: u64 = 0xD202;
const SALT_COMPUTE: u64 = 0xC011;
const SALT_LINK_JITTER: u64 = 0x11A7;
const SALT_LINK_HET: u64 = 0x4E70;
const SALT_FLAKY: u64 = 0xF1A6;

/// SplitMix64 finalizer — the avalanche step behind the hash coins.
#[inline]
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 31;
    h
}

/// Uniform draw in `[0, 1)` as a pure function of
/// `(seed, iter, a, b, salt)`. Order-independent by construction: the
/// same coordinates give the same coin no matter when (or how often)
/// they are queried — the determinism contract of the whole module.
#[inline]
pub fn coin(seed: u64, iter: usize, a: usize, b: usize, salt: u64) -> f64 {
    let mut h = seed ^ salt;
    for v in [iter as u64, a as u64, b as u64] {
        h = mix64(h.wrapping_add(v).wrapping_add(0x9E37_79B9_7F4A_7C15));
    }
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// A named cluster condition: heterogeneity, straggler, and fault knobs
/// composed into one preset. All-zero knobs (`clean`) make the
/// simulator collapse onto the closed-form cost model exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Per-exchange transient drop probability. Drops are decided per
    /// *unordered pair* per iteration, so a lost exchange degrades both
    /// endpoints symmetrically (symmetric plans stay symmetric).
    pub drop_prob: f64,
    /// Fraction of nodes that are stragglers (the first
    /// `round(frac·n)` node ids — deterministic and topology-neutral
    /// for the graphs the runner sweeps).
    pub straggler_frac: f64,
    /// Compute-time multiplier applied to straggler nodes.
    pub straggler_factor: f64,
    /// Per-node per-iteration compute jitter amplitude: compute times
    /// are scaled by `1 + jitter·U[0,1)`.
    pub compute_jitter: f64,
    /// Per-exchange link jitter amplitude (same scaling law).
    pub link_jitter: f64,
    /// Static per-edge heterogeneity: each unordered pair's link cost
    /// is scaled by a fixed `1 + spread·U[0,1)` drawn once per edge.
    pub het_spread: f64,
    /// Node dropout windows `(node, from, until)`: the node is offline
    /// (network-partitioned, still computing locally) for iterations
    /// `from ≤ k < until`.
    pub dropout: Vec<(usize, usize, usize)>,
    /// Per-node per-iteration probability of a *transient* slowdown
    /// (GC pause, co-tenant burst): an independent coin per (iter,
    /// node), unlike `straggler_frac`'s persistent prefix.
    pub flaky_prob: f64,
    /// Compute-time multiplier applied when the flaky coin fires.
    pub flaky_factor: f64,
}

impl Scenario {
    /// Uniform, failure-free network — the cost-model special case.
    pub fn clean() -> Scenario {
        Scenario {
            name: "clean".into(),
            drop_prob: 0.0,
            straggler_frac: 0.0,
            straggler_factor: 1.0,
            compute_jitter: 0.0,
            link_jitter: 0.0,
            het_spread: 0.0,
            dropout: Vec::new(),
            flaky_prob: 0.0,
            flaky_factor: 1.0,
        }
    }

    /// 1-in-8 nodes compute 4× slower, everyone jitters ±20%. No
    /// message faults: the training trajectory is bitwise identical to
    /// `clean`; only the clock slows.
    pub fn straggler() -> Scenario {
        Scenario {
            name: "straggler".into(),
            straggler_frac: 0.125,
            straggler_factor: 4.0,
            compute_jitter: 0.2,
            ..Scenario::clean()
        }
    }

    /// Lossy heterogeneous fabric: 30% transient exchange drops, one
    /// node partitioned for iterations [50, 90), uneven link speeds.
    pub fn lossy() -> Scenario {
        Scenario {
            name: "lossy".into(),
            drop_prob: 0.3,
            link_jitter: 0.1,
            het_spread: 0.5,
            dropout: vec![(1, 50, 90)],
            ..Scenario::clean()
        }
    }

    /// Transient stragglers: any node is 4× slower with probability
    /// 1/8, independently per iteration. Timing-only (faultless), so
    /// the trajectory is bitwise identical to `clean` — but unlike the
    /// persistent `straggler` preset the slow set changes every round,
    /// which is the regime where bounded-staleness execution shines.
    pub fn flaky() -> Scenario {
        Scenario {
            name: "flaky".into(),
            flaky_prob: 0.125,
            flaky_factor: 4.0,
            compute_jitter: 0.2,
            ..Scenario::clean()
        }
    }

    /// Parse a preset by name (the CLI/config surface).
    pub fn parse(name: &str) -> Option<Scenario> {
        Some(match name {
            "clean" => Scenario::clean(),
            "straggler" => Scenario::straggler(),
            "flaky" => Scenario::flaky(),
            "lossy" => Scenario::lossy(),
            _ => return None,
        })
    }

    /// Can this scenario ever alter a mixing plan? (Stragglers and
    /// jitter change the clock but never the plan.)
    pub fn is_faultless(&self) -> bool {
        self.drop_prob == 0.0 && self.dropout.is_empty()
    }

    fn straggler_count(&self, n: usize) -> usize {
        ((self.straggler_frac * n as f64).round() as usize).min(n)
    }

    fn offline(&self, node: usize, iter: usize) -> bool {
        self.dropout.iter().any(|&(u, from, until)| u == node && iter >= from && iter < until)
    }
}

/// One simulated event, in event-queue order. Recorded only when the
/// simulator was built with [`NetSim::recording`]; the trace (together
/// with the degraded plans) is the determinism witness compared across
/// lane counts in `tests/proptests.rs` and across the arena/reference
/// implementations in `tests/netsim_scale.rs`.
#[derive(Clone, Debug, PartialEq)]
pub enum SimEvent {
    /// Node was offline (network-partitioned) for this iteration.
    Offline { iter: usize, node: usize },
    /// Node finished its local forward+backward at time `t`.
    ComputeDone { iter: usize, node: usize, t: f64 },
    /// `dst` finished the exchange slot pulling from `src` at time `t`;
    /// `dropped` means the pair's exchange failed this iteration.
    Pull { iter: usize, dst: usize, src: usize, t: f64, dropped: bool },
    /// One full ring-allreduce collective finished at time `t`.
    Allreduce { iter: usize, t: f64 },
}

/// Determinism witness: the ordered event trace plus every degraded
/// plan the simulator produced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimLog {
    pub events: Vec<SimEvent>,
    pub degraded: Vec<(usize, MixingPlan)>,
}

/// Outcome of one simulated round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Slowest node's compute time this round (seconds).
    pub compute: f64,
    /// Communication critical path beyond the slowest compute
    /// (seconds). On a clean uniform network this equals the α-β
    /// closed form exactly (to f64 round-off).
    pub comm: f64,
    /// Renormalized plan, present iff at least one fault fired. `None`
    /// means the caller must keep using the original plan — which is
    /// what makes fault-free instrumented runs bitwise identical.
    pub degraded: Option<MixingPlan>,
    /// Unordered pairs whose exchange was lost this round.
    pub dropped_pairs: usize,
    /// Nodes offline this round.
    pub offline_nodes: usize,
    /// Payload bytes put on the wire this round — the bytes-to-accuracy
    /// ledger of [`crate::compress`]. Both paths price offline nodes the
    /// same way: **a dead endpoint transmits nothing, so offline slots
    /// cost time but zero bytes**. Gossip rounds: every executed pull
    /// slot whose *both* endpoints are online carries the full message —
    /// a transiently dropped exchange was still transmitted (then lost),
    /// while a pull touching an offline node times out unpaid. Allreduce:
    /// each ring link carries its chunk every phase; a chunk lost to the
    /// drop coin is retransmitted (doubling that link's bytes), and a
    /// phase touching an offline endpoint reroutes at double *time* but
    /// zero bytes.
    pub bytes_on_wire: f64,
}

impl RoundOutcome {
    /// End-to-end iteration time under DDP-style comm/compute overlap —
    /// the same combination rule as [`CostModel::iteration_time`].
    pub fn iteration_time(&self, overlap: f64) -> f64 {
        self.compute + self.comm - self.compute.min(self.comm) * overlap
    }
}

/// Heap entry of the retired queue implementation — kept for
/// [`NetSim::simulate_round_reference`]. Total order on
/// `(t, kind, node, slot)` — f64 ties broken structurally, so the pop
/// order (and hence the trace) is a pure function of the inputs.
#[derive(Clone, Copy, PartialEq)]
struct Pending {
    t: f64,
    /// 0 = compute-done, 1 = slot-done.
    kind: u8,
    node: usize,
    slot: usize,
}

impl Eq for Pending {}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.kind.cmp(&other.kind))
            .then(self.node.cmp(&other.node))
            .then(self.slot.cmp(&other.slot))
    }
}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Fixed-size bit vector (one u64 word per 64 nodes) — the arena's
/// offline / lost flags. `reset` keeps the allocation.
#[derive(Clone, Debug, Default)]
struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    fn reset(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
    }

    #[inline]
    fn set(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    fn get(&self, i: usize) -> bool {
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    fn bytes(&self) -> usize {
        self.words.capacity() * 8
    }
}

/// Reusable per-round scratch owned by [`NetSim`]: flat SoA per-node
/// state plus the indexed event queue. Allocated lazily on first use,
/// retained across rounds — after warm-up a simulated round performs no
/// heap allocation (the acceptance criterion the n = 2²⁰ bench rides
/// on). Total live size is `O(n + recorded events)`; see
/// [`NetSim::arena_bytes`].
#[derive(Clone, Debug, Default)]
struct RoundArena {
    /// Per-node compute-ready time for the current round.
    t_comp: Vec<f64>,
    /// Per-node session-finish time (doubles as the node's slot clock —
    /// slots are sequential per node, so one running value suffices).
    finish: Vec<f64>,
    /// Nodes offline this iteration.
    offline: BitSet,
    /// Allreduce links that lost at least one chunk this round.
    link_lost: BitSet,
    /// Event queue SoA — one entry per ComputeDone/Pull event, filled
    /// only when the simulator records. Parallel arrays: time, kind
    /// (0 = compute-done, 1 = slot-done), node, slot.
    ev_t: Vec<f64>,
    ev_kind: Vec<u8>,
    ev_node: Vec<u32>,
    ev_slot: Vec<u32>,
    /// Event indices in emission order (the calendar queue's output).
    order: Vec<u32>,
    /// Calendar bucket offsets (counting-sort prefix sums) + scatter
    /// cursors.
    bucket_ptr: Vec<u32>,
    cursor: Vec<u32>,
}

impl RoundArena {
    /// Bytes of live arena state (by capacity — the retained
    /// allocations are the honest peak-RSS proxy).
    fn bytes(&self) -> usize {
        self.t_comp.capacity() * 8
            + self.finish.capacity() * 8
            + self.offline.bytes()
            + self.link_lost.bytes()
            + self.ev_t.capacity() * 8
            + self.ev_kind.capacity()
            + self.ev_node.capacity() * 4
            + self.ev_slot.capacity() * 4
            + self.order.capacity() * 4
            + self.bucket_ptr.capacity() * 4
            + self.cursor.capacity() * 4
    }

    /// Sort the recorded events into emission order — the calendar
    /// queue. Bucket by time over `[lo, hi]` (the round's bounded
    /// horizon; the map is monotone, so equal times share a bucket and
    /// bucket order implies strict time order), then order each bucket
    /// by the full `(t, kind, node, slot)` comparator. That comparator
    /// is a strict total order on distinct events (kind 0 is unique per
    /// node, kind 1 per `(node, slot)`), and every event's key is ≥ its
    /// causal predecessor's, so this concatenation reproduces the
    /// retired heap's pop order exactly.
    fn sort_events(&mut self) {
        let m = self.ev_t.len();
        assert!(m < u32::MAX as usize, "event queue exceeds u32 indexing");
        self.order.clear();
        self.order.extend(0..m as u32);
        if m <= 1 {
            return;
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &t in &self.ev_t {
            lo = lo.min(t);
            hi = hi.max(t);
        }
        let nb = m;
        let width = (hi - lo) / nb as f64;
        let bucket_of = |t: f64| -> usize {
            if width > 0.0 {
                (((t - lo) / width) as usize).min(nb - 1)
            } else {
                0
            }
        };
        self.bucket_ptr.clear();
        self.bucket_ptr.resize(nb + 1, 0);
        for &t in &self.ev_t {
            self.bucket_ptr[bucket_of(t) + 1] += 1;
        }
        for b in 0..nb {
            self.bucket_ptr[b + 1] += self.bucket_ptr[b];
        }
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.bucket_ptr[..nb]);
        for e in 0..m {
            let b = bucket_of(self.ev_t[e]);
            self.order[self.cursor[b] as usize] = e as u32;
            self.cursor[b] += 1;
        }
        let RoundArena { ev_t, ev_kind, ev_node, ev_slot, order, bucket_ptr, .. } = self;
        for b in 0..nb {
            let (s, e) = (bucket_ptr[b] as usize, bucket_ptr[b + 1] as usize);
            order[s..e].sort_unstable_by(|&x, &y| {
                let (x, y) = (x as usize, y as usize);
                ev_t[x]
                    .total_cmp(&ev_t[y])
                    .then(ev_kind[x].cmp(&ev_kind[y]))
                    .then(ev_node[x].cmp(&ev_node[y]))
                    .then(ev_slot[x].cmp(&ev_slot[y]))
            });
        }
    }
}

/// The simulator: the α-β [`CostModel`] (kept whole so every slot is
/// priced by [`CostModel::link_time`] — the one expression the closed
/// forms use, so the two paths cannot drift) composed with a
/// [`Scenario`] and a seed.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub cost: CostModel,
    pub scenario: Scenario,
    pub seed: u64,
    /// Cumulative totals across all simulated rounds.
    pub rounds: usize,
    pub dropped_total: usize,
    pub degraded_rounds: usize,
    /// Cumulative payload bytes on the wire across all simulated rounds
    /// (sum of [`RoundOutcome::bytes_on_wire`]).
    pub bytes_on_wire_total: f64,
    record: bool,
    log: SimLog,
    arena: RoundArena,
}

impl NetSim {
    /// Build from the α-β cost model (the clean special case it must
    /// reproduce exactly) plus a scenario.
    pub fn new(cost: &CostModel, scenario: Scenario, seed: u64) -> NetSim {
        NetSim {
            cost: *cost,
            scenario,
            seed,
            rounds: 0,
            dropped_total: 0,
            degraded_rounds: 0,
            bytes_on_wire_total: 0.0,
            record: false,
            log: SimLog::default(),
            arena: RoundArena::default(),
        }
    }

    /// Enable event-trace + degraded-plan recording (the determinism
    /// witness). Off by default: traces grow with `iters · nnz`.
    pub fn recording(mut self) -> Self {
        self.record = true;
        self
    }

    /// Take the recorded log, leaving an empty one behind.
    pub fn take_log(&mut self) -> SimLog {
        std::mem::take(&mut self.log)
    }

    /// Bytes of live simulator scratch (the reusable [`RoundArena`], by
    /// retained capacity). `tests/netsim_scale.rs` asserts this stays
    /// `O(n + edges)` — no dense `n × n` anywhere.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Per-node compute time for iteration `k` (seconds); `n` is the
    /// round's node count (straggler selection is a prefix of node ids).
    pub(crate) fn compute_time(&self, k: usize, u: usize, n: usize) -> f64 {
        let s = &self.scenario;
        let mut t = self.cost.compute;
        if s.straggler_factor != 1.0 && u < s.straggler_count(n) {
            t *= s.straggler_factor;
        }
        if s.flaky_prob > 0.0 && coin(self.seed, k, u, u, SALT_FLAKY) < s.flaky_prob {
            t *= s.flaky_factor;
        }
        if s.compute_jitter > 0.0 {
            t *= 1.0 + s.compute_jitter * coin(self.seed, k, u, u, SALT_COMPUTE);
        }
        t
    }

    /// Duration of one exchange slot between `u` and `v` at iteration
    /// `k` carrying `msg_bytes`. Symmetric in `(u, v)` — both ends of a
    /// pairwise exchange observe the same duration.
    pub(crate) fn slot_time(&self, k: usize, u: usize, v: usize, msg_bytes: f64) -> f64 {
        let (a, b) = (u.min(v), u.max(v));
        let s = &self.scenario;
        let mut t = self.cost.link_time(msg_bytes);
        if s.het_spread > 0.0 {
            t *= 1.0 + s.het_spread * coin(self.seed, 0, a, b, SALT_LINK_HET);
        }
        if s.link_jitter > 0.0 {
            t *= 1.0 + s.link_jitter * coin(self.seed, k, a, b, SALT_LINK_JITTER);
        }
        t
    }

    /// Per-node ready-time oracle view for the bounded-staleness
    /// executors (docs/DESIGN.md §Async runtime): read-only queries of
    /// the same deterministic hash-derived compute/link draws the
    /// round simulation uses, one node (or one pull) at a time instead
    /// of one round at a time. Counters do not advance.
    pub fn ready_oracle(&self) -> ReadyOracle<'_> {
        ReadyOracle { sim: self }
    }

    /// Was the pairwise exchange `{u, v}` lost at iteration `k`?
    /// (Offline endpoints drop every exchange; otherwise a transient
    /// per-pair coin.) Pure — safe to consult repeatedly.
    fn pair_dropped(&self, k: usize, u: usize, v: usize) -> bool {
        if self.scenario.offline(u, k) || self.scenario.offline(v, k) {
            return true;
        }
        self.scenario.drop_prob > 0.0
            && coin(self.seed, k, u.min(v), u.max(v), SALT_DROP) < self.scenario.drop_prob
    }

    /// Simulate one partial-averaging round for `plan` at iteration `k`.
    ///
    /// Event model: node `u` finishes compute at its drawn time, then
    /// works through one exchange slot per distinct partner in
    /// ascending order; a slot cannot start before the partner has
    /// finished its own compute (pull semantics — the straggler
    /// coupling), and each slot costs the α-β link time of that edge.
    /// Clean uniform case: every node's session is
    /// `degree·(α + S·β)`, so the round's comm time is
    /// `max_degree·(α + S·β)` — exactly
    /// [`CostModel::partial_averaging_time`].
    ///
    /// Because a slot only ever waits on the *compute* time of its
    /// partner, each node's chain folds independently in `O(degree)` —
    /// no queue. The arena is reused across rounds, so after warm-up a
    /// round allocates only when a fault forces a degraded plan.
    /// Bitwise-identical (times, traces, degraded plans, counters) to
    /// [`NetSim::simulate_round_reference`] — pinned in
    /// `tests/netsim_scale.rs`.
    pub fn simulate_round(&mut self, k: usize, plan: &MixingPlan, msg_bytes: f64) -> RoundOutcome {
        let n = plan.n;
        let mut arena = std::mem::take(&mut self.arena);

        arena.offline.reset(n);
        arena.t_comp.clear();
        for u in 0..n {
            if self.scenario.offline(u, k) {
                arena.offline.set(u);
            }
            arena.t_comp.push(self.compute_time(k, u, n));
        }
        let compute_max = arena.t_comp.iter().cloned().fold(0.0, f64::max);

        if self.record {
            for u in 0..n {
                if arena.offline.get(u) {
                    self.log.events.push(SimEvent::Offline { iter: k, node: u });
                }
            }
        }

        // Per-node chain walk: fold each session left-to-right. A
        // partner becomes pull-able once it has computed; offline
        // partners never answer, so a pull from one is an immediate
        // timeout slot (full slot duration, no readiness wait, zero
        // payload).
        arena.ev_t.clear();
        arena.ev_kind.clear();
        arena.ev_node.clear();
        arena.ev_slot.clear();
        arena.finish.clear();
        let mut slots_on_wire = 0u64;
        for u in 0..n {
            let t0 = arena.t_comp[u];
            if self.record {
                arena.ev_t.push(t0);
                arena.ev_kind.push(0);
                arena.ev_node.push(u as u32);
                arena.ev_slot.push(0);
            }
            if arena.offline.get(u) || plan.partners(u).is_empty() {
                arena.finish.push(t0);
                continue;
            }
            let mut t = t0;
            for (slot, &v) in plan.partners(u).iter().enumerate() {
                let v = v as usize;
                let avail = if arena.offline.get(v) { 0.0 } else { arena.t_comp[v] };
                let start = t.max(avail);
                t = start + self.slot_time(k, u, v, msg_bytes);
                if !arena.offline.get(v) {
                    slots_on_wire += 1;
                }
                if self.record {
                    arena.ev_t.push(t);
                    arena.ev_kind.push(1);
                    arena.ev_node.push(u as u32);
                    arena.ev_slot.push(slot as u32);
                }
            }
            arena.finish.push(t);
        }
        let total = arena.finish.iter().cloned().fold(0.0, f64::max);

        if self.record {
            arena.sort_events();
            for &e in &arena.order {
                let e = e as usize;
                let u = arena.ev_node[e] as usize;
                let t = arena.ev_t[e];
                if arena.ev_kind[e] == 0 {
                    self.log.events.push(SimEvent::ComputeDone { iter: k, node: u, t });
                } else {
                    let v = plan.partners(u)[arena.ev_slot[e] as usize] as usize;
                    let dropped = self.pair_dropped(k, u, v);
                    self.log.events.push(SimEvent::Pull { iter: k, dst: u, src: v, t, dropped });
                }
            }
        }

        // Faults → degraded plan (None when nothing fired). The drop
        // coins here are the same pure hashes the trace recorded.
        let mut dropped_pairs = 0usize;
        let degraded = if self.scenario.is_faultless() {
            None
        } else {
            for u in 0..n {
                for &v in plan.partners(u) {
                    let v = v as usize;
                    if v > u && self.pair_dropped(k, u, v) {
                        dropped_pairs += 1;
                    }
                }
            }
            plan.degrade_if(|i| arena.offline.get(i), |i, j| self.pair_dropped(k, i, j))
        };
        let offline_nodes = arena.offline.count();
        let bytes_on_wire = slots_on_wire as f64 * msg_bytes;
        self.rounds += 1;
        self.dropped_total += dropped_pairs;
        self.bytes_on_wire_total += bytes_on_wire;
        if let Some(d) = &degraded {
            self.degraded_rounds += 1;
            if self.record {
                self.log.degraded.push((k, d.clone()));
            }
        }
        self.arena = arena;
        RoundOutcome {
            compute: compute_max,
            comm: total - compute_max,
            degraded,
            dropped_pairs,
            offline_nodes,
            bytes_on_wire,
        }
    }

    /// Reference twin of [`NetSim::simulate_round`]: the pre-arena
    /// implementation — fresh per-round `Vec`s, a
    /// `BinaryHeap<Reverse<Pending>>` event queue, and the
    /// rows-materializing [`MixingPlan::degrade_reference`]. Kept (like
    /// the scalar kernel twins) as the bitwise pin for the arena path
    /// and the honest "before" side of `bench_netsim`'s comparator.
    /// Updates the same counters and log, so a sim driven entirely
    /// through this twin is observationally identical.
    pub fn simulate_round_reference(
        &mut self,
        k: usize,
        plan: &MixingPlan,
        msg_bytes: f64,
    ) -> RoundOutcome {
        let n = plan.n;
        let offline: Vec<bool> = (0..n).map(|u| self.scenario.offline(u, k)).collect();
        let t_comp: Vec<f64> = (0..n).map(|u| self.compute_time(k, u, n)).collect();
        let compute_max = t_comp.iter().cloned().fold(0.0, f64::max);
        let avail = |v: usize| if offline[v] { 0.0 } else { t_comp[v] };

        if self.record {
            for u in 0..n {
                if offline[u] {
                    self.log.events.push(SimEvent::Offline { iter: k, node: u });
                }
            }
        }

        let mut heap: BinaryHeap<std::cmp::Reverse<Pending>> = BinaryHeap::new();
        for (u, &t) in t_comp.iter().enumerate() {
            heap.push(std::cmp::Reverse(Pending { t, kind: 0, node: u, slot: 0 }));
        }
        let mut finish = t_comp.clone();
        while let Some(std::cmp::Reverse(ev)) = heap.pop() {
            let u = ev.node;
            if ev.kind == 0 {
                if self.record {
                    self.log.events.push(SimEvent::ComputeDone { iter: k, node: u, t: ev.t });
                }
                if !offline[u] && !plan.partners(u).is_empty() {
                    let v = plan.partners(u)[0] as usize;
                    let start = ev.t.max(avail(v));
                    let end = start + self.slot_time(k, u, v, msg_bytes);
                    heap.push(std::cmp::Reverse(Pending { t: end, kind: 1, node: u, slot: 0 }));
                }
            } else {
                let v = plan.partners(u)[ev.slot] as usize;
                if self.record {
                    let dropped = self.pair_dropped(k, u, v);
                    self.log.events.push(SimEvent::Pull {
                        iter: k,
                        dst: u,
                        src: v,
                        t: ev.t,
                        dropped,
                    });
                }
                if ev.slot + 1 < plan.partners(u).len() {
                    let v2 = plan.partners(u)[ev.slot + 1] as usize;
                    let start = ev.t.max(avail(v2));
                    let end = start + self.slot_time(k, u, v2, msg_bytes);
                    heap.push(std::cmp::Reverse(Pending {
                        t: end,
                        kind: 1,
                        node: u,
                        slot: ev.slot + 1,
                    }));
                } else {
                    finish[u] = ev.t;
                }
            }
        }
        let total = finish.iter().cloned().fold(0.0, f64::max);

        let mut dropped_pairs = 0usize;
        let degraded = if self.scenario.is_faultless() {
            None
        } else {
            for u in 0..n {
                for &v in plan.partners(u) {
                    let v = v as usize;
                    if v > u && self.pair_dropped(k, u, v) {
                        dropped_pairs += 1;
                    }
                }
            }
            plan.degrade_reference(&offline, |i, j| self.pair_dropped(k, i, j))
        };
        let offline_nodes = offline.iter().filter(|&&b| b).count();
        let mut slots_on_wire = 0u64;
        for u in 0..n {
            if !offline[u] {
                for &v in plan.partners(u) {
                    if !offline[v as usize] {
                        slots_on_wire += 1;
                    }
                }
            }
        }
        let bytes_on_wire = slots_on_wire as f64 * msg_bytes;
        self.rounds += 1;
        self.dropped_total += dropped_pairs;
        self.bytes_on_wire_total += bytes_on_wire;
        if let Some(d) = &degraded {
            self.degraded_rounds += 1;
            if self.record {
                self.log.degraded.push((k, d.clone()));
            }
        }
        RoundOutcome {
            compute: compute_max,
            comm: total - compute_max,
            degraded,
            dropped_pairs,
            offline_nodes,
            bytes_on_wire,
        }
    }

    /// Simulate one ring-allreduce collective over `n` nodes at
    /// iteration `k` (the parallel-SGD baseline). The collective starts
    /// when the slowest node has computed and runs `2(n−1)` synchronous
    /// phases; each phase lasts as long as its slowest link. A dropped
    /// chunk is retransmitted and a phase touching an offline node
    /// times out and reroutes — either way that link's phase cost
    /// doubles, but only the retransmission is billed bytes: an offline
    /// endpoint transmits nothing (see [`RoundOutcome::bytes_on_wire`]).
    /// An allreduce cannot renormalize a loss away, so the collective
    /// always completes exactly and there is never a degraded plan —
    /// faults only cost it time. Clean uniform case:
    /// `2(n−1)·(α + (S/n)·β)` — exactly [`CostModel::allreduce_time`].
    pub fn simulate_allreduce(&mut self, k: usize, n: usize, msg_bytes: f64) -> RoundOutcome {
        let n = n.max(1);
        let mut arena = std::mem::take(&mut self.arena);
        arena.t_comp.clear();
        for u in 0..n {
            arena.t_comp.push(self.compute_time(k, u, n));
        }
        let compute_max = arena.t_comp.iter().cloned().fold(0.0, f64::max);
        let chunk = msg_bytes / n as f64;
        arena.offline.reset(n);
        for u in 0..n {
            if self.scenario.offline(u, k) {
                arena.offline.set(u);
            }
        }
        let offline_nodes = arena.offline.count();
        let s = &self.scenario;
        let uniform = s.het_spread == 0.0
            && s.link_jitter == 0.0
            && s.drop_prob == 0.0
            && offline_nodes == 0;
        let phases = 2 * (n - 1);
        let mut comm = 0.0f64;
        let mut bytes_on_wire = 0.0f64;
        // Ring links that lost at least one chunk this round — counted
        // per unordered link per *round*, the same unit as the gossip
        // path's dropped pairs, so the `dropped` statistic stays
        // comparable across baselines.
        arena.link_lost.reset(n);
        if uniform {
            // Repeated addition, not `phases × dur` — bitwise-faithful
            // to the per-phase accumulation of the general path (and of
            // the pre-arena implementation).
            let dur = self.cost.link_time(chunk);
            for _ in 0..phases {
                comm += dur;
            }
            bytes_on_wire = phases as f64 * n as f64 * chunk;
        } else {
            for phase in 0..phases {
                let mut worst = 0.0f64;
                for u in 0..n {
                    let v = (u + 1) % n;
                    let mut d = self.slot_time(k, u, v, chunk);
                    let offline = arena.offline.get(u) || arena.offline.get(v);
                    // `!offline &&` mirrors the short-circuit the combined
                    // predicate had: an offline endpoint never draws the
                    // drop coin, so splitting the cases keeps every coin
                    // stream (and hence every downstream draw) unchanged.
                    let dropped = !offline
                        && s.drop_prob > 0.0
                        && coin(self.seed, k, phase * n + u, v, SALT_DROP_AR) < s.drop_prob;
                    if offline {
                        // Timeout + reroute doubles the phase cost, but a
                        // dead endpoint transmits nothing: zero bytes —
                        // the same pricing the gossip ledger applies to
                        // pulls from offline partners.
                        d *= 2.0;
                        arena.link_lost.set(u);
                    } else if dropped {
                        // Transmitted, lost, retransmitted: double bytes.
                        d *= 2.0;
                        arena.link_lost.set(u);
                        bytes_on_wire += 2.0 * chunk;
                    } else {
                        bytes_on_wire += chunk;
                    }
                    worst = worst.max(d);
                }
                comm += worst;
            }
        }
        let dropped_pairs = arena.link_lost.count();
        if self.record {
            self.log.events.push(SimEvent::Allreduce { iter: k, t: compute_max + comm });
        }
        self.rounds += 1;
        self.dropped_total += dropped_pairs;
        self.bytes_on_wire_total += bytes_on_wire;
        self.arena = arena;
        RoundOutcome {
            compute: compute_max,
            comm,
            degraded: None,
            dropped_pairs,
            offline_nodes,
            bytes_on_wire,
        }
    }
}

/// Read-only per-node timing queries over a [`NetSim`]
/// ([`NetSim::ready_oracle`]): the bounded-staleness executors ask
/// "when is node `u`'s wave-`k` compute done?" and "when does `u`'s
/// pull of `v` finish?" one event at a time — the same deterministic
/// draws as [`NetSim::simulate_round`], without advancing any round
/// counters. Pure: safe to consult in any order, which is what makes
/// the out-of-order executor's clock a function of published versions
/// rather than of scheduling order.
pub struct ReadyOracle<'a> {
    sim: &'a NetSim,
}

impl ReadyOracle<'_> {
    /// Absolute time node `u`'s wave-`k` compute finishes when started
    /// at `start` (`n` = the round's node count, for straggler
    /// selection).
    pub fn compute_done(&self, k: usize, u: usize, n: usize, start: f64) -> f64 {
        start + self.sim.compute_time(k, u, n)
    }

    /// Absolute time the exchange slot `u ← v` at wave `k` finishes
    /// when started at `start`, carrying `msg_bytes`.
    pub fn pull_done(&self, k: usize, u: usize, v: usize, start: f64, msg_bytes: f64) -> f64 {
        start + self.sim.slot_time(k, u, v, msg_bytes)
    }

    /// Compute/communication overlap fraction of the underlying cost
    /// model.
    pub fn overlap(&self) -> f64 {
        self.sim.cost.overlap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::static_exp_plan;
    use crate::topology::schedule::Schedule;
    use crate::topology::TopologyKind;

    fn cost() -> CostModel {
        CostModel::paper_default(0.4)
    }

    #[test]
    fn coin_is_pure_and_roughly_uniform() {
        assert_eq!(coin(1, 2, 3, 4, 5), coin(1, 2, 3, 4, 5));
        assert_ne!(coin(1, 2, 3, 4, 5), coin(2, 2, 3, 4, 5));
        assert_ne!(coin(1, 2, 3, 4, SALT_DROP), coin(1, 2, 3, 4, SALT_COMPUTE));
        let n = 20_000;
        let mean: f64 = (0..n).map(|i| coin(7, i, 0, 1, SALT_DROP)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn clean_round_matches_cost_model_exactly() {
        let plan = static_exp_plan(16);
        let mut sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let msg = 1e8;
        let out = sim.simulate_round(0, &plan, msg);
        let want = cost().partial_averaging_time(&plan, msg);
        assert!((out.comm - want).abs() <= 1e-12 * want, "{} vs {want}", out.comm);
        assert_eq!(out.compute, 0.4);
        assert!(out.degraded.is_none());
        assert_eq!(out.dropped_pairs, 0);
    }

    #[test]
    fn clean_allreduce_matches_cost_model_exactly() {
        let mut sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let msg = 1e8;
        let out = sim.simulate_allreduce(0, 32, msg);
        let want = cost().allreduce_time(32, msg);
        assert!((out.comm - want).abs() <= 1e-12 * want, "{} vs {want}", out.comm);
        assert!(out.degraded.is_none());
    }

    #[test]
    fn degenerate_sizes_zero_phase_collectives_and_pure_latency_rounds() {
        // n = 1: 2(n−1) = 0 phases — zero comm, zero bytes, and the
        // closed form agrees.
        let mut sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let out = sim.simulate_allreduce(0, 1, 1e8);
        assert_eq!(out.comm, 0.0);
        assert_eq!(out.bytes_on_wire, 0.0);
        assert_eq!(cost().allreduce_time(1, 1e8), 0.0);

        // msg_bytes = 0: pure-latency rounds. The clock still charges α
        // per slot/phase; the bytes ledger is exactly zero.
        let plan = static_exp_plan(16);
        let mut sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let gossip = sim.simulate_round(0, &plan, 0.0);
        let want = cost().partial_averaging_time(&plan, 0.0);
        assert!((gossip.comm - want).abs() <= 1e-12 * want, "{} vs {want}", gossip.comm);
        assert!(gossip.comm > 0.0, "latency term must survive zero payload");
        assert_eq!(gossip.bytes_on_wire, 0.0);
        let ar = sim.simulate_allreduce(1, 16, 0.0);
        assert!((ar.comm - cost().allreduce_time(16, 0.0)).abs() <= 1e-12 * ar.comm);
        assert_eq!(ar.bytes_on_wire, 0.0);
    }

    #[test]
    fn allreduce_pays_time_but_not_bytes_for_offline_nodes() {
        let n = 16usize;
        let msg = 1e8;
        let scen = Scenario { dropout: vec![(0, 0, 2)], ..Scenario::clean() };
        let mut sim = NetSim::new(&cost(), scen, 1);
        let partitioned = sim.simulate_allreduce(0, n, msg);
        let healed = sim.simulate_allreduce(5, n, msg);
        assert_eq!(partitioned.offline_nodes, 1);
        assert!(partitioned.degraded.is_none(), "allreduce completes exactly, only slower");
        assert!(
            partitioned.comm > healed.comm,
            "partitioned collective {} should cost more than healed {}",
            partitioned.comm,
            healed.comm
        );
        assert!((healed.comm - cost().allreduce_time(n, msg)).abs() <= 1e-11 * healed.comm);
        // Time doubles on the two ring links touching the dead node, but a
        // dead transmitter is never billed bytes: both links go unpaid, so
        // the round carries exactly (n−2)/n of the clean payload.
        let chunk = msg / n as f64;
        let phases = 2 * (n - 1);
        assert_eq!(healed.bytes_on_wire, phases as f64 * n as f64 * chunk);
        assert_eq!(
            partitioned.bytes_on_wire,
            phases as f64 * (n - 2) as f64 * chunk,
            "offline endpoints must not be billed bytes"
        );
        assert!(partitioned.bytes_on_wire < healed.bytes_on_wire);
    }

    #[test]
    fn allreduce_bills_dropped_chunks_double_and_offline_gossip_pulls_zero() {
        let n = 16usize;
        let msg = 1e8;
        // drop_prob = 1.0: every chunk is transmitted, lost, and
        // retransmitted — exactly 2× the clean ledger, unlike offline.
        let scen = Scenario { drop_prob: 1.0, ..Scenario::clean() };
        let mut sim = NetSim::new(&cost(), scen, 1);
        let lossy = sim.simulate_allreduce(0, n, msg);
        let mut clean_sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let clean = clean_sim.simulate_allreduce(0, n, msg);
        assert_eq!(lossy.bytes_on_wire, 2.0 * clean.bytes_on_wire);

        // The gossip ledger prices the same offline scenario the same
        // way: pulls touching the dead node cost zero bytes.
        let plan = static_exp_plan(n);
        let scen = Scenario { dropout: vec![(0, 0, 2)], ..Scenario::clean() };
        let mut sim = NetSim::new(&cost(), scen, 1);
        let faulted = sim.simulate_round(0, &plan, msg);
        let healed = sim.simulate_round(5, &plan, msg);
        let dead_slots: usize = (0..n)
            .map(|u| {
                if u == 0 {
                    plan.partners(u).len()
                } else {
                    plan.partners(u).iter().filter(|&&v| v == 0).count()
                }
            })
            .sum();
        assert!(dead_slots > 0);
        assert_eq!(
            faulted.bytes_on_wire,
            healed.bytes_on_wire - dead_slots as f64 * msg
        );
    }

    #[test]
    fn straggler_slows_round_without_degrading_plan() {
        let plan = static_exp_plan(16);
        let mut clean = NetSim::new(&cost(), Scenario::clean(), 3);
        let mut slow = NetSim::new(&cost(), Scenario::straggler(), 3);
        let a = clean.simulate_round(0, &plan, 1e8);
        let b = slow.simulate_round(0, &plan, 1e8);
        assert!(b.compute > a.compute, "straggler compute {} !> {}", b.compute, a.compute);
        assert!(
            b.iteration_time(0.7) > a.iteration_time(0.7),
            "straggler round not slower"
        );
        assert!(b.degraded.is_none(), "stragglers must not alter the plan");
        assert_eq!(
            a.bytes_on_wire, b.bytes_on_wire,
            "stragglers change the clock, never the traffic"
        );
    }

    #[test]
    fn lossy_round_degrades_and_counts_drops() {
        let plan = static_exp_plan(16);
        let mut sim = NetSim::new(&cost(), Scenario::lossy(), 5);
        // 16-node static exp has 7·16/2 = 56 partner pairs at 30% drop:
        // a fault fires essentially surely; the assertion documents it.
        let out = sim.simulate_round(0, &plan, 1e8);
        assert!(out.dropped_pairs > 0, "expected transient drops at p=0.3");
        let d = out.degraded.expect("faults fired ⇒ degraded plan");
        for (i, row) in d.rows_vec().iter().enumerate() {
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-9, "row {i} sum {sum}");
        }
        assert_eq!(sim.degraded_rounds, 1);
    }

    #[test]
    fn dropout_window_isolates_node() {
        let scen = Scenario { dropout: vec![(2, 0, 3)], ..Scenario::clean() };
        let mut sched = Schedule::new(TopologyKind::Ring, 8, 0);
        let plan = sched.plan_at(0).clone();
        let mut sim = NetSim::new(&cost(), scen, 1);
        let out = sim.simulate_round(1, &plan, 1e6);
        assert_eq!(out.offline_nodes, 1);
        let d = out.degraded.expect("offline node degrades the plan");
        assert_eq!(d.rows_vec()[2], vec![(2, 1.0)]);
        // Ring is symmetric; pair-level dropout must keep it symmetric.
        assert!(d.symmetric, "degraded ring lost symmetry");
        // Outside the window: untouched.
        let out2 = sim.simulate_round(5, &plan, 1e6);
        assert!(out2.degraded.is_none());
    }

    #[test]
    fn recorded_trace_is_reproducible() {
        let plan = static_exp_plan(8);
        let run = || {
            let mut sim = NetSim::new(&cost(), Scenario::lossy(), 11).recording();
            for k in 0..6 {
                sim.simulate_round(k, &plan, 1e7);
            }
            sim.take_log()
        };
        let a = run();
        let b = run();
        assert!(!a.events.is_empty());
        assert_eq!(a, b, "same seed must reproduce the exact trace");
        let mut other = NetSim::new(&cost(), Scenario::lossy(), 12).recording();
        for k in 0..6 {
            other.simulate_round(k, &plan, 1e7);
        }
        assert_ne!(a, other.take_log(), "different seed should change the trace");
    }

    #[test]
    fn arena_round_matches_reference_bitwise() {
        // The arena chain-walk and the retired heap produce identical
        // traces, outcomes (to the bit), counters, and degraded plans —
        // the determinism acceptance criterion, checked here at module
        // scale and again at n = 4096 in tests/netsim_scale.rs.
        for scen in [Scenario::clean(), Scenario::straggler(), Scenario::lossy()] {
            for n in [1usize, 2, 8, 16, 33] {
                let plan = static_exp_plan(n);
                let mut arena_sim = NetSim::new(&cost(), scen.clone(), 9).recording();
                let mut ref_sim = NetSim::new(&cost(), scen.clone(), 9).recording();
                for k in [0usize, 1, 55] {
                    let a = arena_sim.simulate_round(k, &plan, 1e7);
                    let b = ref_sim.simulate_round_reference(k, &plan, 1e7);
                    let tag = format!("{} n={n} k={k}", scen.name);
                    assert_eq!(a.compute.to_bits(), b.compute.to_bits(), "{tag}");
                    assert_eq!(a.comm.to_bits(), b.comm.to_bits(), "{tag}");
                    assert_eq!(a.degraded, b.degraded, "{} n={n} k={k}", scen.name);
                    assert_eq!(a.dropped_pairs, b.dropped_pairs);
                    assert_eq!(a.offline_nodes, b.offline_nodes);
                    assert_eq!(a.bytes_on_wire.to_bits(), b.bytes_on_wire.to_bits());
                }
                assert_eq!(arena_sim.take_log(), ref_sim.take_log(), "{} n={n}", scen.name);
                assert_eq!(arena_sim.dropped_total, ref_sim.dropped_total);
                assert_eq!(arena_sim.degraded_rounds, ref_sim.degraded_rounds);
            }
        }
    }

    #[test]
    fn bytes_on_wire_counts_executed_slots() {
        // Clean round: every directed partner slot carries the message.
        let plan = static_exp_plan(16);
        let mut sim = NetSim::new(&cost(), Scenario::clean(), 1);
        let out = sim.simulate_round(0, &plan, 1e7);
        let directed_slots: usize = (0..16).map(|u| plan.partners(u).len()).sum();
        assert_eq!(out.bytes_on_wire, directed_slots as f64 * 1e7);
        assert_eq!(sim.bytes_on_wire_total, out.bytes_on_wire);
        // An offline node sends nothing and is pulled-from by nobody.
        let scen = Scenario { dropout: vec![(2, 0, 3)], ..Scenario::clean() };
        let mut sim2 = NetSim::new(&cost(), scen, 1);
        let out2 = sim2.simulate_round(1, &plan, 1e7);
        assert!(out2.bytes_on_wire < out.bytes_on_wire);
    }

    #[test]
    fn iteration_time_overlap_rule_matches_cost_model() {
        let c = cost();
        let plan = static_exp_plan(16);
        let mut sim = NetSim::new(&c, Scenario::clean(), 1);
        let msg = 1e8;
        let out = sim.simulate_round(0, &plan, msg);
        let want = {
            let comm = c.partial_averaging_time(&plan, msg);
            c.compute + comm - c.compute.min(comm) * c.overlap
        };
        let got = out.iteration_time(c.overlap);
        assert!((got - want).abs() <= 1e-12 * want, "{got} vs {want}");
    }
}
