//! Experiment configuration: JSON files (parsed with the in-crate
//! [`crate::util::json`] reader) plus CLI overrides.
//!
//! Example config (see `configs/` at the repo root):
//!
//! ```json
//! {
//!   "nodes": 16,
//!   "topology": "one_peer_exp",
//!   "algorithm": "dmsgd",
//!   "iters": 2000,
//!   "lr": 0.05,
//!   "beta": 0.9,
//!   "batch": 32,
//!   "heterogeneous": false,
//!   "seed": 1
//! }
//! ```

use crate::compress::CompressorKind;
use crate::coordinator::{AsyncExec, ExecutionMode};
use crate::optim::AlgorithmKind;
use crate::topology::{family, Topology, TopologyKind};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Resolve a topology name through the open family registry; the error
/// lists every registered name (generated from the registry, never
/// hand-written — the same bug class as the old `exp` usage list).
pub fn parse_topology(s: &str) -> Result<Topology> {
    family::find(s).ok_or_else(|| {
        anyhow!("unknown topology {s} (registered: {})", family::names().join(" "))
    })
}

/// Sweep scheduling knobs shared by every grid-running surface
/// (`expograph exp --jobs/--cache`, `expograph netsim jobs=/cache=`):
/// how many cells run concurrently, and whether completed cells are
/// served from the on-disk result cache (docs/DESIGN.md §Sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepConfig {
    /// Parallel sweep jobs; 0 = auto (one per core). The per-cell
    /// engine lane budget keeps `jobs × lanes ≤ cores` either way.
    pub jobs: usize,
    /// Serve completed cells from `<out>/.cache/` and persist new ones.
    pub cache: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { jobs: 0, cache: true }
    }
}

/// Largest accepted staleness bound: the executor keeps `τ + 2` payload
/// versions per node, so an absurd τ is a memory bug, not a knob.
pub const MAX_STALENESS: usize = 4096;

/// Parse an execution mode (`sync` or `async:<τ>`, τ ≤
/// [`MAX_STALENESS`]) with a config-surface error message.
pub fn parse_execution(s: &str) -> Result<ExecutionMode> {
    let mode = ExecutionMode::parse(s).ok_or_else(|| {
        anyhow!(
            "unknown execution mode {s} (sync | async:<staleness>; \
             pick the async executor with exec=waves|ooo)"
        )
    })?;
    if let ExecutionMode::Async { tau } = mode {
        if tau > MAX_STALENESS {
            bail!("async staleness {tau} exceeds the limit ({MAX_STALENESS})");
        }
    }
    Ok(mode)
}

/// Parse an async executor variant (`waves` or `ooo`) with a
/// config-surface error message.
pub fn parse_async_exec(s: &str) -> Result<AsyncExec> {
    AsyncExec::parse(s).ok_or_else(|| {
        anyhow!("unknown async executor {s} (waves | ooo — out-of-order ready batches)")
    })
}

/// Parse an on/off-style boolean (`on|off|true|false|1|0`).
pub fn parse_switch(value: &str) -> Result<bool> {
    match value {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("expected on|off (or true|false), got {other}"),
    }
}

impl SweepConfig {
    /// Apply a `key=value` override if the key belongs to this config;
    /// returns whether it was consumed (so host configs can fall back
    /// to their own keys).
    pub fn set(&mut self, key: &str, value: &str) -> Result<bool> {
        match key {
            "jobs" => {
                self.jobs = value.parse().map_err(|e| anyhow!("jobs: {e}"))?;
                Ok(true)
            }
            "cache" => {
                self.cache = parse_switch(value).map_err(|e| anyhow!("cache: {e}"))?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }
}

/// One training-run configuration. `topology` is an open-registry
/// handle, so config files and CLI overrides accept the finite-time
/// families (`base4`, `ceca`, …) alongside the paper zoo.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub nodes: usize,
    pub topology: Topology,
    pub algorithm: AlgorithmKind,
    pub iters: usize,
    pub lr: f32,
    pub beta: f32,
    pub batch: usize,
    pub heterogeneous: bool,
    pub warmup_allreduce: bool,
    pub seed: u64,
    /// Execution mode: `"sync"` (bulk-synchronous rounds) or
    /// `"async:<τ>"` (bounded-staleness gossip — docs/DESIGN.md §Async
    /// runtime). `async:0` is bitwise identical to `sync`.
    pub execution: ExecutionMode,
    /// Async executor variant: `"ooo"` (out-of-order ready batches,
    /// default) or `"waves"` (the serial-wave reference — the escape
    /// hatch mirroring `fused_probe`). Both are bitwise identical;
    /// ignored under `execution=sync`.
    pub exec: AsyncExec,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nodes: 16,
            topology: TopologyKind::OnePeerExp.family(),
            algorithm: AlgorithmKind::DmSgd,
            iters: 2000,
            lr: 0.05,
            beta: 0.9,
            batch: 32,
            heterogeneous: false,
            warmup_allreduce: true,
            seed: 1,
            execution: ExecutionMode::Sync,
            exec: AsyncExec::Ooo,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document; absent keys keep defaults.
    pub fn from_json(doc: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let obj = doc.as_object().ok_or_else(|| anyhow!("config must be a JSON object"))?;
        for (key, val) in obj {
            match key.as_str() {
                "nodes" => cfg.nodes = val.as_usize().context("nodes")?,
                "iters" => cfg.iters = val.as_usize().context("iters")?,
                "batch" => cfg.batch = val.as_usize().context("batch")?,
                "seed" => cfg.seed = val.as_f64().context("seed")? as u64,
                "lr" => cfg.lr = val.as_f64().context("lr")? as f32,
                "beta" => cfg.beta = val.as_f64().context("beta")? as f32,
                "heterogeneous" => cfg.heterogeneous = val.as_bool().context("heterogeneous")?,
                "warmup_allreduce" => {
                    cfg.warmup_allreduce = val.as_bool().context("warmup_allreduce")?
                }
                "topology" => {
                    let s = val.as_str().context("topology")?;
                    cfg.topology = parse_topology(s)?;
                }
                "algorithm" => {
                    let s = val.as_str().context("algorithm")?;
                    cfg.algorithm =
                        AlgorithmKind::parse(s).ok_or_else(|| anyhow!("unknown algorithm {s}"))?;
                }
                "execution" => {
                    let s = val.as_str().context("execution")?;
                    cfg.execution = parse_execution(s)?;
                }
                "exec" => {
                    let s = val.as_str().context("exec")?;
                    cfg.exec = parse_async_exec(s)?;
                }
                other => bail!("unknown config key: {other}"),
            }
        }
        if cfg.nodes == 0 {
            bail!("nodes must be positive");
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation (called after CLI overrides too, since
    /// `set` is per-key and order-independent).
    pub fn validate(&self) -> Result<()> {
        if self.topology.requires_pow2() && !self.nodes.is_power_of_two() {
            bail!("topology {} requires a power-of-two node count, got {}", self.topology, self.nodes);
        }
        Ok(())
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_json(&doc)
    }

    /// Apply a `key=value` CLI override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "nodes" => self.nodes = value.parse()?,
            "iters" => self.iters = value.parse()?,
            "batch" => self.batch = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "beta" => self.beta = value.parse()?,
            "heterogeneous" => self.heterogeneous = value.parse()?,
            "warmup_allreduce" => self.warmup_allreduce = value.parse()?,
            "topology" => self.topology = parse_topology(value)?,
            "algorithm" => {
                self.algorithm = AlgorithmKind::parse(value)
                    .ok_or_else(|| anyhow!("unknown algorithm {value}"))?
            }
            "execution" => self.execution = parse_execution(value)?,
            "exec" => self.exec = parse_async_exec(value)?,
            other => bail!("unknown config key: {other}"),
        }
        Ok(())
    }
}

/// Configuration for the `netsim` subcommand: a topology × n ×
/// scenario sweep measuring simulated time-to-target (the Table 2/3
/// analogue under heterogeneous / faulty networks — docs/DESIGN.md
/// §NetSim).
#[derive(Clone, Debug)]
pub struct NetSimRunConfig {
    pub nodes: Vec<usize>,
    pub topologies: Vec<TopologyKind>,
    /// Scenario presets, parsed once here via
    /// [`crate::netsim::Scenario::parse`] — the runner consumes them
    /// directly, so an unknown name can only fail at the config surface.
    pub scenarios: Vec<crate::netsim::Scenario>,
    /// Iteration budget per run (runs that miss the target report the
    /// full budget's simulated time).
    pub iters: usize,
    /// Parameter dimension of the synthetic heterogeneous quadratic.
    pub dim: usize,
    /// Target: mean squared distance to the global optimum below
    /// `tol · err₀`.
    pub tol: f64,
    /// Gossip message size (defaults to ResNet-50-scale, like Table 2).
    /// This is the *dense* payload; every wire-size computation prices
    /// rounds at `compressor.wire_bytes(msg_bytes)`.
    pub msg_bytes: f64,
    /// Gossip payload compressor (`compressor=identity|topk[:frac]|int8`).
    pub compressor: CompressorKind,
    /// Per-iteration local compute seconds.
    pub compute: f64,
    pub seed: u64,
    /// Plan-only mode (`plan_only=on`, or the `--large-n` preset):
    /// scalar consensus to the initial mean instead of P-dimensional
    /// training — the only mode allowed past n = 65536, where training
    /// state (n × dim floats per optimizer slot) stops fitting.
    pub plan_only: bool,
    /// Sweep scheduling (jobs + result cache) for the cell grid.
    pub sweep: SweepConfig,
}

/// Largest node count the training-state path accepts; beyond this the
/// sweep must run `plan_only` (enforced by
/// [`NetSimRunConfig::validate`]).
pub const NETSIM_TRAINING_MAX_NODES: usize = 65_536;

impl Default for NetSimRunConfig {
    fn default() -> Self {
        NetSimRunConfig {
            nodes: vec![16, 64],
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Grid2D,
                TopologyKind::StaticExp,
                TopologyKind::OnePeerExp,
            ],
            scenarios: vec![
                crate::netsim::Scenario::clean(),
                crate::netsim::Scenario::straggler(),
                crate::netsim::Scenario::lossy(),
            ],
            iters: 1200,
            dim: 32,
            tol: 0.01,
            msg_bytes: 25.5e6 * 4.0,
            compressor: CompressorKind::Identity,
            compute: 0.4,
            seed: 1,
            plan_only: false,
            sweep: SweepConfig::default(),
        }
    }
}

impl NetSimRunConfig {
    /// Apply a `key=value` CLI override. List values are
    /// comma-separated (`nodes=8,64`, `topologies=ring,one_peer_exp`,
    /// `scenarios=clean,lossy`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "nodes" => {
                self.nodes = value
                    .split(',')
                    .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("nodes: {e}")))
                    .collect::<Result<Vec<_>>>()?;
                if self.nodes.is_empty() || self.nodes.contains(&0) {
                    bail!("nodes must be a non-empty list of positive sizes");
                }
            }
            "topologies" => {
                self.topologies = value
                    .split(',')
                    .map(|s| {
                        let s = s.trim();
                        TopologyKind::parse(s).ok_or_else(|| {
                            anyhow!(
                                "unknown topology {s} (netsim sweeps the paper zoo: {})",
                                family::kind_names().join(" ")
                            )
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                if self.topologies.is_empty() {
                    bail!("topologies must be non-empty");
                }
            }
            "scenarios" => {
                self.scenarios = value
                    .split(',')
                    .map(|s| {
                        let s = s.trim();
                        crate::netsim::Scenario::parse(s)
                            .ok_or_else(|| {
                                anyhow!("unknown scenario {s} (clean|straggler|flaky|lossy)")
                            })
                    })
                    .collect::<Result<Vec<_>>>()?;
                if self.scenarios.is_empty() {
                    bail!("scenarios must be non-empty");
                }
            }
            "iters" => {
                self.iters = value.parse()?;
                if self.iters == 0 {
                    bail!("iters must be positive");
                }
            }
            "dim" => {
                self.dim = value.parse()?;
                if self.dim == 0 {
                    bail!("dim must be positive");
                }
            }
            "tol" => {
                self.tol = value.parse()?;
                if !self.tol.is_finite() || self.tol <= 0.0 {
                    bail!("tol must be positive");
                }
            }
            "msg_bytes" => {
                self.msg_bytes = value.parse()?;
                if !self.msg_bytes.is_finite() || self.msg_bytes <= 0.0 {
                    bail!("msg_bytes must be positive");
                }
            }
            "compressor" => {
                self.compressor = CompressorKind::parse(value).ok_or_else(|| {
                    anyhow!("unknown compressor {value} (identity | topk[:frac] | int8)")
                })?;
            }
            "compute" => {
                self.compute = value.parse()?;
                if !self.compute.is_finite() || self.compute < 0.0 {
                    bail!("compute must be non-negative");
                }
            }
            "seed" => self.seed = value.parse()?,
            "plan_only" => {
                self.plan_only = parse_switch(value).map_err(|e| anyhow!("plan_only: {e}"))?;
            }
            other => {
                if !self.sweep.set(other, value)? {
                    bail!("unknown netsim config key: {other}");
                }
            }
        }
        Ok(())
    }

    /// Cross-field validation (called by the runner and the CLI after
    /// all overrides, since `set` is per-key and order-independent):
    /// node counts past [`NETSIM_TRAINING_MAX_NODES`] require the
    /// plan-only path — the training path would allocate `n × dim`
    /// floats per optimizer slot.
    pub fn validate(&self) -> Result<()> {
        if !self.plan_only {
            if let Some(&n) = self.nodes.iter().find(|&&n| n > NETSIM_TRAINING_MAX_NODES) {
                bail!(
                    "n={n} exceeds the training-state limit ({NETSIM_TRAINING_MAX_NODES}); \
                     large-n sweeps must set plan_only=on (or use --large-n)"
                );
            }
        }
        Ok(())
    }

    /// The `--large-n` preset: the scaling axis of the tentpole —
    /// one-peer exponential plans only (O(1) degree, streamed per
    /// round), clean + lossy scenarios, n ∈ {2¹⁴, 2¹⁶, 2²⁰}, plan-only
    /// consensus, one job (a 2²⁰-node cell owns the machine's memory
    /// bandwidth; parallel cells would just thrash).
    pub fn apply_large_n_preset(&mut self) {
        self.nodes = vec![1 << 14, 1 << 16, 1 << 20];
        self.topologies = vec![TopologyKind::OnePeerExp];
        self.scenarios = vec![crate::netsim::Scenario::clean(), crate::netsim::Scenario::lossy()];
        self.plan_only = true;
        self.iters = 256;
        self.sweep.jobs = 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = Json::parse(
            r#"{"nodes": 8, "topology": "static_exp", "algorithm": "qg_dmsgd",
                "iters": 100, "lr": 0.1, "beta": 0.8, "batch": 16,
                "heterogeneous": true, "seed": 42}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.nodes, 8);
        assert_eq!(cfg.topology, TopologyKind::StaticExp);
        assert_eq!(cfg.algorithm, AlgorithmKind::QgDmSgd);
        assert!(cfg.heterogeneous);
        assert_eq!(cfg.seed, 42);
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let cfg = RunConfig::from_json(&Json::parse(r#"{"nodes": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.algorithm, AlgorithmKind::DmSgd);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(RunConfig::from_json(&Json::parse(r#"{"nopes": 1}"#).unwrap()).is_err());
        assert!(RunConfig::from_json(&Json::parse(r#"{"topology": "mobius"}"#).unwrap()).is_err());
        assert!(RunConfig::from_json(&Json::parse(r#"{"nodes": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn netsim_config_overrides_and_validation() {
        use crate::netsim::Scenario;
        let mut cfg = NetSimRunConfig::default();
        cfg.set("nodes", "8,64").unwrap();
        cfg.set("topologies", "ring,one_peer_exp").unwrap();
        cfg.set("scenarios", "clean,lossy").unwrap();
        cfg.set("iters", "300").unwrap();
        cfg.set("tol", "0.02").unwrap();
        assert_eq!(cfg.nodes, vec![8, 64]);
        assert_eq!(cfg.topologies, vec![TopologyKind::Ring, TopologyKind::OnePeerExp]);
        assert_eq!(cfg.scenarios, vec![Scenario::clean(), Scenario::lossy()]);
        assert_eq!(cfg.iters, 300);
        assert!(cfg.set("scenarios", "sunny").is_err());
        assert!(cfg.set("topologies", "mobius").is_err());
        assert!(cfg.set("nodes", "0").is_err());
        assert!(cfg.set("iters", "0").is_err());
        assert!(cfg.set("dim", "0").is_err());
        assert!(cfg.set("tol", "-1").is_err());
        assert!(cfg.set("msg_bytes", "nan").is_err());
        assert!(cfg.set("bogus", "1").is_err());
        assert_eq!(cfg.compressor, CompressorKind::Identity);
        cfg.set("compressor", "topk:0.25").unwrap();
        assert_eq!(cfg.compressor, CompressorKind::TopK { frac: 0.25 });
        cfg.set("compressor", "int8").unwrap();
        assert_eq!(cfg.compressor, CompressorKind::Int8);
        cfg.set("compressor", "identity").unwrap();
        assert!(cfg.set("compressor", "gzip").is_err());
        // Sweep keys ride along on the netsim config surface.
        cfg.set("jobs", "4").unwrap();
        cfg.set("cache", "off").unwrap();
        assert_eq!(cfg.sweep, SweepConfig { jobs: 4, cache: false });
        assert!(cfg.set("cache", "sideways").is_err());
    }

    #[test]
    fn netsim_plan_only_knob_and_large_n_validation() {
        let mut cfg = NetSimRunConfig::default();
        assert!(!cfg.plan_only);
        assert!(cfg.validate().is_ok());
        // Past the training-state limit the sweep must be plan-only.
        cfg.set("nodes", "1048576").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("plan_only"), "error must point at the knob: {err}");
        cfg.set("plan_only", "on").unwrap();
        assert!(cfg.plan_only);
        assert!(cfg.validate().is_ok());
        cfg.set("plan_only", "off").unwrap();
        assert!(cfg.validate().is_err());
        assert!(cfg.set("plan_only", "sideways").is_err());
        // At or below the limit the training path stays allowed.
        cfg.set("nodes", "65536").unwrap();
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn large_n_preset_is_plan_only_one_peer() {
        let mut cfg = NetSimRunConfig::default();
        cfg.apply_large_n_preset();
        assert_eq!(cfg.nodes, vec![1 << 14, 1 << 16, 1 << 20]);
        assert_eq!(cfg.topologies, vec![TopologyKind::OnePeerExp]);
        assert_eq!(cfg.scenarios.len(), 2);
        assert!(cfg.plan_only);
        assert_eq!(cfg.sweep.jobs, 1);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn sweep_config_switch_parsing() {
        assert_eq!(SweepConfig::default(), SweepConfig { jobs: 0, cache: true });
        for (s, want) in [("on", true), ("true", true), ("1", true), ("off", false)] {
            assert_eq!(parse_switch(s).unwrap(), want, "{s}");
        }
        assert!(parse_switch("maybe").is_err());
        let mut sw = SweepConfig::default();
        assert!(!sw.set("nodes", "8").unwrap(), "foreign keys are not consumed");
        assert!(sw.set("jobs", "x").is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        cfg.set("topology", "ring").unwrap();
        cfg.set("lr", "0.25").unwrap();
        assert_eq!(cfg.topology, TopologyKind::Ring);
        assert_eq!(cfg.lr, 0.25);
        assert!(cfg.set("bogus", "1").is_err());
    }

    #[test]
    fn execution_mode_round_trips_through_config_surfaces() {
        // JSON key.
        let doc = Json::parse(r#"{"nodes": 8, "execution": "async:2"}"#).unwrap();
        let cfg = RunConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.execution, ExecutionMode::Async { tau: 2 });
        // Absent key keeps the bulk-synchronous default.
        assert_eq!(RunConfig::default().execution, ExecutionMode::Sync);
        // CLI override, including the label() round trip.
        let mut cfg = RunConfig::default();
        cfg.set("execution", "async:0").unwrap();
        assert_eq!(cfg.execution, ExecutionMode::Async { tau: 0 });
        cfg.set("execution", &ExecutionMode::Async { tau: 3 }.label()).unwrap();
        assert_eq!(cfg.execution, ExecutionMode::Async { tau: 3 });
        cfg.set("execution", "sync").unwrap();
        assert_eq!(cfg.execution, ExecutionMode::Sync);
        // Rejections: garbage, missing τ, and an absurd τ.
        assert!(cfg.set("execution", "bulk").is_err());
        assert!(cfg.set("execution", "async").is_err());
        assert!(cfg.set("execution", "async:9999999").is_err());
        let err =
            RunConfig::from_json(&Json::parse(r#"{"execution": "async:5000"}"#).unwrap())
                .unwrap_err()
                .to_string();
        assert!(err.contains("staleness"), "{err}");
        // The parse error names the executor sub-knob.
        let err = cfg.set("execution", "warp").unwrap_err().to_string();
        assert!(err.contains("exec=waves|ooo"), "{err}");
    }

    #[test]
    fn async_exec_round_trips_through_config_surfaces() {
        // JSON key.
        let doc = Json::parse(r#"{"nodes": 8, "exec": "waves"}"#).unwrap();
        let cfg = RunConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.exec, AsyncExec::Waves);
        // Absent key keeps the out-of-order default.
        assert_eq!(RunConfig::default().exec, AsyncExec::Ooo);
        // CLI override, including the label() round trip.
        let mut cfg = RunConfig::default();
        cfg.set("exec", "waves").unwrap();
        assert_eq!(cfg.exec, AsyncExec::Waves);
        cfg.set("exec", AsyncExec::Ooo.label()).unwrap();
        assert_eq!(cfg.exec, AsyncExec::Ooo);
        // Rejections name both accepted values.
        let err = cfg.set("exec", "eager").unwrap_err().to_string();
        assert!(err.contains("waves") && err.contains("ooo"), "{err}");
    }

    #[test]
    fn netsim_scenarios_accept_flaky() {
        use crate::netsim::Scenario;
        let mut cfg = NetSimRunConfig::default();
        cfg.set("scenarios", "clean,flaky").unwrap();
        assert_eq!(cfg.scenarios, vec![Scenario::clean(), Scenario::flaky()]);
        let err = cfg.set("scenarios", "sunny").unwrap_err().to_string();
        assert!(err.contains("flaky"), "error must list the flaky preset: {err}");
    }

    #[test]
    fn topology_override_accepts_open_registry_families() {
        let mut cfg = RunConfig::default();
        cfg.set("topology", "base4").unwrap();
        assert_eq!(cfg.topology.name(), "base4");
        assert_eq!(cfg.topology.kind(), None);
        cfg.set("topology", "ceca").unwrap();
        assert_eq!(cfg.topology.name(), "ceca");
        // Aliases resolve through the same registry lookup.
        cfg.set("topology", "base_k").unwrap();
        assert_eq!(cfg.topology.name(), "base4");
        cfg.set("topology", "parallel").unwrap();
        assert_eq!(cfg.topology, TopologyKind::FullyConnected);
    }

    #[test]
    fn unknown_topology_error_lists_registered_names() {
        let err = RunConfig::default().set("topology", "mobius").unwrap_err().to_string();
        for name in crate::topology::family::names() {
            assert!(err.contains(name), "error listing missing {name}: {err}");
        }
    }

    #[test]
    fn validate_rejects_pow2_families_on_other_sizes() {
        let doc = Json::parse(r#"{"nodes": 12, "topology": "hypercube"}"#).unwrap();
        assert!(RunConfig::from_json(&doc).is_err());
        let mut cfg = RunConfig::default();
        cfg.set("topology", "one_peer_hypercube").unwrap();
        cfg.set("nodes", "12").unwrap();
        assert!(cfg.validate().is_err());
        cfg.set("nodes", "16").unwrap();
        assert!(cfg.validate().is_ok());
        // Finite-time families accept any n by construction.
        cfg.set("topology", "ceca").unwrap();
        cfg.set("nodes", "12").unwrap();
        assert!(cfg.validate().is_ok());
    }
}
