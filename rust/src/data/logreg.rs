//! Distributed logistic regression data per Appendix D.5.
//!
//! Node `i` holds `M` samples `{h_{i,m}, y_{i,m}}` with `h ~ N(0, 10·I_d)`
//! and `y ∈ {±1}` drawn by passing `hᵀx*_i` through the logistic link.
//! Homogeneous data: all nodes share one `x*`; heterogeneous: each node
//! draws (and normalizes) its own `x*_i`.

use crate::util::rng::Pcg;

/// One node's local dataset.
#[derive(Clone, Debug)]
pub struct LogRegShard {
    /// Features, row-major `M × d`.
    pub features: Vec<f64>,
    /// Labels in `{+1, −1}`, length `M`.
    pub labels: Vec<f64>,
    /// The generating parameter `x*_i` (normalized), length `d`.
    pub x_star: Vec<f64>,
    pub m: usize,
    pub d: usize,
}

/// The full distributed problem: one shard per node.
#[derive(Clone, Debug)]
pub struct LogRegProblem {
    pub shards: Vec<LogRegShard>,
    pub d: usize,
    /// Consensus ground truth `x̄* = (1/n)Σ x*_i` (what DmSGD converges
    /// toward when measuring MSE as in Fig. 13).
    pub x_star_mean: Vec<f64>,
}

/// Configuration for the generator.
#[derive(Clone, Copy, Debug)]
pub struct LogRegConfig {
    pub nodes: usize,
    /// Samples per node (paper: 14000 for Fig. 13).
    pub samples_per_node: usize,
    /// Feature dimension (paper: 10).
    pub dim: usize,
    /// Heterogeneous data: distinct `x*_i` per node.
    pub heterogeneous: bool,
    pub seed: u64,
}

impl Default for LogRegConfig {
    fn default() -> Self {
        LogRegConfig { nodes: 64, samples_per_node: 14_000, dim: 10, heterogeneous: true, seed: 1 }
    }
}

fn normalized_gaussian(rng: &mut Pcg, d: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    for x in v.iter_mut() {
        *x /= norm;
    }
    v
}

/// Generate the distributed problem.
pub fn generate(cfg: &LogRegConfig) -> LogRegProblem {
    let mut rng = Pcg::new(cfg.seed, 0x106);
    let shared_star = normalized_gaussian(&mut rng, cfg.dim);
    let mut shards = Vec::with_capacity(cfg.nodes);
    for node in 0..cfg.nodes {
        let mut node_rng = Pcg::new(cfg.seed ^ (node as u64).wrapping_mul(0x9E3779B9), 0x107);
        let x_star = if cfg.heterogeneous {
            normalized_gaussian(&mut node_rng, cfg.dim)
        } else {
            shared_star.clone()
        };
        let mut features = Vec::with_capacity(cfg.samples_per_node * cfg.dim);
        let mut labels = Vec::with_capacity(cfg.samples_per_node);
        let feat_std = 10.0_f64.sqrt(); // h ~ N(0, 10 I_d)
        for _ in 0..cfg.samples_per_node {
            let mut dot = 0.0;
            for j in 0..cfg.dim {
                let h = node_rng.normal() * feat_std;
                dot += h * x_star[j];
                features.push(h);
            }
            let p = 1.0 / (1.0 + (-dot).exp());
            let y = if node_rng.uniform() <= p { 1.0 } else { -1.0 };
            labels.push(y);
        }
        shards.push(LogRegShard {
            features,
            labels,
            x_star,
            m: cfg.samples_per_node,
            d: cfg.dim,
        });
    }
    let mut x_star_mean = vec![0.0; cfg.dim];
    for s in &shards {
        for j in 0..cfg.dim {
            x_star_mean[j] += s.x_star[j] / cfg.nodes as f64;
        }
    }
    LogRegProblem { shards, d: cfg.dim, x_star_mean }
}

impl LogRegShard {
    /// Feature row `m`.
    #[inline]
    pub fn feature(&self, m: usize) -> &[f64] {
        &self.features[m * self.d..(m + 1) * self.d]
    }

    /// Full-batch loss `1/M Σ ln(1 + exp(−y·hᵀx))`.
    pub fn loss(&self, x: &[f64]) -> f64 {
        let mut total = 0.0;
        for m in 0..self.m {
            let z: f64 = self.feature(m).iter().zip(x).map(|(h, w)| h * w).sum();
            total += softplus(-self.labels[m] * z);
        }
        total / self.m as f64
    }

    /// Stochastic gradient on minibatch indices `batch` (accumulated into
    /// `grad`, which is zeroed first).
    pub fn minibatch_grad(&self, x: &[f64], batch: &[usize], grad: &mut [f64]) {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let scale = 1.0 / batch.len() as f64;
        for &m in batch {
            let h = self.feature(m);
            let y = self.labels[m];
            let z: f64 = h.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
            // ∂/∂x ln(1+exp(−y z)) = −y·σ(−y z)·h
            let coeff = -y * sigmoid(-y * z) * scale;
            for (g, hv) in grad.iter_mut().zip(h.iter()) {
                *g += coeff * hv;
            }
        }
    }

    /// Full-batch gradient.
    pub fn full_grad(&self, x: &[f64], grad: &mut [f64]) {
        let all: Vec<usize> = (0..self.m).collect();
        self.minibatch_grad(x, &all, grad);
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[inline]
fn softplus(z: f64) -> f64 {
    // ln(1 + e^z), numerically stable.
    if z > 30.0 {
        z
    } else if z < -30.0 {
        z.exp()
    } else {
        (1.0 + z.exp()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LogRegProblem {
        generate(&LogRegConfig {
            nodes: 4,
            samples_per_node: 200,
            dim: 6,
            heterogeneous: true,
            seed: 3,
        })
    }

    #[test]
    fn shapes_and_labels() {
        let p = small();
        assert_eq!(p.shards.len(), 4);
        for s in &p.shards {
            assert_eq!(s.features.len(), 200 * 6);
            assert!(s.labels.iter().all(|&y| y == 1.0 || y == -1.0));
            let norm: f64 = s.x_star.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9, "x* normalized");
        }
    }

    #[test]
    fn heterogeneous_stars_differ_homogeneous_agree() {
        let het = small();
        assert_ne!(het.shards[0].x_star, het.shards[1].x_star);
        let hom = generate(&LogRegConfig { heterogeneous: false, nodes: 3, ..Default::default() });
        assert_eq!(hom.shards[0].x_star, hom.shards[2].x_star);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small();
        let s = &p.shards[0];
        let x: Vec<f64> = (0..6).map(|i| 0.1 * (i as f64) - 0.2).collect();
        let mut grad = vec![0.0; 6];
        s.full_grad(&x, &mut grad);
        let eps = 1e-6;
        for j in 0..6 {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (s.loss(&xp) - s.loss(&xm)) / (2.0 * eps);
            assert!((fd - grad[j]).abs() < 1e-6, "j={j}: fd={fd} grad={}", grad[j]);
        }
    }

    #[test]
    fn gradient_descent_reduces_loss() {
        let p = small();
        let s = &p.shards[0];
        let mut x = vec![0.0; 6];
        let mut grad = vec![0.0; 6];
        let l0 = s.loss(&x);
        for _ in 0..50 {
            s.full_grad(&x, &mut grad);
            for (xi, gi) in x.iter_mut().zip(grad.iter()) {
                *xi -= 0.05 * gi;
            }
        }
        let l1 = s.loss(&x);
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
        // And the learned direction correlates with x*.
        let dot: f64 = x.iter().zip(&s.x_star).map(|(a, b)| a * b).sum();
        assert!(dot > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = small();
        let b = small();
        assert_eq!(a.shards[2].features, b.shards[2].features);
        assert_eq!(a.shards[2].labels, b.shards[2].labels);
    }
}
