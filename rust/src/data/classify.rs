//! Gaussian-mixture classification workload for the Table 2/3/4 accuracy
//! comparisons.
//!
//! `C` classes with unit-norm random means `μ_c · sep` in `R^d`; a sample of
//! class `c` is `μ_c·sep + N(0, I_d)`. A held-out validation set plays the
//! role of ImageNet's validation accuracy. Difficulty (and therefore the
//! spread between topologies) is controlled by `sep`.

use crate::util::rng::Pcg;

/// A labeled dense dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major `len × dim` features (f32: this feeds the f32 training
    /// stack).
    pub features: Vec<f32>,
    /// Class labels in `0..classes`.
    pub labels: Vec<u32>,
    pub len: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    #[inline]
    pub fn feature(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }
}

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClassifyConfig {
    pub dim: usize,
    pub classes: usize,
    pub train_per_class: usize,
    pub val_per_class: usize,
    /// Class-mean separation (higher ⇒ easier). 2.0 gives ~90% linear
    /// accuracy at d=32, C=10 — enough head-room to see topology effects.
    pub separation: f64,
    pub seed: u64,
}

impl Default for ClassifyConfig {
    fn default() -> Self {
        ClassifyConfig {
            dim: 32,
            classes: 10,
            train_per_class: 500,
            val_per_class: 100,
            separation: 2.0,
            seed: 7,
        }
    }
}

/// Generated train/validation pair.
#[derive(Clone, Debug)]
pub struct ClassifyData {
    pub train: Dataset,
    pub val: Dataset,
    /// Class means (row-major `classes × dim`), for diagnostics.
    pub means: Vec<f64>,
}

/// Generate the workload.
pub fn generate(cfg: &ClassifyConfig) -> ClassifyData {
    let mut rng = Pcg::new(cfg.seed, 0xC1A55);
    // Unit-norm class means scaled by separation.
    let mut means = vec![0.0f64; cfg.classes * cfg.dim];
    for c in 0..cfg.classes {
        let mut norm = 0.0;
        for j in 0..cfg.dim {
            let v = rng.normal();
            means[c * cfg.dim + j] = v;
            norm += v * v;
        }
        let norm = norm.sqrt().max(1e-12);
        for j in 0..cfg.dim {
            means[c * cfg.dim + j] *= cfg.separation / norm;
        }
    }
    let make = |per_class: usize, stream: u64| -> Dataset {
        let mut rng = Pcg::new(cfg.seed ^ stream, 0xC1A56);
        let len = per_class * cfg.classes;
        let mut features = Vec::with_capacity(len * cfg.dim);
        let mut labels = Vec::with_capacity(len);
        // Interleave classes so contiguous slices are balanced.
        for i in 0..per_class {
            let _ = i;
            for c in 0..cfg.classes {
                for j in 0..cfg.dim {
                    features.push((means[c * cfg.dim + j] + rng.normal()) as f32);
                }
                labels.push(c as u32);
            }
        }
        Dataset { features, labels, len, dim: cfg.dim, classes: cfg.classes }
    };
    ClassifyData { train: make(cfg.train_per_class, 0x7EA1), val: make(cfg.val_per_class, 0x7EA2), means }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let d = generate(&ClassifyConfig { train_per_class: 20, val_per_class: 5, ..Default::default() });
        assert_eq!(d.train.len, 200);
        assert_eq!(d.val.len, 50);
        assert_eq!(d.train.features.len(), 200 * 32);
        assert!(d.train.labels.iter().all(|&c| c < 10));
    }

    #[test]
    fn classes_are_separable_by_nearest_mean() {
        let cfg = ClassifyConfig { train_per_class: 50, val_per_class: 50, ..Default::default() };
        let d = generate(&cfg);
        let mut correct = 0;
        for i in 0..d.val.len {
            let f = d.val.feature(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..cfg.classes {
                let dist: f64 = (0..cfg.dim)
                    .map(|j| {
                        let diff = f[j] as f64 - d.means[c * cfg.dim + j];
                        diff * diff
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as u32 == d.val.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.val.len as f64;
        assert!(acc > 0.6, "nearest-mean accuracy too low: {acc}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&ClassifyConfig::default());
        let b = generate(&ClassifyConfig::default());
        assert_eq!(a.train.features, b.train.features);
    }
}
