//! Sharding a dataset across nodes.
//!
//! * **Homogeneous** (the paper's data-center assumption, Table 1): a
//!   global shuffle, then contiguous equal slices — every node sees the
//!   same distribution, so the heterogeneity bound `b² ≈ 0`.
//! * **Heterogeneous** (Appendix C / Table 8): Dirichlet-style label skew —
//!   each node draws class proportions so `∇f_i` differ across nodes
//!   (`b² > 0`).

use super::classify::Dataset;
use crate::util::rng::Pcg;

/// How to split the data across nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    /// IID shuffle → equal slices.
    Homogeneous,
    /// Label-skewed with Dirichlet concentration `alpha` (lower = more
    /// skewed; 0.1 is highly heterogeneous, 100 ≈ iid).
    Heterogeneous { alpha: f64 },
}

/// Per-node index lists into the parent dataset.
#[derive(Clone, Debug)]
pub struct Shards {
    pub indices: Vec<Vec<usize>>,
}

impl Shards {
    pub fn node(&self, i: usize) -> &[usize] {
        &self.indices[i]
    }

    pub fn num_nodes(&self) -> usize {
        self.indices.len()
    }
}

/// Sample from Dirichlet(alpha, …, alpha) via normalized Gamma draws
/// (Marsaglia–Tsang for shape ≥ 1, boost trick below 1).
fn dirichlet(rng: &mut Pcg, k: usize, alpha: f64) -> Vec<f64> {
    fn gamma(rng: &mut Pcg, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) · U^{1/a}
            let u = rng.uniform().max(1e-300);
            return gamma(rng, shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = rng.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = rng.uniform().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }
    let draws: Vec<f64> = (0..k).map(|_| gamma(rng, alpha).max(1e-12)).collect();
    let sum: f64 = draws.iter().sum();
    draws.into_iter().map(|g| g / sum).collect()
}

/// Split `data` into `nodes` shards.
pub fn shard(data: &Dataset, nodes: usize, mode: Sharding, seed: u64) -> Shards {
    let mut rng = Pcg::new(seed, 0x5AAD);
    match mode {
        Sharding::Homogeneous => {
            let mut idx: Vec<usize> = (0..data.len).collect();
            rng.shuffle(&mut idx);
            let per = data.len / nodes;
            let indices = (0..nodes)
                .map(|i| idx[i * per..(i + 1) * per].to_vec())
                .collect();
            Shards { indices }
        }
        Sharding::Heterogeneous { alpha } => {
            // Group sample indices by class.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.classes];
            for (i, &c) in data.labels.iter().enumerate() {
                by_class[c as usize].push(i);
            }
            for cls in by_class.iter_mut() {
                rng.shuffle(cls);
            }
            let mut indices: Vec<Vec<usize>> = vec![Vec::new(); nodes];
            for cls in &by_class {
                // Node proportions for this class.
                let props = dirichlet(&mut rng, nodes, alpha);
                let mut cursor = 0usize;
                for (node, p) in props.iter().enumerate() {
                    let take = if node + 1 == nodes {
                        cls.len() - cursor
                    } else {
                        ((p * cls.len() as f64).round() as usize).min(cls.len() - cursor)
                    };
                    indices[node].extend_from_slice(&cls[cursor..cursor + take]);
                    cursor += take;
                }
            }
            // Guarantee every node has at least one sample.
            for node in 0..nodes {
                if indices[node].is_empty() {
                    indices[node].push(rng.below(data.len));
                }
                let node_indices = &mut indices[node];
                rng.shuffle(node_indices);
            }
            Shards { indices }
        }
    }
}

/// Label-distribution skew measure: mean total-variation distance between a
/// node's label distribution and the global one. 0 = perfectly iid.
pub fn label_skew(data: &Dataset, shards: &Shards) -> f64 {
    let c = data.classes;
    let mut global = vec![0.0f64; c];
    for &l in &data.labels {
        global[l as usize] += 1.0;
    }
    let total: f64 = global.iter().sum();
    for g in global.iter_mut() {
        *g /= total;
    }
    let mut tv_sum = 0.0;
    for node in &shards.indices {
        let mut local = vec![0.0f64; c];
        for &i in node {
            local[data.labels[i] as usize] += 1.0;
        }
        let lt: f64 = local.iter().sum::<f64>().max(1.0);
        let tv: f64 = local
            .iter()
            .zip(global.iter())
            .map(|(l, g)| (l / lt - g).abs())
            .sum::<f64>()
            / 2.0;
        tv_sum += tv;
    }
    tv_sum / shards.num_nodes() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classify::{generate, ClassifyConfig};

    fn data() -> Dataset {
        generate(&ClassifyConfig { train_per_class: 100, val_per_class: 10, ..Default::default() })
            .train
    }

    #[test]
    fn homogeneous_shards_are_equal_and_disjoint() {
        let d = data();
        let s = shard(&d, 8, Sharding::Homogeneous, 1);
        assert_eq!(s.num_nodes(), 8);
        let mut seen = vec![false; d.len];
        for node in &s.indices {
            assert_eq!(node.len(), d.len / 8);
            for &i in node {
                assert!(!seen[i], "index {i} duplicated");
                seen[i] = true;
            }
        }
    }

    #[test]
    fn heterogeneous_is_more_skewed_than_homogeneous() {
        let d = data();
        let hom = shard(&d, 8, Sharding::Homogeneous, 2);
        let het = shard(&d, 8, Sharding::Heterogeneous { alpha: 0.1 }, 2);
        let s_hom = label_skew(&d, &hom);
        let s_het = label_skew(&d, &het);
        assert!(s_het > s_hom + 0.1, "hom={s_hom} het={s_het}");
        // No node starves.
        for node in &het.indices {
            assert!(!node.is_empty());
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Pcg::seeded(4);
        for &alpha in &[0.1, 1.0, 10.0] {
            let p = dirichlet(&mut rng, 6, alpha);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn large_alpha_approaches_uniform() {
        let mut rng = Pcg::seeded(5);
        let p = dirichlet(&mut rng, 4, 1000.0);
        for &x in &p {
            assert!((x - 0.25).abs() < 0.05, "{p:?}");
        }
    }
}
