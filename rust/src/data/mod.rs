//! Synthetic workloads.
//!
//! The paper's experiments run on ImageNet/VOC/COCO on a 256-GPU cluster;
//! per docs/DESIGN.md §Substitutions we reproduce the *relative* behaviour with
//! synthetic workloads whose statistical structure matches what the theory
//! depends on:
//!
//! * [`logreg`] — the distributed logistic regression of Appendix D.5
//!   (the workload behind Fig. 1 and Fig. 13), with per-node ground-truth
//!   `x*_i` for the heterogeneous case.
//! * [`classify`] — Gaussian-mixture classification for the Table 2/3/4
//!   accuracy comparisons, with label-skew to control heterogeneity.
//! * [`corpus`] — a tiny public-domain text corpus + byte tokenizer for
//!   the end-to-end transformer example.
//! * [`shard`] — homogeneous (iid) vs heterogeneous (label-skewed)
//!   sharding across nodes.

pub mod classify;
pub mod corpus;
pub mod logreg;
pub mod shard;
