//! Tiny text corpus + byte-level tokenizer for the end-to-end transformer
//! example (`examples/transformer_e2e.rs`).
//!
//! The embedded corpus is public-domain text (Lewis Carroll, *Alice's
//! Adventures in Wonderland*, 1865 — opening chapters). It is small (~8 KB)
//! but real: a character-level LM trained on it shows a clean, paper-style
//! loss curve within a few hundred steps.

use crate::util::rng::Pcg;

/// Public-domain training text.
pub const ALICE: &str = r#"Alice was beginning to get very tired of sitting by her sister on the
bank, and of having nothing to do: once or twice she had peeped into
the book her sister was reading, but it had no pictures or
conversations in it, "and what is the use of a book," thought Alice
"without pictures or conversations?"

So she was considering in her own mind (as well as she could, for the
hot day made her feel very sleepy and stupid), whether the pleasure of
making a daisy-chain would be worth the trouble of getting up and
picking the daisies, when suddenly a White Rabbit with pink eyes ran
close by her.

There was nothing so very remarkable in that; nor did Alice think it
so very much out of the way to hear the Rabbit say to itself, "Oh
dear! Oh dear! I shall be late!" (when she thought it over afterwards,
it occurred to her that she ought to have wondered at this, but at the
time it all seemed quite natural); but when the Rabbit actually took a
watch out of its waistcoat-pocket, and looked at it, and then hurried
on, Alice started to her feet, for it flashed across her mind that she
had never before seen a rabbit with either a waistcoat-pocket, or a
watch to take out of it, and burning with curiosity, she ran across
the field after it, and fortunately was just in time to see it pop
down a large rabbit-hole under the hedge.

In another moment down went Alice after it, never once considering how
in the world she was to get out again.

The rabbit-hole went straight on like a tunnel for some way, and then
dipped suddenly down, so suddenly that Alice had not a moment to think
about stopping herself before she found herself falling down a very
deep well.

Either the well was very deep, or she fell very slowly, for she had
plenty of time as she went down to look about her and to wonder what
was going to happen next. First, she tried to look down and make out
what she was coming to, but it was too dark to see anything; then she
looked at the sides of the well, and noticed that they were filled
with cupboards and book-shelves; here and there she saw maps and
pictures hung upon pegs. She took down a jar from one of the shelves
as she passed; it was labelled "ORANGE MARMALADE", but to her great
disappointment it was empty: she did not like to drop the jar for fear
of killing somebody underneath, so managed to put it into one of the
cupboards as she fell past it.

"Well!" thought Alice to herself, "after such a fall as this, I shall
think nothing of tumbling down stairs! How brave they'll all think me
at home! Why, I wouldn't say anything about it, even if I fell off the
top of the house!" (Which was very likely true.)

Down, down, down. Would the fall never come to an end? "I wonder how
many miles I've fallen by this time?" she said aloud. "I must be
getting somewhere near the centre of the earth. Let me see: that would
be four thousand miles down, I think--" (for, you see, Alice had learnt
several things of this sort in her lessons in the schoolroom, and
though this was not a very good opportunity for showing off her
knowledge, as there was no one to listen to her, still it was good
practice to say it over) "--yes, that's about the right distance--but
then I wonder what Latitude or Longitude I've got to?" (Alice had no
idea what Latitude was, or Longitude either, but thought they were
nice grand words to say.)

Presently she began again. "I wonder if I shall fall right through the
earth! How funny it'll seem to come out among the people that walk
with their heads downward! The Antipathies, I think--" (she was rather
glad there was no one listening, this time, as it didn't sound at all
the right word) "--but I shall have to ask them what the name of the
country is, you know. Please, Ma'am, is this New Zealand or Australia?"
(and she tried to curtsey as she spoke--fancy curtseying as you're
falling through the air! Do you think you could manage it?) "And what
an ignorant little girl she'll think me for asking! No, it'll never do
to ask: perhaps I shall see it written up somewhere."

Down, down, down. There was nothing else to do, so Alice soon began
talking again. "Dinah'll miss me very much to-night, I should think!"
(Dinah was the cat.) "I hope they'll remember her saucer of milk at
tea-time. Dinah my dear! I wish you were down here with me! There are
no mice in the air, I'm afraid, but you might catch a bat, and that's
very like a mouse, you know. But do cats eat bats, I wonder?" And here
Alice began to get rather sleepy, and went on saying to herself, in a
dreamy sort of way, "Do cats eat bats? Do cats eat bats?" and
sometimes, "Do bats eat cats?" for, you see, as she couldn't answer
either question, it didn't much matter which way she put it. She felt
that she was dozing off, and had just begun to dream that she was
walking hand in hand with Dinah, and saying to her very earnestly,
"Now, Dinah, tell me the truth: did you ever eat a bat?" when suddenly,
thump! thump! down she came upon a heap of sticks and dry leaves, and
the fall was over.

Alice was not a bit hurt, and she jumped up on to her feet in a
moment: she looked up, but it was all dark overhead; before her was
another long passage, and the White Rabbit was still in sight,
hurrying down it. There was not a moment to be lost: away went Alice
like the wind, and was just in time to hear it say, as it turned a
corner, "Oh my ears and whiskers, how late it's getting!" She was
close behind it when she turned the corner, but the Rabbit was no
longer to be seen: she found herself in a long, low hall, which was
lit up by a row of lamps hanging from the roof.
"#;

/// Byte-level vocabulary size (full byte range keeps the tokenizer total).
pub const VOCAB_SIZE: usize = 256;

/// Tokenized corpus with batch sampling.
#[derive(Clone)]
pub struct Corpus {
    pub tokens: Vec<u8>,
}

impl Corpus {
    /// The embedded Alice corpus.
    pub fn alice() -> Corpus {
        Corpus { tokens: ALICE.as_bytes().to_vec() }
    }

    /// From arbitrary text.
    pub fn from_text(text: &str) -> Corpus {
        Corpus { tokens: text.as_bytes().to_vec() }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Split token stream into `nodes` contiguous shards (data-parallel
    /// "documents" per node).
    pub fn shard(&self, nodes: usize) -> Vec<Corpus> {
        let per = self.tokens.len() / nodes;
        (0..nodes)
            .map(|i| Corpus { tokens: self.tokens[i * per..(i + 1) * per].to_vec() })
            .collect()
    }

    /// Sample a `(batch, seq+1)` window batch of token ids as i32 (inputs
    /// are `[.., :seq]`, targets `[.., 1:]` — the model consumes the full
    /// window and does the shift internally).
    pub fn sample_batch(&self, rng: &mut Pcg, batch: usize, seq: usize) -> Vec<i32> {
        assert!(self.tokens.len() > seq + 1, "corpus shorter than sequence length");
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - seq - 1);
            for t in 0..=seq {
                out.push(self.tokens[start + t] as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_nontrivial() {
        let c = Corpus::alice();
        assert!(c.len() > 4000, "corpus too small: {}", c.len());
    }

    #[test]
    fn shards_partition_evenly() {
        let c = Corpus::alice();
        let shards = c.shard(8);
        assert_eq!(shards.len(), 8);
        let per = c.len() / 8;
        for s in &shards {
            assert_eq!(s.len(), per);
        }
    }

    #[test]
    fn batches_have_window_shape_and_range() {
        let c = Corpus::alice();
        let mut rng = Pcg::seeded(1);
        let b = c.sample_batch(&mut rng, 4, 16);
        assert_eq!(b.len(), 4 * 17);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn batch_windows_are_contiguous_text() {
        let c = Corpus::alice();
        let mut rng = Pcg::seeded(2);
        let b = c.sample_batch(&mut rng, 1, 8);
        // The window must appear in the corpus.
        let window: Vec<u8> = b.iter().map(|&t| t as u8).collect();
        let found = c.tokens.windows(9).any(|w| w == window.as_slice());
        assert!(found);
    }
}
