//! Per-iteration communication-time model (α-β model).
//!
//! The paper (citing Ben-Nun & Hoefler [5] and Patarasuk & Yuan [47])
//! charges global averaging `Ω(n)` time — either `Ω(n)` bandwidth through a
//! parameter server or `Ω(n)` latency through ring-allreduce — and partial
//! averaging `Ω(max degree)` time. We make this concrete with the classic
//! α-β model:
//!
//! * point-to-point message of `S` bytes: `α + S·β`
//! * a node exchanging with `d` neighbors sequentially: `d·(α + S·β)`
//! * ring-allreduce over n nodes: `2(n−1)·(α + (S/n)·β)`
//!
//! with `α` the per-message latency and `β` seconds/byte (1/bandwidth).
//! Defaults approximate the paper's testbed: 25 Gbps TCP inter-node links,
//! ~0.1 ms latency. The *shape* of the resulting per-iteration times — not
//! their absolute values — is what Tables 2–3 validate.
//!
//! These closed forms are the **fast path** for a uniform, failure-free
//! network. The discrete-event [`crate::netsim`] generalizes them to
//! heterogeneous links, stragglers, and faults, and collapses onto them
//! exactly in the clean case (pinned by `tests/netsim.rs`).

use crate::topology::plan::MixingPlan;
use crate::topology::{Topology, TopologyKind};

/// Communication cost parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Seconds per byte (1/bandwidth).
    pub beta: f64,
    /// Per-iteration local computation time (seconds) — forward+backward.
    pub compute: f64,
    /// Fraction of communication hidden behind computation (DDP-style
    /// overlap; the paper's implementation overlaps comm and backprop).
    pub overlap: f64,
}

impl CostModel {
    /// Defaults mirroring the paper's testbed: 25 Gbps links, 0.1 ms
    /// latency, and a compute time normalized per model elsewhere.
    pub fn paper_default(compute: f64) -> CostModel {
        CostModel {
            alpha: 1e-4,
            beta: 8.0 / 25e9, // seconds per byte over 25 Gbps
            compute,
            overlap: 0.7,
        }
    }

    /// One point-to-point message of `msg_bytes`: `α + S·β`. The unit
    /// every other formula (and the [`crate::netsim`] exchange slots)
    /// is built from — one expression so the two paths cannot drift.
    #[inline]
    pub fn link_time(&self, msg_bytes: f64) -> f64 {
        self.alpha + msg_bytes * self.beta
    }

    /// Time for one partial-averaging round given the realized mixing
    /// plan. The degree (max distinct partners of any node) is plan
    /// metadata, so this is `O(1)` — no `O(n²)` matrix scan.
    pub fn partial_averaging_time(&self, plan: &MixingPlan, msg_bytes: f64) -> f64 {
        plan.max_degree as f64 * self.link_time(msg_bytes)
    }

    /// Time for a ring-allreduce of `msg_bytes` across `n` nodes.
    pub fn allreduce_time(&self, n: usize, msg_bytes: f64) -> f64 {
        let n = n.max(1) as f64;
        2.0 * (n - 1.0) * self.link_time(msg_bytes / n)
    }

    /// Per-iteration communication time of a topology at size `n`,
    /// without drawing an actual matrix (uses the analytic degree).
    pub fn comm_time(&self, kind: TopologyKind, n: usize, msg_bytes: f64) -> f64 {
        self.comm_time_topo(kind.family(), n, msg_bytes)
    }

    /// [`CostModel::comm_time`] for any registered family: the family
    /// declares its own cost-model dispatch (collective all-reduce for
    /// the parallel baseline, per-neighbor α-β exchanges otherwise) —
    /// no per-kind `match` here (docs/DESIGN.md §Topology registry).
    pub fn comm_time_topo(&self, topo: Topology, n: usize, msg_bytes: f64) -> f64 {
        if topo.uses_allreduce() {
            self.allreduce_time(n, msg_bytes)
        } else {
            topo.analytic_degree(n) as f64 * self.link_time(msg_bytes)
        }
    }

    /// End-to-end iteration time: compute + non-overlapped communication.
    pub fn iteration_time(&self, kind: TopologyKind, n: usize, msg_bytes: f64) -> f64 {
        let comm = self.comm_time(kind, n, msg_bytes);
        let hidden = (self.compute.min(comm)) * self.overlap;
        self.compute + comm - hidden
    }
}

/// Analytic per-iteration communication degree per topology (the
/// "Per-iter Comm." column of Tables 1/7/8). Declared per family in the
/// registry; this wrapper keeps the historical kind-based signature.
pub fn analytic_degree(kind: TopologyKind, n: usize) -> usize {
    kind.family().analytic_degree(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_column_matches_table1() {
        let n = 32;
        assert_eq!(analytic_degree(TopologyKind::Ring, n), 2);
        assert_eq!(analytic_degree(TopologyKind::Grid2D, n), 4);
        assert_eq!(analytic_degree(TopologyKind::HalfRandom, n), 15); // n/2-ish
        assert_eq!(analytic_degree(TopologyKind::RandomMatch, n), 1);
        assert_eq!(analytic_degree(TopologyKind::StaticExp, n), 5); // log2(32)
        assert_eq!(analytic_degree(TopologyKind::OnePeerExp, n), 1);
    }

    #[test]
    fn time_ordering_matches_table2_observation2() {
        // 32-node ordering: one-peer ≈ match < ring < grid < static exp <
        // half-random; allreduce worst in latency for large n.
        let m = CostModel::paper_default(0.1);
        let n = 32;
        let bytes = 100e6; // ~25M params f32
        let t = |k| m.comm_time(k, n, bytes);
        assert!(t(TopologyKind::OnePeerExp) <= t(TopologyKind::Ring));
        assert!((t(TopologyKind::OnePeerExp) - t(TopologyKind::RandomMatch)).abs() < 1e-12);
        assert!(t(TopologyKind::Ring) < t(TopologyKind::Grid2D));
        assert!(t(TopologyKind::Grid2D) < t(TopologyKind::StaticExp));
        assert!(t(TopologyKind::StaticExp) < t(TopologyKind::HalfRandom));
    }

    #[test]
    fn allreduce_scales_with_latency_term() {
        let m = CostModel::paper_default(0.0);
        // Small messages: latency dominates, grows ~2(n−1)·α.
        let t8 = m.allreduce_time(8, 1.0);
        let t64 = m.allreduce_time(64, 1.0);
        assert!(t64 / t8 > 8.0, "latency term should scale ~n");
        // Large messages: bandwidth term ~2S·β regardless of n.
        let big = 1e9;
        let b8 = m.allreduce_time(8, big);
        let b64 = m.allreduce_time(64, big);
        assert!((b64 - b8).abs() / b8 < 0.25);
    }

    #[test]
    fn overlap_hides_communication() {
        let mut m = CostModel::paper_default(1.0);
        m.overlap = 1.0;
        let t = m.iteration_time(TopologyKind::Ring, 16, 1e6);
        // Fully-overlapped small comm: iteration ≈ compute.
        assert!((t - 1.0).abs() < 0.05, "t={t}");
        m.overlap = 0.0;
        let t0 = m.iteration_time(TopologyKind::Ring, 16, 1e6);
        assert!(t0 > t);
    }

    #[test]
    fn comm_time_routes_through_the_family_registry() {
        let m = CostModel::paper_default(0.0);
        let n = 48;
        let msg = 1e6;
        let ceca = crate::topology::family::find("ceca").unwrap();
        assert!((m.comm_time_topo(ceca, n, msg) - 2.0 * m.link_time(msg)).abs() < 1e-15);
        let base4 = crate::topology::family::find("base4").unwrap();
        assert!(m.comm_time_topo(base4, n, msg) > 0.0);
        // The parallel baseline is still priced as a collective.
        let full = TopologyKind::FullyConnected.family();
        assert_eq!(m.comm_time_topo(full, n, msg), m.allreduce_time(n, msg));
        assert_eq!(m.comm_time(TopologyKind::FullyConnected, n, msg), m.allreduce_time(n, msg));
    }

    #[test]
    fn degenerate_sizes_are_well_defined() {
        let m = CostModel::paper_default(0.3);
        // n = 1: a one-node "collective" has 2(n−1) = 0 phases — zero
        // time, not a negative or NaN one.
        assert_eq!(m.allreduce_time(1, 1e8), 0.0);
        assert_eq!(m.allreduce_time(0, 1e8), 0.0); // clamps to n = 1
        // msg_bytes = 0: pure-latency rounds — the α term survives.
        assert_eq!(m.link_time(0.0), m.alpha);
        let n = 16;
        assert_eq!(m.allreduce_time(n, 0.0), 2.0 * (n as f64 - 1.0) * m.alpha);
        let plan = crate::topology::exponential::static_exp_plan(n);
        assert_eq!(
            m.partial_averaging_time(&plan, 0.0),
            plan.max_degree as f64 * m.alpha
        );
    }

    #[test]
    fn partial_averaging_uses_realized_degree() {
        let m = CostModel::paper_default(0.0);
        let plan = crate::topology::exponential::static_exp_plan(16);
        let t = m.partial_averaging_time(&plan, 1e6);
        assert!(t > 0.0);
        // Plan metadata must agree with the dense scan it replaced.
        let w = crate::topology::exponential::static_exp_weights(16);
        assert_eq!(plan.max_degree, crate::topology::weight::max_comm_degree(&w));
    }
}
