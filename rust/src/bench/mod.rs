//! Micro-benchmark harness (criterion is unavailable offline, so the
//! `rust/benches/*.rs` targets use this in-crate harness: warmup, repeated
//! timed runs, and robust statistics).

use std::time::Instant;

/// Statistics from one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Median seconds per iteration.
    pub median: f64,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Min / max seconds per iteration.
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    /// Render "name  median  (min … max)" with adaptive units.
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>12}  ({} … {})  [{} samples]",
            self.name,
            fmt_secs(self.median),
            fmt_secs(self.min),
            fmt_secs(self.max),
            self.iters
        )
    }

    /// Throughput line given an items/bytes count processed per iteration.
    pub fn report_throughput(&self, items: f64, unit: &str) -> String {
        format!("{}  |  {:.3} {}/s", self.report(), items / self.median, unit)
    }
}

/// Format seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly: `warmup` untimed runs, then timed runs until both
/// `min_iters` and `min_seconds` of accumulated time are reached (capped at
/// `max_iters`).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_config(name, 3, 10, 512, 1.0, &mut f)
}

/// Configurable variant for expensive benchmarks.
pub fn bench_config<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    min_seconds: f64,
    f: &mut F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || (start.elapsed().as_secs_f64() < min_seconds && samples.len() < max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= max_iters {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let median = if n % 2 == 1 {
        samples[n / 2]
    } else {
        0.5 * (samples[n / 2 - 1] + samples[n / 2])
    };
    BenchStats {
        name: name.to_string(),
        iters: n,
        median,
        mean: samples.iter().sum::<f64>() / n as f64,
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Was the bench binary invoked with `--quiet`? (CI mode: reduced
/// sample counts / skipped exploratory sections, same recorded sizes.)
pub fn quiet() -> bool {
    std::env::args().any(|a| a == "--quiet")
}

/// Resolve a bench artifact name against the **workspace root** (the
/// parent of this crate's manifest dir), so `BENCH_*.json` lands at the
/// repo root regardless of the CWD the bench was launched from.
pub fn output_path(name: &str) -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(name)
}

/// Write a bench JSON artifact to [`output_path`]; exits nonzero on
/// failure so CI cannot silently lose a recording.
pub fn write_json(name: &str, json: &str) {
    let path = output_path(name);
    match std::fs::write(&path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("could not write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut acc = 0u64;
        let stats = bench_config("noop", 1, 5, 16, 0.01, &mut || {
            acc = acc.wrapping_add(1);
            black_box(acc);
        });
        assert!(stats.iters >= 5);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
        assert!(stats.median >= 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" us"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
