//! One-peer hypercube (Remark 6 / the paper's future-work direction).
//!
//! At iteration `k`, node `i` pairs with `i XOR 2^{mod(k,τ)}` and averages
//! ½–½. Unlike the one-peer *exponential* graph this realization is a
//! perfect matching, so `W^{(k)}` is **symmetric** — the property D² and
//! DecentLaM need — while keeping Ω(1) per-iteration communication AND
//! periodic exact averaging in τ = log₂(n) steps (Shi et al. [54]):
//! after all τ bit-dimensions have been averaged once, every node holds
//! the global mean (the classic hypercube all-reduce).
//!
//! Requires `n = 2^τ`.

use super::exponential::tau;
use super::plan::{MixingPlan, PlanBuilder};
use super::TopologyKind;
use crate::linalg::Matrix;

/// Weight matrix of the one-peer hypercube realization with bit `t`.
pub fn one_peer_hypercube_weights(n: usize, t: usize) -> Matrix {
    assert!(n.is_power_of_two(), "one-peer hypercube requires n = 2^tau");
    let period = tau(n).max(1);
    let bit = 1usize << (t % period);
    let mut w = Matrix::zeros(n, n);
    if n == 1 {
        w[(0, 0)] = 1.0;
        return w;
    }
    for i in 0..n {
        let j = i ^ bit;
        w[(i, i)] = 0.5;
        w[(i, j)] = 0.5;
    }
    w
}

/// Direct sparse constructor for the one-peer hypercube realization with
/// bit `t`: a symmetric ½–½ perfect matching along one bit-dimension —
/// exactly two nonzeros per row, no dense matrix.
pub fn one_peer_hypercube_plan(n: usize, t: usize) -> MixingPlan {
    assert!(n.is_power_of_two(), "one-peer hypercube requires n = 2^tau");
    if n == 1 {
        return MixingPlan::from_rows(vec![vec![(0, 1.0)]], Some(TopologyKind::OnePeerHypercube));
    }
    let period = tau(n).max(1);
    let bit = 1usize << (t % period);
    let mut b = PlanBuilder::new(n, 2 * n);
    for i in 0..n {
        b.push(i, 0.5);
        b.push(i ^ bit, 0.5);
        b.finish_row();
    }
    b.finish(Some(TopologyKind::OnePeerHypercube))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::weight::{is_doubly_stochastic, max_comm_degree};

    #[test]
    fn realizations_are_symmetric_doubly_stochastic_matchings() {
        for n in [2usize, 4, 8, 16, 32] {
            for t in 0..tau(n) {
                let w = one_peer_hypercube_weights(n, t);
                assert!(is_doubly_stochastic(&w, 1e-12), "n={n} t={t}");
                assert!(w.is_symmetric(0.0), "n={n} t={t}");
                assert_eq!(max_comm_degree(&w), 1, "n={n} t={t}: perfect matching");
            }
        }
    }

    #[test]
    fn plan_matches_dense_builder() {
        for n in [1usize, 2, 4, 8, 16, 32] {
            for t in 0..tau(n).max(1) {
                let want = MixingPlan::from_dense(&one_peer_hypercube_weights(n, t));
                let got = one_peer_hypercube_plan(n, t);
                assert_eq!(got.rows_vec(), want.rows_vec(), "n={n} t={t}");
                assert_eq!(got.max_degree, want.max_degree, "n={n} t={t}");
                assert!(got.symmetric, "matchings are symmetric (n={n} t={t})");
            }
        }
    }

    #[test]
    fn exact_averaging_after_tau_steps() {
        // The hypercube all-reduce property: ∏ W^{(t)} = J.
        for n in [4usize, 8, 16, 64] {
            let mut prod = Matrix::eye(n);
            for t in 0..tau(n) {
                prod = one_peer_hypercube_weights(n, t).matmul(&prod);
            }
            assert!(prod.sub(&Matrix::averaging(n)).max_abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn eigenvalues_admit_d2() {
        // D² needs λ_min(W) > −1/3; a ½–½ matching has eigenvalues {0, 1},
        // comfortably inside.
        let w = one_peer_hypercube_weights(8, 1);
        let eig = crate::linalg::jacobi::sym_eigenvalues(&w);
        let min = eig.values.last().copied().unwrap();
        assert!(min > -1.0 / 3.0 - 1e-12, "λ_min = {min}");
        assert!(min > -1e-12 && eig.values[0] <= 1.0 + 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        one_peer_hypercube_weights(6, 0);
    }
}
