//! Random topologies (Appendix A.3.1 / A.3.3):
//!
//! * **½-random graph** — each edge present independently with `p = ½`,
//!   weighted with the max-degree lazy rule `W = A/d_max + (I − D/d_max)`
//!   (symmetric doubly stochastic; this is the standard construction
//!   behind the paper's `W = A/d_max` shorthand).
//! * **Erdős–Rényi** `G(n, p)` with `p = (1+c)·log(n)/n`.
//! * **2-D geometric random graph** `G(n, r)` with `r² = (1+c)·log(n)/n` —
//!   nodes placed uniformly in the unit square, edges within radius `r`.
//!
//! ER and geometric graphs are weighted with Metropolis (they can be
//! irregular and even disconnected at moderate n — exactly the failure mode
//! Table 6 reports).

use super::graphs::Graph;
use super::metropolis::{metropolis_plan, metropolis_weights};
use super::plan::MixingPlan;
use crate::linalg::Matrix;
use crate::util::rng::Pcg;

/// Bernoulli(p) graph on `n` nodes.
pub fn gnp_graph(n: usize, p: f64, seed: u64) -> Graph {
    let mut g = Graph::empty(n);
    let mut rng = Pcg::new(seed, 0x6E9);
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.uniform() < p {
                g.add_edge(i, j);
            }
        }
    }
    g
}

/// The paper's ½-random graph with max-degree lazy-walk weights.
pub fn half_random_weights(n: usize, seed: u64) -> Matrix {
    let g = gnp_graph(n, 0.5, seed);
    max_degree_weights(&g)
}

/// `W = A/d_max + (I − D/d_max)`: symmetric doubly stochastic for any
/// undirected graph.
pub fn max_degree_weights(g: &Graph) -> Matrix {
    let n = g.n();
    let dmax = g.max_degree().max(1) as f64;
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        for &j in g.neighbors(i) {
            w[(i, j)] = 1.0 / dmax;
        }
        w[(i, i)] = 1.0 - g.degree(i) as f64 / dmax;
    }
    w
}

/// Direct sparse constructor for the max-degree lazy-walk weights:
/// `1/d_max` per edge plus the `1 − d_i/d_max` diagonal, straight from
/// the adjacency lists (arithmetic mirrors [`max_degree_weights`], so
/// the plan is bitwise identical to its `from_dense` — including the
/// dropped exactly-zero diagonal of maximum-degree nodes).
pub fn max_degree_plan(g: &Graph) -> MixingPlan {
    let n = g.n();
    let dmax = g.max_degree().max(1) as f64;
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(g.degree(i) + 1);
        for &j in g.neighbors(i) {
            row.push((j, 1.0 / dmax));
        }
        let diag = 1.0 - g.degree(i) as f64 / dmax;
        if diag != 0.0 {
            row.push((i, diag));
        }
        rows.push(row);
    }
    MixingPlan::from_rows(rows, None)
}

/// The paper's ½-random graph as a sparse plan.
pub fn half_random_plan(n: usize, seed: u64) -> MixingPlan {
    max_degree_plan(&gnp_graph(n, 0.5, seed))
}

/// Erdős–Rényi `G(n, p)` with the connectivity-threshold scaling
/// `p = (1+c)·ln(n)/n`.
pub fn erdos_renyi_graph(n: usize, c: f64, seed: u64) -> Graph {
    let p = ((1.0 + c) * (n as f64).ln() / n as f64).min(1.0);
    gnp_graph(n, p, seed)
}

/// 2-D geometric random graph with `r² = (1+c)·ln(n)/n`.
pub fn geometric_graph(n: usize, c: f64, seed: u64) -> Graph {
    let r2 = (1.0 + c) * (n as f64).ln() / n as f64;
    let mut rng = Pcg::new(seed, 0x6E0);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = pts[i].0 - pts[j].0;
            let dy = pts[i].1 - pts[j].1;
            if dx * dx + dy * dy <= r2 {
                g.add_edge(i, j);
            }
        }
    }
    g
}

/// Metropolis weights over an ER graph.
pub fn erdos_renyi_weights(n: usize, c: f64, seed: u64) -> Matrix {
    metropolis_weights(&erdos_renyi_graph(n, c, seed))
}

/// Metropolis weights over a geometric graph.
pub fn geometric_weights(n: usize, c: f64, seed: u64) -> Matrix {
    metropolis_weights(&geometric_graph(n, c, seed))
}

/// Metropolis plan over an ER graph (sparse, same seed ⇒ same graph as
/// [`erdos_renyi_weights`]).
pub fn erdos_renyi_plan(n: usize, c: f64, seed: u64) -> MixingPlan {
    metropolis_plan(&erdos_renyi_graph(n, c, seed))
}

/// Metropolis plan over a geometric graph.
pub fn geometric_plan(n: usize, c: f64, seed: u64) -> MixingPlan {
    metropolis_plan(&geometric_graph(n, c, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::weight::{degree_spread, is_doubly_stochastic};

    #[test]
    fn half_random_is_doubly_stochastic_and_dense() {
        for n in [8usize, 16, 33] {
            let w = half_random_weights(n, 42);
            assert!(is_doubly_stochastic(&w, 1e-12), "n={n}");
            assert!(w.is_symmetric(1e-15));
            // Expected degree ≈ (n−1)/2; check it's in a generous band.
            let (_, hi) = degree_spread(&w);
            assert!(hi as f64 > 0.25 * n as f64, "n={n} hi={hi}");
        }
    }

    #[test]
    fn er_and_geometric_weights_are_doubly_stochastic() {
        for n in [16usize, 40] {
            assert!(is_doubly_stochastic(&erdos_renyi_weights(n, 1.0, 3), 1e-12));
            assert!(is_doubly_stochastic(&geometric_weights(n, 1.0, 3), 1e-12));
        }
    }

    #[test]
    fn plans_match_dense_builders_for_random_graphs() {
        for (n, seed) in [(8usize, 42u64), (16, 7), (33, 19)] {
            let want = MixingPlan::from_dense(&half_random_weights(n, seed));
            let got = half_random_plan(n, seed);
            assert_eq!(got.rows_vec(), want.rows_vec(), "half-random n={n}");
            assert_eq!(got.max_degree, want.max_degree, "half-random n={n}");
            assert_eq!(got.symmetric, want.symmetric, "half-random n={n}");
            let want = MixingPlan::from_dense(&erdos_renyi_weights(n, 1.0, seed));
            assert_eq!(erdos_renyi_plan(n, 1.0, seed).rows_vec(), want.rows_vec(), "er n={n}");
            let want = MixingPlan::from_dense(&geometric_weights(n, 1.0, seed));
            assert_eq!(geometric_plan(n, 1.0, seed).rows_vec(), want.rows_vec(), "geo n={n}");
        }
    }

    #[test]
    fn max_degree_plan_drops_zero_diagonal() {
        // The hub of a star has degree d_max, so its diagonal is exactly 0
        // and must not be stored (from_dense drops exact zeros).
        let g = crate::topology::graphs::star(6);
        let plan = max_degree_plan(&g);
        assert!(plan.row_entries(0).all(|(j, _)| j != 0), "hub diagonal must be dropped");
        assert!(plan.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn er_degrees_can_be_unbalanced() {
        // The paper's Table 6 point: ER degrees are not identical.
        let g = erdos_renyi_graph(64, 0.5, 17);
        let degs: Vec<usize> = (0..64).map(|i| g.degree(i)).collect();
        let lo = *degs.iter().min().unwrap();
        let hi = *degs.iter().max().unwrap();
        assert!(hi > lo, "ER degrees unexpectedly uniform");
    }

    #[test]
    fn gnp_extremes() {
        let g0 = gnp_graph(10, 0.0, 1);
        assert_eq!(g0.num_edges(), 0);
        let g1 = gnp_graph(10, 1.0, 1);
        assert_eq!(g1.num_edges(), 45);
        assert!(g1.is_connected());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gnp_graph(20, 0.3, 5);
        let b = gnp_graph(20, 0.3, 5);
        for i in 0..20 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }
}
