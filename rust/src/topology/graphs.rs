//! Undirected graph representation and the classic static topologies:
//! ring, star, 2D-grid, 2D-torus and hypercube (Appendix A.3.1).

/// Simple undirected graph on nodes `0..n` (no self-loops; weight matrices
/// add the diagonal separately).
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Empty graph on `n` nodes.
    pub fn empty(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n] }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Add the undirected edge `{u, v}` (idempotent, ignores self-loops).
    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.n && v < self.n, "edge out of range");
        if u == v || self.adj[u].contains(&v) {
            return;
        }
        self.adj[u].push(v);
        self.adj[v].push(u);
    }

    /// Neighbors of node `i`.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Maximum degree over all nodes.
    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// Total number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Is the graph connected? (BFS from node 0; the empty graph with
    /// `n ≤ 1` counts as connected.)
    pub fn is_connected(&self) -> bool {
        if self.n <= 1 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }
}

/// Undirected ring on `n` nodes.
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 0..n {
        g.add_edge(i, (i + 1) % n);
    }
    g
}

/// Star: node 0 is the hub.
pub fn star(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 1..n {
        g.add_edge(0, i);
    }
    g
}

/// Factor `n` into `r × c` with `r ≤ c` and `r` the largest divisor
/// `≤ √n` — used to shape grids/tori for non-square `n` (the paper's
/// experiments use n = 4, 8, 16, 32).
pub fn grid_shape(n: usize) -> (usize, usize) {
    assert!(n > 0);
    let mut r = (n as f64).sqrt() as usize;
    while r > 1 && n % r != 0 {
        r -= 1;
    }
    (r.max(1), n / r.max(1))
}

/// 2D grid (no wraparound).
pub fn grid2d(n: usize) -> Graph {
    let (r, c) = grid_shape(n);
    let mut g = Graph::empty(n);
    for i in 0..r {
        for j in 0..c {
            let u = i * c + j;
            if j + 1 < c {
                g.add_edge(u, u + 1);
            }
            if i + 1 < r {
                g.add_edge(u, u + c);
            }
        }
    }
    g
}

/// 2D torus (grid with wraparound).
pub fn torus2d(n: usize) -> Graph {
    let (r, c) = grid_shape(n);
    let mut g = Graph::empty(n);
    for i in 0..r {
        for j in 0..c {
            let u = i * c + j;
            g.add_edge(u, i * c + (j + 1) % c);
            g.add_edge(u, ((i + 1) % r) * c + j);
        }
    }
    g
}

/// Hypercube on `n = 2^τ` nodes (Remark 2). Panics if `n` is not a power
/// of two.
pub fn hypercube(n: usize) -> Graph {
    assert!(n.is_power_of_two(), "hypercube requires n = 2^tau");
    let mut g = Graph::empty(n);
    let tau = n.trailing_zeros() as usize;
    for u in 0..n {
        for b in 0..tau {
            g.add_edge(u, u ^ (1 << b));
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_degrees_and_connectivity() {
        let g = ring(8);
        assert!(g.is_connected());
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.num_edges(), 8);
        // n = 2 ring degenerates to a single edge.
        let g2 = ring(2);
        assert_eq!(g2.num_edges(), 1);
        assert_eq!(g2.max_degree(), 1);
    }

    #[test]
    fn star_has_hub() {
        let g = star(9);
        assert!(g.is_connected());
        assert_eq!(g.degree(0), 8);
        assert_eq!(g.max_degree(), 8);
        for i in 1..9 {
            assert_eq!(g.degree(i), 1);
        }
    }

    #[test]
    fn grid_shapes() {
        assert_eq!(grid_shape(16), (4, 4));
        assert_eq!(grid_shape(8), (2, 4));
        assert_eq!(grid_shape(32), (4, 8));
        assert_eq!(grid_shape(7), (1, 7)); // prime: degenerates to a path
    }

    #[test]
    fn grid_and_torus_structure() {
        let g = grid2d(16);
        assert!(g.is_connected());
        assert_eq!(g.max_degree(), 4);
        // Corner of a 4x4 grid has degree 2.
        assert_eq!(g.degree(0), 2);
        let t = torus2d(16);
        assert!(t.is_connected());
        // Torus is 4-regular.
        for i in 0..16 {
            assert_eq!(t.degree(i), 4);
        }
        assert_eq!(t.num_edges(), 32);
    }

    #[test]
    fn torus_small_dims_no_duplicate_edges() {
        // 2xC torus: wraparound in the length-2 dimension is the same edge
        // both ways; add_edge must dedupe.
        let t = torus2d(8); // (2, 4)
        assert!(t.is_connected());
        for i in 0..8 {
            assert_eq!(t.degree(i), 3, "node {i}: vertical wrap is a single edge");
        }
    }

    #[test]
    fn hypercube_structure() {
        let h = hypercube(16);
        assert!(h.is_connected());
        for i in 0..16 {
            assert_eq!(h.degree(i), 4);
        }
    }

    #[test]
    fn disconnected_graph_detected() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(!g.is_connected());
    }
}
