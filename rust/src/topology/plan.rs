//! `MixingPlan` — the canonical sparse-first representation of a mixing
//! matrix `W^{(k)}`.
//!
//! The paper's whole point is that exponential graphs need only
//! `O(log n)` (static) or `O(1)` (one-peer) neighbors per node, so the
//! training path never materializes a dense `n × n` matrix: every
//! topology has a *direct sparse constructor* (neighbor lists + per-edge
//! weights), and [`crate::topology::schedule::Schedule::plan_at`] hands
//! out cached borrowed plans. Dense [`Matrix`] form survives only behind
//! the [`MixingPlan::to_dense`] escape hatch for spectral analysis
//! (eigen/ρ computations) and tests. See docs/DESIGN.md §Plan cache.
//!
//! Storage is flat CSR: `row_ptr` (n+1 offsets) into parallel `cols` /
//! `weights_f64` / `weights_f32` arrays. The f64 weights are the source
//! of truth (exact rationals like `1/(τ+1)`, preserving Lemma 1's exact-
//! averaging property on the f64 consensus path); the f32 copy is cast
//! **once at construction**, so the training kernels never pay a
//! per-nonzero-per-chunk cast and never chase per-row heap pointers.
//! Constructors still hand [`MixingPlan::from_rows`] per-row nonzero
//! lists; the CSR flattening is internal.
//!
//! The mixing kernels (`mix`, `mix_dmsgd`) that consume a plan live in
//! [`crate::coordinator::mixing`]; this module owns construction and
//! structural metadata (`max_degree`, symmetry, originating
//! [`TopologyKind`]).

use super::TopologyKind;
use crate::linalg::Matrix;

/// Sparse row-major mixing matrix (flat CSR) plus structural metadata.
///
/// Row `i` holds the sorted `(j, w_ij)` nonzeros of `W`'s row `i`; the
/// kernels read them through [`MixingPlan::row`] as contiguous column /
/// weight slices.
#[derive(Clone, Debug, PartialEq)]
pub struct MixingPlan {
    /// Number of nodes (rows).
    pub n: usize,
    /// CSR row offsets: row `i`'s nonzeros live at
    /// `row_ptr[i]..row_ptr[i+1]` in the parallel arrays below.
    row_ptr: Vec<u32>,
    /// Column index of each nonzero, ascending within a row.
    cols: Vec<u32>,
    /// `f64` weight of each nonzero (the source of truth).
    weights_f64: Vec<f64>,
    /// `f32` weight of each nonzero, cast once at construction for the
    /// training kernels.
    weights_f32: Vec<f32>,
    /// For each node, its *distinct* off-diagonal communication
    /// partners (union of in- and out-neighbors), ascending. Built once
    /// at construction; [`crate::netsim`] walks these lists directly
    /// every simulated round instead of re-deriving them.
    pub partners: Vec<Vec<usize>>,
    /// Max over nodes of the number of distinct partners (the longest
    /// `partners` list) — the paper's per-iteration communication
    /// degree.
    pub max_degree: usize,
    /// Is `W` exactly symmetric? (What D²/Exact-Diffusion require.)
    pub symmetric: bool,
    /// The topology this plan was built from, when known.
    pub kind: Option<TopologyKind>,
}

/// Borrowed view of one CSR row: parallel column / weight slices. The
/// kernels iterate `cols[t]` with `w32[t]` (training, f32) or `w64[t]`
/// (consensus, f64); `t` ascends in column order, which is what the
/// determinism contract pins (docs/DESIGN.md §Engine).
#[derive(Clone, Copy, Debug)]
pub struct PlanRow<'a> {
    /// Column indices, ascending.
    pub cols: &'a [u32],
    /// f64 weights, parallel to `cols`.
    pub w64: &'a [f64],
    /// f32 weights, parallel to `cols` (cast once at plan construction).
    pub w32: &'a [f32],
}

impl<'a> PlanRow<'a> {
    /// Number of nonzeros in the row.
    #[inline]
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }
}

impl MixingPlan {
    /// Build a plan from per-row nonzero lists. Rows are sorted by column
    /// index, then flattened into CSR; `max_degree` and symmetry are
    /// derived from the structure in `O(nnz log nnz)`. Deterministic
    /// schedules pay this once at cache build; stochastic schedules
    /// (random matching, sampled one-peer) pay it per draw — if that ever
    /// shows up in a profile, give the matching/one-peer constructors a
    /// variant taking their analytic metadata (degree 1–2, symmetry by
    /// `n | 2·hop`) instead.
    pub fn from_rows(mut rows: Vec<Vec<(usize, f64)>>, kind: Option<TopologyKind>) -> MixingPlan {
        for row in rows.iter_mut() {
            row.sort_unstable_by_key(|e| e.0);
        }
        let n = rows.len();
        let partners = partner_lists(&rows);
        let max_degree = partners.iter().map(Vec::len).max().unwrap_or(0);
        let symmetric = rows_symmetric(&rows);
        let nnz: usize = rows.iter().map(Vec::len).sum();
        assert!(n < u32::MAX as usize && nnz < u32::MAX as usize, "plan exceeds u32 CSR indexing");
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::with_capacity(nnz);
        let mut weights_f64 = Vec::with_capacity(nnz);
        let mut weights_f32 = Vec::with_capacity(nnz);
        row_ptr.push(0u32);
        for row in &rows {
            for &(j, w) in row {
                cols.push(j as u32);
                weights_f64.push(w);
                weights_f32.push(w as f32);
            }
            row_ptr.push(cols.len() as u32);
        }
        MixingPlan {
            n,
            row_ptr,
            cols,
            weights_f64,
            weights_f32,
            partners,
            max_degree,
            symmetric,
            kind,
        }
    }

    /// Tag the plan with its originating topology kind.
    pub fn with_kind(mut self, kind: TopologyKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Convert from a dense weight matrix, dropping exact zeros. This is
    /// the legacy path — kept for tests, ad-hoc matrices, and as the
    /// reference the direct constructors are property-tested against.
    pub fn from_dense(w: &Matrix) -> MixingPlan {
        let n = w.rows();
        assert_eq!(n, w.cols(), "mixing matrix must be square");
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::new();
            for j in 0..n {
                let v = w[(i, j)];
                if v != 0.0 {
                    row.push((j, v));
                }
            }
            rows.push(row);
        }
        MixingPlan::from_rows(rows, None)
    }

    /// The exact-averaging plan `J = 11ᵀ/n` (parallel SGD baseline).
    pub fn averaging(n: usize) -> MixingPlan {
        let w = 1.0 / n as f64;
        let rows = (0..n).map(|_| (0..n).map(|j| (j, w)).collect()).collect();
        MixingPlan::from_rows(rows, Some(TopologyKind::FullyConnected))
    }

    /// Borrowed CSR view of row `i` (the kernels' access path).
    #[inline]
    pub fn row(&self, i: usize) -> PlanRow<'_> {
        let s = self.row_ptr[i] as usize;
        let e = self.row_ptr[i + 1] as usize;
        PlanRow {
            cols: &self.cols[s..e],
            w64: &self.weights_f64[s..e],
            w32: &self.weights_f32[s..e],
        }
    }

    /// Number of nonzeros in row `i`.
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Iterate row `i`'s `(j, w_ij)` nonzeros in ascending-`j` order
    /// (f64 weights — the consensus/metadata path).
    #[inline]
    pub fn row_entries(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let r = self.row(i);
        r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| (j as usize, w))
    }

    /// Materialize the per-row nonzero lists (the pre-CSR representation).
    /// Allocating — for tests, property checks, and structural diffs
    /// only; the kernels use [`MixingPlan::row`].
    pub fn rows_vec(&self) -> Vec<Vec<(usize, f64)>> {
        (0..self.n).map(|i| self.row_entries(i).collect()).collect()
    }

    /// Dense escape hatch for spectral analysis (eigen/ρ) and tests —
    /// never called on the training path.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for i in 0..self.n {
            for (j, w) in self.row_entries(i) {
                m[(i, j)] = w;
            }
        }
        m
    }

    /// Total number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Sparse matrix-vector product `W x` in `f64` (the consensus/gossip
    /// simulation path). Accumulates in ascending-`j` order, matching the
    /// dense [`Matrix::matvec`] bit-for-bit on the stored nonzeros.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "matvec dimension mismatch");
        (0..self.n)
            .map(|i| {
                let r = self.row(i);
                r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| w * x[j as usize]).sum()
            })
            .collect()
    }

    /// Fault-renormalized copy of the plan (the network simulator's
    /// degraded-plan rule, docs/DESIGN.md §NetSim): an `offline` node
    /// keeps only itself (`row u = {(u, 1)}`), and in every online row
    /// `i` each off-diagonal entry `(j, w)` whose message was lost
    /// (`offline[j]` or `dropped(i, j)`) is folded into the diagonal —
    /// the self-weight absorbs the lost mass, so each row's sum is
    /// preserved (row-stochasticity survives any fault pattern).
    ///
    /// `dropped` must be symmetric in its arguments for symmetric input
    /// plans to stay symmetric (the simulator drops per unordered
    /// pair). Returns `None` when no entry changed, so fault-free
    /// rounds keep borrowing the original plan bit-for-bit.
    pub fn degrade(
        &self,
        offline: &[bool],
        mut dropped: impl FnMut(usize, usize) -> bool,
    ) -> Option<MixingPlan> {
        assert_eq!(offline.len(), self.n, "offline mask dimension mismatch");
        let mut changed = false;
        let mut rows = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let row = self.row(i);
            if offline[i] {
                if row.len() != 1 || row.cols[0] as usize != i || row.w64[0] != 1.0 {
                    changed = true;
                }
                rows.push(vec![(i, 1.0)]);
                continue;
            }
            let mut out = Vec::with_capacity(row.len());
            let mut absorbed = 0.0f64;
            let mut diag = None;
            for (j, w) in self.row_entries(i) {
                if j != i && (offline[j] || dropped(i, j)) {
                    absorbed += w;
                    changed = true;
                } else {
                    if j == i {
                        diag = Some(out.len());
                    }
                    out.push((j, w));
                }
            }
            if absorbed != 0.0 {
                match diag {
                    Some(p) => out[p].1 += absorbed,
                    None => out.push((i, absorbed)),
                }
            }
            rows.push(out);
        }
        changed.then(|| MixingPlan::from_rows(rows, self.kind))
    }

    /// Is the plan doubly stochastic to tolerance `tol`?
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let mut col_sums = vec![0.0f64; self.n];
        for i in 0..self.n {
            let mut rsum = 0.0;
            for (j, w) in self.row_entries(i) {
                if w < -tol {
                    return false;
                }
                rsum += w;
                col_sums[j] += w;
            }
            if (rsum - 1.0).abs() > tol {
                return false;
            }
        }
        col_sums.iter().all(|c| (c - 1.0).abs() <= tol)
    }
}

/// Distinct communication partners per node, matching
/// [`crate::topology::weight::max_comm_degree`]'s notion on the dense
/// form: `j` is a partner of `i` iff `w_ij ≠ 0` or `w_ji ≠ 0`, `i ≠ j`.
/// Ascending and deduplicated; the longest list is `max_degree`.
fn partner_lists(rows: &[Vec<(usize, f64)>]) -> Vec<Vec<usize>> {
    let n = rows.len();
    let mut partners: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, row) in rows.iter().enumerate() {
        for &(j, w) in row {
            if i != j && w != 0.0 {
                partners[i].push(j);
                partners[j].push(i);
            }
        }
    }
    for p in partners.iter_mut() {
        p.sort_unstable();
        p.dedup();
    }
    partners
}

/// Exact structural symmetry: every stored `(i, j, w)` has a matching
/// `(j, i, w)` (bitwise-equal weight, mirroring
/// `Matrix::is_symmetric(0.0)` on the dense form).
fn rows_symmetric(rows: &[Vec<(usize, f64)>]) -> bool {
    let lookup = |i: usize, j: usize| -> Option<f64> {
        let row = &rows[i];
        row.binary_search_by_key(&j, |e| e.0).ok().map(|p| row[p].1)
    };
    rows.iter()
        .enumerate()
        .all(|(i, row)| row.iter().all(|&(j, w)| lookup(j, i) == Some(w)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::{one_peer_exp_weights, static_exp_weights};

    #[test]
    fn from_dense_roundtrips_to_dense() {
        for w in [static_exp_weights(9), one_peer_exp_weights(8, 1), Matrix::averaging(5)] {
            let plan = MixingPlan::from_dense(&w);
            assert_eq!(plan.to_dense(), w);
        }
    }

    #[test]
    fn metadata_matches_dense_queries() {
        let w = static_exp_weights(16);
        let plan = MixingPlan::from_dense(&w);
        assert_eq!(plan.max_degree, crate::topology::weight::max_comm_degree(&w));
        assert_eq!(plan.symmetric, w.is_symmetric(0.0));
        assert!(!plan.symmetric, "static exp is asymmetric for n > 2");
        let j = MixingPlan::averaging(6);
        assert!(j.symmetric);
        assert_eq!(j.max_degree, 5);
        assert_eq!(j.kind, Some(TopologyKind::FullyConnected));
    }

    #[test]
    fn matvec_matches_dense_matvec() {
        let w = static_exp_weights(12);
        let plan = MixingPlan::from_dense(&w);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let sparse = plan.matvec(&x);
        let dense = w.matvec(&x);
        for (a, b) in sparse.iter().zip(dense.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn doubly_stochastic_check() {
        assert!(MixingPlan::averaging(7).is_doubly_stochastic(1e-12));
        let mut rows = MixingPlan::averaging(3).rows_vec();
        rows[0][0].1 = 0.9;
        let bad = MixingPlan::from_rows(rows, None);
        assert!(!bad.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn degrade_none_when_no_fault_fires() {
        let plan = MixingPlan::from_dense(&static_exp_weights(16));
        let offline = vec![false; 16];
        assert!(plan.degrade(&offline, |_, _| false).is_none());
    }

    #[test]
    fn degrade_folds_lost_mass_into_diagonal() {
        let plan = MixingPlan::from_dense(&one_peer_exp_weights(8, 0));
        let offline = vec![false; 8];
        // Drop the {0, 1} exchange: rows 0 and 7 lose their partner.
        let d = plan
            .degrade(&offline, |a, b| (a.min(b), a.max(b)) == (0, 1))
            .expect("a drop must degrade");
        let drows = d.rows_vec();
        assert_eq!(drows[0], vec![(0, 1.0)]);
        // Row 1 pulls from node 2, which was not dropped.
        assert_eq!(drows[1], plan.rows_vec()[1]);
        for (i, row) in drows.iter().enumerate() {
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
        assert_eq!(d.kind, plan.kind);
    }

    #[test]
    fn degrade_offline_node_keeps_only_itself() {
        let plan = MixingPlan::from_dense(&static_exp_weights(8));
        let mut offline = vec![false; 8];
        offline[3] = true;
        let d = plan.degrade(&offline, |_, _| false).expect("offline degrades");
        let drows = d.rows_vec();
        assert_eq!(drows[3], vec![(3, 1.0)]);
        for (i, row) in drows.iter().enumerate() {
            assert!(i == 3 || row.iter().all(|&(j, _)| j != 3), "row {i} still reads node 3");
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn from_rows_sorts_and_counts() {
        let plan = MixingPlan::from_rows(
            vec![vec![(1, 0.5), (0, 0.5)], vec![(0, 0.5), (1, 0.5)]],
            None,
        );
        assert_eq!(plan.rows_vec()[0], vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(plan.max_degree, 1);
        assert!(plan.symmetric);
        assert_eq!(plan.nnz(), 4);
    }

    #[test]
    fn csr_layout_is_consistent() {
        // The CSR arrays are parallel, rows are contiguous and ascending,
        // and the cached f32 weights are exactly the f64 weights cast
        // once (what the kernels rely on).
        let plan = MixingPlan::from_dense(&static_exp_weights(16));
        let mut total = 0usize;
        for i in 0..plan.n {
            let row = plan.row(i);
            assert_eq!(row.cols.len(), row.w64.len());
            assert_eq!(row.cols.len(), row.w32.len());
            assert_eq!(row.len(), plan.row_len(i));
            assert!(row.cols.windows(2).all(|p| p[0] < p[1]), "row {i} not ascending");
            for t in 0..row.len() {
                assert_eq!(row.w32[t].to_bits(), (row.w64[t] as f32).to_bits());
            }
            total += row.len();
        }
        assert_eq!(total, plan.nnz());
    }

    #[test]
    fn empty_rows_are_representable() {
        // A row with no nonzeros must survive the CSR flattening (the
        // kernels zero such output rows).
        let plan = MixingPlan::from_rows(vec![vec![(0, 1.0)], vec![], vec![(2, 1.0)]], None);
        assert_eq!(plan.row_len(1), 0);
        assert!(plan.row(1).is_empty());
        assert!(plan.rows_vec()[1].is_empty());
        assert_eq!(plan.nnz(), 2);
    }
}
