//! `MixingPlan` — the canonical sparse-first representation of a mixing
//! matrix `W^{(k)}`.
//!
//! The paper's whole point is that exponential graphs need only
//! `O(log n)` (static) or `O(1)` (one-peer) neighbors per node, so the
//! training path never materializes a dense `n × n` matrix: every
//! topology has a *direct sparse constructor* (neighbor lists + per-edge
//! weights), and [`crate::topology::schedule::Schedule::plan_at`] hands
//! out cached borrowed plans. Dense [`Matrix`] form survives only behind
//! the [`MixingPlan::to_dense`] escape hatch for spectral analysis
//! (eigen/ρ computations) and tests. See docs/DESIGN.md §Plan cache.
//!
//! Storage is flat CSR: `row_ptr` (n+1 offsets) into parallel `cols` /
//! `weights_f64` / `weights_f32` arrays. The f64 weights are the source
//! of truth (exact rationals like `1/(τ+1)`, preserving Lemma 1's exact-
//! averaging property on the f64 consensus path); the f32 copy is cast
//! **once at construction**, so the training kernels never pay a
//! per-nonzero-per-chunk cast and never chase per-row heap pointers.
//! The per-node communication-partner lists (what [`crate::netsim`]
//! walks every simulated round) are flat CSR too — at `n = 2²⁰` a
//! `Vec<Vec<usize>>` would cost a heap allocation plus pointer chase
//! per node, which is exactly the layout this module exists to avoid.
//!
//! There is **one construction path**: [`PlanBuilder`] streams nonzeros
//! row by row straight into the CSR arrays (no intermediate
//! `Vec<Vec<(usize, f64)>>`), and [`MixingPlan::from_rows`] is a thin
//! adapter over it for callers that already hold per-row lists.
//!
//! The mixing kernels (`mix`, `mix_dmsgd`) that consume a plan live in
//! [`crate::coordinator::mixing`]; this module owns construction and
//! structural metadata (`max_degree`, symmetry, originating
//! [`TopologyKind`]).

use super::TopologyKind;
use crate::linalg::Matrix;

/// Sparse row-major mixing matrix (flat CSR) plus structural metadata.
///
/// Row `i` holds the sorted `(j, w_ij)` nonzeros of `W`'s row `i`; the
/// kernels read them through [`MixingPlan::row`] as contiguous column /
/// weight slices.
#[derive(Clone, Debug, PartialEq)]
pub struct MixingPlan {
    /// Number of nodes (rows).
    pub n: usize,
    /// CSR row offsets: row `i`'s nonzeros live at
    /// `row_ptr[i]..row_ptr[i+1]` in the parallel arrays below.
    row_ptr: Vec<u32>,
    /// Column index of each nonzero, ascending within a row.
    cols: Vec<u32>,
    /// `f64` weight of each nonzero (the source of truth).
    weights_f64: Vec<f64>,
    /// `f32` weight of each nonzero, cast once at construction for the
    /// training kernels.
    weights_f32: Vec<f32>,
    /// CSR offsets into `partner_cols`: node `u`'s *distinct*
    /// off-diagonal communication partners (union of in- and
    /// out-neighbors), ascending, live at
    /// `partner_ptr[u]..partner_ptr[u+1]`. Built once at construction;
    /// [`crate::netsim`] walks these slices directly every simulated
    /// round instead of re-deriving them.
    partner_ptr: Vec<u32>,
    /// Partner ids, ascending within each node's segment.
    partner_cols: Vec<u32>,
    /// Max over nodes of the number of distinct partners (the longest
    /// partner segment) — the paper's per-iteration communication
    /// degree.
    pub max_degree: usize,
    /// Is `W` exactly symmetric? (What D²/Exact-Diffusion require.)
    pub symmetric: bool,
    /// The topology this plan was built from, when known.
    pub kind: Option<TopologyKind>,
}

/// Borrowed view of one CSR row: parallel column / weight slices. The
/// kernels iterate `cols[t]` with `w32[t]` (training, f32) or `w64[t]`
/// (consensus, f64); `t` ascends in column order, which is what the
/// determinism contract pins (docs/DESIGN.md §Engine).
#[derive(Clone, Copy, Debug)]
pub struct PlanRow<'a> {
    /// Column indices, ascending.
    pub cols: &'a [u32],
    /// f64 weights, parallel to `cols`.
    pub w64: &'a [f64],
    /// f32 weights, parallel to `cols` (cast once at plan construction).
    pub w32: &'a [f32],
}

impl<'a> PlanRow<'a> {
    /// Number of nonzeros in the row.
    #[inline]
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }
}

/// Streaming CSR constructor: push nonzeros row by row, then
/// [`PlanBuilder::finish`]. This is the **only** construction path —
/// [`MixingPlan::from_rows`] adapts per-row lists onto it — so the
/// closed-form family constructors can build million-node plans without
/// ever materializing a `Vec<Vec<(usize, f64)>>` (one heap allocation
/// per row is exactly the layout the large-n netsim path cannot
/// afford).
///
/// Rows are sorted by column on [`PlanBuilder::finish_row`] (in a
/// reused scratch, skipped when the row was pushed ascending — every
/// in-tree constructor except the wrap-around static-exp rows);
/// `finish` derives the partner CSR, `max_degree`, and symmetry in
/// `O(nnz log max_row)`.
pub struct PlanBuilder {
    row_ptr: Vec<u32>,
    cols: Vec<u32>,
    weights_f64: Vec<f64>,
    weights_f32: Vec<f32>,
    /// Reused per-row sort scratch (allocated at most once per build).
    scratch: Vec<(u32, f64)>,
}

impl PlanBuilder {
    /// Start a build; `n_hint` / `nnz_hint` pre-size the arrays (exact
    /// values avoid every reallocation, approximations are fine).
    pub fn new(n_hint: usize, nnz_hint: usize) -> PlanBuilder {
        let mut row_ptr = Vec::with_capacity(n_hint + 1);
        row_ptr.push(0u32);
        PlanBuilder {
            row_ptr,
            cols: Vec::with_capacity(nnz_hint),
            weights_f64: Vec::with_capacity(nnz_hint),
            weights_f32: Vec::with_capacity(nnz_hint),
            scratch: Vec::new(),
        }
    }

    /// Append one nonzero `(j, w)` to the current row.
    #[inline]
    pub fn push(&mut self, j: usize, w: f64) {
        self.cols.push(j as u32);
        self.weights_f64.push(w);
        self.weights_f32.push(w as f32);
    }

    /// Close the current row: sort its nonzeros by column (no-op when
    /// pushed ascending) and advance the row offsets.
    pub fn finish_row(&mut self) {
        let start = *self.row_ptr.last().unwrap() as usize;
        if !self.cols[start..].windows(2).all(|p| p[0] <= p[1]) {
            self.scratch.clear();
            self.scratch.extend(
                self.cols[start..]
                    .iter()
                    .zip(&self.weights_f64[start..])
                    .map(|(&c, &w)| (c, w)),
            );
            self.scratch.sort_unstable_by_key(|e| e.0);
            for (t, &(c, w)) in self.scratch.iter().enumerate() {
                self.cols[start + t] = c;
                self.weights_f64[start + t] = w;
                self.weights_f32[start + t] = w as f32;
            }
        }
        self.row_ptr.push(self.cols.len() as u32);
    }

    /// Derive structural metadata (partner CSR, `max_degree`, symmetry)
    /// and seal the plan.
    pub fn finish(self, kind: Option<TopologyKind>) -> MixingPlan {
        let n = self.row_ptr.len() - 1;
        let nnz = self.cols.len();
        assert!(n < u32::MAX as usize && nnz < u32::MAX as usize, "plan exceeds u32 CSR indexing");
        let (partner_ptr, partner_cols) =
            partner_csr(n, &self.row_ptr, &self.cols, &self.weights_f64);
        let max_degree = (0..n)
            .map(|u| (partner_ptr[u + 1] - partner_ptr[u]) as usize)
            .max()
            .unwrap_or(0);
        let symmetric = csr_symmetric(n, &self.row_ptr, &self.cols, &self.weights_f64);
        MixingPlan {
            n,
            row_ptr: self.row_ptr,
            cols: self.cols,
            weights_f64: self.weights_f64,
            weights_f32: self.weights_f32,
            partner_ptr,
            partner_cols,
            max_degree,
            symmetric,
            kind,
        }
    }
}

impl MixingPlan {
    /// Build a plan from per-row nonzero lists — a thin adapter over
    /// [`PlanBuilder`] for callers that already hold materialized rows
    /// (tests, `from_dense`, ad-hoc matrices). Large-n constructors
    /// should stream through the builder directly.
    pub fn from_rows(rows: Vec<Vec<(usize, f64)>>, kind: Option<TopologyKind>) -> MixingPlan {
        let nnz: usize = rows.iter().map(Vec::len).sum();
        let mut b = PlanBuilder::new(rows.len(), nnz);
        for row in &rows {
            for &(j, w) in row {
                b.push(j, w);
            }
            b.finish_row();
        }
        b.finish(kind)
    }

    /// Tag the plan with its originating topology kind.
    pub fn with_kind(mut self, kind: TopologyKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Convert from a dense weight matrix, dropping exact zeros. This is
    /// the legacy path — kept for tests, ad-hoc matrices, and as the
    /// reference the direct constructors are property-tested against.
    pub fn from_dense(w: &Matrix) -> MixingPlan {
        let n = w.rows();
        assert_eq!(n, w.cols(), "mixing matrix must be square");
        let mut b = PlanBuilder::new(n, n);
        for i in 0..n {
            for j in 0..n {
                let v = w[(i, j)];
                if v != 0.0 {
                    b.push(j, v);
                }
            }
            b.finish_row();
        }
        b.finish(None)
    }

    /// The exact-averaging plan `J = 11ᵀ/n` (parallel SGD baseline).
    pub fn averaging(n: usize) -> MixingPlan {
        let w = 1.0 / n as f64;
        let mut b = PlanBuilder::new(n, n * n);
        for _ in 0..n {
            for j in 0..n {
                b.push(j, w);
            }
            b.finish_row();
        }
        b.finish(Some(TopologyKind::FullyConnected))
    }

    /// Borrowed CSR view of row `i` (the kernels' access path).
    #[inline]
    pub fn row(&self, i: usize) -> PlanRow<'_> {
        let s = self.row_ptr[i] as usize;
        let e = self.row_ptr[i + 1] as usize;
        PlanRow {
            cols: &self.cols[s..e],
            w64: &self.weights_f64[s..e],
            w32: &self.weights_f32[s..e],
        }
    }

    /// Number of nonzeros in row `i`.
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Node `u`'s distinct off-diagonal communication partners (union of
    /// in- and out-neighbors), ascending — a borrowed CSR segment, the
    /// same degree notion as [`MixingPlan::max_degree`].
    #[inline]
    pub fn partners(&self, u: usize) -> &[u32] {
        &self.partner_cols[self.partner_ptr[u] as usize..self.partner_ptr[u + 1] as usize]
    }

    /// Iterate row `i`'s `(j, w_ij)` nonzeros in ascending-`j` order
    /// (f64 weights — the consensus/metadata path).
    #[inline]
    pub fn row_entries(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let r = self.row(i);
        r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| (j as usize, w))
    }

    /// Materialize the per-row nonzero lists (the pre-CSR representation).
    /// Allocating — for tests, property checks, and structural diffs
    /// only; the kernels use [`MixingPlan::row`].
    pub fn rows_vec(&self) -> Vec<Vec<(usize, f64)>> {
        (0..self.n).map(|i| self.row_entries(i).collect()).collect()
    }

    /// Dense escape hatch for spectral analysis (eigen/ρ) and tests —
    /// never called on the training path.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for i in 0..self.n {
            for (j, w) in self.row_entries(i) {
                m[(i, j)] = w;
            }
        }
        m
    }

    /// Total number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Bytes of live plan state (all CSR arrays, by length) — the
    /// peak-RSS proxy the large-n tests/benches assert is `O(n + nnz)`.
    pub fn state_bytes(&self) -> usize {
        self.row_ptr.len() * 4
            + self.cols.len() * 4
            + self.weights_f64.len() * 8
            + self.weights_f32.len() * 4
            + self.partner_ptr.len() * 4
            + self.partner_cols.len() * 4
    }

    /// Sparse matrix-vector product `W x` in `f64` (the consensus/gossip
    /// simulation path). Accumulates in ascending-`j` order, matching the
    /// dense [`Matrix::matvec`] bit-for-bit on the stored nonzeros.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        self.matvec_into(x, &mut out);
        out
    }

    /// Allocation-free [`MixingPlan::matvec`] into a caller-owned
    /// buffer — the large-n plan-only consensus loop double-buffers
    /// through this. Identical accumulation order, so the two entry
    /// points are bitwise-equal.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.n, "matvec dimension mismatch");
        assert_eq!(out.len(), self.n, "matvec output dimension mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            let r = self.row(i);
            *o = r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| w * x[j as usize]).sum();
        }
    }

    /// Fault-renormalized copy of the plan (the network simulator's
    /// degraded-plan rule, docs/DESIGN.md §NetSim): an `offline` node
    /// keeps only itself (`row u = {(u, 1)}`), and in every online row
    /// `i` each off-diagonal entry `(j, w)` whose message was lost
    /// (`offline[j]` or `dropped(i, j)`) is folded into the diagonal —
    /// the self-weight absorbs the lost mass, so each row's sum is
    /// preserved (row-stochasticity survives any fault pattern).
    ///
    /// `dropped` must be symmetric in its arguments for symmetric input
    /// plans to stay symmetric (the simulator drops per unordered
    /// pair), and pure — it is consulted once per surviving structure
    /// query, not once per nonzero. Returns `None` when no entry
    /// changed, so fault-free rounds keep borrowing the original plan
    /// bit-for-bit.
    pub fn degrade(
        &self,
        offline: &[bool],
        dropped: impl FnMut(usize, usize) -> bool,
    ) -> Option<MixingPlan> {
        assert_eq!(offline.len(), self.n, "offline mask dimension mismatch");
        self.degrade_if(|u| offline[u], dropped)
    }

    /// [`MixingPlan::degrade`] with the offline set as a predicate (so
    /// the simulator's bitset mask needs no `Vec<bool>` materialize).
    ///
    /// Builds the degraded plan **CSR-direct** in one pass over the
    /// input CSR — no `rows_vec()` materialize, no `from_rows`
    /// round-trip — and derives the partner lists by filtering the
    /// original partner CSR (a pair survives iff both endpoints are
    /// online and the exchange was not dropped). Bitwise-identical to
    /// [`MixingPlan::degrade_reference`], pinned by tests/kernels.rs.
    pub fn degrade_if(
        &self,
        offline: impl Fn(usize) -> bool,
        mut dropped: impl FnMut(usize, usize) -> bool,
    ) -> Option<MixingPlan> {
        let n = self.n;
        let mut changed = false;
        let mut row_ptr: Vec<u32> = Vec::with_capacity(n + 1);
        row_ptr.push(0u32);
        let mut cols: Vec<u32> = Vec::with_capacity(self.cols.len());
        let mut w64: Vec<f64> = Vec::with_capacity(self.cols.len());
        let mut w32: Vec<f32> = Vec::with_capacity(self.cols.len());
        for i in 0..n {
            let row = self.row(i);
            if offline(i) {
                if row.len() != 1 || row.cols[0] as usize != i || row.w64[0] != 1.0 {
                    changed = true;
                }
                cols.push(i as u32);
                w64.push(1.0);
                w32.push(1.0);
                row_ptr.push(cols.len() as u32);
                continue;
            }
            let start = cols.len();
            let mut absorbed = 0.0f64;
            let mut diag: Option<usize> = None;
            for t in 0..row.len() {
                let j = row.cols[t] as usize;
                let w = row.w64[t];
                if j != i && (offline(j) || dropped(i, j)) {
                    absorbed += w;
                    changed = true;
                } else {
                    if j == i {
                        diag = Some(cols.len());
                    }
                    cols.push(j as u32);
                    w64.push(w);
                    w32.push(w as f32);
                }
            }
            if absorbed != 0.0 {
                match diag {
                    Some(p) => {
                        w64[p] += absorbed;
                        w32[p] = w64[p] as f32;
                    }
                    None => {
                        // The surviving entries are still ascending, so
                        // the absorbing diagonal slots in at its sorted
                        // position (the reference path appends and
                        // re-sorts; only the current row's tail shifts).
                        let pos = start + cols[start..].partition_point(|&c| (c as usize) < i);
                        cols.insert(pos, i as u32);
                        w64.insert(pos, absorbed);
                        w32.insert(pos, absorbed as f32);
                    }
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        if !changed {
            return None;
        }
        // Partner lists: a pair {u, v} survives iff both ends are online
        // and the exchange was not dropped (a lost pair loses *both*
        // directed entries, an offline node keeps none) — so the
        // degraded partner CSR is a filter of the original one.
        let mut partner_ptr: Vec<u32> = Vec::with_capacity(n + 1);
        partner_ptr.push(0u32);
        let mut partner_cols: Vec<u32> = Vec::with_capacity(self.partner_cols.len());
        let mut max_degree = 0usize;
        for u in 0..n {
            if !offline(u) {
                for &v in self.partners(u) {
                    let vv = v as usize;
                    if !offline(vv) && !dropped(u, vv) {
                        partner_cols.push(v);
                    }
                }
            }
            let deg = partner_cols.len() - *partner_ptr.last().unwrap() as usize;
            max_degree = max_degree.max(deg);
            partner_ptr.push(partner_cols.len() as u32);
        }
        let symmetric = csr_symmetric(n, &row_ptr, &cols, &w64);
        Some(MixingPlan {
            n,
            row_ptr,
            cols,
            weights_f64: w64,
            weights_f32: w32,
            partner_ptr,
            partner_cols,
            max_degree,
            symmetric,
            kind: self.kind,
        })
    }

    /// Reference twin of [`MixingPlan::degrade_if`]: materialize the
    /// per-row lists, apply the renormalization rule, and rebuild
    /// through [`MixingPlan::from_rows`] — the pre-arena implementation,
    /// kept (like the scalar kernel twins, docs/DESIGN.md §Perf) as the
    /// bitwise pin for the CSR-direct path and the honest "before" side
    /// of `bench_netsim`'s comparator.
    pub fn degrade_reference(
        &self,
        offline: &[bool],
        mut dropped: impl FnMut(usize, usize) -> bool,
    ) -> Option<MixingPlan> {
        assert_eq!(offline.len(), self.n, "offline mask dimension mismatch");
        let mut changed = false;
        let mut rows = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let row = self.row(i);
            if offline[i] {
                if row.len() != 1 || row.cols[0] as usize != i || row.w64[0] != 1.0 {
                    changed = true;
                }
                rows.push(vec![(i, 1.0)]);
                continue;
            }
            let mut out = Vec::with_capacity(row.len());
            let mut absorbed = 0.0f64;
            let mut diag = None;
            for (j, w) in self.row_entries(i) {
                if j != i && (offline[j] || dropped(i, j)) {
                    absorbed += w;
                    changed = true;
                } else {
                    if j == i {
                        diag = Some(out.len());
                    }
                    out.push((j, w));
                }
            }
            if absorbed != 0.0 {
                match diag {
                    Some(p) => out[p].1 += absorbed,
                    None => out.push((i, absorbed)),
                }
            }
            rows.push(out);
        }
        changed.then(|| MixingPlan::from_rows(rows, self.kind))
    }

    /// Is the plan doubly stochastic to tolerance `tol`?
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let mut col_sums = vec![0.0f64; self.n];
        for i in 0..self.n {
            let mut rsum = 0.0;
            for (j, w) in self.row_entries(i) {
                if w < -tol {
                    return false;
                }
                rsum += w;
                col_sums[j] += w;
            }
            if (rsum - 1.0).abs() > tol {
                return false;
            }
        }
        col_sums.iter().all(|c| (c - 1.0).abs() <= tol)
    }
}

/// Distinct communication partners per node as a flat CSR, matching
/// [`crate::topology::weight::max_comm_degree`]'s notion on the dense
/// form: `j` is a partner of `i` iff `w_ij ≠ 0` or `w_ji ≠ 0`, `i ≠ j`.
/// Ascending and deduplicated within each segment.
///
/// Two passes over the nonzeros (count, scatter) into one flat
/// adjacency array with possible duplicates (an edge stored in both
/// directions appears twice), then per-segment sort + dedup with
/// in-place compaction — `O(n + nnz log max_deg)` time, `O(n + nnz)`
/// memory, zero per-node allocations.
fn partner_csr(n: usize, row_ptr: &[u32], cols: &[u32], w: &[f64]) -> (Vec<u32>, Vec<u32>) {
    // Pass 1: directed-degree counts (duplicates included).
    let mut ptr = vec![0u32; n + 1];
    for i in 0..n {
        for t in row_ptr[i] as usize..row_ptr[i + 1] as usize {
            let j = cols[t] as usize;
            if j != i && w[t] != 0.0 {
                ptr[i + 1] += 1;
                ptr[j + 1] += 1;
            }
        }
    }
    for u in 0..n {
        ptr[u + 1] += ptr[u];
    }
    // Pass 2: scatter both directions of every stored edge.
    let mut adj = vec![0u32; ptr[n] as usize];
    let mut cursor: Vec<u32> = ptr[..n].to_vec();
    for i in 0..n {
        for t in row_ptr[i] as usize..row_ptr[i + 1] as usize {
            let j = cols[t] as usize;
            if j != i && w[t] != 0.0 {
                adj[cursor[i] as usize] = j as u32;
                cursor[i] += 1;
                adj[cursor[j] as usize] = i as u32;
                cursor[j] += 1;
            }
        }
    }
    // Sort + dedup each segment, compacting in place (the write cursor
    // never catches up with the segment being read).
    let mut out_ptr = vec![0u32; n + 1];
    let mut write = 0usize;
    for u in 0..n {
        let (s, e) = (ptr[u] as usize, ptr[u + 1] as usize);
        adj[s..e].sort_unstable();
        let mut prev = u32::MAX;
        for t in s..e {
            let v = adj[t];
            if v != prev {
                adj[write] = v;
                write += 1;
                prev = v;
            }
        }
        out_ptr[u + 1] = write as u32;
    }
    adj.truncate(write);
    (out_ptr, adj)
}

/// Exact structural symmetry on CSR arrays: every stored `(i, j, w)`
/// has a matching `(j, i, w)` (bitwise-equal weight, mirroring
/// `Matrix::is_symmetric(0.0)` on the dense form).
fn csr_symmetric(n: usize, row_ptr: &[u32], cols: &[u32], w: &[f64]) -> bool {
    let lookup = |i: usize, j: u32| -> Option<f64> {
        let (s, e) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
        cols[s..e].binary_search(&j).ok().map(|p| w[s + p])
    };
    (0..n).all(|i| {
        (row_ptr[i] as usize..row_ptr[i + 1] as usize)
            .all(|t| lookup(cols[t] as usize, i as u32) == Some(w[t]))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::{one_peer_exp_weights, static_exp_weights};

    #[test]
    fn from_dense_roundtrips_to_dense() {
        for w in [static_exp_weights(9), one_peer_exp_weights(8, 1), Matrix::averaging(5)] {
            let plan = MixingPlan::from_dense(&w);
            assert_eq!(plan.to_dense(), w);
        }
    }

    #[test]
    fn metadata_matches_dense_queries() {
        let w = static_exp_weights(16);
        let plan = MixingPlan::from_dense(&w);
        assert_eq!(plan.max_degree, crate::topology::weight::max_comm_degree(&w));
        assert_eq!(plan.symmetric, w.is_symmetric(0.0));
        assert!(!plan.symmetric, "static exp is asymmetric for n > 2");
        let j = MixingPlan::averaging(6);
        assert!(j.symmetric);
        assert_eq!(j.max_degree, 5);
        assert_eq!(j.kind, Some(TopologyKind::FullyConnected));
    }

    #[test]
    fn matvec_matches_dense_matvec() {
        let w = static_exp_weights(12);
        let plan = MixingPlan::from_dense(&w);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let sparse = plan.matvec(&x);
        let dense = w.matvec(&x);
        for (a, b) in sparse.iter().zip(dense.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn matvec_into_is_bitwise_matvec() {
        let plan = MixingPlan::from_dense(&static_exp_weights(17));
        let x: Vec<f64> = (0..17).map(|i| (i as f64 * 0.7).cos()).collect();
        let a = plan.matvec(&x);
        let mut b = vec![0.0f64; 17];
        plan.matvec_into(&x, &mut b);
        for (u, v) in a.iter().zip(b.iter()) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn doubly_stochastic_check() {
        assert!(MixingPlan::averaging(7).is_doubly_stochastic(1e-12));
        let mut rows = MixingPlan::averaging(3).rows_vec();
        rows[0][0].1 = 0.9;
        let bad = MixingPlan::from_rows(rows, None);
        assert!(!bad.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn builder_streaming_equals_from_rows() {
        // The streaming path and the per-row-list adapter build the
        // identical plan (full struct equality: CSR arrays, partners,
        // metadata) — including out-of-order (wrap-around) rows.
        let n = 24usize;
        let rows: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|i| {
                vec![
                    ((i + 5) % n, 0.25),
                    (i, 0.5),
                    ((i + 1) % n, 0.25),
                ]
            })
            .collect();
        let via_rows = MixingPlan::from_rows(rows.clone(), Some(TopologyKind::Ring));
        let mut b = PlanBuilder::new(n, 3 * n);
        for row in &rows {
            for &(j, w) in row {
                b.push(j, w);
            }
            b.finish_row();
        }
        let streamed = b.finish(Some(TopologyKind::Ring));
        assert_eq!(streamed, via_rows);
    }

    #[test]
    fn partner_segments_match_brute_force_union() {
        for w in [static_exp_weights(16), static_exp_weights(9), one_peer_exp_weights(12, 1)] {
            let plan = MixingPlan::from_dense(&w);
            let n = plan.n;
            for u in 0..n {
                let mut want: Vec<u32> = (0..n)
                    .filter(|&v| v != u && (w[(u, v)] != 0.0 || w[(v, u)] != 0.0))
                    .map(|v| v as u32)
                    .collect();
                want.sort_unstable();
                assert_eq!(plan.partners(u), &want[..], "node {u}");
            }
        }
    }

    #[test]
    fn degrade_none_when_no_fault_fires() {
        let plan = MixingPlan::from_dense(&static_exp_weights(16));
        let offline = vec![false; 16];
        assert!(plan.degrade(&offline, |_, _| false).is_none());
        assert!(plan.degrade_reference(&offline, |_, _| false).is_none());
    }

    #[test]
    fn degrade_folds_lost_mass_into_diagonal() {
        let plan = MixingPlan::from_dense(&one_peer_exp_weights(8, 0));
        let offline = vec![false; 8];
        // Drop the {0, 1} exchange: rows 0 and 7 lose their partner.
        let d = plan
            .degrade(&offline, |a, b| (a.min(b), a.max(b)) == (0, 1))
            .expect("a drop must degrade");
        let drows = d.rows_vec();
        assert_eq!(drows[0], vec![(0, 1.0)]);
        // Row 1 pulls from node 2, which was not dropped.
        assert_eq!(drows[1], plan.rows_vec()[1]);
        for (i, row) in drows.iter().enumerate() {
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
        assert_eq!(d.kind, plan.kind);
    }

    #[test]
    fn degrade_offline_node_keeps_only_itself() {
        let plan = MixingPlan::from_dense(&static_exp_weights(8));
        let mut offline = vec![false; 8];
        offline[3] = true;
        let d = plan.degrade(&offline, |_, _| false).expect("offline degrades");
        let drows = d.rows_vec();
        assert_eq!(drows[3], vec![(3, 1.0)]);
        for (i, row) in drows.iter().enumerate() {
            assert!(i == 3 || row.iter().all(|&(j, _)| j != 3), "row {i} still reads node 3");
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn degrade_csr_direct_matches_reference_bitwise() {
        // The CSR-direct degrade and the rows_vec→from_rows reference
        // build the identical struct (PartialEq covers the CSR arrays,
        // the filtered partner lists, max_degree, and symmetry) across
        // plans with and without diagonals, offline nodes, and drops.
        let perm = MixingPlan::from_rows(
            (0..6).map(|i| vec![((i + 1) % 6, 1.0)]).collect(),
            None,
        );
        let plans = [
            MixingPlan::from_dense(&static_exp_weights(16)),
            MixingPlan::from_dense(&one_peer_exp_weights(8, 1)),
            MixingPlan::averaging(7),
            perm,
        ];
        for (p, plan) in plans.iter().enumerate() {
            let n = plan.n;
            let mut offline = vec![false; n];
            offline[1] = true;
            let hash_drop = |a: usize, b: usize| (a.min(b) * 31 + a.max(b) * 17) % 3 == 0;
            for (o, d) in [
                (vec![false; n], true),
                (offline.clone(), false),
                (offline, true),
            ] {
                let drop_fn = |a: usize, b: usize| d && hash_drop(a, b);
                let fast = plan.degrade(&o, drop_fn);
                let slow = plan.degrade_reference(&o, drop_fn);
                assert_eq!(fast, slow, "plan {p}");
                if let Some(fast) = fast {
                    // The absorbing diagonal lands at its sorted
                    // position even when the original row had none.
                    for i in 0..n {
                        let r = fast.row(i);
                        assert!(r.cols.windows(2).all(|c| c[0] < c[1]), "plan {p} row {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn from_rows_sorts_and_counts() {
        let plan = MixingPlan::from_rows(
            vec![vec![(1, 0.5), (0, 0.5)], vec![(0, 0.5), (1, 0.5)]],
            None,
        );
        assert_eq!(plan.rows_vec()[0], vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(plan.max_degree, 1);
        assert!(plan.symmetric);
        assert_eq!(plan.nnz(), 4);
    }

    #[test]
    fn csr_layout_is_consistent() {
        // The CSR arrays are parallel, rows are contiguous and ascending,
        // and the cached f32 weights are exactly the f64 weights cast
        // once (what the kernels rely on).
        let plan = MixingPlan::from_dense(&static_exp_weights(16));
        let mut total = 0usize;
        for i in 0..plan.n {
            let row = plan.row(i);
            assert_eq!(row.cols.len(), row.w64.len());
            assert_eq!(row.cols.len(), row.w32.len());
            assert_eq!(row.len(), plan.row_len(i));
            assert!(row.cols.windows(2).all(|p| p[0] < p[1]), "row {i} not ascending");
            for t in 0..row.len() {
                assert_eq!(row.w32[t].to_bits(), (row.w64[t] as f32).to_bits());
            }
            total += row.len();
        }
        assert_eq!(total, plan.nnz());
        assert!(plan.state_bytes() >= plan.nnz() * 16 + (plan.n + 1) * 8);
    }

    #[test]
    fn empty_rows_are_representable() {
        // A row with no nonzeros must survive the CSR flattening (the
        // kernels zero such output rows).
        let plan = MixingPlan::from_rows(vec![vec![(0, 1.0)], vec![], vec![(2, 1.0)]], None);
        assert_eq!(plan.row_len(1), 0);
        assert!(plan.row(1).is_empty());
        assert!(plan.rows_vec()[1].is_empty());
        assert_eq!(plan.nnz(), 2);
        assert!(plan.partners(1).is_empty());
    }
}
