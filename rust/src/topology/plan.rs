//! `MixingPlan` — the canonical sparse-first representation of a mixing
//! matrix `W^{(k)}`.
//!
//! The paper's whole point is that exponential graphs need only
//! `O(log n)` (static) or `O(1)` (one-peer) neighbors per node, so the
//! training path never materializes a dense `n × n` matrix: every
//! topology has a *direct sparse constructor* (neighbor lists + per-edge
//! weights), and [`crate::topology::schedule::Schedule::plan_at`] hands
//! out cached borrowed plans. Dense [`Matrix`] form survives only behind
//! the [`MixingPlan::to_dense`] escape hatch for spectral analysis
//! (eigen/ρ computations) and tests. See docs/DESIGN.md §Plan cache.
//!
//! The mixing kernels (`mix`, `mix_dmsgd`) that consume a plan live in
//! [`crate::coordinator::mixing`]; this module owns construction and
//! structural metadata (`max_degree`, symmetry, originating
//! [`TopologyKind`]).

use super::TopologyKind;
use crate::linalg::Matrix;

/// Sparse row-major mixing matrix plus structural metadata.
///
/// Row `i` holds the sorted `(j, w_ij)` nonzeros of `W`'s row `i` in
/// `f64` (weights are exact rationals like `1/(τ+1)`; keeping them in
/// `f64` preserves the exact-averaging property of Lemma 1 for the
/// consensus simulations — the `f32` cast happens once per nonzero inside
/// the training kernels).
#[derive(Clone, Debug, PartialEq)]
pub struct MixingPlan {
    /// Number of nodes (rows).
    pub n: usize,
    /// For each output row `i`: the `(j, w_ij)` of its nonzero entries,
    /// sorted by `j`.
    pub rows: Vec<Vec<(usize, f64)>>,
    /// For each node, its *distinct* off-diagonal communication
    /// partners (union of in- and out-neighbors), ascending. Built once
    /// at construction; [`crate::netsim`] walks these lists directly
    /// every simulated round instead of re-deriving them.
    pub partners: Vec<Vec<usize>>,
    /// Max over nodes of the number of distinct partners (the longest
    /// `partners` list) — the paper's per-iteration communication
    /// degree.
    pub max_degree: usize,
    /// Is `W` exactly symmetric? (What D²/Exact-Diffusion require.)
    pub symmetric: bool,
    /// The topology this plan was built from, when known.
    pub kind: Option<TopologyKind>,
}

impl MixingPlan {
    /// Build a plan from per-row nonzero lists. Rows are sorted by column
    /// index; `max_degree` and symmetry are derived from the structure in
    /// `O(nnz log nnz)`. Deterministic schedules pay this once at cache
    /// build; stochastic schedules (random matching, sampled one-peer)
    /// pay it per draw — if that ever shows up in a profile, give the
    /// matching/one-peer constructors a variant taking their analytic
    /// metadata (degree 1–2, symmetry by `n | 2·hop`) instead.
    pub fn from_rows(mut rows: Vec<Vec<(usize, f64)>>, kind: Option<TopologyKind>) -> MixingPlan {
        for row in rows.iter_mut() {
            row.sort_unstable_by_key(|e| e.0);
        }
        let n = rows.len();
        let partners = partner_lists(&rows);
        let max_degree = partners.iter().map(Vec::len).max().unwrap_or(0);
        let symmetric = rows_symmetric(&rows);
        MixingPlan { n, rows, partners, max_degree, symmetric, kind }
    }

    /// Tag the plan with its originating topology kind.
    pub fn with_kind(mut self, kind: TopologyKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Convert from a dense weight matrix, dropping exact zeros. This is
    /// the legacy path — kept for tests, ad-hoc matrices, and as the
    /// reference the direct constructors are property-tested against.
    pub fn from_dense(w: &Matrix) -> MixingPlan {
        let n = w.rows();
        assert_eq!(n, w.cols(), "mixing matrix must be square");
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::new();
            for j in 0..n {
                let v = w[(i, j)];
                if v != 0.0 {
                    row.push((j, v));
                }
            }
            rows.push(row);
        }
        MixingPlan::from_rows(rows, None)
    }

    /// The exact-averaging plan `J = 11ᵀ/n` (parallel SGD baseline).
    pub fn averaging(n: usize) -> MixingPlan {
        let w = 1.0 / n as f64;
        let rows = (0..n).map(|_| (0..n).map(|j| (j, w)).collect()).collect();
        MixingPlan::from_rows(rows, Some(TopologyKind::FullyConnected))
    }

    /// Dense escape hatch for spectral analysis (eigen/ρ) and tests —
    /// never called on the training path.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for (i, row) in self.rows.iter().enumerate() {
            for &(j, w) in row {
                m[(i, j)] = w;
            }
        }
        m
    }

    /// Total number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Sparse matrix-vector product `W x` in `f64` (the consensus/gossip
    /// simulation path). Accumulates in ascending-`j` order, matching the
    /// dense [`Matrix::matvec`] bit-for-bit on the stored nonzeros.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "matvec dimension mismatch");
        self.rows
            .iter()
            .map(|row| row.iter().map(|&(j, w)| w * x[j]).sum())
            .collect()
    }

    /// Fault-renormalized copy of the plan (the network simulator's
    /// degraded-plan rule, docs/DESIGN.md §NetSim): an `offline` node
    /// keeps only itself (`row u = {(u, 1)}`), and in every online row
    /// `i` each off-diagonal entry `(j, w)` whose message was lost
    /// (`offline[j]` or `dropped(i, j)`) is folded into the diagonal —
    /// the self-weight absorbs the lost mass, so each row's sum is
    /// preserved (row-stochasticity survives any fault pattern).
    ///
    /// `dropped` must be symmetric in its arguments for symmetric input
    /// plans to stay symmetric (the simulator drops per unordered
    /// pair). Returns `None` when no entry changed, so fault-free
    /// rounds keep borrowing the original plan bit-for-bit.
    pub fn degrade(
        &self,
        offline: &[bool],
        mut dropped: impl FnMut(usize, usize) -> bool,
    ) -> Option<MixingPlan> {
        assert_eq!(offline.len(), self.n, "offline mask dimension mismatch");
        let mut changed = false;
        let mut rows = Vec::with_capacity(self.n);
        for (i, row) in self.rows.iter().enumerate() {
            if offline[i] {
                if row.len() != 1 || row[0] != (i, 1.0) {
                    changed = true;
                }
                rows.push(vec![(i, 1.0)]);
                continue;
            }
            let mut out = Vec::with_capacity(row.len());
            let mut absorbed = 0.0f64;
            let mut diag = None;
            for &(j, w) in row {
                if j != i && (offline[j] || dropped(i, j)) {
                    absorbed += w;
                    changed = true;
                } else {
                    if j == i {
                        diag = Some(out.len());
                    }
                    out.push((j, w));
                }
            }
            if absorbed != 0.0 {
                match diag {
                    Some(p) => out[p].1 += absorbed,
                    None => out.push((i, absorbed)),
                }
            }
            rows.push(out);
        }
        changed.then(|| MixingPlan::from_rows(rows, self.kind))
    }

    /// Is the plan doubly stochastic to tolerance `tol`?
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let mut col_sums = vec![0.0f64; self.n];
        for row in &self.rows {
            let mut rsum = 0.0;
            for &(j, w) in row {
                if w < -tol {
                    return false;
                }
                rsum += w;
                col_sums[j] += w;
            }
            if (rsum - 1.0).abs() > tol {
                return false;
            }
        }
        col_sums.iter().all(|c| (c - 1.0).abs() <= tol)
    }
}

/// Distinct communication partners per node, matching
/// [`crate::topology::weight::max_comm_degree`]'s notion on the dense
/// form: `j` is a partner of `i` iff `w_ij ≠ 0` or `w_ji ≠ 0`, `i ≠ j`.
/// Ascending and deduplicated; the longest list is `max_degree`.
fn partner_lists(rows: &[Vec<(usize, f64)>]) -> Vec<Vec<usize>> {
    let n = rows.len();
    let mut partners: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, row) in rows.iter().enumerate() {
        for &(j, w) in row {
            if i != j && w != 0.0 {
                partners[i].push(j);
                partners[j].push(i);
            }
        }
    }
    for p in partners.iter_mut() {
        p.sort_unstable();
        p.dedup();
    }
    partners
}

/// Exact structural symmetry: every stored `(i, j, w)` has a matching
/// `(j, i, w)` (bitwise-equal weight, mirroring
/// `Matrix::is_symmetric(0.0)` on the dense form).
fn rows_symmetric(rows: &[Vec<(usize, f64)>]) -> bool {
    let lookup = |i: usize, j: usize| -> Option<f64> {
        let row = &rows[i];
        row.binary_search_by_key(&j, |e| e.0).ok().map(|p| row[p].1)
    };
    rows.iter()
        .enumerate()
        .all(|(i, row)| row.iter().all(|&(j, w)| lookup(j, i) == Some(w)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::{one_peer_exp_weights, static_exp_weights};

    #[test]
    fn from_dense_roundtrips_to_dense() {
        for w in [static_exp_weights(9), one_peer_exp_weights(8, 1), Matrix::averaging(5)] {
            let plan = MixingPlan::from_dense(&w);
            assert_eq!(plan.to_dense(), w);
        }
    }

    #[test]
    fn metadata_matches_dense_queries() {
        let w = static_exp_weights(16);
        let plan = MixingPlan::from_dense(&w);
        assert_eq!(plan.max_degree, crate::topology::weight::max_comm_degree(&w));
        assert_eq!(plan.symmetric, w.is_symmetric(0.0));
        assert!(!plan.symmetric, "static exp is asymmetric for n > 2");
        let j = MixingPlan::averaging(6);
        assert!(j.symmetric);
        assert_eq!(j.max_degree, 5);
        assert_eq!(j.kind, Some(TopologyKind::FullyConnected));
    }

    #[test]
    fn matvec_matches_dense_matvec() {
        let w = static_exp_weights(12);
        let plan = MixingPlan::from_dense(&w);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let sparse = plan.matvec(&x);
        let dense = w.matvec(&x);
        for (a, b) in sparse.iter().zip(dense.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn doubly_stochastic_check() {
        assert!(MixingPlan::averaging(7).is_doubly_stochastic(1e-12));
        let mut bad = MixingPlan::averaging(3);
        bad.rows[0][0].1 = 0.9;
        assert!(!bad.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn degrade_none_when_no_fault_fires() {
        let plan = MixingPlan::from_dense(&static_exp_weights(16));
        let offline = vec![false; 16];
        assert!(plan.degrade(&offline, |_, _| false).is_none());
    }

    #[test]
    fn degrade_folds_lost_mass_into_diagonal() {
        let plan = MixingPlan::from_dense(&one_peer_exp_weights(8, 0));
        let offline = vec![false; 8];
        // Drop the {0, 1} exchange: rows 0 and 7 lose their partner.
        let d = plan
            .degrade(&offline, |a, b| (a.min(b), a.max(b)) == (0, 1))
            .expect("a drop must degrade");
        assert_eq!(d.rows[0], vec![(0, 1.0)]);
        // Row 1 pulls from node 2, which was not dropped.
        assert_eq!(d.rows[1], plan.rows[1]);
        for (i, row) in d.rows.iter().enumerate() {
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
        assert_eq!(d.kind, plan.kind);
    }

    #[test]
    fn degrade_offline_node_keeps_only_itself() {
        let plan = MixingPlan::from_dense(&static_exp_weights(8));
        let mut offline = vec![false; 8];
        offline[3] = true;
        let d = plan.degrade(&offline, |_, _| false).expect("offline degrades");
        assert_eq!(d.rows[3], vec![(3, 1.0)]);
        for (i, row) in d.rows.iter().enumerate() {
            assert!(i == 3 || row.iter().all(|&(j, _)| j != 3), "row {i} still reads node 3");
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn from_rows_sorts_and_counts() {
        let plan = MixingPlan::from_rows(
            vec![vec![(1, 0.5), (0, 0.5)], vec![(0, 0.5), (1, 0.5)]],
            None,
        );
        assert_eq!(plan.rows[0], vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(plan.max_degree, 1);
        assert!(plan.symmetric);
        assert_eq!(plan.nnz(), 4);
    }
}
