//! `MixingPlan` — the canonical sparse-first representation of a mixing
//! matrix `W^{(k)}`.
//!
//! The paper's whole point is that exponential graphs need only
//! `O(log n)` (static) or `O(1)` (one-peer) neighbors per node, so the
//! training path never materializes a dense `n × n` matrix: every
//! topology has a *direct sparse constructor* (neighbor lists + per-edge
//! weights), and [`crate::topology::schedule::Schedule::plan_at`] hands
//! out cached borrowed plans. Dense [`Matrix`] form survives only behind
//! the [`MixingPlan::to_dense`] escape hatch for spectral analysis
//! (eigen/ρ computations) and tests. See docs/DESIGN.md §Plan cache.
//!
//! The mixing kernels (`mix`, `mix_dmsgd`) that consume a plan live in
//! [`crate::coordinator::mixing`]; this module owns construction and
//! structural metadata (`max_degree`, symmetry, originating
//! [`TopologyKind`]).

use super::TopologyKind;
use crate::linalg::Matrix;

/// Sparse row-major mixing matrix plus structural metadata.
///
/// Row `i` holds the sorted `(j, w_ij)` nonzeros of `W`'s row `i` in
/// `f64` (weights are exact rationals like `1/(τ+1)`; keeping them in
/// `f64` preserves the exact-averaging property of Lemma 1 for the
/// consensus simulations — the `f32` cast happens once per nonzero inside
/// the training kernels).
#[derive(Clone, Debug, PartialEq)]
pub struct MixingPlan {
    /// Number of nodes (rows).
    pub n: usize,
    /// For each output row `i`: the `(j, w_ij)` of its nonzero entries,
    /// sorted by `j`.
    pub rows: Vec<Vec<(usize, f64)>>,
    /// Max over nodes of the number of *distinct* off-diagonal partners
    /// (union of in- and out-neighbors) — the paper's per-iteration
    /// communication degree.
    pub max_degree: usize,
    /// Is `W` exactly symmetric? (What D²/Exact-Diffusion require.)
    pub symmetric: bool,
    /// The topology this plan was built from, when known.
    pub kind: Option<TopologyKind>,
}

impl MixingPlan {
    /// Build a plan from per-row nonzero lists. Rows are sorted by column
    /// index; `max_degree` and symmetry are derived from the structure in
    /// `O(nnz log nnz)`. Deterministic schedules pay this once at cache
    /// build; stochastic schedules (random matching, sampled one-peer)
    /// pay it per draw — if that ever shows up in a profile, give the
    /// matching/one-peer constructors a variant taking their analytic
    /// metadata (degree 1–2, symmetry by `n | 2·hop`) instead.
    pub fn from_rows(mut rows: Vec<Vec<(usize, f64)>>, kind: Option<TopologyKind>) -> MixingPlan {
        for row in rows.iter_mut() {
            row.sort_unstable_by_key(|e| e.0);
        }
        let n = rows.len();
        let max_degree = union_max_degree(&rows);
        let symmetric = rows_symmetric(&rows);
        MixingPlan { n, rows, max_degree, symmetric, kind }
    }

    /// Tag the plan with its originating topology kind.
    pub fn with_kind(mut self, kind: TopologyKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Convert from a dense weight matrix, dropping exact zeros. This is
    /// the legacy path — kept for tests, ad-hoc matrices, and as the
    /// reference the direct constructors are property-tested against.
    pub fn from_dense(w: &Matrix) -> MixingPlan {
        let n = w.rows();
        assert_eq!(n, w.cols(), "mixing matrix must be square");
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::new();
            for j in 0..n {
                let v = w[(i, j)];
                if v != 0.0 {
                    row.push((j, v));
                }
            }
            rows.push(row);
        }
        MixingPlan::from_rows(rows, None)
    }

    /// The exact-averaging plan `J = 11ᵀ/n` (parallel SGD baseline).
    pub fn averaging(n: usize) -> MixingPlan {
        let w = 1.0 / n as f64;
        let rows = (0..n).map(|_| (0..n).map(|j| (j, w)).collect()).collect();
        MixingPlan::from_rows(rows, Some(TopologyKind::FullyConnected))
    }

    /// Dense escape hatch for spectral analysis (eigen/ρ) and tests —
    /// never called on the training path.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for (i, row) in self.rows.iter().enumerate() {
            for &(j, w) in row {
                m[(i, j)] = w;
            }
        }
        m
    }

    /// Total number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Sparse matrix-vector product `W x` in `f64` (the consensus/gossip
    /// simulation path). Accumulates in ascending-`j` order, matching the
    /// dense [`Matrix::matvec`] bit-for-bit on the stored nonzeros.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "matvec dimension mismatch");
        self.rows
            .iter()
            .map(|row| row.iter().map(|&(j, w)| w * x[j]).sum())
            .collect()
    }

    /// Is the plan doubly stochastic to tolerance `tol`?
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let mut col_sums = vec![0.0f64; self.n];
        for row in &self.rows {
            let mut rsum = 0.0;
            for &(j, w) in row {
                if w < -tol {
                    return false;
                }
                rsum += w;
                col_sums[j] += w;
            }
            if (rsum - 1.0).abs() > tol {
                return false;
            }
        }
        col_sums.iter().all(|c| (c - 1.0).abs() <= tol)
    }
}

/// Max over nodes of distinct communication partners, matching
/// [`crate::topology::weight::max_comm_degree`] on the dense form:
/// `j` is a partner of `i` iff `w_ij ≠ 0` or `w_ji ≠ 0`, `i ≠ j`.
fn union_max_degree(rows: &[Vec<(usize, f64)>]) -> usize {
    let n = rows.len();
    let mut partners: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, row) in rows.iter().enumerate() {
        for &(j, w) in row {
            if i != j && w != 0.0 {
                partners[i].push(j);
                partners[j].push(i);
            }
        }
    }
    partners
        .iter_mut()
        .map(|p| {
            p.sort_unstable();
            p.dedup();
            p.len()
        })
        .max()
        .unwrap_or(0)
}

/// Exact structural symmetry: every stored `(i, j, w)` has a matching
/// `(j, i, w)` (bitwise-equal weight, mirroring
/// `Matrix::is_symmetric(0.0)` on the dense form).
fn rows_symmetric(rows: &[Vec<(usize, f64)>]) -> bool {
    let lookup = |i: usize, j: usize| -> Option<f64> {
        let row = &rows[i];
        row.binary_search_by_key(&j, |e| e.0).ok().map(|p| row[p].1)
    };
    rows.iter()
        .enumerate()
        .all(|(i, row)| row.iter().all(|&(j, w)| lookup(j, i) == Some(w)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::{one_peer_exp_weights, static_exp_weights};

    #[test]
    fn from_dense_roundtrips_to_dense() {
        for w in [static_exp_weights(9), one_peer_exp_weights(8, 1), Matrix::averaging(5)] {
            let plan = MixingPlan::from_dense(&w);
            assert_eq!(plan.to_dense(), w);
        }
    }

    #[test]
    fn metadata_matches_dense_queries() {
        let w = static_exp_weights(16);
        let plan = MixingPlan::from_dense(&w);
        assert_eq!(plan.max_degree, crate::topology::weight::max_comm_degree(&w));
        assert_eq!(plan.symmetric, w.is_symmetric(0.0));
        assert!(!plan.symmetric, "static exp is asymmetric for n > 2");
        let j = MixingPlan::averaging(6);
        assert!(j.symmetric);
        assert_eq!(j.max_degree, 5);
        assert_eq!(j.kind, Some(TopologyKind::FullyConnected));
    }

    #[test]
    fn matvec_matches_dense_matvec() {
        let w = static_exp_weights(12);
        let plan = MixingPlan::from_dense(&w);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let sparse = plan.matvec(&x);
        let dense = w.matvec(&x);
        for (a, b) in sparse.iter().zip(dense.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn doubly_stochastic_check() {
        assert!(MixingPlan::averaging(7).is_doubly_stochastic(1e-12));
        let mut bad = MixingPlan::averaging(3);
        bad.rows[0][0].1 = 0.9;
        assert!(!bad.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn from_rows_sorts_and_counts() {
        let plan = MixingPlan::from_rows(
            vec![vec![(1, 0.5), (0, 0.5)], vec![(0, 0.5), (1, 0.5)]],
            None,
        );
        assert_eq!(plan.rows[0], vec![(0, 0.5), (1, 0.5)]);
        assert_eq!(plan.max_degree, 1);
        assert!(plan.symmetric);
        assert_eq!(plan.nnz(), 4);
    }
}
