//! Bipartite random match graph (Appendix A.3.1): at each iteration a
//! uniformly random perfect matching pairs the nodes; matched pairs average
//! ½–½. Each node communicates with exactly one peer per iteration, like
//! the one-peer exponential graph — but without the periodic
//! exact-averaging property (Fig. 4).

use super::plan::MixingPlan;
use super::TopologyKind;
use crate::linalg::Matrix;
use crate::util::rng::Pcg;

/// Stateful generator of random-matching weight matrices.
#[derive(Clone, Debug)]
pub struct RandomMatching {
    n: usize,
    rng: Pcg,
}

impl RandomMatching {
    pub fn new(n: usize, seed: u64) -> Self {
        RandomMatching { n, rng: Pcg::new(seed, 0xA7C) }
    }

    /// Sample the next matching's weight matrix. For odd `n` one node is
    /// left unmatched (self-weight 1).
    pub fn next_weights(&mut self) -> Matrix {
        let n = self.n;
        let perm = self.rng.permutation(n);
        let mut w = Matrix::zeros(n, n);
        let pairs = n / 2;
        for p in 0..pairs {
            let a = perm[2 * p];
            let b = perm[2 * p + 1];
            w[(a, a)] = 0.5;
            w[(b, b)] = 0.5;
            w[(a, b)] = 0.5;
            w[(b, a)] = 0.5;
        }
        if n % 2 == 1 {
            let lone = perm[n - 1];
            w[(lone, lone)] = 1.0;
        }
        w
    }

    /// Sample the next matching directly as a sparse plan — two nonzeros
    /// per matched row, one for the odd-n leftover — consuming the RNG
    /// exactly like [`RandomMatching::next_weights`] (same seed ⇒ same
    /// sequence of matchings on either path).
    pub fn next_plan(&mut self) -> MixingPlan {
        let n = self.n;
        let perm = self.rng.permutation(n);
        let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for p in 0..n / 2 {
            let a = perm[2 * p];
            let b = perm[2 * p + 1];
            rows[a] = vec![(a, 0.5), (b, 0.5)];
            rows[b] = vec![(a, 0.5), (b, 0.5)];
        }
        if n % 2 == 1 {
            let lone = perm[n - 1];
            rows[lone] = vec![(lone, 1.0)];
        }
        MixingPlan::from_rows(rows, Some(TopologyKind::RandomMatch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::weight::{is_doubly_stochastic, max_comm_degree};

    #[test]
    fn matchings_are_doubly_stochastic_symmetric_degree_1() {
        let mut m = RandomMatching::new(16, 3);
        for _ in 0..20 {
            let w = m.next_weights();
            assert!(is_doubly_stochastic(&w, 1e-12));
            assert!(w.is_symmetric(0.0));
            assert_eq!(max_comm_degree(&w), 1);
        }
    }

    #[test]
    fn odd_n_leaves_one_self_loop() {
        let mut m = RandomMatching::new(7, 9);
        let w = m.next_weights();
        assert!(is_doubly_stochastic(&w, 1e-12));
        let lones = (0..7).filter(|&i| (w[(i, i)] - 1.0).abs() < 1e-15).count();
        assert_eq!(lones, 1);
    }

    #[test]
    fn matchings_vary_over_time() {
        let mut m = RandomMatching::new(8, 5);
        let a = m.next_weights();
        let mut differs = false;
        for _ in 0..10 {
            if m.next_weights() != a {
                differs = true;
                break;
            }
        }
        assert!(differs, "matching never changed over 10 draws");
    }

    #[test]
    fn plan_matches_dense_builder_draw_for_draw() {
        for n in [7usize, 8, 16] {
            let mut dense = RandomMatching::new(n, 21);
            let mut sparse = RandomMatching::new(n, 21);
            for draw in 0..6 {
                let want = MixingPlan::from_dense(&dense.next_weights());
                let got = sparse.next_plan();
                assert_eq!(got.rows_vec(), want.rows_vec(), "n={n} draw={draw}");
                assert_eq!(got.max_degree, want.max_degree, "n={n} draw={draw}");
                assert!(got.symmetric, "n={n} draw={draw}");
            }
        }
    }

    #[test]
    fn matching_squares_to_projection() {
        // A ½–½ matching matrix is idempotent: W² = W.
        let mut m = RandomMatching::new(12, 11);
        let w = m.next_weights();
        assert!(w.matmul(&w).sub(&w).max_abs() < 1e-12);
    }
}
