//! Metropolis–Hastings weights for undirected graphs ([43, Eq. (8)]):
//!
//! `w_ij = 1 / (1 + max(d_i, d_j))` for edges `{i,j}`,
//! `w_ii = 1 − Σ_{j≠i} w_ij`.
//!
//! The result is symmetric and doubly stochastic for any undirected graph,
//! which is how the paper weights ring, star, grid, torus, and the ER /
//! geometric random graphs.

use super::graphs::Graph;
use crate::linalg::Matrix;

/// Build the Metropolis weight matrix of an undirected graph.
pub fn metropolis_weights(g: &Graph) -> Matrix {
    let n = g.n();
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in g.neighbors(i) {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w[(i, j)] = wij;
            diag -= wij;
        }
        w[(i, i)] = diag;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::graphs;
    use crate::topology::weight::is_doubly_stochastic;

    #[test]
    fn metropolis_is_doubly_stochastic_and_symmetric() {
        for n in [3usize, 5, 8, 16, 31] {
            for g in [graphs::ring(n), graphs::star(n), graphs::grid2d(n), graphs::torus2d(n)] {
                let w = metropolis_weights(&g);
                assert!(is_doubly_stochastic(&w, 1e-12), "n={n}");
                assert!(w.is_symmetric(1e-15), "n={n}");
            }
        }
    }

    #[test]
    fn ring_weights_known_values() {
        // 4-ring: all degrees 2 → edge weight 1/3, diagonal 1/3.
        let w = metropolis_weights(&graphs::ring(4));
        assert!((w[(0, 1)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 0)] - 1.0 / 3.0).abs() < 1e-15);
        assert_eq!(w[(0, 2)], 0.0);
    }

    #[test]
    fn star_hub_diagonal() {
        // Star n=5: hub degree 4, leaves degree 1 → edge weight 1/5.
        let w = metropolis_weights(&graphs::star(5));
        assert!((w[(0, 1)] - 0.2).abs() < 1e-15);
        assert!((w[(0, 0)] - (1.0 - 4.0 * 0.2)).abs() < 1e-15);
        assert!((w[(1, 1)] - 0.8).abs() < 1e-15);
    }
}
