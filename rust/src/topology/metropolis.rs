//! Metropolis–Hastings weights for undirected graphs ([43, Eq. (8)]):
//!
//! `w_ij = 1 / (1 + max(d_i, d_j))` for edges `{i,j}`,
//! `w_ii = 1 − Σ_{j≠i} w_ij`.
//!
//! The result is symmetric and doubly stochastic for any undirected graph,
//! which is how the paper weights ring, star, grid, torus, and the ER /
//! geometric random graphs.

use super::graphs::Graph;
use super::plan::MixingPlan;
use crate::linalg::Matrix;

/// Build the Metropolis weight matrix of an undirected graph.
pub fn metropolis_weights(g: &Graph) -> Matrix {
    let n = g.n();
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in g.neighbors(i) {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w[(i, j)] = wij;
            diag -= wij;
        }
        w[(i, i)] = diag;
    }
    w
}

/// Direct sparse constructor: Metropolis weights straight from the
/// adjacency lists — `O(Σ deg)` work and memory, no dense matrix. The
/// arithmetic mirrors [`metropolis_weights`] operation-for-operation so
/// the resulting plan is bitwise identical to
/// `MixingPlan::from_dense(&metropolis_weights(g))`.
pub fn metropolis_plan(g: &Graph) -> MixingPlan {
    let n = g.n();
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let mut row = Vec::with_capacity(g.degree(i) + 1);
        let mut diag = 1.0;
        for &j in g.neighbors(i) {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            row.push((j, wij));
            diag -= wij;
        }
        // Metropolis diagonals are strictly positive, but keep the exact-
        // zero guard so the plan matches `from_dense` (which drops zeros)
        // for any graph.
        if diag != 0.0 {
            row.push((i, diag));
        }
        rows.push(row);
    }
    MixingPlan::from_rows(rows, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::graphs;
    use crate::topology::weight::is_doubly_stochastic;

    #[test]
    fn metropolis_is_doubly_stochastic_and_symmetric() {
        for n in [3usize, 5, 8, 16, 31] {
            for g in [graphs::ring(n), graphs::star(n), graphs::grid2d(n), graphs::torus2d(n)] {
                let w = metropolis_weights(&g);
                assert!(is_doubly_stochastic(&w, 1e-12), "n={n}");
                assert!(w.is_symmetric(1e-15), "n={n}");
            }
        }
    }

    #[test]
    fn ring_weights_known_values() {
        // 4-ring: all degrees 2 → edge weight 1/3, diagonal 1/3.
        let w = metropolis_weights(&graphs::ring(4));
        assert!((w[(0, 1)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 0)] - 1.0 / 3.0).abs() < 1e-15);
        assert_eq!(w[(0, 2)], 0.0);
    }

    #[test]
    fn plan_matches_dense_for_classic_graphs() {
        for n in [2usize, 3, 5, 8, 16, 31] {
            for g in [graphs::ring(n), graphs::star(n), graphs::grid2d(n), graphs::torus2d(n)] {
                let want = MixingPlan::from_dense(&metropolis_weights(&g));
                let got = metropolis_plan(&g);
                assert_eq!(got.rows_vec(), want.rows_vec(), "n={n}");
                assert_eq!(got.max_degree, want.max_degree, "n={n}");
                assert!(got.symmetric, "Metropolis weights are symmetric (n={n})");
            }
        }
    }

    #[test]
    fn star_hub_diagonal() {
        // Star n=5: hub degree 4, leaves degree 1 → edge weight 1/5.
        let w = metropolis_weights(&graphs::star(5));
        assert!((w[(0, 1)] - 0.2).abs() < 1e-15);
        assert!((w[(0, 0)] - (1.0 - 4.0 * 0.2)).abs() < 1e-15);
        assert!((w[(1, 1)] - 0.8).abs() < 1e-15);
    }
}
