//! Network topologies and their doubly-stochastic weight matrices.
//!
//! This is the paper's object of study. Every topology in the evaluation is
//! implemented:
//!
//! | Topology | Module | Weight rule |
//! |---|---|---|
//! | ring, star, 2D-grid, 2D-torus, hypercube | [`graphs`] | Metropolis ([`metropolis`]) |
//! | ½-random graph | [`random`] | max-degree lazy walk `A/d_max + (I−D/d_max)` |
//! | Erdős–Rényi `G(n,p)`, geometric `G(n,r)` | [`random`] | Metropolis |
//! | bipartite random match | [`matching`] | pairwise ½–½ (time-varying) |
//! | static exponential | [`exponential`] | Eq. (5): circulant `1/(τ+1)` |
//! | one-peer exponential | [`exponential`] | Eq. (7): time-varying ½–½ |
//!
//! [`schedule`] exposes the uniform [`schedule::Schedule`] interface the
//! coordinator consumes: a (possibly time-varying) sequence `W^{(k)}`,
//! represented sparsely as cached [`plan::MixingPlan`]s — every topology
//! has a direct sparse constructor, and the dense [`crate::linalg::Matrix`]
//! form survives only behind `to_dense()` for spectral analysis and tests
//! (docs/DESIGN.md §Plan cache).
//!
//! Dispatch is an **open registry** ([`family`], docs/DESIGN.md
//! §Topology registry): every per-kind behavior (plan construction,
//! analytic degree/ρ, exact-averaging period, cost-model dispatch,
//! config names) is declared once per [`family::TopologyFamily`], and
//! [`finite_time`] extends the zoo with exact-averaging schedules for
//! **arbitrary n** (base-(k+1) after Takezawa et al.; CECA-style
//! one/two-peer after Ding et al.).

pub mod exponential;
pub mod family;
pub mod finite_time;
pub mod graphs;
pub mod hypercube_onepeer;
pub mod matching;
pub mod metropolis;
pub mod plan;
pub mod random;
pub mod schedule;
pub mod weight;

pub use family::{Topology, TopologyFamily};
pub use graphs::Graph;
pub use plan::MixingPlan;
pub use schedule::{Schedule, TopologyKind};
pub use weight::{is_doubly_stochastic, max_comm_degree};
