//! The uniform topology-schedule interface consumed by the coordinator:
//! a (possibly time-varying) sequence of weight matrices `W^{(k)}`.

use super::exponential::{one_peer_exp_weights, static_exp_weights, OnePeerOrder, OnePeerSequence};
use super::graphs;
use super::matching::RandomMatching;
use super::metropolis::metropolis_weights;
use super::random;
use crate::linalg::Matrix;

/// Every topology evaluated in the paper, plus the fully-connected
/// (all-reduce) baseline used by parallel SGD.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    Ring,
    Star,
    Grid2D,
    Torus2D,
    Hypercube,
    /// ½-random graph (each edge with probability ½, max-degree weights).
    HalfRandom,
    /// Erdős–Rényi `G(n, p)` at the connectivity threshold scaling.
    ErdosRenyi,
    /// 2-D geometric random graph.
    Geometric,
    /// Bipartite random match (time-varying).
    RandomMatch,
    /// Static exponential graph (Eq. (5)).
    StaticExp,
    /// One-peer exponential graph, cyclic order (Eq. (7)).
    OnePeerExp,
    /// One-peer exponential, random permutation per period (App. B.3.2).
    OnePeerExpPerm,
    /// One-peer exponential, uniform sampling with replacement (App. B.3.2).
    OnePeerExpUniform,
    /// One-peer hypercube (Remark 6 / future work): symmetric ½–½
    /// matchings along bit-dimensions; exact averaging each τ steps.
    OnePeerHypercube,
    /// Global averaging `J = 11ᵀ/n` every iteration (parallel SGD).
    FullyConnected,
}

impl TopologyKind {
    /// Short machine-readable name (used in CSV output and CLI).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Star => "star",
            TopologyKind::Grid2D => "grid",
            TopologyKind::Torus2D => "torus",
            TopologyKind::Hypercube => "hypercube",
            TopologyKind::HalfRandom => "half_random",
            TopologyKind::ErdosRenyi => "erdos_renyi",
            TopologyKind::Geometric => "geometric",
            TopologyKind::RandomMatch => "random_match",
            TopologyKind::StaticExp => "static_exp",
            TopologyKind::OnePeerExp => "one_peer_exp",
            TopologyKind::OnePeerExpPerm => "one_peer_exp_perm",
            TopologyKind::OnePeerExpUniform => "one_peer_exp_uniform",
            TopologyKind::OnePeerHypercube => "one_peer_hypercube",
            TopologyKind::FullyConnected => "fully_connected",
        }
    }

    /// Parse from the CLI/config name.
    pub fn parse(s: &str) -> Option<TopologyKind> {
        Some(match s {
            "ring" => TopologyKind::Ring,
            "star" => TopologyKind::Star,
            "grid" => TopologyKind::Grid2D,
            "torus" => TopologyKind::Torus2D,
            "hypercube" => TopologyKind::Hypercube,
            "half_random" => TopologyKind::HalfRandom,
            "erdos_renyi" => TopologyKind::ErdosRenyi,
            "geometric" => TopologyKind::Geometric,
            "random_match" => TopologyKind::RandomMatch,
            "static_exp" => TopologyKind::StaticExp,
            "one_peer_exp" => TopologyKind::OnePeerExp,
            "one_peer_exp_perm" => TopologyKind::OnePeerExpPerm,
            "one_peer_exp_uniform" => TopologyKind::OnePeerExpUniform,
            "one_peer_hypercube" => TopologyKind::OnePeerHypercube,
            "fully_connected" | "parallel" => TopologyKind::FullyConnected,
            _ => return None,
        })
    }

    /// Is the weight-matrix sequence time-varying?
    pub fn is_time_varying(&self) -> bool {
        matches!(
            self,
            TopologyKind::RandomMatch
                | TopologyKind::OnePeerExp
                | TopologyKind::OnePeerExpPerm
                | TopologyKind::OnePeerExpUniform
                | TopologyKind::OnePeerHypercube
        )
    }

    /// The six topologies of Table 1 / Table 2.
    pub fn table1() -> [TopologyKind; 6] {
        [
            TopologyKind::Ring,
            TopologyKind::Grid2D,
            TopologyKind::HalfRandom,
            TopologyKind::RandomMatch,
            TopologyKind::StaticExp,
            TopologyKind::OnePeerExp,
        ]
    }
}

impl std::fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

enum State {
    Static(Matrix),
    OnePeer(OnePeerSequence),
    OnePeerHc { n: usize },
    Matching(RandomMatching),
}

/// A stream of weight matrices `W^{(0)}, W^{(1)}, …` for one topology.
///
/// Static topologies return the same matrix each iteration; time-varying
/// ones advance internal state. `weight_at` must be called with
/// non-decreasing `k` for the stochastic schedules to stay reproducible.
pub struct Schedule {
    kind: TopologyKind,
    n: usize,
    state: State,
}

impl Schedule {
    /// Build a schedule for `kind` on `n` nodes. `seed` feeds the random
    /// topologies (and is ignored by deterministic ones).
    pub fn new(kind: TopologyKind, n: usize, seed: u64) -> Schedule {
        let state = match kind {
            TopologyKind::Ring => State::Static(metropolis_weights(&graphs::ring(n))),
            TopologyKind::Star => State::Static(metropolis_weights(&graphs::star(n))),
            TopologyKind::Grid2D => State::Static(metropolis_weights(&graphs::grid2d(n))),
            TopologyKind::Torus2D => State::Static(metropolis_weights(&graphs::torus2d(n))),
            TopologyKind::Hypercube => State::Static(metropolis_weights(&graphs::hypercube(n))),
            TopologyKind::HalfRandom => State::Static(random::half_random_weights(n, seed)),
            TopologyKind::ErdosRenyi => State::Static(random::erdos_renyi_weights(n, 1.0, seed)),
            TopologyKind::Geometric => State::Static(random::geometric_weights(n, 1.0, seed)),
            TopologyKind::StaticExp => State::Static(static_exp_weights(n)),
            TopologyKind::FullyConnected => State::Static(Matrix::averaging(n)),
            TopologyKind::RandomMatch => State::Matching(RandomMatching::new(n, seed)),
            TopologyKind::OnePeerExp => {
                State::OnePeer(OnePeerSequence::new(n, OnePeerOrder::Cyclic, seed))
            }
            TopologyKind::OnePeerExpPerm => {
                State::OnePeer(OnePeerSequence::new(n, OnePeerOrder::RandomPermutation, seed))
            }
            TopologyKind::OnePeerExpUniform => {
                State::OnePeer(OnePeerSequence::new(n, OnePeerOrder::UniformSampling, seed))
            }
            TopologyKind::OnePeerHypercube => State::OnePeerHc { n },
        };
        Schedule { kind, n, state }
    }

    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Weight matrix `W^{(k)}`.
    pub fn weight_at(&mut self, k: usize) -> Matrix {
        match &mut self.state {
            State::Static(w) => w.clone(),
            State::OnePeer(seq) => seq.weight_at(k),
            State::OnePeerHc { n } => {
                crate::topology::hypercube_onepeer::one_peer_hypercube_weights(*n, k)
            }
            State::Matching(m) => m.next_weights(),
        }
    }

    /// Borrow the static matrix without cloning (None for time-varying).
    pub fn static_weights(&self) -> Option<&Matrix> {
        match &self.state {
            State::Static(w) => Some(w),
            _ => None,
        }
    }
}

/// Convenience: the static weight matrix of a non-time-varying topology.
pub fn static_weights(kind: TopologyKind, n: usize, seed: u64) -> Matrix {
    let mut s = Schedule::new(kind, n, seed);
    s.weight_at(0)
}

/// Variant of [`one_peer_exp_weights`] re-exported here for schedule users.
pub fn one_peer_weights(n: usize, t: usize) -> Matrix {
    one_peer_exp_weights(n, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::weight::is_doubly_stochastic;

    #[test]
    fn all_kinds_produce_doubly_stochastic_sequences() {
        let kinds = [
            TopologyKind::Ring,
            TopologyKind::Star,
            TopologyKind::Grid2D,
            TopologyKind::Torus2D,
            TopologyKind::Hypercube,
            TopologyKind::HalfRandom,
            TopologyKind::ErdosRenyi,
            TopologyKind::Geometric,
            TopologyKind::RandomMatch,
            TopologyKind::StaticExp,
            TopologyKind::OnePeerExp,
            TopologyKind::OnePeerExpPerm,
            TopologyKind::OnePeerExpUniform,
            TopologyKind::FullyConnected,
        ];
        for kind in kinds {
            let n = 16; // power of two so hypercube is valid
            let mut s = Schedule::new(kind, n, 1234);
            for k in 0..6 {
                let w = s.weight_at(k);
                assert!(is_doubly_stochastic(&w, 1e-12), "{kind} k={k}");
            }
        }
    }

    #[test]
    fn static_kinds_are_constant() {
        let mut s = Schedule::new(TopologyKind::Ring, 8, 0);
        assert_eq!(s.weight_at(0), s.weight_at(5));
        assert!(s.static_weights().is_some());
    }

    #[test]
    fn one_peer_cycles_with_period_tau() {
        let mut s = Schedule::new(TopologyKind::OnePeerExp, 8, 0);
        let w0 = s.weight_at(0);
        let w3 = s.weight_at(3);
        assert_eq!(w0, w3); // τ(8) = 3
        assert_ne!(w0, s.weight_at(1));
    }

    #[test]
    fn names_roundtrip() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::OnePeerExp,
            TopologyKind::FullyConnected,
            TopologyKind::Geometric,
        ] {
            assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TopologyKind::parse("nope"), None);
    }
}
