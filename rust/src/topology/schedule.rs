//! The uniform topology-schedule interface consumed by the coordinator:
//! a (possibly time-varying) sequence of mixing plans `W^{(k)}`.
//!
//! Sparse-first: [`Schedule::plan_at`] hands out **cached borrowed
//! plans** — static topologies cache one [`MixingPlan`]; periodic
//! time-varying schedules (one-peer exponential with period
//! `τ = ⌈log₂ n⌉`, Theorem 2; one-peer hypercube; the finite-time
//! base-(k+1) and CECA-style families for arbitrary `n`) precompute the
//! full period once and cycle; only genuinely stochastic schedules
//! (random matching, permuted/uniform-sampled one-peer) regenerate per
//! iteration — and those build sparsely from their matchings, never
//! through a dense matrix. Amortized per-iteration topology cost on
//! every deterministic schedule is `O(1)`.
//!
//! Construction is routed through the open family registry
//! ([`crate::topology::family`], docs/DESIGN.md §Topology registry):
//! [`Schedule::new`] resolves a paper-zoo [`TopologyKind`] to its
//! registered family, and [`Schedule::from_family`] builds any
//! registered [`Topology`] — including the open extensions that have no
//! enum variant. The dense [`Matrix`] form survives only behind
//! [`Schedule::weight_at`] / [`MixingPlan::to_dense`] for spectral
//! analysis and tests (docs/DESIGN.md §Plan cache).

use super::exponential::one_peer_exp_weights;
use super::family::{self, FamilySchedule, PlanGen, Topology};
use super::plan::MixingPlan;
use crate::linalg::Matrix;

/// Every topology evaluated in the paper, plus the fully-connected
/// (all-reduce) baseline used by parallel SGD. This is the **closed**
/// paper zoo; open extensions (base-(k+1), CECA, …) exist only as
/// registered [`Topology`] families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    Ring,
    Star,
    Grid2D,
    Torus2D,
    Hypercube,
    /// ½-random graph (each edge with probability ½, max-degree weights).
    HalfRandom,
    /// Erdős–Rényi `G(n, p)` at the connectivity threshold scaling.
    ErdosRenyi,
    /// 2-D geometric random graph.
    Geometric,
    /// Bipartite random match (time-varying).
    RandomMatch,
    /// Static exponential graph (Eq. (5)).
    StaticExp,
    /// One-peer exponential graph, cyclic order (Eq. (7)).
    OnePeerExp,
    /// One-peer exponential, random permutation per period (App. B.3.2).
    OnePeerExpPerm,
    /// One-peer exponential, uniform sampling with replacement (App. B.3.2).
    OnePeerExpUniform,
    /// One-peer hypercube (Remark 6 / future work): symmetric ½–½
    /// matchings along bit-dimensions; exact averaging each τ steps.
    OnePeerHypercube,
    /// Global averaging `J = 11ᵀ/n` every iteration (parallel SGD).
    FullyConnected,
}

impl TopologyKind {
    /// Short machine-readable name (used in CSV output and CLI).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Star => "star",
            TopologyKind::Grid2D => "grid",
            TopologyKind::Torus2D => "torus",
            TopologyKind::Hypercube => "hypercube",
            TopologyKind::HalfRandom => "half_random",
            TopologyKind::ErdosRenyi => "erdos_renyi",
            TopologyKind::Geometric => "geometric",
            TopologyKind::RandomMatch => "random_match",
            TopologyKind::StaticExp => "static_exp",
            TopologyKind::OnePeerExp => "one_peer_exp",
            TopologyKind::OnePeerExpPerm => "one_peer_exp_perm",
            TopologyKind::OnePeerExpUniform => "one_peer_exp_uniform",
            TopologyKind::OnePeerHypercube => "one_peer_hypercube",
            TopologyKind::FullyConnected => "fully_connected",
        }
    }

    /// The registered family behind this kind.
    pub fn family(self) -> Topology {
        family::of_kind(self)
    }

    /// Parse from the CLI/config name — via the registry, so names and
    /// aliases can never drift from [`family::find`]. Open families
    /// parse to a [`Topology`] but not to a kind.
    pub fn parse(s: &str) -> Option<TopologyKind> {
        family::find(s).and_then(|t| t.kind())
    }

    /// Is the weight-matrix sequence time-varying?
    pub fn is_time_varying(&self) -> bool {
        matches!(
            self,
            TopologyKind::RandomMatch
                | TopologyKind::OnePeerExp
                | TopologyKind::OnePeerExpPerm
                | TopologyKind::OnePeerExpUniform
                | TopologyKind::OnePeerHypercube
        )
    }

    /// Is the sequence a deterministic cycle (static, or periodic with
    /// period `τ(n)`)? These kinds are fully precomputed by
    /// [`Schedule::plan_at`] and never regenerate.
    pub fn is_deterministic(&self) -> bool {
        !matches!(
            self,
            TopologyKind::RandomMatch
                | TopologyKind::OnePeerExpPerm
                | TopologyKind::OnePeerExpUniform
        )
    }

    /// The six topologies of Table 1 / Table 2.
    pub fn table1() -> [TopologyKind; 6] {
        [
            TopologyKind::Ring,
            TopologyKind::Grid2D,
            TopologyKind::HalfRandom,
            TopologyKind::RandomMatch,
            TopologyKind::StaticExp,
            TopologyKind::OnePeerExp,
        ]
    }
}

impl std::fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

enum State {
    /// One plan, every iteration (static topologies).
    Static(MixingPlan),
    /// A precomputed period of plans; iteration `k` uses `k mod τ`.
    Periodic(Vec<MixingPlan>),
    /// Stochastic: regenerate (sparsely) per iteration; the last plan is
    /// cached so repeated `plan_at(k)` calls for the same `k` are
    /// idempotent and do not advance the RNG.
    Stochastic { gen: Box<dyn PlanGen>, current: MixingPlan, at: Option<usize> },
}

/// A stream of mixing plans `W^{(0)}, W^{(1)}, …` for one topology.
///
/// Static topologies return the same cached plan each iteration;
/// periodic ones cycle through a precomputed period; stochastic ones
/// advance internal RNG state and must be queried with non-decreasing
/// `k` to stay reproducible.
pub struct Schedule {
    topo: Topology,
    n: usize,
    state: State,
}

impl Schedule {
    /// Build a schedule for a paper-zoo `kind` on `n` nodes (resolved
    /// through the registry). `seed` feeds the random topologies (and
    /// is ignored by deterministic ones).
    pub fn new(kind: TopologyKind, n: usize, seed: u64) -> Schedule {
        Schedule::from_family(kind.family(), n, seed)
    }

    /// Build a schedule for any registered family — the open-registry
    /// entry point ([`family::find`] resolves config/CLI names).
    pub fn from_family(topo: Topology, n: usize, seed: u64) -> Schedule {
        let state = match topo.build(n, seed) {
            FamilySchedule::Static(plan) => State::Static(plan),
            FamilySchedule::Periodic(plans) => {
                assert!(!plans.is_empty(), "{topo}: empty periodic cycle");
                State::Periodic(plans)
            }
            // `current` starts as a trivial dummy for every stochastic
            // family — `at: None` forces the first `plan_at` call to
            // draw the real plan.
            FamilySchedule::Stochastic(gen) => {
                State::Stochastic { gen, current: MixingPlan::averaging(1), at: None }
            }
        };
        if let Some(kind) = topo.kind() {
            debug_assert_eq!(
                kind.is_deterministic(),
                !matches!(state, State::Stochastic { .. }),
                "TopologyKind::is_deterministic out of sync with the family schedule for {kind}"
            );
        }
        Schedule { topo, n, state }
    }

    /// The family this schedule was built from.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// The paper-zoo kind, when the family has one.
    pub fn kind(&self) -> Option<TopologyKind> {
        self.topo.kind()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The mixing plan `W^{(k)}` — the training hot path. Deterministic
    /// schedules return a cached borrow in `O(1)` with zero allocation;
    /// stochastic ones regenerate sparsely (never through a dense
    /// matrix) and must be queried with non-decreasing `k`.
    pub fn plan_at(&mut self, k: usize) -> &MixingPlan {
        match &mut self.state {
            State::Static(plan) => plan,
            State::Periodic(period) => &period[k % period.len()],
            State::Stochastic { gen, current, at } => {
                if *at != Some(k) {
                    *current = gen.plan_at(k);
                    *at = Some(k);
                }
                current
            }
        }
    }

    /// Dense weight matrix `W^{(k)}` — escape hatch for spectral/ρ
    /// analysis and tests; never used on the training path.
    pub fn weight_at(&mut self, k: usize) -> Matrix {
        self.plan_at(k).to_dense()
    }

    /// Borrow the cached plan of a static topology (None for
    /// time-varying schedules).
    pub fn static_plan(&self) -> Option<&MixingPlan> {
        match &self.state {
            State::Static(plan) => Some(plan),
            _ => None,
        }
    }

    /// Length of the deterministic cycle: 1 for static topologies, the
    /// period `τ(n)` for periodic ones, `None` for stochastic schedules.
    pub fn period(&self) -> Option<usize> {
        match &self.state {
            State::Static(_) => Some(1),
            State::Periodic(period) => Some(period.len()),
            State::Stochastic { .. } => None,
        }
    }
}

/// Convenience: the static weight matrix of a non-time-varying topology
/// (dense escape hatch; first realization for time-varying kinds).
pub fn static_weights(kind: TopologyKind, n: usize, seed: u64) -> Matrix {
    let mut s = Schedule::new(kind, n, seed);
    s.weight_at(0)
}

/// Variant of [`one_peer_exp_weights`] re-exported here for schedule users.
pub fn one_peer_weights(n: usize, t: usize) -> Matrix {
    one_peer_exp_weights(n, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::tau;
    use crate::topology::weight::is_doubly_stochastic;

    #[test]
    fn all_kinds_produce_doubly_stochastic_sequences() {
        let kinds = [
            TopologyKind::Ring,
            TopologyKind::Star,
            TopologyKind::Grid2D,
            TopologyKind::Torus2D,
            TopologyKind::Hypercube,
            TopologyKind::HalfRandom,
            TopologyKind::ErdosRenyi,
            TopologyKind::Geometric,
            TopologyKind::RandomMatch,
            TopologyKind::StaticExp,
            TopologyKind::OnePeerExp,
            TopologyKind::OnePeerExpPerm,
            TopologyKind::OnePeerExpUniform,
            TopologyKind::OnePeerHypercube,
            TopologyKind::FullyConnected,
        ];
        for kind in kinds {
            let n = 16; // power of two so hypercube is valid
            let mut s = Schedule::new(kind, n, 1234);
            for k in 0..6 {
                let w = s.weight_at(k);
                assert!(is_doubly_stochastic(&w, 1e-12), "{kind} k={k}");
                assert!(s.plan_at(k).is_doubly_stochastic(1e-12), "{kind} k={k} (plan)");
            }
        }
    }

    #[test]
    fn static_kinds_are_constant() {
        let mut s = Schedule::new(TopologyKind::Ring, 8, 0);
        assert_eq!(s.weight_at(0), s.weight_at(5));
        assert!(s.static_plan().is_some());
        assert_eq!(s.period(), Some(1));
        assert_eq!(s.kind(), Some(TopologyKind::Ring));
        assert_eq!(s.topology(), TopologyKind::Ring);
    }

    #[test]
    fn one_peer_cycles_with_period_tau() {
        let mut s = Schedule::new(TopologyKind::OnePeerExp, 8, 0);
        let w0 = s.weight_at(0);
        let w3 = s.weight_at(3);
        assert_eq!(w0, w3); // τ(8) = 3
        assert_ne!(w0, s.weight_at(1));
        assert_eq!(s.period(), Some(3));
    }

    #[test]
    fn periodic_plan_cache_is_tau_periodic() {
        // plan_at(k) == plan_at(k + τ) for the periodic kinds, across a
        // full period and from both one schedule and a fresh one.
        for kind in [TopologyKind::OnePeerExp, TopologyKind::OnePeerHypercube] {
            let n = 16;
            let period = tau(n);
            let mut s = Schedule::new(kind, n, 0);
            for k in 0..period {
                let a = s.plan_at(k).clone();
                let b = s.plan_at(k + period).clone();
                assert_eq!(a, b, "{kind} k={k}");
                let mut fresh = Schedule::new(kind, n, 99);
                assert_eq!(&a, fresh.plan_at(k + 2 * period), "{kind} k={k} (fresh)");
            }
            assert_eq!(s.period(), Some(period));
        }
    }

    #[test]
    fn stochastic_plan_at_is_idempotent_per_iteration() {
        let mut s = Schedule::new(TopologyKind::RandomMatch, 12, 5);
        let first = s.plan_at(0).clone();
        assert_eq!(&first, s.plan_at(0), "same k must not re-draw");
        let second = s.plan_at(1).clone();
        let mut replay = Schedule::new(TopologyKind::RandomMatch, 12, 5);
        assert_eq!(&first, replay.plan_at(0));
        assert_eq!(&second, replay.plan_at(1));
        assert_eq!(s.period(), None);
    }

    #[test]
    fn deterministic_kind_classification() {
        assert!(TopologyKind::StaticExp.is_deterministic());
        assert!(TopologyKind::OnePeerExp.is_deterministic());
        assert!(TopologyKind::OnePeerHypercube.is_deterministic());
        assert!(!TopologyKind::RandomMatch.is_deterministic());
        assert!(!TopologyKind::OnePeerExpPerm.is_deterministic());
        assert!(!TopologyKind::OnePeerExpUniform.is_deterministic());
    }

    #[test]
    fn names_roundtrip() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::OnePeerExp,
            TopologyKind::FullyConnected,
            TopologyKind::Geometric,
        ] {
            assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TopologyKind::parse("nope"), None);
        // Open-registry families have no kind but do resolve as families.
        assert_eq!(TopologyKind::parse("base4"), None);
        assert!(crate::topology::family::find("base4").is_some());
    }

    #[test]
    fn finite_time_families_build_periodic_schedules() {
        for (name, n) in [("base4", 12usize), ("base2", 24), ("ceca", 48)] {
            let topo = crate::topology::family::find(name).unwrap();
            let mut s = Schedule::from_family(topo, n, 0);
            let period = topo.exact_period(n).unwrap();
            assert_eq!(s.period(), Some(period), "{name} n={n}");
            let first = s.plan_at(0).clone();
            assert_eq!(&first, s.plan_at(period), "{name} n={n}: cycle wraps");
            assert_eq!(s.kind(), None, "{name} is not in the closed enum");
        }
    }
}
