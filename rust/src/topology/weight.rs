//! Weight-matrix validation and structural queries (Assumption A.4).

use crate::linalg::Matrix;

/// Is `w` doubly stochastic to tolerance `tol`? (Rows and columns each sum
/// to 1, all entries non-negative.)
pub fn is_doubly_stochastic(w: &Matrix, tol: f64) -> bool {
    if w.rows() != w.cols() {
        return false;
    }
    let n = w.rows();
    for i in 0..n {
        let mut rsum = 0.0;
        for j in 0..n {
            let v = w[(i, j)];
            if v < -tol {
                return false;
            }
            rsum += v;
        }
        if (rsum - 1.0).abs() > tol {
            return false;
        }
    }
    for j in 0..n {
        let mut csum = 0.0;
        for i in 0..n {
            csum += w[(i, j)];
        }
        if (csum - 1.0).abs() > tol {
            return false;
        }
    }
    true
}

/// Per-iteration communication degree implied by a weight matrix: the
/// maximum over nodes of the number of *distinct neighbors* the node
/// exchanges with (union of in- and out-neighbors, excluding itself).
///
/// This drives the paper's "Per-iter Comm." columns: 2 for ring, 4 for
/// grid/torus, `⌈log₂ n⌉` for static exponential, 1 for one-peer
/// exponential and bipartite random match.
pub fn max_comm_degree(w: &Matrix) -> usize {
    let n = w.rows();
    let mut best = 0;
    for i in 0..n {
        let mut deg = 0;
        for j in 0..n {
            if i != j && (w[(i, j)] != 0.0 || w[(j, i)] != 0.0) {
                deg += 1;
            }
        }
        best = best.max(deg);
    }
    best
}

/// Average communication degree across nodes (for random-graph balance
/// reporting, Table 6).
pub fn mean_comm_degree(w: &Matrix) -> f64 {
    let n = w.rows();
    let mut total = 0usize;
    for i in 0..n {
        for j in 0..n {
            if i != j && (w[(i, j)] != 0.0 || w[(j, i)] != 0.0) {
                total += 1;
            }
        }
    }
    total as f64 / n as f64
}

/// Min/max node degree (for the degree-balance column of Table 6).
pub fn degree_spread(w: &Matrix) -> (usize, usize) {
    let n = w.rows();
    let mut lo = usize::MAX;
    let mut hi = 0;
    for i in 0..n {
        let mut deg = 0;
        for j in 0..n {
            if i != j && (w[(i, j)] != 0.0 || w[(j, i)] != 0.0) {
                deg += 1;
            }
        }
        lo = lo.min(deg);
        hi = hi.max(deg);
    }
    (if lo == usize::MAX { 0 } else { lo }, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaging_matrix_is_doubly_stochastic() {
        assert!(is_doubly_stochastic(&Matrix::averaging(5), 1e-12));
        assert!(is_doubly_stochastic(&Matrix::eye(5), 1e-12));
    }

    #[test]
    fn rejects_non_stochastic() {
        let mut w = Matrix::eye(3);
        w[(0, 0)] = 0.5; // row 0 sums to 0.5
        assert!(!is_doubly_stochastic(&w, 1e-12));
        let mut neg = Matrix::averaging(3);
        neg[(0, 1)] = -0.1;
        neg[(0, 0)] = 1.0 - (-0.1) - 1.0 / 3.0; // row still sums to 1
        assert!(!is_doubly_stochastic(&neg, 1e-12));
    }

    #[test]
    fn comm_degree_counts_union_of_directions() {
        // Directed: node 0 sends to 1 (w[1][0] > 0 means 1 receives from 0).
        let mut w = Matrix::eye(3);
        w[(1, 0)] = 0.5;
        w[(1, 1)] = 0.5;
        // Node 0 and node 1 each touch one neighbor; node 2 none.
        assert_eq!(max_comm_degree(&w), 1);
        let (lo, hi) = degree_spread(&w);
        assert_eq!((lo, hi), (0, 1));
    }

    #[test]
    fn full_averaging_degree_is_n_minus_1() {
        assert_eq!(max_comm_degree(&Matrix::averaging(6)), 5);
        assert!((mean_comm_degree(&Matrix::averaging(6)) - 5.0).abs() < 1e-12);
    }
}
