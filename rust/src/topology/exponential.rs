//! Exponential graphs — the paper's contribution.
//!
//! * **Static exponential graph** (Sec. 3, Eq. (5)): node `i` receives from
//!   nodes `i + 2^t (mod n)` for `t = 0..τ−1`, `τ = ⌈log₂ n⌉`, every entry
//!   `1/(τ+1)`. The matrix is circulant and doubly stochastic but (for
//!   `n > 2`) *not* symmetric.
//! * **One-peer exponential graph** (Sec. 4, Eq. (7)): at iteration `k`
//!   node `i` averages ½–½ with the single neighbor `i + 2^{mod(k,τ)}`
//!   (mod n). For `n = 2^τ`, any `τ` distinct realizations multiply to
//!   exact averaging (Lemma 1).
//!
//! Sampling strategies for the one-peer sequence (Appendix B.3.2):
//! cyclic (the paper's default), random permutation (still exact-averaging),
//! and uniform sampling with replacement (only asymptotically exact).

use super::plan::{MixingPlan, PlanBuilder};
use super::TopologyKind;
use crate::linalg::Matrix;
use crate::util::rng::Pcg;

/// `τ = ⌈log₂ n⌉` — the period of the one-peer schedule and the degree of
/// the static graph.
pub fn tau(n: usize) -> usize {
    assert!(n >= 1);
    if n == 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// Hop offsets of the static exponential graph: `2^0, 2^1, …, 2^{τ−1}`
/// (all `< n`, all distinct).
pub fn hop_offsets(n: usize) -> Vec<usize> {
    (0..tau(n)).map(|t| 1usize << t).collect()
}

/// Weight matrix of the static exponential graph (Eq. (5)).
pub fn static_exp_weights(n: usize) -> Matrix {
    let t = tau(n);
    let coeff = 1.0 / (t as f64 + 1.0);
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        w[(i, i)] = coeff;
        for &h in &hop_offsets(n) {
            let j = (i + h) % n;
            // For n = 1 or degenerate offsets j == i, fold into the diagonal.
            w[(i, j)] += coeff;
        }
    }
    if n == 1 {
        w[(0, 0)] = 1.0;
    }
    w
}

/// Direct sparse constructor for the static exponential graph (Eq. (5)):
/// row `i` holds `1/(τ+1)` at `i` and at `i + 2^t (mod n)` for
/// `t = 0..τ−1`. Streams straight into CSR through [`PlanBuilder`] —
/// no dense matrix, no per-row `Vec`s — `O(n log n)` nonzeros total.
pub fn static_exp_plan(n: usize) -> MixingPlan {
    if n == 1 {
        return MixingPlan::from_rows(vec![vec![(0, 1.0)]], Some(TopologyKind::StaticExp));
    }
    let t = tau(n);
    let coeff = 1.0 / (t as f64 + 1.0);
    let hops = hop_offsets(n);
    let mut b = PlanBuilder::new(n, n * (t + 1));
    for i in 0..n {
        b.push(i, coeff);
        for &h in &hops {
            b.push((i + h) % n, coeff);
        }
        b.finish_row();
    }
    b.finish(Some(TopologyKind::StaticExp))
}

/// Direct sparse constructor for the one-peer exponential realization
/// with hop exponent `t` (Eq. (7)): row `i` is `½` at `i` and `½` at
/// `i + 2^{mod(t,τ)} (mod n)`. Exactly two nonzeros per row, streamed
/// straight into CSR — this is the constructor the million-node netsim
/// path rides on.
pub fn one_peer_exp_plan(n: usize, t: usize) -> MixingPlan {
    if n == 1 {
        return MixingPlan::from_rows(vec![vec![(0, 1.0)]], Some(TopologyKind::OnePeerExp));
    }
    let period = tau(n);
    let hop = 1usize << (t % period.max(1));
    let mut b = PlanBuilder::new(n, 2 * n);
    for i in 0..n {
        b.push(i, 0.5);
        b.push((i + hop) % n, 0.5);
        b.finish_row();
    }
    b.finish(Some(TopologyKind::OnePeerExp))
}

/// Generating vector (first column) of the static exponential circulant:
/// entry `c[d] = 1/(τ+1)` iff `d = 0` or `d = n − 2^t` for some hop `2^t`.
///
/// (`W[i][j] ≠ 0` iff `j − i ≡ 2^t`, i.e. first-column index
/// `d = i − j ≡ −2^t (mod n)`.)
pub fn static_exp_generating_vector(n: usize) -> Vec<f64> {
    let t = tau(n);
    let coeff = 1.0 / (t as f64 + 1.0);
    let mut c = vec![0.0; n];
    c[0] = coeff;
    for &h in &hop_offsets(n) {
        c[(n - h % n) % n] += coeff;
    }
    if n == 1 {
        c[0] = 1.0;
    }
    c
}

/// Weight matrix of the one-peer exponential realization with hop exponent
/// `t` (i.e. `W^{(k)}` with `t = mod(k, τ)`): Eq. (7).
pub fn one_peer_exp_weights(n: usize, t: usize) -> Matrix {
    let period = tau(n);
    let mut w = Matrix::zeros(n, n);
    if n == 1 {
        w[(0, 0)] = 1.0;
        return w;
    }
    let hop = 1usize << (t % period.max(1));
    for i in 0..n {
        let j = (i + hop) % n;
        w[(i, i)] += 0.5;
        w[(i, j)] += 0.5;
    }
    w
}

/// How the one-peer sequence walks through the τ hop exponents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OnePeerOrder {
    /// `t = mod(k, τ)` — the paper's default (Eq. (7)).
    Cyclic,
    /// Random permutation of `{0..τ}` per period, sampled without
    /// replacement (Appendix B.3.2 — retains exact averaging).
    RandomPermutation,
    /// Uniform sampling with replacement (Appendix B.3.2 — only
    /// asymptotically exact).
    UniformSampling,
}

/// Stateful generator of one-peer hop exponents under a sampling strategy.
#[derive(Clone, Debug)]
pub struct OnePeerSequence {
    n: usize,
    order: OnePeerOrder,
    rng: Pcg,
    perm: Vec<usize>,
    pos: usize,
}

impl OnePeerSequence {
    pub fn new(n: usize, order: OnePeerOrder, seed: u64) -> Self {
        OnePeerSequence { n, order, rng: Pcg::new(seed, 0x0E), perm: Vec::new(), pos: 0 }
    }

    /// Hop exponent for iteration `k`. For `Cyclic` this is a pure function
    /// of `k`; the random strategies consume the internal RNG and must be
    /// called with consecutive `k`.
    pub fn exponent_at(&mut self, k: usize) -> usize {
        let period = tau(self.n).max(1);
        match self.order {
            OnePeerOrder::Cyclic => k % period,
            OnePeerOrder::UniformSampling => self.rng.below(period),
            OnePeerOrder::RandomPermutation => {
                if self.pos == 0 || self.pos >= self.perm.len() {
                    self.perm = self.rng.permutation(period);
                    self.pos = 0;
                }
                let t = self.perm[self.pos];
                self.pos += 1;
                t
            }
        }
    }

    /// Weight matrix for iteration `k` (dense escape hatch; the training
    /// path uses [`OnePeerSequence::plan_at`]).
    pub fn weight_at(&mut self, k: usize) -> Matrix {
        let t = self.exponent_at(k);
        one_peer_exp_weights(self.n, t)
    }

    /// Sparse plan for iteration `k` — built directly from the sampled
    /// hop exponent, never through a dense matrix.
    pub fn plan_at(&mut self, k: usize) -> MixingPlan {
        let t = self.exponent_at(k);
        one_peer_exp_plan(self.n, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::weight::{is_doubly_stochastic, max_comm_degree};

    #[test]
    fn tau_values() {
        assert_eq!(tau(1), 0);
        assert_eq!(tau(2), 1);
        assert_eq!(tau(3), 2);
        assert_eq!(tau(4), 2);
        assert_eq!(tau(5), 3);
        assert_eq!(tau(6), 3);
        assert_eq!(tau(8), 3);
        assert_eq!(tau(9), 4);
        assert_eq!(tau(64), 6);
        assert_eq!(tau(290), 9);
    }

    #[test]
    fn static_exp_is_doubly_stochastic() {
        for n in [2usize, 3, 4, 6, 8, 9, 16, 33, 64] {
            let w = static_exp_weights(n);
            assert!(is_doubly_stochastic(&w, 1e-12), "n={n}");
        }
    }

    #[test]
    fn static_exp_6node_matches_paper_figure() {
        // Fig. 6: n=6, τ=3, nonzeros 1/4 at offsets {0,1,2,4}.
        let w = static_exp_weights(6);
        for i in 0..6 {
            for j in 0..6 {
                let offset = (j + 6 - i) % 6;
                let expect = if matches!(offset, 0 | 1 | 2 | 4) { 0.25 } else { 0.0 };
                assert!((w[(i, j)] - expect).abs() < 1e-15, "({i},{j})");
            }
        }
    }

    #[test]
    fn static_exp_degree_is_tau() {
        for n in [4usize, 8, 16, 32, 64] {
            let w = static_exp_weights(n);
            // Directed: each node sends to τ nodes and receives from τ nodes;
            // for power-of-two n the union has 2τ (τ=1: same node) members...
            // comm degree counts distinct *partners*, direction-agnostic.
            let deg = max_comm_degree(&w);
            let t = tau(n);
            assert!(deg <= 2 * t && deg >= t, "n={n} deg={deg} tau={t}");
        }
    }

    #[test]
    fn generating_vector_reconstructs_matrix() {
        for n in [5usize, 6, 8, 12] {
            let c = static_exp_generating_vector(n);
            let w = static_exp_weights(n);
            for i in 0..n {
                for j in 0..n {
                    let d = (i + n - j) % n;
                    assert!((w[(i, j)] - c[d]).abs() < 1e-15, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn one_peer_is_doubly_stochastic_with_degree_1() {
        for n in [2usize, 3, 4, 6, 8, 16, 17] {
            for t in 0..tau(n) {
                let w = one_peer_exp_weights(n, t);
                assert!(is_doubly_stochastic(&w, 1e-12), "n={n} t={t}");
                assert!(max_comm_degree(&w) <= 2, "n={n} t={t}");
            }
        }
    }

    #[test]
    fn one_peer_power_of_two_exact_averaging() {
        // Lemma 1: product of all τ realizations equals J = 11ᵀ/n.
        for n in [2usize, 4, 8, 16, 32] {
            let mut prod = Matrix::eye(n);
            for t in 0..tau(n) {
                prod = one_peer_exp_weights(n, t).matmul(&prod);
            }
            let err = prod.sub(&Matrix::averaging(n)).max_abs();
            assert!(err < 1e-12, "n={n} err={err}");
        }
    }

    #[test]
    fn one_peer_non_power_of_two_not_exact() {
        // Remark 4: no exact averaging for n not a power of 2.
        for n in [3usize, 5, 6, 12] {
            let mut prod = Matrix::eye(n);
            for t in 0..tau(n) {
                prod = one_peer_exp_weights(n, t).matmul(&prod);
            }
            let err = prod.sub(&Matrix::averaging(n)).max_abs();
            assert!(err > 1e-3, "n={n} unexpectedly exact");
        }
    }

    #[test]
    fn one_peer_any_order_exact_averaging() {
        // Lemma 3: any ordering of the τ distinct matrices works (they
        // commute — all circulant).
        let n = 16;
        let orders = [[3usize, 0, 2, 1], [1, 3, 0, 2]];
        for ord in orders {
            let mut prod = Matrix::eye(n);
            for &t in &ord {
                prod = one_peer_exp_weights(n, t).matmul(&prod);
            }
            assert!(prod.sub(&Matrix::averaging(n)).max_abs() < 1e-12);
        }
    }

    #[test]
    fn direct_plans_match_dense_builders() {
        for n in [1usize, 2, 3, 4, 6, 8, 9, 16, 33, 64] {
            let want = MixingPlan::from_dense(&static_exp_weights(n));
            let got = static_exp_plan(n);
            assert_eq!(got.rows_vec(), want.rows_vec(), "static exp n={n}");
            assert_eq!(got.max_degree, want.max_degree, "static exp n={n}");
            assert_eq!(got.symmetric, want.symmetric, "static exp n={n}");
            for t in 0..tau(n).max(1) {
                let want = MixingPlan::from_dense(&one_peer_exp_weights(n, t));
                let got = one_peer_exp_plan(n, t);
                assert_eq!(got.rows_vec(), want.rows_vec(), "one peer n={n} t={t}");
                assert_eq!(got.max_degree, want.max_degree, "one peer n={n} t={t}");
                assert_eq!(got.symmetric, want.symmetric, "one peer n={n} t={t}");
            }
        }
    }

    #[test]
    fn sequence_strategies_cover_period() {
        let n = 16;
        let period = tau(n);
        // Cyclic: exponents are 0,1,2,3,0,1,...
        let mut cyc = OnePeerSequence::new(n, OnePeerOrder::Cyclic, 1);
        let exps: Vec<usize> = (0..8).map(|k| cyc.exponent_at(k)).collect();
        assert_eq!(exps, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        // Random permutation: each period is a permutation of 0..τ.
        let mut perm = OnePeerSequence::new(n, OnePeerOrder::RandomPermutation, 7);
        for _period in 0..5 {
            let mut seen = vec![false; period];
            for k in 0..period {
                let t = perm.exponent_at(k);
                assert!(!seen[t], "duplicate exponent in one period");
                seen[t] = true;
            }
        }
        // Uniform sampling: exponents in range.
        let mut unif = OnePeerSequence::new(n, OnePeerOrder::UniformSampling, 9);
        for k in 0..100 {
            assert!(unif.exponent_at(k) < period);
        }
    }
}
