//! Finite-time exact-averaging topology families for **arbitrary n** —
//! the first open extensions of the [`crate::topology::family`]
//! registry (docs/DESIGN.md §Topology registry).
//!
//! The paper's headline exact-averaging property (Lemma 1: τ one-peer
//! exponential realizations multiply to `J`) holds only for `n = 2^τ`.
//! Two follow-up lines remove that restriction, and both are reproduced
//! here as τ-period plan cycles:
//!
//! * **Base-(k+1) graphs** (after Takezawa et al., *Beyond Exponential
//!   Graph*, 2023): [`BaseKFamily`] factors `n` into mixed-radix
//!   factors `f_1 · f_2 ⋯ f_τ = n` with each `f_t ≤ k+1` whenever `n`
//!   is `(k+1)`-smooth, and round `t` averages each node `i` with
//!   `i + j·s_t (mod n)` for `j < f_t` at weight `1/f_t`
//!   (stride `s_t = f_1⋯f_{t−1}`). Every round is circulant and doubly
//!   stochastic, and the mixed-radix decomposition makes the period
//!   product **exactly** `J`: the product's generating symbol is
//!   `(1/n)·Σ_d ω^d`, which vanishes at every non-unit root of unity.
//!   For `n = 2^τ` with radix 2 this *is* the one-peer exponential
//!   schedule, weight for weight. Documented substitution: when `n` has
//!   a prime factor `p > k+1` (e.g. `n = 5`), that factor becomes one
//!   higher-degree round instead of Takezawa et al.'s more intricate
//!   uneven-group construction.
//! * **CECA-style one/two-peer schedules** (after Ding et al.,
//!   *DSGD-CECA*, 2023): [`CecaFamily`] runs a balanced merge tree —
//!   each round merges disjoint node groups `A`, `B` (sizes within 1 of
//!   each other) by the convex combination `|A|/(|A|+|B|)` ·
//!   `|B|/(|A|+|B|)`, every node contacting at most **2** partners per
//!   round — reaching the exact global average in `⌈log₂ n⌉` rounds for
//!   ANY `n`. Documented substitution: rounds are **row**-stochastic
//!   (columns only balance over the full period, whose product is
//!   exactly `J`); the published CECA achieves per-round double
//!   stochasticity with `p` or `p+1` rounds via a subtler weighting.
//!   Unlike the (commuting, circulant) base-(k+1) rounds, the merge
//!   rounds do not commute — exactness holds for periods aligned to
//!   `k = 0`, which is how [`crate::topology::schedule::Schedule`]
//!   serves them.

use super::exponential::tau;
use super::family::{FamilySchedule, TopologyFamily};
use super::plan::{MixingPlan, PlanBuilder};

/// The single-node (and `n = 1`) schedule: the identity plan.
fn identity_plan() -> MixingPlan {
    MixingPlan::from_rows(vec![vec![(0, 1.0)]], None)
}

/// Smallest prime factor of `m ≥ 2` (trial division — `m` here is a
/// cluster size, not a cryptographic modulus).
fn smallest_prime_factor(m: usize) -> usize {
    let mut d = 2;
    while d * d <= m {
        if m % d == 0 {
            return d;
        }
        d += 1;
    }
    m
}

/// Mixed-radix factorization of `n` with factors capped at `radix`
/// (largest cap-respecting factor first). When the remainder has no
/// divisor `≤ radix`, its smallest prime factor (necessarily `> radix`)
/// is peeled instead, so the factorization always multiplies back to
/// `n` and the schedule stays exact for every `n`.
pub fn mixed_radix_factors(n: usize, radix: usize) -> Vec<usize> {
    assert!(radix >= 2, "radix must be at least 2");
    let mut m = n.max(1);
    let mut factors = Vec::new();
    while m > 1 {
        match (2..=radix.min(m)).rev().find(|f| m % f == 0) {
            Some(f) => {
                factors.push(f);
                m /= f;
            }
            None => {
                let p = smallest_prime_factor(m);
                factors.push(p);
                m /= p;
            }
        }
    }
    factors
}

/// The τ-round base-(k+1) plan cycle for `n` nodes: round `t` is the
/// circulant `(1/f_t)·Σ_{j<f_t} C^{j·s_t}` with stride
/// `s_t = f_1⋯f_{t−1}` (`C` = cyclic shift). `∏_t W^{(t)} = J` exactly
/// by the mixed-radix covering argument (every residue `0..n` is hit by
/// exactly one exponent combination `Σ_t j_t s_t`).
pub fn base_k_cycle(n: usize, radix: usize) -> Vec<MixingPlan> {
    let factors = mixed_radix_factors(n, radix);
    let mut plans = Vec::with_capacity(factors.len().max(1));
    if factors.is_empty() {
        plans.push(identity_plan());
        return plans;
    }
    let mut stride = 1usize;
    for &f in &factors {
        let w = 1.0 / f as f64;
        let mut b = PlanBuilder::new(n, n * f);
        for i in 0..n {
            for j in 0..f {
                b.push((i + j * stride) % n, w);
            }
            b.finish_row();
        }
        plans.push(b.finish(None));
        stride *= f;
    }
    plans
}

/// One merge of the CECA-style schedule: groups `[lo, mid)` and
/// `[mid, hi)` (sizes `α ≥ β ≥ 1`, `α − β ≤ 1`).
type Merge = (usize, usize, usize);

/// Balanced merge tree over `[lo, hi)`: a segment of size `s` merges
/// its two halves at round `⌈log₂ s⌉ − 1`; both halves complete on
/// strictly earlier rounds (`⌈log₂⌈s/2⌉⌉ = ⌈log₂ s⌉ − 1`), so every
/// group is internally uniform before it merges.
fn schedule_merges(lo: usize, hi: usize, rounds: &mut [Vec<Merge>]) {
    let s = hi - lo;
    if s <= 1 {
        return;
    }
    let mid = lo + s.div_ceil(2);
    schedule_merges(lo, mid, rounds);
    schedule_merges(mid, hi, rounds);
    rounds[tau(s) - 1].push((lo, mid, hi));
}

/// The `p = ⌈log₂ n⌉`-round CECA-style plan cycle: after round `r`,
/// every group that merged holds the exact average of its members'
/// starting values (uniform within the group, bitwise — both sides of
/// a merge evaluate the same two-term convex combination in the same
/// accumulation order), so the period product is exactly `J` for ANY
/// `n`. Every node touches at most 2 partners per round (its read
/// partner, plus at most one extra reader when `|A| = |B| + 1`).
pub fn ceca_cycle(n: usize) -> Vec<MixingPlan> {
    let p = tau(n);
    if p == 0 {
        return vec![identity_plan()];
    }
    let mut rounds: Vec<Vec<Merge>> = vec![Vec::new(); p];
    schedule_merges(0, n, &mut rounds);
    // Every row is either the identity `{(i, 1)}` or a two-entry merge
    // row, so three flat per-node arrays (partner id, self weight,
    // partner weight) describe a round completely and the plan streams
    // into CSR with no per-row `Vec`s.
    let mut other: Vec<u32> = Vec::with_capacity(n);
    let mut w_self: Vec<f64> = Vec::with_capacity(n);
    let mut w_other: Vec<f64> = Vec::with_capacity(n);
    rounds
        .iter()
        .map(|merges| {
            other.clear();
            other.extend(0..n as u32);
            w_self.clear();
            w_self.resize(n, 1.0);
            w_other.clear();
            w_other.resize(n, 0.0);
            for &(lo, mid, hi) in merges {
                let alpha = mid - lo;
                let beta = hi - mid;
                let wa = alpha as f64 / (alpha + beta) as f64;
                let wb = beta as f64 / (alpha + beta) as f64;
                for u in lo..mid {
                    other[u] = (mid + (u - lo) % beta) as u32;
                    w_self[u] = wa;
                    w_other[u] = wb;
                }
                for v in mid..hi {
                    other[v] = (lo + (v - mid)) as u32;
                    w_self[v] = wb;
                    w_other[v] = wa;
                }
            }
            let mut b = PlanBuilder::new(n, 2 * n);
            for i in 0..n {
                b.push(i, w_self[i]);
                if other[i] as usize != i {
                    b.push(other[i] as usize, w_other[i]);
                }
                b.finish_row();
            }
            b.finish(None)
        })
        .collect()
}

/// Base-(k+1) graph family (after Takezawa et al. 2023): finite-time
/// exact averaging for arbitrary `n` with per-round factor cap
/// `radix = k + 1`.
pub struct BaseKFamily {
    radix: usize,
    names: &'static [&'static str],
}

impl BaseKFamily {
    /// The factor cap `k + 1`.
    pub fn radix(&self) -> usize {
        self.radix
    }
}

impl TopologyFamily for BaseKFamily {
    fn names(&self) -> &'static [&'static str] {
        self.names
    }

    fn build(&self, n: usize, _seed: u64) -> FamilySchedule {
        if n <= 1 {
            FamilySchedule::Static(identity_plan())
        } else {
            FamilySchedule::Periodic(base_k_cycle(n, self.radix))
        }
    }

    fn analytic_degree(&self, n: usize) -> usize {
        // Paper accounting (one send + one receive per peer slot, like
        // one-peer exp's degree 1): worst round has f−1 out-neighbors.
        // Radix 2 therefore reports 1, consistent with the bitwise-equal
        // one-peer exponential schedule at powers of two.
        mixed_radix_factors(n, self.radix)
            .iter()
            .map(|&f| (f - 1).min(n.saturating_sub(1)))
            .max()
            .unwrap_or(0)
    }

    fn max_degree_bound(&self, n: usize) -> Option<usize> {
        // Realized *partners* (union of in- and out-neighbors): up to
        // 2(f−1) per round for the circulant rounds.
        Some(
            mixed_radix_factors(n, self.radix)
                .iter()
                .map(|&f| (2 * (f - 1)).min(n.saturating_sub(1)))
                .max()
                .unwrap_or(0),
        )
    }

    fn exact_period(&self, n: usize) -> Option<usize> {
        Some(mixed_radix_factors(n, self.radix).len().max(1))
    }

    fn theory_row(&self, n: usize) -> (String, String) {
        let period = mixed_radix_factors(n, self.radix).len().max(1);
        (
            format!("exact avg each {period} iters (any n)"),
            format!("{}", self.analytic_degree(n)),
        )
    }

    fn is_time_varying(&self) -> bool {
        true
    }
}

/// CECA-style one/two-peer family (after Ding et al. 2023): exact
/// averaging in `⌈log₂ n⌉` rounds for arbitrary `n`, at most 2 partners
/// per node per round.
pub struct CecaFamily {
    names: &'static [&'static str],
}

impl TopologyFamily for CecaFamily {
    fn names(&self) -> &'static [&'static str] {
        self.names
    }

    fn build(&self, n: usize, _seed: u64) -> FamilySchedule {
        if n <= 1 {
            FamilySchedule::Static(identity_plan())
        } else {
            FamilySchedule::Periodic(ceca_cycle(n))
        }
    }

    fn analytic_degree(&self, n: usize) -> usize {
        2.min(n.saturating_sub(1))
    }

    fn max_degree_bound(&self, n: usize) -> Option<usize> {
        Some(self.analytic_degree(n))
    }

    fn exact_period(&self, n: usize) -> Option<usize> {
        Some(tau(n).max(1))
    }

    fn theory_row(&self, n: usize) -> (String, String) {
        (format!("exact avg each {} iters (any n)", tau(n).max(1)), "2".into())
    }

    fn is_time_varying(&self) -> bool {
        true
    }
}

/// Base-2 graph (radix 2): identical to one-peer exp at powers of two,
/// still exact elsewhere (odd factors fall back to higher degree).
pub static BASE2: BaseKFamily = BaseKFamily { radix: 2, names: &["base2"] };
/// Base-3 graph (radix 3).
pub static BASE3: BaseKFamily = BaseKFamily { radix: 3, names: &["base3"] };
/// Base-4 graph (radix 4) — the default base-(k+1) instance.
pub static BASE4: BaseKFamily = BaseKFamily { radix: 4, names: &["base4", "base_k"] };
/// CECA-style one/two-peer schedule.
pub static CECA: CecaFamily = CecaFamily { names: &["ceca", "ceca_one_two_peer"] };

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::topology::exponential::one_peer_exp_plan;

    fn period_product(plans: &[MixingPlan], n: usize) -> Matrix {
        let mut prod = Matrix::eye(n);
        for plan in plans {
            prod = plan.to_dense().matmul(&prod);
        }
        prod
    }

    #[test]
    fn mixed_radix_factors_multiply_back() {
        for n in [1usize, 2, 3, 5, 6, 12, 24, 35, 48, 64, 97, 1024] {
            for radix in [2usize, 3, 4] {
                let fs = mixed_radix_factors(n, radix);
                assert_eq!(fs.iter().product::<usize>(), n.max(1), "n={n} radix={radix}");
                // Factors only exceed the cap when nothing ≤ cap divides.
                for &f in &fs {
                    assert!(f >= 2, "n={n} radix={radix}: factor {f}");
                }
            }
        }
        assert_eq!(mixed_radix_factors(12, 4), vec![4, 3]);
        assert_eq!(mixed_radix_factors(48, 4), vec![4, 4, 3]);
        assert_eq!(mixed_radix_factors(5, 4), vec![5]);
        assert_eq!(mixed_radix_factors(64, 2).len(), 6);
    }

    #[test]
    fn base_k_cycle_is_exact_for_any_n() {
        for n in [2usize, 3, 5, 6, 12, 24, 48, 64] {
            for radix in [2usize, 3, 4] {
                let plans = base_k_cycle(n, radix);
                let err = period_product(&plans, n).sub(&Matrix::averaging(n)).max_abs();
                assert!(err < 1e-12, "n={n} radix={radix}: |prod - J| = {err}");
                for (t, plan) in plans.iter().enumerate() {
                    assert!(plan.is_doubly_stochastic(1e-12), "n={n} radix={radix} t={t}");
                }
            }
        }
    }

    #[test]
    fn base2_matches_one_peer_exp_at_powers_of_two() {
        for n in [2usize, 4, 8, 16, 64] {
            let plans = base_k_cycle(n, 2);
            assert_eq!(plans.len(), tau(n));
            for (t, plan) in plans.iter().enumerate() {
                assert_eq!(plan.rows_vec(), one_peer_exp_plan(n, t).rows_vec(), "n={n} t={t}");
            }
        }
    }

    #[test]
    fn ceca_cycle_is_exact_row_stochastic_two_peer() {
        for n in [2usize, 3, 5, 6, 7, 12, 24, 31, 48, 64] {
            let plans = ceca_cycle(n);
            assert_eq!(plans.len(), tau(n), "n={n}: period is ceil(log2 n)");
            let err = period_product(&plans, n).sub(&Matrix::averaging(n)).max_abs();
            assert!(err < 1e-12, "n={n}: |prod - J| = {err}");
            for (r, plan) in plans.iter().enumerate() {
                assert!(plan.max_degree <= 2, "n={n} round {r}: degree {}", plan.max_degree);
                for (i, row) in plan.rows_vec().iter().enumerate() {
                    let sum: f64 = row.iter().map(|&(_, w)| w).sum();
                    assert!((sum - 1.0).abs() < 1e-12, "n={n} round {r} row {i}");
                    assert!(row.iter().all(|&(_, w)| w >= 0.0), "n={n} round {r} row {i}");
                }
            }
        }
    }

    #[test]
    fn ceca_gossip_hits_consensus_bitwise() {
        // Both sides of every merge evaluate the same convex combination
        // in the same order, so after the period all nodes hold the
        // bitwise-identical value.
        let n = 12;
        let mut x: Vec<f64> = (0..n).map(|i| ((i * 37 + 5) % 11) as f64 / 3.0).collect();
        for plan in ceca_cycle(n) {
            x = plan.matvec(&x);
        }
        for v in &x {
            assert_eq!(v.to_bits(), x[0].to_bits(), "consensus is bitwise");
        }
    }

    #[test]
    fn one_node_schedules_are_identity() {
        assert_eq!(ceca_cycle(1)[0].rows_vec(), vec![vec![(0, 1.0)]]);
        assert_eq!(base_k_cycle(1, 4)[0].rows_vec(), vec![vec![(0, 1.0)]]);
    }
}
