//! The open topology-family registry (docs/DESIGN.md §Topology registry).
//!
//! [`TopologyFamily`] is the trait a topology implements **once**: its
//! config/CLI names, how to build its plan stream ([`FamilySchedule`]),
//! its analytic per-iteration communication degree, its closed-form ρ
//! when one exists, its finite-time exact-averaging period when it has
//! one, and its cost-model dispatch. Every per-kind `match` that used to
//! be re-implemented across schedule / spectral / costmodel / config /
//! exp now routes through [`find`] / [`of_kind`] — adding a topology
//! family is one `impl` plus one entry in [`FAMILIES`], not eight-module
//! surgery.
//!
//! The paper zoo ([`TopologyKind`]) survives as a closed enum whose
//! per-kind behavior is declared here as data ([`KindFamily`] statics);
//! the finite-time families for arbitrary `n`
//! ([`crate::topology::finite_time`]) are the first open extensions.

use super::exponential::{self, one_peer_exp_plan, static_exp_plan, OnePeerOrder, OnePeerSequence};
use super::finite_time;
use super::graphs;
use super::hypercube_onepeer::one_peer_hypercube_plan;
use super::matching::RandomMatching;
use super::metropolis::metropolis_plan;
use super::plan::MixingPlan;
use super::random;
use super::schedule::TopologyKind;
use std::fmt;

/// A stateful generator for genuinely stochastic plan streams (the only
/// schedules that regenerate per iteration). Must be queried with
/// non-decreasing `k`; the idempotence cache lives in
/// [`crate::topology::schedule::Schedule`].
pub trait PlanGen: Send {
    fn plan_at(&mut self, k: usize) -> MixingPlan;
}

impl PlanGen for OnePeerSequence {
    fn plan_at(&mut self, k: usize) -> MixingPlan {
        OnePeerSequence::plan_at(self, k)
    }
}

impl PlanGen for RandomMatching {
    fn plan_at(&mut self, _k: usize) -> MixingPlan {
        self.next_plan()
    }
}

/// What a family's [`TopologyFamily::build`] returns: one cached plan,
/// a finite cycle (period τ — the exact-averaging period for the
/// finite-time families), or a stochastic generator. The schedule cache
/// serves the first two as borrowed plans with zero per-iteration
/// allocation (docs/DESIGN.md §Plan cache).
pub enum FamilySchedule {
    /// One plan, every iteration.
    Static(MixingPlan),
    /// A precomputed cycle; iteration `k` uses `k mod τ`.
    Periodic(Vec<MixingPlan>),
    /// Regenerates (sparsely) per iteration.
    Stochastic(Box<dyn PlanGen>),
}

/// One topology family: everything the rest of the codebase needs to
/// know about a topology, declared in one place.
pub trait TopologyFamily: Sync {
    /// Config/CLI names — canonical first, then aliases. All are
    /// accepted by [`find`]; listings use the canonical name.
    fn names(&self) -> &'static [&'static str];

    /// The paper-zoo enum variant, when this family belongs to the
    /// closed set ([`None`] for open extensions).
    fn kind(&self) -> Option<TopologyKind> {
        None
    }

    /// Construct the plan stream for `n` nodes. `seed` feeds stochastic
    /// families and is ignored by deterministic ones.
    fn build(&self, n: usize, seed: u64) -> FamilySchedule;

    /// Analytic per-iteration communication degree (the "Per-iter
    /// Comm." column of Tables 1/7/8; the cost model's fast path).
    fn analytic_degree(&self, n: usize) -> usize;

    /// Hard upper bound on any realized plan's `max_degree` (distinct
    /// communication partners), when the family guarantees one. `None`
    /// for the random-graph families, where the analytic degree is only
    /// an expectation.
    fn max_degree_bound(&self, n: usize) -> Option<usize>;

    /// Closed-form ρ (second largest eigenvalue magnitude) when the
    /// paper gives one, e.g. ring `(1 + 2cos(2π/n))/3` or static exp
    /// `(τ−1)/(τ+1)` for even n.
    fn analytic_rho(&self, _n: usize) -> Option<f64> {
        None
    }

    /// Finite-time exact averaging: the period τ with
    /// `∏_{k<τ} W^{(k)} = J` exactly, when the family achieves it at
    /// this `n` (periods are aligned to `k = 0`; order matters for the
    /// non-commuting families).
    fn exact_period(&self, _n: usize) -> Option<usize> {
        None
    }

    /// Theory columns of Table 5: (asymptotic `1−ρ`, max degree).
    fn theory_row(&self, _n: usize) -> (String, String) {
        ("-".into(), "-".into())
    }

    /// Is the weight-matrix sequence time-varying?
    fn is_time_varying(&self) -> bool;

    /// Does the family require `n` to be a power of two?
    fn requires_pow2(&self) -> bool {
        false
    }

    /// Cost-model dispatch: priced as a ring-allreduce collective
    /// instead of per-neighbor exchanges (the parallel baseline).
    fn uses_allreduce(&self) -> bool {
        false
    }

    /// Canonical name.
    fn name(&self) -> &'static str {
        self.names()[0]
    }
}

/// Copyable handle to a registered family — what flows through configs,
/// schedules, and experiment grids. Equality/hash/`Display` are by
/// canonical name (unique across the registry); `Debug` prints the
/// paper-zoo variant when there is one, so existing `{:?}` output (CLI,
/// cache keys) is unchanged for the closed set.
#[derive(Clone, Copy)]
pub struct Topology(&'static dyn TopologyFamily);

impl Topology {
    pub fn family(&self) -> &'static dyn TopologyFamily {
        self.0
    }

    pub fn name(&self) -> &'static str {
        self.0.name()
    }

    pub fn kind(&self) -> Option<TopologyKind> {
        self.0.kind()
    }

    pub fn build(&self, n: usize, seed: u64) -> FamilySchedule {
        self.0.build(n, seed)
    }

    pub fn analytic_degree(&self, n: usize) -> usize {
        self.0.analytic_degree(n)
    }

    pub fn max_degree_bound(&self, n: usize) -> Option<usize> {
        self.0.max_degree_bound(n)
    }

    pub fn analytic_rho(&self, n: usize) -> Option<f64> {
        self.0.analytic_rho(n)
    }

    pub fn exact_period(&self, n: usize) -> Option<usize> {
        self.0.exact_period(n)
    }

    pub fn theory_row(&self, n: usize) -> (String, String) {
        self.0.theory_row(n)
    }

    pub fn is_time_varying(&self) -> bool {
        self.0.is_time_varying()
    }

    pub fn requires_pow2(&self) -> bool {
        self.0.requires_pow2()
    }

    pub fn uses_allreduce(&self) -> bool {
        self.0.uses_allreduce()
    }
}

impl PartialEq for Topology {
    fn eq(&self, other: &Self) -> bool {
        self.name() == other.name()
    }
}

impl Eq for Topology {}

impl std::hash::Hash for Topology {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.name().hash(state);
    }
}

impl PartialEq<TopologyKind> for Topology {
    fn eq(&self, other: &TopologyKind) -> bool {
        self.kind() == Some(*other)
    }
}

impl PartialEq<Topology> for TopologyKind {
    fn eq(&self, other: &Topology) -> bool {
        other.kind() == Some(*self)
    }
}

impl fmt::Debug for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind() {
            Some(kind) => write!(f, "{kind:?}"),
            None => f.write_str(self.name()),
        }
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A paper-zoo family declared as data: per-kind behavior lives in the
/// function pointers below, so the closed set stays compact while going
/// through the exact same trait surface as the open extensions.
pub struct KindFamily {
    kind: TopologyKind,
    names: &'static [&'static str],
    build: fn(usize, u64) -> FamilySchedule,
    degree: fn(usize) -> usize,
    max_degree: fn(usize) -> Option<usize>,
    rho: fn(usize) -> Option<f64>,
    theory: fn(usize) -> (String, String),
    exact_period: fn(usize) -> Option<usize>,
    time_varying: bool,
    requires_pow2: bool,
    uses_allreduce: bool,
}

impl TopologyFamily for KindFamily {
    fn names(&self) -> &'static [&'static str] {
        self.names
    }

    fn kind(&self) -> Option<TopologyKind> {
        Some(self.kind)
    }

    fn build(&self, n: usize, seed: u64) -> FamilySchedule {
        (self.build)(n, seed)
    }

    fn analytic_degree(&self, n: usize) -> usize {
        (self.degree)(n)
    }

    fn max_degree_bound(&self, n: usize) -> Option<usize> {
        (self.max_degree)(n)
    }

    fn analytic_rho(&self, n: usize) -> Option<f64> {
        (self.rho)(n)
    }

    fn exact_period(&self, n: usize) -> Option<usize> {
        (self.exact_period)(n)
    }

    fn theory_row(&self, n: usize) -> (String, String) {
        (self.theory)(n)
    }

    fn is_time_varying(&self) -> bool {
        self.time_varying
    }

    fn requires_pow2(&self) -> bool {
        self.requires_pow2
    }

    fn uses_allreduce(&self) -> bool {
        self.uses_allreduce
    }
}

// ---- paper-zoo builders (moved from the old Schedule::new match) ------

fn build_ring(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(metropolis_plan(&graphs::ring(n)).with_kind(TopologyKind::Ring))
}

fn build_star(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(metropolis_plan(&graphs::star(n)).with_kind(TopologyKind::Star))
}

fn build_grid2d(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(metropolis_plan(&graphs::grid2d(n)).with_kind(TopologyKind::Grid2D))
}

fn build_torus2d(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(metropolis_plan(&graphs::torus2d(n)).with_kind(TopologyKind::Torus2D))
}

fn build_hypercube(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(metropolis_plan(&graphs::hypercube(n)).with_kind(TopologyKind::Hypercube))
}

fn build_half_random(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Static(random::half_random_plan(n, seed).with_kind(TopologyKind::HalfRandom))
}

fn build_erdos_renyi(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Static(random::erdos_renyi_plan(n, 1.0, seed).with_kind(TopologyKind::ErdosRenyi))
}

fn build_geometric(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Static(random::geometric_plan(n, 1.0, seed).with_kind(TopologyKind::Geometric))
}

fn build_static_exp(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(static_exp_plan(n))
}

fn build_fully_connected(n: usize, _seed: u64) -> FamilySchedule {
    FamilySchedule::Static(MixingPlan::averaging(n))
}

fn build_one_peer_exp(n: usize, _seed: u64) -> FamilySchedule {
    let period = exponential::tau(n).max(1);
    FamilySchedule::Periodic((0..period).map(|t| one_peer_exp_plan(n, t)).collect())
}

fn build_one_peer_hypercube(n: usize, _seed: u64) -> FamilySchedule {
    let period = exponential::tau(n).max(1);
    FamilySchedule::Periodic((0..period).map(|t| one_peer_hypercube_plan(n, t)).collect())
}

fn build_one_peer_exp_perm(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Stochastic(Box::new(OnePeerSequence::new(
        n,
        OnePeerOrder::RandomPermutation,
        seed,
    )))
}

fn build_one_peer_exp_uniform(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Stochastic(Box::new(OnePeerSequence::new(
        n,
        OnePeerOrder::UniformSampling,
        seed,
    )))
}

fn build_random_match(n: usize, seed: u64) -> FamilySchedule {
    FamilySchedule::Stochastic(Box::new(RandomMatching::new(n, seed)))
}

// ---- analytic degrees (moved from the old costmodel match) ------------

fn deg_two(n: usize) -> usize {
    2.min(n.saturating_sub(1))
}

fn deg_four(n: usize) -> usize {
    4.min(n.saturating_sub(1))
}

fn deg_full(n: usize) -> usize {
    n.saturating_sub(1)
}

fn deg_half(n: usize) -> usize {
    n.saturating_sub(1) / 2
}

fn deg_expected_log(n: usize) -> usize {
    // expected degree ≈ (1+c)·ln n at c=1
    (2.0 * (n as f64).ln()).ceil() as usize
}

fn deg_one(_n: usize) -> usize {
    1
}

fn deg_tau(n: usize) -> usize {
    exponential::tau(n)
}

// ---- realized-degree bounds -------------------------------------------

fn bound_two(n: usize) -> Option<usize> {
    Some(2.min(n.saturating_sub(1)))
}

fn bound_four(n: usize) -> Option<usize> {
    Some(4.min(n.saturating_sub(1)))
}

fn bound_full(n: usize) -> Option<usize> {
    Some(n.saturating_sub(1))
}

fn bound_one(n: usize) -> Option<usize> {
    Some(1.min(n.saturating_sub(1)))
}

fn bound_tau(n: usize) -> Option<usize> {
    Some(exponential::tau(n))
}

fn bound_static_exp(n: usize) -> Option<usize> {
    // Directed: τ out-neighbors plus τ in-neighbors (the comm degree
    // counts distinct partners, direction-agnostic).
    Some((2 * exponential::tau(n)).min(n.saturating_sub(1)))
}

fn bound_none(_n: usize) -> Option<usize> {
    None
}

// ---- closed-form ρ ----------------------------------------------------

fn rho_none(_n: usize) -> Option<f64> {
    None
}

fn rho_ring(n: usize) -> Option<f64> {
    // Metropolis ring weights are circulant with eigenvalues
    // 1/3 + (2/3)cos(2πk/n), so ρ = (1 + 2cos(2π/n))/3 for n ≥ 4.
    if n >= 4 {
        Some((1.0 + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos()) / 3.0)
    } else {
        None
    }
}

fn rho_static_exp(n: usize) -> Option<f64> {
    // Proposition 1 with equality for even n.
    if n >= 2 && n % 2 == 0 {
        let t = exponential::tau(n) as f64;
        Some((t - 1.0) / (t + 1.0))
    } else {
        None
    }
}

fn rho_hypercube(n: usize) -> Option<f64> {
    // Remark 2: gap 2/(1 + log2 n), i.e. ρ = (τ−1)/(τ+1).
    if n >= 2 && n.is_power_of_two() {
        let t = exponential::tau(n) as f64;
        Some((t - 1.0) / (t + 1.0))
    } else {
        None
    }
}

fn rho_zero(_n: usize) -> Option<f64> {
    Some(0.0)
}

// ---- exact-averaging periods ------------------------------------------

fn ep_none(_n: usize) -> Option<usize> {
    None
}

fn ep_pow2_tau(n: usize) -> Option<usize> {
    // Lemma 1: exact averaging after τ = log2(n) steps iff n = 2^τ.
    if n.is_power_of_two() {
        Some(exponential::tau(n).max(1))
    } else {
        None
    }
}

fn ep_one(_n: usize) -> Option<usize> {
    Some(1)
}

// ---- Table 5 theory rows (moved from the old spectral match) ----------

fn theory_default(_n: usize) -> (String, String) {
    ("-".into(), "-".into())
}

fn theory_ring(n: usize) -> (String, String) {
    let nf = n as f64;
    (format!("O(1/n^2) ~ {:.2e}", 1.0 / (nf * nf)), "2".into())
}

fn theory_star(n: usize) -> (String, String) {
    let nf = n as f64;
    (format!("O(1/n^2) ~ {:.2e}", 1.0 / (nf * nf)), format!("{}", n - 1))
}

fn theory_grid(n: usize) -> (String, String) {
    let nf = n as f64;
    let log2n = nf.log2().max(1.0);
    (format!("O(1/(n log n)) ~ {:.2e}", 1.0 / (nf * log2n)), "4".into())
}

fn theory_torus(n: usize) -> (String, String) {
    let nf = n as f64;
    (format!("O(1/n) ~ {:.2e}", 1.0 / nf), "4".into())
}

fn theory_half_random(n: usize) -> (String, String) {
    ("O(1)".into(), format!("{}", (n - 1) / 2))
}

fn theory_random_match(_n: usize) -> (String, String) {
    ("N.A.".into(), "1".into())
}

fn theory_static_exp(n: usize) -> (String, String) {
    let t = exponential::tau(n);
    (
        format!("2/(1+ceil(log2 n)) = {:.4}", 2.0 / (1.0 + t as f64)),
        format!("{t}"),
    )
}

fn theory_one_peer_exp(_n: usize) -> (String, String) {
    ("N.A. (time-varying)".into(), "1".into())
}

// ---- the paper zoo, declared ------------------------------------------

static RING: KindFamily = KindFamily {
    kind: TopologyKind::Ring,
    names: &["ring"],
    build: build_ring,
    degree: deg_two,
    max_degree: bound_two,
    rho: rho_ring,
    theory: theory_ring,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static STAR: KindFamily = KindFamily {
    kind: TopologyKind::Star,
    names: &["star"],
    build: build_star,
    degree: deg_full,
    max_degree: bound_full,
    rho: rho_none,
    theory: theory_star,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static GRID2D: KindFamily = KindFamily {
    kind: TopologyKind::Grid2D,
    names: &["grid"],
    build: build_grid2d,
    degree: deg_four,
    max_degree: bound_four,
    rho: rho_none,
    theory: theory_grid,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static TORUS2D: KindFamily = KindFamily {
    kind: TopologyKind::Torus2D,
    names: &["torus"],
    build: build_torus2d,
    degree: deg_four,
    max_degree: bound_four,
    rho: rho_none,
    theory: theory_torus,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static HYPERCUBE: KindFamily = KindFamily {
    kind: TopologyKind::Hypercube,
    names: &["hypercube"],
    build: build_hypercube,
    degree: deg_tau,
    max_degree: bound_tau,
    rho: rho_hypercube,
    theory: theory_default,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: true,
    uses_allreduce: false,
};

static HALF_RANDOM: KindFamily = KindFamily {
    kind: TopologyKind::HalfRandom,
    names: &["half_random"],
    build: build_half_random,
    degree: deg_half,
    max_degree: bound_none,
    rho: rho_none,
    theory: theory_half_random,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static ERDOS_RENYI: KindFamily = KindFamily {
    kind: TopologyKind::ErdosRenyi,
    names: &["erdos_renyi"],
    build: build_erdos_renyi,
    degree: deg_expected_log,
    max_degree: bound_none,
    rho: rho_none,
    theory: theory_default,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static GEOMETRIC: KindFamily = KindFamily {
    kind: TopologyKind::Geometric,
    names: &["geometric"],
    build: build_geometric,
    degree: deg_expected_log,
    max_degree: bound_none,
    rho: rho_none,
    theory: theory_default,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static RANDOM_MATCH: KindFamily = KindFamily {
    kind: TopologyKind::RandomMatch,
    names: &["random_match"],
    build: build_random_match,
    degree: deg_one,
    max_degree: bound_one,
    rho: rho_none,
    theory: theory_random_match,
    exact_period: ep_none,
    time_varying: true,
    requires_pow2: false,
    uses_allreduce: false,
};

static STATIC_EXP: KindFamily = KindFamily {
    kind: TopologyKind::StaticExp,
    names: &["static_exp"],
    build: build_static_exp,
    degree: deg_tau,
    max_degree: bound_static_exp,
    rho: rho_static_exp,
    theory: theory_static_exp,
    exact_period: ep_none,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: false,
};

static ONE_PEER_EXP: KindFamily = KindFamily {
    kind: TopologyKind::OnePeerExp,
    names: &["one_peer_exp"],
    build: build_one_peer_exp,
    degree: deg_one,
    max_degree: bound_two,
    rho: rho_none,
    theory: theory_one_peer_exp,
    exact_period: ep_pow2_tau,
    time_varying: true,
    requires_pow2: false,
    uses_allreduce: false,
};

static ONE_PEER_EXP_PERM: KindFamily = KindFamily {
    kind: TopologyKind::OnePeerExpPerm,
    names: &["one_peer_exp_perm"],
    build: build_one_peer_exp_perm,
    degree: deg_one,
    max_degree: bound_two,
    rho: rho_none,
    theory: theory_default,
    // App. B.3.2: a per-period permutation of the τ distinct hops keeps
    // periodic exact averaging (the realizations commute).
    exact_period: ep_pow2_tau,
    time_varying: true,
    requires_pow2: false,
    uses_allreduce: false,
};

static ONE_PEER_EXP_UNIFORM: KindFamily = KindFamily {
    kind: TopologyKind::OnePeerExpUniform,
    names: &["one_peer_exp_uniform"],
    build: build_one_peer_exp_uniform,
    degree: deg_one,
    max_degree: bound_two,
    rho: rho_none,
    theory: theory_default,
    exact_period: ep_none,
    time_varying: true,
    requires_pow2: false,
    uses_allreduce: false,
};

static ONE_PEER_HYPERCUBE: KindFamily = KindFamily {
    kind: TopologyKind::OnePeerHypercube,
    names: &["one_peer_hypercube"],
    build: build_one_peer_hypercube,
    degree: deg_one,
    max_degree: bound_one,
    rho: rho_none,
    theory: theory_default,
    exact_period: ep_pow2_tau,
    time_varying: true,
    requires_pow2: true,
    uses_allreduce: false,
};

static FULLY_CONNECTED: KindFamily = KindFamily {
    kind: TopologyKind::FullyConnected,
    names: &["fully_connected", "parallel"],
    build: build_fully_connected,
    degree: deg_full,
    max_degree: bound_full,
    rho: rho_zero,
    theory: theory_default,
    exact_period: ep_one,
    time_varying: false,
    requires_pow2: false,
    uses_allreduce: true,
};

/// Every registered family: the paper zoo first, then the finite-time
/// extensions for arbitrary `n`. **This list is the single source of
/// truth** — config parsing, CLI error listings, the registry proptests,
/// and Table-style sweeps all iterate it. Adding a family = one impl +
/// one entry here.
pub static FAMILIES: &[&dyn TopologyFamily] = &[
    &RING,
    &STAR,
    &GRID2D,
    &TORUS2D,
    &HYPERCUBE,
    &HALF_RANDOM,
    &ERDOS_RENYI,
    &GEOMETRIC,
    &RANDOM_MATCH,
    &STATIC_EXP,
    &ONE_PEER_EXP,
    &ONE_PEER_EXP_PERM,
    &ONE_PEER_EXP_UNIFORM,
    &ONE_PEER_HYPERCUBE,
    &FULLY_CONNECTED,
    &finite_time::BASE2,
    &finite_time::BASE3,
    &finite_time::BASE4,
    &finite_time::CECA,
];

/// Iterate every registered family as a handle.
pub fn families() -> impl Iterator<Item = Topology> {
    FAMILIES.iter().map(|f| Topology(*f))
}

/// Look a family up by any of its registered names.
pub fn find(name: &str) -> Option<Topology> {
    FAMILIES
        .iter()
        .find(|f| f.names().iter().any(|&alias| alias == name))
        .map(|f| Topology(*f))
}

/// The family behind a paper-zoo kind.
pub fn of_kind(kind: TopologyKind) -> Topology {
    FAMILIES
        .iter()
        .find(|f| f.kind() == Some(kind))
        .map(|f| Topology(*f))
        .expect("every TopologyKind has a registered family")
}

/// Canonical names of every registered family, registry order. Error
/// messages and usage text are generated from this — never hand-listed
/// (the hand-written `exp` id list bug class).
pub fn names() -> Vec<&'static str> {
    FAMILIES.iter().map(|f| f.name()).collect()
}

/// Canonical names of the paper-zoo (closed-enum) families only — what
/// surfaces restricted to `TopologyKind` (e.g. the netsim sweep) accept.
pub fn kind_names() -> Vec<&'static str> {
    FAMILIES.iter().filter(|f| f.kind().is_some()).map(|f| f.name()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolve() {
        let mut seen = std::collections::BTreeSet::new();
        for fam in FAMILIES {
            for name in fam.names() {
                assert!(seen.insert(*name), "duplicate registered name {name}");
                let found = find(name).unwrap_or_else(|| panic!("{name} not findable"));
                assert_eq!(found.name(), fam.name(), "{name} resolves to the wrong family");
            }
        }
        assert!(find("mobius").is_none());
    }

    #[test]
    fn every_kind_has_a_family_and_roundtrips() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Star,
            TopologyKind::Grid2D,
            TopologyKind::Torus2D,
            TopologyKind::Hypercube,
            TopologyKind::HalfRandom,
            TopologyKind::ErdosRenyi,
            TopologyKind::Geometric,
            TopologyKind::RandomMatch,
            TopologyKind::StaticExp,
            TopologyKind::OnePeerExp,
            TopologyKind::OnePeerExpPerm,
            TopologyKind::OnePeerExpUniform,
            TopologyKind::OnePeerHypercube,
            TopologyKind::FullyConnected,
        ] {
            let topo = of_kind(kind);
            assert_eq!(topo.kind(), Some(kind));
            assert_eq!(topo.name(), kind.name(), "canonical name drifted for {kind:?}");
            assert_eq!(topo.is_time_varying(), kind.is_time_varying(), "{kind:?}");
            assert_eq!(topo, kind, "cross-type equality");
        }
    }

    #[test]
    fn handle_equality_and_display() {
        let a = find("one_peer_exp").unwrap();
        let b = of_kind(TopologyKind::OnePeerExp);
        assert_eq!(a, b);
        assert_ne!(a, find("static_exp").unwrap());
        assert_eq!(format!("{a}"), "one_peer_exp");
        assert_eq!(format!("{a:?}"), "OnePeerExp");
        let base = find("base4").unwrap();
        assert_eq!(format!("{base:?}"), "base4", "open families debug as their name");
        assert_eq!(find("parallel").unwrap(), of_kind(TopologyKind::FullyConnected));
    }

    #[test]
    fn degrees_match_legacy_costmodel_values() {
        let n = 32;
        assert_eq!(of_kind(TopologyKind::Ring).analytic_degree(n), 2);
        assert_eq!(of_kind(TopologyKind::Grid2D).analytic_degree(n), 4);
        assert_eq!(of_kind(TopologyKind::HalfRandom).analytic_degree(n), 15);
        assert_eq!(of_kind(TopologyKind::RandomMatch).analytic_degree(n), 1);
        assert_eq!(of_kind(TopologyKind::StaticExp).analytic_degree(n), 5);
        assert_eq!(of_kind(TopologyKind::OnePeerExp).analytic_degree(n), 1);
        assert_eq!(of_kind(TopologyKind::FullyConnected).analytic_degree(n), 31);
    }

    #[test]
    fn exact_periods_follow_lemma1() {
        let one_peer = of_kind(TopologyKind::OnePeerExp);
        assert_eq!(one_peer.exact_period(16), Some(4));
        assert_eq!(one_peer.exact_period(12), None, "no exact averaging off powers of two");
        assert_eq!(of_kind(TopologyKind::FullyConnected).exact_period(7), Some(1));
        assert_eq!(of_kind(TopologyKind::StaticExp).exact_period(16), None);
    }
}
