//! Pure-Rust reference models.
//!
//! These run the laptop-scale topology sweeps (Tables 2/3/4/9/10, Figs.
//! 1/13) where one AOT artifact per `(n, shape)` combination would be
//! impractical; the AOT transformer path (`runtime` + `python/compile`)
//! covers the deep-learning end-to-end example. Both stacks share the same
//! coordinator and optimizers.

pub mod mlp;

pub use mlp::{Mlp, MlpConfig};
