//! Two-layer MLP classifier with manual backprop (f32).
//!
//! Architecture: `x → W1·x + b1 → tanh → W2·h + b2 → softmax CE`.
//! Parameters live in one flat `Vec<f32>` (layout below) so the
//! decentralized optimizers can treat models as opaque vectors — the same
//! contract the AOT transformer artifacts use.

use crate::data::classify::Dataset;
use crate::util::rng::Pcg;

/// MLP shape.
#[derive(Clone, Copy, Debug)]
pub struct MlpConfig {
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl MlpConfig {
    /// Number of parameters: `h·d + h + C·h + C`.
    pub fn param_count(&self) -> usize {
        self.hidden * self.input + self.hidden + self.classes * self.hidden + self.classes
    }
}

/// Flat-parameter MLP. All methods are stateless with respect to
/// parameters — they take the flat slice explicitly.
#[derive(Clone, Copy, Debug)]
pub struct Mlp {
    pub cfg: MlpConfig,
}

/// Offsets into the flat parameter vector.
struct Layout {
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    end: usize,
}

impl Mlp {
    pub fn new(cfg: MlpConfig) -> Mlp {
        Mlp { cfg }
    }

    fn layout(&self) -> Layout {
        let MlpConfig { input, hidden, classes } = self.cfg;
        let w1 = 0;
        let b1 = w1 + hidden * input;
        let w2 = b1 + hidden;
        let b2 = w2 + classes * hidden;
        Layout { w1, b1, w2, b2, end: b2 + classes }
    }

    /// Xavier-style deterministic initialization.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let l = self.layout();
        let mut rng = Pcg::new(seed, 0x317);
        let mut p = vec![0.0f32; l.end];
        let s1 = (2.0 / (self.cfg.input + self.cfg.hidden) as f64).sqrt();
        for v in p[l.w1..l.b1].iter_mut() {
            *v = (rng.normal() * s1) as f32;
        }
        let s2 = (2.0 / (self.cfg.hidden + self.cfg.classes) as f64).sqrt();
        for v in p[l.w2..l.b2].iter_mut() {
            *v = (rng.normal() * s2) as f32;
        }
        p
    }

    /// Forward pass logits for one sample into `logits` (scratch `hid` is
    /// the tanh hidden activation).
    fn forward(&self, params: &[f32], x: &[f32], hid: &mut [f32], logits: &mut [f32]) {
        let l = self.layout();
        let MlpConfig { input, hidden, classes } = self.cfg;
        for h in 0..hidden {
            let row = &params[l.w1 + h * input..l.w1 + (h + 1) * input];
            let mut acc = params[l.b1 + h];
            for (w, xi) in row.iter().zip(x.iter()) {
                acc += w * xi;
            }
            hid[h] = acc.tanh();
        }
        for c in 0..classes {
            let row = &params[l.w2 + c * hidden..l.w2 + (c + 1) * hidden];
            let mut acc = params[l.b2 + c];
            for (w, hv) in row.iter().zip(hid.iter()) {
                acc += w * hv;
            }
            logits[c] = acc;
        }
    }

    /// Mean cross-entropy loss and gradient over the minibatch `batch`
    /// (indices into `data`). `grad` is zeroed and filled; returns loss.
    pub fn loss_grad(
        &self,
        params: &[f32],
        data: &Dataset,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f32 {
        let l = self.layout();
        assert_eq!(params.len(), l.end);
        assert_eq!(grad.len(), l.end);
        grad.iter_mut().for_each(|g| *g = 0.0);
        let MlpConfig { input, hidden, classes } = self.cfg;
        let mut hid = vec![0.0f32; hidden];
        let mut logits = vec![0.0f32; classes];
        let mut probs = vec![0.0f32; classes];
        let mut dhid = vec![0.0f32; hidden];
        let scale = 1.0 / batch.len() as f32;
        let mut loss = 0.0f32;
        for &idx in batch {
            let x = data.feature(idx);
            let y = data.labels[idx] as usize;
            self.forward(params, x, &mut hid, &mut logits);
            // Softmax + CE.
            let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut z = 0.0f32;
            for c in 0..classes {
                probs[c] = (logits[c] - maxl).exp();
                z += probs[c];
            }
            for c in 0..classes {
                probs[c] /= z;
            }
            loss -= (probs[y].max(1e-12)).ln() * scale;
            // Backprop: dlogits = probs − one_hot(y).
            probs[y] -= 1.0;
            dhid.iter_mut().for_each(|d| *d = 0.0);
            for c in 0..classes {
                let dl = probs[c] * scale;
                grad[l.b2 + c] += dl;
                let wrow = &params[l.w2 + c * hidden..l.w2 + (c + 1) * hidden];
                let grow = &mut grad[l.w2 + c * hidden..l.w2 + (c + 1) * hidden];
                for h in 0..hidden {
                    grow[h] += dl * hid[h];
                    dhid[h] += dl * wrow[h];
                }
            }
            for h in 0..hidden {
                let da = dhid[h] * (1.0 - hid[h] * hid[h]); // tanh'
                grad[l.b1 + h] += da;
                let grow = &mut grad[l.w1 + h * input..l.w1 + (h + 1) * input];
                for (g, xi) in grow.iter_mut().zip(x.iter()) {
                    *g += da * xi;
                }
            }
        }
        loss
    }

    /// Mean loss without gradient (for validation curves).
    pub fn loss(&self, params: &[f32], data: &Dataset, batch: &[usize]) -> f32 {
        let MlpConfig { hidden, classes, .. } = self.cfg;
        let mut hid = vec![0.0f32; hidden];
        let mut logits = vec![0.0f32; classes];
        let mut loss = 0.0f32;
        for &idx in batch {
            let x = data.feature(idx);
            let y = data.labels[idx] as usize;
            self.forward(params, x, &mut hid, &mut logits);
            let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let z: f32 = logits.iter().map(|&v| (v - maxl).exp()).sum();
            loss += z.ln() + maxl - logits[y];
        }
        loss / batch.len() as f32
    }

    /// Top-1 accuracy over a dataset.
    pub fn accuracy(&self, params: &[f32], data: &Dataset) -> f64 {
        let MlpConfig { hidden, classes, .. } = self.cfg;
        let mut hid = vec![0.0f32; hidden];
        let mut logits = vec![0.0f32; classes];
        let mut correct = 0usize;
        for i in 0..data.len {
            self.forward(params, data.feature(i), &mut hid, &mut logits);
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as u32 == data.labels[i] {
                correct += 1;
            }
        }
        correct as f64 / data.len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classify::{generate, ClassifyConfig};

    fn setup() -> (Mlp, Dataset, Dataset) {
        let d = generate(&ClassifyConfig {
            dim: 8,
            classes: 4,
            train_per_class: 60,
            val_per_class: 30,
            separation: 2.5,
            seed: 5,
        });
        let mlp = Mlp::new(MlpConfig { input: 8, hidden: 16, classes: 4 });
        (mlp, d.train, d.val)
    }

    #[test]
    fn param_count_matches_layout() {
        let (mlp, _, _) = setup();
        assert_eq!(mlp.cfg.param_count(), 16 * 8 + 16 + 4 * 16 + 4);
        assert_eq!(mlp.init(0).len(), mlp.cfg.param_count());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (mlp, train, _) = setup();
        let params = mlp.init(3);
        let batch: Vec<usize> = (0..16).collect();
        let mut grad = vec![0.0f32; params.len()];
        let loss = mlp.loss_grad(&params, &train, &batch, &mut grad);
        assert!((loss - mlp.loss(&params, &train, &batch)).abs() < 1e-5);
        // Probe a spread of parameter indices.
        let eps = 1e-3f32;
        for &j in &[0usize, 5, 130, 140, 170, params.len() - 1] {
            let mut pp = params.clone();
            pp[j] += eps;
            let lp = mlp.loss(&pp, &train, &batch);
            pp[j] -= 2.0 * eps;
            let lm = mlp.loss(&pp, &train, &batch);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[j]).abs() < 2e-3_f32.max(0.05 * fd.abs()),
                "j={j}: fd={fd} grad={}",
                grad[j]
            );
        }
    }

    #[test]
    fn sgd_learns_to_classify() {
        let (mlp, train, val) = setup();
        let mut params = mlp.init(1);
        let mut grad = vec![0.0f32; params.len()];
        let mut rng = Pcg::seeded(9);
        let acc0 = mlp.accuracy(&params, &val);
        for _ in 0..400 {
            let batch: Vec<usize> = (0..32).map(|_| rng.below(train.len)).collect();
            mlp.loss_grad(&params, &train, &batch, &mut grad);
            for (p, g) in params.iter_mut().zip(grad.iter()) {
                *p -= 0.1 * g;
            }
        }
        let acc1 = mlp.accuracy(&params, &val);
        assert!(acc1 > 0.7, "val accuracy {acc0} -> {acc1}");
        assert!(acc1 > acc0);
    }
}
