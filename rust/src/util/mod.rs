//! Cross-cutting utilities built in-crate (the sandbox has no network, so
//! no third-party crates beyond `xla`/`anyhow`): a PCG random number
//! generator, a JSON reader/writer for configs and artifact manifests, CSV
//! result emission, and plain-text table rendering.

pub mod csv;
pub mod json;
pub mod rng;
pub mod table;
