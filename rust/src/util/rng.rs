//! Deterministic PCG-XSH-RR 64/32 random number generator.
//!
//! Every stochastic component of the system (data synthesis, gradient-noise
//! sampling, random topologies, bipartite matching permutations) draws from
//! this generator so that experiments are exactly reproducible from a seed.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Pcg::new(seed, 0)
    }

    /// Next 32 uniform random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free enough
    /// for our bounds; uses 64-bit multiply-shift with rejection).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // Rejection sampling to remove modulo bias.
        let bound = bound as u64;
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % bound) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Pcg::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_support() {
        let mut rng = Pcg::seeded(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg::seeded(5);
        let p = rng.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}
