//! Minimal JSON reader/writer.
//!
//! Used for experiment configs and the `artifacts/manifest.json` emitted by
//! the AOT pipeline. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed for our manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_to(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_document() {
        let src = r#"{
            "artifacts": [
                {"name": "logreg_grad", "path": "artifacts/logreg_grad.hlo.txt",
                 "inputs": [{"shape": [64, 10], "dtype": "f32"}], "num_outputs": 2}
            ],
            "version": 1, "flag": true, "opt": null
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("logreg_grad"));
        // Round trip through Display.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-3.5", -3.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"c\" é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[[1,2],[3,[4,{"k":[5]}]]]"#).unwrap();
        let inner = v.as_array().unwrap()[1].as_array().unwrap()[1].as_array().unwrap();
        assert_eq!(inner[1].get("k").unwrap().as_array().unwrap()[0].as_f64(), Some(5.0));
    }
}
