//! Plain-text table rendering for experiment output (the printed analogue
//! of the paper's Tables 1–10).

/// A simple aligned text table.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                line.push_str(cell);
                for _ in cell.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = TextTable::new(&["topology", "rho"]);
        t.row(vec!["ring".into(), "0.99".into()]);
        t.row(vec!["one-peer exp".into(), "1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "topology      rho");
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines[2], "ring          0.99");
        assert_eq!(lines[3], "one-peer exp  1");
    }
}
