//! CSV result emission. Every experiment writes its series/rows to
//! `results/<id>.csv` so figures can be re-plotted externally.

use std::fs;
use std::io::Write;
use std::path::Path;

/// The canonical numeric CSV cell: shortest round-trip representation,
/// negative zero normalized to `0`, and **non-finite values as an empty
/// field** — the sink-layer NaN policy (docs/DESIGN.md §Sweep) shared by
/// [`CsvWriter::row_f64`] and [`crate::sweep::Sink`]. Empty-vs-`0`
/// matters: an absent measurement must not plot as a data point.
pub fn num_cell(v: f64) -> String {
    if !v.is_finite() {
        return String::new();
    }
    if v == 0.0 {
        // Collapses -0.0 so cached (JSON round-tripped) results render
        // byte-identically to cold runs.
        return "0".to_string();
    }
    format!("{v}")
}

/// A CSV writer with a fixed header.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row of already-formatted cells. Panics on arity mismatch.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Append a row of f64 values (full precision; non-finite values
    /// render as empty fields via [`num_cell`]).
    pub fn row_f64(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| num_cell(*v)).collect::<Vec<_>>());
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn quote(cell: &str) -> String {
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    /// Render to a CSV string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| Self::quote(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| Self::quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut w = CsvWriter::new(&["n", "rho"]);
        w.row_f64(&[8.0, 0.5]);
        w.row(&["16".into(), "0.25".into()]);
        assert_eq!(w.render(), "n,rho\n8,0.5\n16,0.25\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn quotes_special_cells() {
        let mut w = CsvWriter::new(&["name"]);
        w.row(&["a,b".into()]);
        w.row(&["say \"hi\"".into()]);
        assert_eq!(w.render(), "name\n\"a,b\"\n\"say \"\"hi\"\"\"\n");
    }

    #[test]
    fn num_cell_policy() {
        assert_eq!(num_cell(0.5), "0.5");
        assert_eq!(num_cell(32.0), "32");
        assert_eq!(num_cell(-0.0), "0");
        assert_eq!(num_cell(f64::NAN), "");
        assert_eq!(num_cell(f64::INFINITY), "");
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row_f64(&[1.0, f64::NAN]);
        assert_eq!(w.render(), "a,b\n1,\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }
}
