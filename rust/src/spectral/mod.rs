//! Spectral-gap analysis (Sec. 3 of the paper).
//!
//! `ρ(W) = max_{λ_i(W) ≠ 1} |λ_i(W)|` — the second largest eigenvalue
//! magnitude; `1 − ρ` is the spectral gap. Dispatch:
//!
//! * symmetric `W` (Metropolis topologies) → Jacobi eigensolver,
//! * circulant `W` (exponential graphs) → DFT of the generating vector
//!   (Lemma 2 / Appendix A.2),
//! * anything else → power iteration on the residue, giving `‖W − J‖₂`
//!   which upper-bounds ρ (and equals it for normal matrices).

use crate::linalg::{fft, jacobi, power, Matrix};
use crate::topology::exponential::{self, tau};
use crate::topology::{schedule, TopologyKind};

/// How a ρ value was computed (for reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RhoMethod {
    SymmetricEig,
    CirculantDft,
    ResidueNorm,
}

/// Detect whether `w` is circulant: `w[i][j]` depends only on `(i−j) mod n`.
pub fn is_circulant(w: &Matrix, tol: f64) -> bool {
    let n = w.rows();
    if n != w.cols() {
        return false;
    }
    for i in 1..n {
        for j in 0..n {
            if (w[(i, j)] - w[(0, (j + n - i) % n)]).abs() > tol {
                return false;
            }
        }
    }
    true
}

/// First column of a circulant matrix (its generating vector).
pub fn generating_vector(w: &Matrix) -> Vec<f64> {
    (0..w.rows()).map(|i| w[(i, 0)]).collect()
}

/// ρ of a circulant doubly-stochastic matrix via DFT: drop the `k = 0`
/// (Perron) eigenvalue, take the max remaining magnitude.
pub fn circulant_rho(w: &Matrix) -> f64 {
    let c = generating_vector(w);
    let eigs = fft::circulant_eigenvalues(&c);
    eigs.iter().skip(1).map(|z| z.abs()).fold(0.0, f64::max)
}

/// ρ(W) with method dispatch. Returns `(rho, method)`.
pub fn rho_with_method(w: &Matrix) -> (f64, RhoMethod) {
    if w.is_symmetric(1e-12) {
        (jacobi::sym_rho(w), RhoMethod::SymmetricEig)
    } else if is_circulant(w, 1e-12) {
        (circulant_rho(w), RhoMethod::CirculantDft)
    } else {
        (power::consensus_norm(w), RhoMethod::ResidueNorm)
    }
}

/// ρ(W).
pub fn rho(w: &Matrix) -> f64 {
    rho_with_method(w).0
}

/// Spectral gap `1 − ρ(W)`.
pub fn spectral_gap(w: &Matrix) -> f64 {
    1.0 - rho(w)
}

/// Proposition 1's bound for the static exponential graph:
/// `ρ ≤ (τ−1)/(τ+1)` i.e. `1 − ρ ≥ 2/(τ+1)`, with equality for even n.
pub fn static_exp_rho_bound(n: usize) -> f64 {
    let t = tau(n) as f64;
    (t - 1.0) / (t + 1.0)
}

/// Spectral gap of a topology kind at size `n` (numerical).
pub fn topology_gap(kind: TopologyKind, n: usize, seed: u64) -> f64 {
    let w = schedule::static_weights(kind, n, seed);
    spectral_gap(&w)
}

/// Numerically verify both claims of Proposition 1 for one `n`:
/// returns `(rho_dft, residue_norm, bound)`.
pub fn verify_proposition1(n: usize) -> (f64, f64, f64) {
    let w = exponential::static_exp_weights(n);
    let r = circulant_rho(&w);
    let norm = power::consensus_norm(&w);
    (r, norm, static_exp_rho_bound(n))
}

/// Theory rows of Table 5 (Appendix A.3.2): asymptotic `1−ρ` and max
/// degree per topology, as closed-form functions of `n` where the paper
/// gives them. Declared per family in the registry
/// (docs/DESIGN.md §Topology registry); this wrapper keeps the
/// historical kind-based signature.
pub fn table5_theory(kind: TopologyKind, n: usize) -> (String, String) {
    kind.family().theory_row(n)
}

/// Closed-form ρ of a registered family when one exists (ring,
/// even-`n` static exp, hypercube, the all-reduce baseline) — the
/// registry's `analytic_rho` declaration, exposed next to the numeric
/// dispatch so callers can cross-check the two.
pub fn analytic_rho(topo: crate::topology::Topology, n: usize) -> Option<f64> {
    topo.analytic_rho(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::exponential::static_exp_weights;

    #[test]
    fn proposition1_even_n_exact() {
        // Even n: 1 − ρ = 2/(1+τ) exactly.
        for n in [4usize, 6, 8, 10, 16, 32, 64, 128, 200] {
            let (rho_dft, norm, bound) = verify_proposition1(n);
            assert!(
                (rho_dft - bound).abs() < 1e-10,
                "n={n}: rho={rho_dft} bound={bound}"
            );
            // ‖W − J‖₂ = ρ(W) (second claim of Prop. 1).
            assert!((norm - rho_dft).abs() < 1e-7, "n={n}: norm={norm} rho={rho_dft}");
        }
    }

    #[test]
    fn proposition1_odd_n_strict() {
        // Odd n: ρ strictly below the bound.
        for n in [5usize, 7, 9, 15, 33, 65] {
            let (rho_dft, _, bound) = verify_proposition1(n);
            assert!(rho_dft < bound - 1e-12, "n={n}: rho={rho_dft} !< bound={bound}");
            assert!(rho_dft > 0.0);
        }
    }

    #[test]
    fn circulant_detection() {
        assert!(is_circulant(&static_exp_weights(6), 1e-12));
        assert!(is_circulant(&Matrix::averaging(5), 1e-12));
        let mut w = Matrix::averaging(4);
        w[(0, 1)] += 0.1;
        w[(0, 0)] -= 0.1;
        assert!(!is_circulant(&w, 1e-12));
    }

    #[test]
    fn gap_ordering_matches_figure3() {
        // Fig. 3: gap(static exp) >> gap(grid) > gap(ring) for moderate n.
        let n = 64;
        let g_exp = topology_gap(TopologyKind::StaticExp, n, 0);
        let g_grid = topology_gap(TopologyKind::Grid2D, n, 0);
        let g_ring = topology_gap(TopologyKind::Ring, n, 0);
        assert!(g_exp > g_grid && g_grid > g_ring, "{g_exp} {g_grid} {g_ring}");
        // Exp graph: exactly 2/(1+6) for n=64.
        assert!((g_exp - 2.0 / 7.0).abs() < 1e-10);
    }

    #[test]
    fn hypercube_gap_matches_remark2() {
        // Remark 2: hypercube (Metropolis ≡ 1/(1+log2 n) per edge) has
        // gap 2/(1 + log2 n).
        let n = 16;
        let g = topology_gap(TopologyKind::Hypercube, n, 0);
        assert!((g - 2.0 / 5.0).abs() < 1e-9, "g={g}");
    }

    #[test]
    fn fully_connected_gap_is_one() {
        assert!((topology_gap(TopologyKind::FullyConnected, 8, 0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn analytic_rho_matches_numeric_dispatch() {
        for (name, n) in
            [("ring", 16usize), ("static_exp", 16), ("hypercube", 16), ("fully_connected", 8)]
        {
            let topo = crate::topology::family::find(name).unwrap();
            let want = analytic_rho(topo, n).expect("closed form declared");
            let w = schedule::static_weights(topo.kind().unwrap(), n, 0);
            let (got, _) = rho_with_method(&w);
            assert!((got - want).abs() < 1e-9, "{name}: numeric {got} vs closed form {want}");
        }
        // No closed form declared ⇒ None (numeric dispatch is the path).
        assert!(analytic_rho(crate::topology::family::find("grid").unwrap(), 16).is_none());
        assert!(analytic_rho(crate::topology::family::find("static_exp").unwrap(), 15).is_none());
    }

    #[test]
    fn rho_method_dispatch() {
        let (_, m1) = rho_with_method(&schedule::static_weights(TopologyKind::Ring, 8, 0));
        assert_eq!(m1, RhoMethod::SymmetricEig);
        let (_, m2) = rho_with_method(&static_exp_weights(8));
        assert_eq!(m2, RhoMethod::CirculantDft);
    }
}
