//! Sharded execution engine: a **persistent worker pool** driving the
//! training hot path.
//!
//! PR 1 made per-iteration topology cost O(1); the remaining hot-path
//! overhead was the compute orchestration itself — every iteration
//! spawned and joined fresh OS threads up to three times (gradients in
//! `Trainer::run_with`, then again inside `mix`/`mix_dmsgd`). This
//! module replaces spawn/join with a pool created **once per run**:
//!
//! * [`Engine::new`] spawns `lanes − 1` workers (the caller's thread is
//!   lane 0) that park on a reusable [`std::sync::Barrier`].
//! * [`Engine::run`] broadcasts one shared closure to every lane; two
//!   barrier waits (start, done) bound each round. Zero thread spawns
//!   per iteration, regardless of how many iterations a run takes.
//! * Each lane owns a **contiguous shard of node rows**
//!   ([`shard_range`]): row-local kernels write disjoint row ranges of
//!   the shared `n × P` stacks, handed out as per-lane views by
//!   [`Lanes::split`] (one uncontended `Mutex` per lane keeps the
//!   broadcast closure safe Rust).
//!
//! Determinism: every kernel routed through the engine computes output
//! rows **row-locally in a fixed order** (ascending neighbor index), so
//! results are bitwise-identical for any lane count — pinned by
//! `tests/engine_determinism.rs`. See docs/DESIGN.md §Engine.
//!
//! Alongside the barrier broadcast the engine has a second dispatch
//! mode for the out-of-order async executor: a persistent [`WorkQueue`]
//! of `(node, wave, stage)` tasks drained by the same worker pool
//! inside a single [`Engine::run_queue`] session, with
//! [`Engine::submit_batch`] charging one dispatch per ready batch
//! instead of two barrier crossings per wave (docs/DESIGN.md §Engine,
//! queue-dispatch contract).

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use crate::coordinator::state::StackedParams;
use crate::coordinator::trainer::GradProvider;
use crate::topology::plan::MixingPlan;

/// Threading threshold shared by the engine and the legacy spawn-per-call
/// mixing wrappers: below ~2 MB of streamed f32 state (`n·P < 2^19`
/// elements) the spawn/wake overhead dominates the row-parallel win
/// (measured in docs/DESIGN.md §Engine). One named constant so the two
/// paths cannot drift.
pub const PARALLEL_MIN_ELEMS: usize = 1 << 19;

/// Lane count for a row-parallel job over `n_rows` rows and
/// `total_elems` streamed elements: 1 below [`PARALLEL_MIN_ELEMS`],
/// otherwise `available_parallelism` capped at `n_rows`.
pub fn auto_lanes(n_rows: usize, total_elems: usize) -> usize {
    if total_elems >= PARALLEL_MIN_ELEMS {
        std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1)
            .min(n_rows)
            .max(1)
    } else {
        1
    }
}

/// Lane count for a job running under an external **lane cap** (the
/// sweep scheduler's per-job budget, docs/DESIGN.md §Sweep): the
/// automatic sizing of [`auto_lanes`] clamped to `cap`, so
/// `sweep jobs × engine lanes` never exceeds the machine and small
/// states still get the single-lane fast path.
pub fn budget_lanes(cap: usize, n_rows: usize, total_elems: usize) -> usize {
    auto_lanes(n_rows, total_elems).min(cap.max(1))
}

/// The contiguous row shard lane `lane` owns out of `n` rows split
/// across `lanes` lanes: `⌈n/lanes⌉`-sized blocks, last block short,
/// surplus lanes empty.
pub fn shard_range(n: usize, lanes: usize, lane: usize) -> Range<usize> {
    let per = n.div_ceil(lanes.max(1));
    let start = (lane * per).min(n);
    let end = ((lane + 1) * per).min(n);
    start..end
}

/// Disjoint per-lane mutable views of a row-major buffer, aligned to
/// [`shard_range`]. Each shard sits behind its own `Mutex` so a shared
/// broadcast closure can claim exactly its lane's rows in safe Rust;
/// the locks are uncontended by construction (one lane per slot).
pub struct Lanes<'a, T> {
    slots: Vec<Mutex<&'a mut [T]>>,
}

impl<'a, T> Lanes<'a, T> {
    /// Split `data` (`n_rows × row_len`, row-major) into `lanes` shards.
    /// An empty `data` yields empty shards for every lane (used for
    /// optimizers that skip the secondary scratch stack).
    pub fn split(data: &'a mut [T], n_rows: usize, row_len: usize, lanes: usize) -> Self {
        let mut slots = Vec::with_capacity(lanes);
        if data.is_empty() {
            for _ in 0..lanes {
                let empty: &'a mut [T] = &mut [];
                slots.push(Mutex::new(empty));
            }
            return Lanes { slots };
        }
        assert_eq!(data.len(), n_rows * row_len, "Lanes::split shape mismatch");
        let mut rest = data;
        for lane in 0..lanes {
            let r = shard_range(n_rows, lanes, lane);
            let (head, tail) = rest.split_at_mut((r.end - r.start) * row_len);
            rest = tail;
            slots.push(Mutex::new(head));
        }
        Lanes { slots }
    }

    /// Claim lane `lane`'s shard (uncontended).
    pub fn lock(&self, lane: usize) -> MutexGuard<'_, &'a mut [T]> {
        self.slots[lane].lock().unwrap()
    }

    pub fn lanes(&self) -> usize {
        self.slots.len()
    }
}

/// One unit of out-of-order work: half of node `node`'s wave `wave`.
/// `stage` 0 is the gradient/stage/publish half, `stage` 1 the
/// mix/commit half (docs/DESIGN.md §Async runtime, ready-set loop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueTask {
    pub node: u32,
    pub wave: u32,
    pub stage: u8,
}

struct QueueInner {
    tasks: VecDeque<QueueTask>,
    closed: bool,
    /// Bumped on every push, nudge, and close, so a waiter can detect
    /// "anything happened since I last looked" with one condvar.
    epoch: u64,
}

/// The shared task injector of the queue dispatch mode: a FIFO of
/// unlocked [`QueueTask`]s plus an event epoch. Workers park in
/// [`WorkQueue::pop_wait`]; the coordinator parks in
/// [`WorkQueue::wait_event`] and is woken by task completions
/// ([`WorkQueue::nudge`]) as well as pushes. Closing the queue releases
/// everyone: poppers drain what remains, then observe `None`.
pub struct WorkQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl Default for WorkQueue {
    fn default() -> Self {
        WorkQueue::new()
    }
}

impl WorkQueue {
    pub fn new() -> WorkQueue {
        WorkQueue {
            inner: Mutex::new(QueueInner { tasks: VecDeque::new(), closed: false, epoch: 0 }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, QueueInner> {
        // Nothing panics while holding this lock; tolerate poison anyway
        // so a panicked round cannot wedge the cleanup path.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue a batch of unlocked tasks and wake every parked lane.
    pub fn push_many(&self, tasks: &[QueueTask]) {
        if tasks.is_empty() {
            return;
        }
        let mut g = self.lock();
        g.tasks.extend(tasks.iter().copied());
        g.epoch += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Bump the event epoch without enqueueing — task completions call
    /// this so a coordinator parked in [`WorkQueue::wait_event`] can
    /// re-check its finalization condition.
    pub fn nudge(&self) {
        let mut g = self.lock();
        g.epoch += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Close the queue: poppers drain the remaining tasks, then see
    /// `None`; waiters wake. Idempotent.
    pub fn close(&self) {
        let mut g = self.lock();
        g.closed = true;
        g.epoch += 1;
        drop(g);
        self.cv.notify_all();
    }

    pub fn closed(&self) -> bool {
        self.lock().closed
    }

    /// Current event epoch; pair with [`WorkQueue::wait_event`].
    pub fn epoch(&self) -> u64 {
        self.lock().epoch
    }

    /// Pop without blocking.
    pub fn try_pop(&self) -> Option<QueueTask> {
        self.lock().tasks.pop_front()
    }

    /// Pop, parking until a task arrives or the queue is closed *and*
    /// drained (tasks still enqueued at close time are handed out).
    pub fn pop_wait(&self) -> Option<QueueTask> {
        let mut g = self.lock();
        loop {
            if let Some(t) = g.tasks.pop_front() {
                return Some(t);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Park until the epoch moves past `seen`, a task is available, or
    /// the queue closes. Read `seen` via [`WorkQueue::epoch`] *before*
    /// checking the condition you are waiting on: any event in between
    /// bumps the epoch, so the wait returns immediately instead of
    /// missing the wake-up.
    pub fn wait_event(&self, seen: u64) {
        let mut g = self.lock();
        while g.epoch == seen && !g.closed && g.tasks.is_empty() {
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Raw row-granular view of a shared row-major buffer for the queue
/// dispatch mode, where row ownership is dynamic (whichever lane runs
/// the `(node, wave)` task owns that node's rows) and cannot be
/// expressed as the static per-lane split of [`Lanes`].
///
/// An empty backing buffer yields empty rows for every index (mirroring
/// [`Lanes::split`] — used for optimizers without a secondary stack).
pub struct RowTable<'a, T> {
    ptr: *mut T,
    len: usize,
    row_len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// Safety: a RowTable hands out raw row slices; all aliasing discipline
// is the caller's (see `row_mut`). Moving/sharing the handle itself
// across threads is safe whenever the element type is.
unsafe impl<T: Send> Send for RowTable<'_, T> {}
unsafe impl<T: Send> Sync for RowTable<'_, T> {}

impl<'a, T> RowTable<'a, T> {
    pub fn new(data: &'a mut [T], row_len: usize) -> RowTable<'a, T> {
        if !data.is_empty() {
            assert!(row_len > 0, "RowTable: zero row_len over non-empty data");
            assert_eq!(data.len() % row_len, 0, "RowTable: shape mismatch");
        }
        RowTable { ptr: data.as_mut_ptr(), len: data.len(), row_len, _marker: PhantomData }
    }

    /// Mutable view of row `i`.
    ///
    /// # Safety
    ///
    /// The caller must guarantee no other live reference to row `i`
    /// (the async executor's task DAG makes rows single-writer by
    /// construction, with queue/DAG mutexes ordering the hand-offs).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [T] {
        if self.len == 0 {
            return &mut [];
        }
        let o = i * self.row_len;
        debug_assert!(o + self.row_len <= self.len, "RowTable row {i} out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(o), self.row_len)
    }

    /// Shared view of row `i`.
    ///
    /// # Safety
    ///
    /// The caller must guarantee no concurrent mutable reference to row
    /// `i` (same DAG discipline as [`RowTable::row_mut`]).
    pub unsafe fn row(&self, i: usize) -> &[T] {
        if self.len == 0 {
            return &[];
        }
        let o = i * self.row_len;
        debug_assert!(o + self.row_len <= self.len, "RowTable row {i} out of bounds");
        std::slice::from_raw_parts(self.ptr.add(o), self.row_len)
    }
}

/// The broadcast job slot: a type-erased pointer to the caller's closure,
/// valid strictly between the start and done barriers of one round.
type Job = *const (dyn Fn(usize) + Sync);

struct JobSlot(std::cell::UnsafeCell<Option<Job>>);

// Safety: the slot is written by the driving thread before the start
// barrier and read by workers after it; the done barrier orders the
// subsequent clear. Barrier waits synchronize (they are mutex/condvar
// based), so there is never an unsynchronized concurrent access.
unsafe impl Sync for JobSlot {}
unsafe impl Send for JobSlot {}

struct Shared {
    start: Barrier,
    done: Barrier,
    job: JobSlot,
    shutdown: AtomicBool,
    panicked: AtomicBool,
}

/// Persistent worker pool. Created once per training run; iterations are
/// driven by reusable barriers instead of spawn/join.
pub struct Engine {
    lanes: usize,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    /// Serializes concurrent `run` callers: the job slot and the barrier
    /// pair assume exactly one driving thread per round, and `Engine` is
    /// `Sync` — without this, two safe `&Engine` drivers could race the
    /// slot and the barriers.
    driver: Mutex<()>,
    /// Lifetime count of broadcast rounds (barrier crossings on
    /// multi-lane pools; inline calls on single-lane ones). The benches
    /// read this to report dispatches/iteration — the quantity the
    /// fused probe and the async executor each shave.
    dispatches: AtomicU64,
}

impl Engine {
    /// Pool with `lanes` total lanes: the calling thread is lane 0,
    /// `lanes − 1` workers are spawned **here, once** — the training
    /// loop itself never spawns.
    pub fn new(lanes: usize) -> Engine {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            start: Barrier::new(lanes),
            done: Barrier::new(lanes),
            job: JobSlot(std::cell::UnsafeCell::new(None)),
            shutdown: AtomicBool::new(false),
            panicked: AtomicBool::new(false),
        });
        let workers = (1..lanes)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("engine-{lane}"))
                    .spawn(move || worker_loop(lane, &shared))
                    .expect("engine: failed to spawn worker")
            })
            .collect();
        Engine { lanes, workers, shared, driver: Mutex::new(()), dispatches: AtomicU64::new(0) }
    }

    /// Pool sized by [`auto_lanes`] for an `n_rows × row_len` state.
    pub fn auto(n_rows: usize, row_len: usize) -> Engine {
        Engine::new(auto_lanes(n_rows, n_rows.saturating_mul(row_len)))
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Total broadcast dispatches since creation (see the field docs).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Broadcast `f` to every lane and wait for completion. `f(lane)`
    /// runs once per lane (lane 0 on the calling thread); the call
    /// returns only after all lanes finished, so `f` may borrow local
    /// state. Single-lane engines degrade to a plain call — no barrier
    /// traffic at all.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.lanes == 1 {
            f(0);
            return;
        }
        // One driving thread per round (see the `driver` field docs). A
        // poisoned lock just means a previous driver panicked mid-round
        // after the done barrier; the protocol state is still consistent.
        let _round = self.driver.lock().unwrap_or_else(|p| p.into_inner());
        // Safety: the pointer is only dereferenced by workers between
        // the two barrier waits below, and we do not return until every
        // worker has passed the done barrier — the closure outlives all
        // uses. The transmute erases the borrow lifetime for storage.
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        unsafe {
            *self.shared.job.0.get() = Some(f_erased as Job);
        }
        self.shared.start.wait();
        let main = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        self.shared.done.wait();
        unsafe {
            *self.shared.job.0.get() = None;
        }
        // Clear the worker-panic latch *before* re-raising lane 0's own
        // panic, so a round where both lanes fail cannot poison the next
        // (healthy) round.
        let worker_panicked = self.shared.panicked.swap(false, Ordering::SeqCst);
        if let Err(p) = main {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("engine: a worker lane panicked");
        }
    }

    /// Per-node stochastic gradients for every row, sharded across the
    /// pool: lane `t` computes rows [`shard_range`]`(n, lanes, t)` of
    /// `grads` and the per-node `losses`. Bitwise-identical for any lane
    /// count (each node's minibatch RNG is seeded by its node index).
    pub fn compute_grads(
        &self,
        provider: &dyn GradProvider,
        params: &StackedParams,
        grads: &mut StackedParams,
        losses: &mut [f64],
        iter: usize,
        seed: u64,
    ) {
        let n = params.n;
        let dim = params.dim;
        assert_eq!(grads.n, n);
        assert_eq!(grads.dim, dim, "grads/params dim mismatch");
        assert_eq!(losses.len(), n);
        let lanes = self.lanes;
        let g = grads.lane_shards(lanes);
        let l = Lanes::split(losses, n, 1, lanes);
        self.run(&|lane| {
            let rows = shard_range(n, lanes, lane);
            if rows.is_empty() {
                return;
            }
            let mut gs = g.lock(lane);
            let mut ls = l.lock(lane);
            for (off, i) in rows.enumerate() {
                let out = &mut gs[off * dim..(off + 1) * dim];
                ls[off] = provider.grad(i, params.row(i), iter, seed, out) as f64;
            }
        });
    }

    /// [`Engine::compute_grads`] fused with the consensus probe: one
    /// broadcast fills `grads`/`losses` *and* the per-node partials of
    /// `Σ_i ‖x_i − x̄‖²` against the serial mean, returning the serial
    /// node-ordered reduction. Each per-node quantity is computed by the
    /// exact same code as the unfused pair ([`Engine::compute_grads`]
    /// then [`Engine::consensus_distance`]), just inside a single
    /// barrier round — so results are bitwise-identical to running the
    /// two dispatches back to back, at one fewer crossing.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_grads_probed(
        &self,
        provider: &dyn GradProvider,
        params: &StackedParams,
        grads: &mut StackedParams,
        losses: &mut [f64],
        iter: usize,
        seed: u64,
    ) -> f64 {
        let n = params.n;
        let dim = params.dim;
        assert_eq!(grads.n, n);
        assert_eq!(grads.dim, dim, "grads/params dim mismatch");
        assert_eq!(losses.len(), n);
        let lanes = self.lanes;
        let mean = params.mean();
        let mut per_node = vec![0.0f64; n];
        {
            let g = grads.lane_shards(lanes);
            let l = Lanes::split(losses, n, 1, lanes);
            let p = Lanes::split(&mut per_node, n, 1, lanes);
            self.run(&|lane| {
                let rows = shard_range(n, lanes, lane);
                if rows.is_empty() {
                    return;
                }
                let mut gs = g.lock(lane);
                let mut ls = l.lock(lane);
                let mut ps = p.lock(lane);
                for (off, i) in rows.enumerate() {
                    let out = &mut gs[off * dim..(off + 1) * dim];
                    ls[off] = provider.grad(i, params.row(i), iter, seed, out) as f64;
                    ps[off] = crate::simd::sum_sq_diff(params.row(i), &mean);
                }
            });
        }
        per_node.iter().sum()
    }

    /// Consensus distance `Σ_i ‖x_i − x̄‖²`, the O(nP) metrics probe.
    /// The mean is the serial [`StackedParams::mean`] (lane-independent),
    /// and the sharded pass writes one partial **per node** — the same
    /// ordered per-row reduction [`crate::simd::sum_sq_diff`] the serial
    /// [`StackedParams::consensus_distance`] uses — reduced serially in
    /// node order. So the value is bitwise-identical to the serial probe
    /// and for any lane count, like everything else the engine computes.
    pub fn consensus_distance(&self, params: &StackedParams) -> f64 {
        let n = params.n;
        let lanes = self.lanes;
        // Serial mean, identical to the plain probe's (lane-independent).
        let mean = params.mean();
        // Sharded per-node squared distances (row-local), then a serial
        // node-ordered reduction.
        let mut per_node = vec![0.0f64; n];
        {
            let p = Lanes::split(&mut per_node, n, 1, lanes);
            self.run(&|lane| {
                let rows = shard_range(n, lanes, lane);
                if rows.is_empty() {
                    return;
                }
                let mut ps = p.lock(lane);
                for (off, i) in rows.enumerate() {
                    ps[off] = crate::simd::sum_sq_diff(params.row(i), &mean);
                }
            });
        }
        per_node.iter().sum()
    }

    /// One sharded gossip step `out = W x` in f64 (the consensus
    /// simulation path): row-local sparse dot products, matching
    /// [`MixingPlan::matvec`] bitwise for any lane count.
    pub fn gossip_into(&self, plan: &MixingPlan, x: &[f64], out: &mut [f64]) {
        let n = plan.n;
        assert_eq!(x.len(), n, "gossip dimension mismatch");
        assert_eq!(out.len(), n, "gossip output mismatch");
        let lanes = self.lanes;
        let o = Lanes::split(out, n, 1, lanes);
        self.run(&|lane| {
            let rows = shard_range(n, lanes, lane);
            if rows.is_empty() {
                return;
            }
            let mut os = o.lock(lane);
            for (off, i) in rows.enumerate() {
                let r = plan.row(i);
                os[off] = r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| w * x[j as usize]).sum();
            }
        });
    }

    /// Enqueue a ready batch of tasks into `queue`, charging exactly
    /// **one dispatch per call** regardless of batch size — the
    /// accounting unit behind the out-of-order executor's amortized-O(1)
    /// dispatches per ready batch (vs two barrier crossings per wave for
    /// the broadcast mode).
    pub fn submit_batch(&self, queue: &WorkQueue, tasks: &[QueueTask]) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        queue.push_many(tasks);
    }

    /// Queue dispatch session: worker lanes `1..lanes` drain `queue`
    /// (each popped task runs `task(lane, t)`), while `coordinator` runs
    /// on the **calling thread** (lane 0) with whatever `&mut` captures
    /// it needs — it typically creates waves, submits ready batches via
    /// [`Engine::submit_batch`], helps drain with
    /// [`WorkQueue::try_pop`], and parks in [`WorkQueue::wait_event`]
    /// between events. The session ends when `coordinator` returns: the
    /// queue is closed, workers drain the leftovers and rejoin the done
    /// barrier. One dispatch for the whole session.
    ///
    /// Panic protocol mirrors [`Engine::run`]: a panicking task closes
    /// the queue (waking everyone) and latches the worker-panic flag; a
    /// coordinator panic is re-raised after the pool quiesces, taking
    /// precedence over the latch.
    pub fn run_queue(
        &self,
        queue: &WorkQueue,
        task: &(dyn Fn(usize, QueueTask) + Sync),
        coordinator: &mut dyn FnMut(),
    ) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.lanes == 1 {
            // Single lane: the coordinator drains everything itself via
            // try_pop (it never parks — the queue holds a runnable task
            // whenever its wave-completion condition is unmet).
            let main = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| coordinator()));
            queue.close();
            if let Err(p) = main {
                std::panic::resume_unwind(p);
            }
            return;
        }
        let _round = self.driver.lock().unwrap_or_else(|p| p.into_inner());
        let drain = |lane: usize| {
            while let Some(t) = queue.pop_wait() {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(lane, t)));
                if let Err(p) = r {
                    // Wake the coordinator and the other lanes, then let
                    // worker_loop's catch_unwind latch the panic flag.
                    queue.close();
                    std::panic::resume_unwind(p);
                }
            }
        };
        let drain_ref: &(dyn Fn(usize) + Sync) = &drain;
        // Safety: same lifetime-erasure contract as `run` — the job is
        // only dereferenced between the two barriers, and we do not
        // return until every worker passed the done barrier.
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                drain_ref,
            )
        };
        unsafe {
            *self.shared.job.0.get() = Some(f_erased as Job);
        }
        self.shared.start.wait();
        let main = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| coordinator()));
        // Session over (or coordinator panicked): release the drain loops.
        queue.close();
        self.shared.done.wait();
        unsafe {
            *self.shared.job.0.get() = None;
        }
        let worker_panicked = self.shared.panicked.swap(false, Ordering::SeqCst);
        if let Err(p) = main {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("engine: a worker lane panicked");
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        // Release the workers from their start barrier; they observe the
        // shutdown flag and exit without touching the (empty) job slot.
        self.shared.start.wait();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(lane: usize, shared: &Shared) {
    loop {
        shared.start.wait();
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Safety: the driving thread published the job before the start
        // barrier and will not clear it until after the done barrier.
        let job = unsafe { (*shared.job.0.get()).expect("engine: no job published") };
        let f = unsafe { &*job };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lane))).is_err() {
            shared.panicked.store(true, Ordering::SeqCst);
        }
        shared.done.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn shard_range_partitions_rows() {
        for (n, lanes) in [(8usize, 3usize), (1, 4), (16, 16), (10, 1), (5, 8)] {
            let mut covered = Vec::new();
            for lane in 0..lanes {
                covered.extend(shard_range(n, lanes, lane));
            }
            assert_eq!(covered, (0..n).collect::<Vec<_>>(), "n={n} lanes={lanes}");
        }
    }

    #[test]
    fn auto_lanes_threshold() {
        assert_eq!(auto_lanes(8, PARALLEL_MIN_ELEMS - 1), 1);
        let big = auto_lanes(1024, PARALLEL_MIN_ELEMS);
        assert!((1..=1024).contains(&big));
        // Never more lanes than rows.
        assert_eq!(auto_lanes(1, PARALLEL_MIN_ELEMS), 1);
    }

    #[test]
    fn budget_lanes_caps_auto_sizing() {
        // Below the threshold the cap is irrelevant: one lane.
        assert_eq!(budget_lanes(16, 8, PARALLEL_MIN_ELEMS - 1), 1);
        // Above it, the cap clamps whatever auto sizing picked.
        assert_eq!(budget_lanes(1, 1024, PARALLEL_MIN_ELEMS), 1);
        assert!(budget_lanes(2, 1024, PARALLEL_MIN_ELEMS) <= 2);
        // A zero cap still yields a runnable single lane.
        assert_eq!(budget_lanes(0, 1024, PARALLEL_MIN_ELEMS), 1);
    }

    #[test]
    fn engine_reuses_workers_across_rounds() {
        let engine = Engine::new(4);
        let hits = AtomicUsize::new(0);
        let lanes_seen = Mutex::new(vec![false; 4]);
        for _ in 0..100 {
            engine.run(&|lane| {
                hits.fetch_add(1, Ordering::SeqCst);
                lanes_seen.lock().unwrap()[lane] = true;
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 400);
        assert!(lanes_seen.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn single_lane_engine_runs_inline() {
        let engine = Engine::new(1);
        let hit = AtomicBool::new(false);
        engine.run(&|lane| {
            assert_eq!(lane, 0);
            hit.store(true, Ordering::SeqCst);
        });
        assert!(hit.load(Ordering::SeqCst));
    }

    #[test]
    fn lanes_split_disjoint_row_views() {
        let mut data = vec![0.0f32; 10 * 3];
        let lanes = Lanes::split(&mut data, 10, 3, 4);
        assert_eq!(lanes.lanes(), 4);
        for lane in 0..4 {
            let mut shard = lanes.lock(lane);
            let r = shard_range(10, 4, lane);
            assert_eq!(shard.len(), (r.end - r.start) * 3);
            for v in shard.iter_mut() {
                *v = lane as f32;
            }
        }
        drop(lanes);
        for lane in 0..4usize {
            for i in shard_range(10, 4, lane) {
                assert_eq!(data[i * 3], lane as f32);
            }
        }
    }

    #[test]
    fn lanes_split_empty_buffer() {
        let mut data: Vec<f32> = Vec::new();
        let lanes = Lanes::split(&mut data, 7, 5, 3);
        for lane in 0..3 {
            assert!(lanes.lock(lane).is_empty());
        }
    }

    #[test]
    fn gossip_matches_matvec_any_lane_count() {
        let plan = crate::topology::exponential::static_exp_plan(12);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let want = plan.matvec(&x);
        for lanes in [1usize, 2, 3, 5] {
            let engine = Engine::new(lanes);
            let mut out = vec![0.0f64; 12];
            engine.gossip_into(&plan, &x, &mut out);
            assert_eq!(out, want, "lanes={lanes}");
        }
    }

    #[test]
    fn engine_consensus_distance_matches_serial() {
        let mut s = StackedParams::zeros(9, 7);
        let mut rng = crate::util::rng::Pcg::seeded(11);
        for v in s.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        // Same f32 mean and f32 differences as the plain serial probe —
        // only the f64 per-node regrouping can differ.
        let want = s.consensus_distance();
        let base = Engine::new(1).consensus_distance(&s);
        assert!(
            (base - want).abs() < 1e-12 * want.max(1.0),
            "engine probe drifted from serial: {base} vs {want}"
        );
        // …and bitwise lane-count-invariant (per-node partials reduced
        // in node order).
        for lanes in [2usize, 3, 4, 9] {
            let engine = Engine::new(lanes);
            let got = engine.consensus_distance(&s);
            assert_eq!(got.to_bits(), base.to_bits(), "lanes={lanes}: {got} vs {base}");
        }
    }

    #[test]
    fn work_queue_fifo_close_drains_then_none() {
        let q = WorkQueue::new();
        let t = |n: u32| QueueTask { node: n, wave: 0, stage: 0 };
        q.push_many(&[t(1), t(2)]);
        assert_eq!(q.try_pop(), Some(t(1)));
        q.close();
        assert!(q.closed());
        // A closed queue still hands out what was enqueued…
        assert_eq!(q.pop_wait(), Some(t(2)));
        // …then reports exhaustion instead of parking.
        assert_eq!(q.pop_wait(), None);
        // Pushes bump the epoch; nudges do too, without enqueueing.
        let e = q.epoch();
        q.nudge();
        assert!(q.epoch() > e);
        // wait_event with a stale epoch returns immediately.
        q.wait_event(e);
    }

    #[test]
    fn run_queue_executes_all_tasks_any_lane_count() {
        for lanes in [1usize, 2, 4] {
            let engine = Engine::new(lanes);
            let queue = WorkQueue::new();
            let total = 64u32;
            let hits = AtomicUsize::new(0);
            let base = engine.dispatches();
            let tasks: Vec<QueueTask> =
                (0..total).map(|n| QueueTask { node: n, wave: 0, stage: 0 }).collect();
            engine.submit_batch(&queue, &tasks);
            let work = |_lane: usize, _t: QueueTask| {
                hits.fetch_add(1, Ordering::SeqCst);
                queue.nudge();
            };
            engine.run_queue(&queue, &work, &mut || loop {
                if let Some(t) = queue.try_pop() {
                    work(0, t);
                    continue;
                }
                let seen = queue.epoch();
                if hits.load(Ordering::SeqCst) as u32 == total {
                    break;
                }
                queue.wait_event(seen);
            });
            assert_eq!(hits.load(Ordering::SeqCst) as u32, total, "lanes={lanes}");
            // One dispatch for the batch, one for the session.
            assert_eq!(engine.dispatches() - base, 2, "lanes={lanes}");
        }
    }

    #[test]
    fn run_queue_worker_panic_propagates_and_pool_survives() {
        let engine = Engine::new(3);
        let queue = WorkQueue::new();
        let tasks: Vec<QueueTask> =
            (0..8u32).map(|n| QueueTask { node: n, wave: 0, stage: 0 }).collect();
        engine.submit_batch(&queue, &tasks);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_queue(
                &queue,
                &|_, t| {
                    if t.node == 3 {
                        panic!("task boom");
                    }
                    queue.nudge();
                },
                &mut || {
                    // Park until the failing task closes the queue.
                    loop {
                        if queue.closed() {
                            panic!("worker lane failed");
                        }
                        let seen = queue.epoch();
                        queue.wait_event(seen);
                    }
                },
            );
        }));
        assert!(caught.is_err());
        // The barrier protocol stays consistent: broadcast still works.
        let hits = AtomicUsize::new(0);
        engine.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn row_table_rows_are_disjoint_and_empty_backing_is_empty() {
        let mut data = vec![0.0f32; 4 * 3];
        let tab = RowTable::new(&mut data, 3);
        for i in 0..4 {
            // Safety: rows touched one at a time.
            let r = unsafe { tab.row_mut(i) };
            r.fill(i as f32);
        }
        for i in 0..4 {
            assert_eq!(unsafe { tab.row(i) }, &[i as f32; 3]);
        }
        drop(tab);
        assert_eq!(data[9], 3.0);
        let mut empty: Vec<f32> = Vec::new();
        let tab = RowTable::new(&mut empty, 5);
        assert!(unsafe { tab.row(2) }.is_empty());
        assert!(unsafe { tab.row_mut(7) }.is_empty());
    }

    #[test]
    fn engine_panic_in_worker_propagates() {
        let engine = Engine::new(3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run(&|lane| {
                if lane == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // Pool stays usable after a worker panic.
        let hits = AtomicUsize::new(0);
        engine.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }
}
