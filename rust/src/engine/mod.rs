//! Sharded execution engine: a **persistent worker pool** driving the
//! training hot path.
//!
//! PR 1 made per-iteration topology cost O(1); the remaining hot-path
//! overhead was the compute orchestration itself — every iteration
//! spawned and joined fresh OS threads up to three times (gradients in
//! `Trainer::run_with`, then again inside `mix`/`mix_dmsgd`). This
//! module replaces spawn/join with a pool created **once per run**:
//!
//! * [`Engine::new`] spawns `lanes − 1` workers (the caller's thread is
//!   lane 0) that park on a reusable [`std::sync::Barrier`].
//! * [`Engine::run`] broadcasts one shared closure to every lane; two
//!   barrier waits (start, done) bound each round. Zero thread spawns
//!   per iteration, regardless of how many iterations a run takes.
//! * Each lane owns a **contiguous shard of node rows**
//!   ([`shard_range`]): row-local kernels write disjoint row ranges of
//!   the shared `n × P` stacks, handed out as per-lane views by
//!   [`Lanes::split`] (one uncontended `Mutex` per lane keeps the
//!   broadcast closure safe Rust).
//!
//! Determinism: every kernel routed through the engine computes output
//! rows **row-locally in a fixed order** (ascending neighbor index), so
//! results are bitwise-identical for any lane count — pinned by
//! `tests/engine_determinism.rs`. See docs/DESIGN.md §Engine.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::thread::JoinHandle;

use crate::coordinator::state::StackedParams;
use crate::coordinator::trainer::GradProvider;
use crate::topology::plan::MixingPlan;

/// Threading threshold shared by the engine and the legacy spawn-per-call
/// mixing wrappers: below ~2 MB of streamed f32 state (`n·P < 2^19`
/// elements) the spawn/wake overhead dominates the row-parallel win
/// (measured in docs/DESIGN.md §Engine). One named constant so the two
/// paths cannot drift.
pub const PARALLEL_MIN_ELEMS: usize = 1 << 19;

/// Lane count for a row-parallel job over `n_rows` rows and
/// `total_elems` streamed elements: 1 below [`PARALLEL_MIN_ELEMS`],
/// otherwise `available_parallelism` capped at `n_rows`.
pub fn auto_lanes(n_rows: usize, total_elems: usize) -> usize {
    if total_elems >= PARALLEL_MIN_ELEMS {
        std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1)
            .min(n_rows)
            .max(1)
    } else {
        1
    }
}

/// Lane count for a job running under an external **lane cap** (the
/// sweep scheduler's per-job budget, docs/DESIGN.md §Sweep): the
/// automatic sizing of [`auto_lanes`] clamped to `cap`, so
/// `sweep jobs × engine lanes` never exceeds the machine and small
/// states still get the single-lane fast path.
pub fn budget_lanes(cap: usize, n_rows: usize, total_elems: usize) -> usize {
    auto_lanes(n_rows, total_elems).min(cap.max(1))
}

/// The contiguous row shard lane `lane` owns out of `n` rows split
/// across `lanes` lanes: `⌈n/lanes⌉`-sized blocks, last block short,
/// surplus lanes empty.
pub fn shard_range(n: usize, lanes: usize, lane: usize) -> Range<usize> {
    let per = n.div_ceil(lanes.max(1));
    let start = (lane * per).min(n);
    let end = ((lane + 1) * per).min(n);
    start..end
}

/// Disjoint per-lane mutable views of a row-major buffer, aligned to
/// [`shard_range`]. Each shard sits behind its own `Mutex` so a shared
/// broadcast closure can claim exactly its lane's rows in safe Rust;
/// the locks are uncontended by construction (one lane per slot).
pub struct Lanes<'a, T> {
    slots: Vec<Mutex<&'a mut [T]>>,
}

impl<'a, T> Lanes<'a, T> {
    /// Split `data` (`n_rows × row_len`, row-major) into `lanes` shards.
    /// An empty `data` yields empty shards for every lane (used for
    /// optimizers that skip the secondary scratch stack).
    pub fn split(data: &'a mut [T], n_rows: usize, row_len: usize, lanes: usize) -> Self {
        let mut slots = Vec::with_capacity(lanes);
        if data.is_empty() {
            for _ in 0..lanes {
                let empty: &'a mut [T] = &mut [];
                slots.push(Mutex::new(empty));
            }
            return Lanes { slots };
        }
        assert_eq!(data.len(), n_rows * row_len, "Lanes::split shape mismatch");
        let mut rest = data;
        for lane in 0..lanes {
            let r = shard_range(n_rows, lanes, lane);
            let (head, tail) = rest.split_at_mut((r.end - r.start) * row_len);
            rest = tail;
            slots.push(Mutex::new(head));
        }
        Lanes { slots }
    }

    /// Claim lane `lane`'s shard (uncontended).
    pub fn lock(&self, lane: usize) -> MutexGuard<'_, &'a mut [T]> {
        self.slots[lane].lock().unwrap()
    }

    pub fn lanes(&self) -> usize {
        self.slots.len()
    }
}

/// The broadcast job slot: a type-erased pointer to the caller's closure,
/// valid strictly between the start and done barriers of one round.
type Job = *const (dyn Fn(usize) + Sync);

struct JobSlot(std::cell::UnsafeCell<Option<Job>>);

// Safety: the slot is written by the driving thread before the start
// barrier and read by workers after it; the done barrier orders the
// subsequent clear. Barrier waits synchronize (they are mutex/condvar
// based), so there is never an unsynchronized concurrent access.
unsafe impl Sync for JobSlot {}
unsafe impl Send for JobSlot {}

struct Shared {
    start: Barrier,
    done: Barrier,
    job: JobSlot,
    shutdown: AtomicBool,
    panicked: AtomicBool,
}

/// Persistent worker pool. Created once per training run; iterations are
/// driven by reusable barriers instead of spawn/join.
pub struct Engine {
    lanes: usize,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    /// Serializes concurrent `run` callers: the job slot and the barrier
    /// pair assume exactly one driving thread per round, and `Engine` is
    /// `Sync` — without this, two safe `&Engine` drivers could race the
    /// slot and the barriers.
    driver: Mutex<()>,
    /// Lifetime count of broadcast rounds (barrier crossings on
    /// multi-lane pools; inline calls on single-lane ones). The benches
    /// read this to report dispatches/iteration — the quantity the
    /// fused probe and the async executor each shave.
    dispatches: AtomicU64,
}

impl Engine {
    /// Pool with `lanes` total lanes: the calling thread is lane 0,
    /// `lanes − 1` workers are spawned **here, once** — the training
    /// loop itself never spawns.
    pub fn new(lanes: usize) -> Engine {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            start: Barrier::new(lanes),
            done: Barrier::new(lanes),
            job: JobSlot(std::cell::UnsafeCell::new(None)),
            shutdown: AtomicBool::new(false),
            panicked: AtomicBool::new(false),
        });
        let workers = (1..lanes)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("engine-{lane}"))
                    .spawn(move || worker_loop(lane, &shared))
                    .expect("engine: failed to spawn worker")
            })
            .collect();
        Engine { lanes, workers, shared, driver: Mutex::new(()), dispatches: AtomicU64::new(0) }
    }

    /// Pool sized by [`auto_lanes`] for an `n_rows × row_len` state.
    pub fn auto(n_rows: usize, row_len: usize) -> Engine {
        Engine::new(auto_lanes(n_rows, n_rows.saturating_mul(row_len)))
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Total broadcast dispatches since creation (see the field docs).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Broadcast `f` to every lane and wait for completion. `f(lane)`
    /// runs once per lane (lane 0 on the calling thread); the call
    /// returns only after all lanes finished, so `f` may borrow local
    /// state. Single-lane engines degrade to a plain call — no barrier
    /// traffic at all.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.lanes == 1 {
            f(0);
            return;
        }
        // One driving thread per round (see the `driver` field docs). A
        // poisoned lock just means a previous driver panicked mid-round
        // after the done barrier; the protocol state is still consistent.
        let _round = self.driver.lock().unwrap_or_else(|p| p.into_inner());
        // Safety: the pointer is only dereferenced by workers between
        // the two barrier waits below, and we do not return until every
        // worker has passed the done barrier — the closure outlives all
        // uses. The transmute erases the borrow lifetime for storage.
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        unsafe {
            *self.shared.job.0.get() = Some(f_erased as Job);
        }
        self.shared.start.wait();
        let main = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        self.shared.done.wait();
        unsafe {
            *self.shared.job.0.get() = None;
        }
        // Clear the worker-panic latch *before* re-raising lane 0's own
        // panic, so a round where both lanes fail cannot poison the next
        // (healthy) round.
        let worker_panicked = self.shared.panicked.swap(false, Ordering::SeqCst);
        if let Err(p) = main {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("engine: a worker lane panicked");
        }
    }

    /// Per-node stochastic gradients for every row, sharded across the
    /// pool: lane `t` computes rows [`shard_range`]`(n, lanes, t)` of
    /// `grads` and the per-node `losses`. Bitwise-identical for any lane
    /// count (each node's minibatch RNG is seeded by its node index).
    pub fn compute_grads(
        &self,
        provider: &dyn GradProvider,
        params: &StackedParams,
        grads: &mut StackedParams,
        losses: &mut [f64],
        iter: usize,
        seed: u64,
    ) {
        let n = params.n;
        let dim = params.dim;
        assert_eq!(grads.n, n);
        assert_eq!(grads.dim, dim, "grads/params dim mismatch");
        assert_eq!(losses.len(), n);
        let lanes = self.lanes;
        let g = grads.lane_shards(lanes);
        let l = Lanes::split(losses, n, 1, lanes);
        self.run(&|lane| {
            let rows = shard_range(n, lanes, lane);
            if rows.is_empty() {
                return;
            }
            let mut gs = g.lock(lane);
            let mut ls = l.lock(lane);
            for (off, i) in rows.enumerate() {
                let out = &mut gs[off * dim..(off + 1) * dim];
                ls[off] = provider.grad(i, params.row(i), iter, seed, out) as f64;
            }
        });
    }

    /// [`Engine::compute_grads`] fused with the consensus probe: one
    /// broadcast fills `grads`/`losses` *and* the per-node partials of
    /// `Σ_i ‖x_i − x̄‖²` against the serial mean, returning the serial
    /// node-ordered reduction. Each per-node quantity is computed by the
    /// exact same code as the unfused pair ([`Engine::compute_grads`]
    /// then [`Engine::consensus_distance`]), just inside a single
    /// barrier round — so results are bitwise-identical to running the
    /// two dispatches back to back, at one fewer crossing.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_grads_probed(
        &self,
        provider: &dyn GradProvider,
        params: &StackedParams,
        grads: &mut StackedParams,
        losses: &mut [f64],
        iter: usize,
        seed: u64,
    ) -> f64 {
        let n = params.n;
        let dim = params.dim;
        assert_eq!(grads.n, n);
        assert_eq!(grads.dim, dim, "grads/params dim mismatch");
        assert_eq!(losses.len(), n);
        let lanes = self.lanes;
        let mean = params.mean();
        let mut per_node = vec![0.0f64; n];
        {
            let g = grads.lane_shards(lanes);
            let l = Lanes::split(losses, n, 1, lanes);
            let p = Lanes::split(&mut per_node, n, 1, lanes);
            self.run(&|lane| {
                let rows = shard_range(n, lanes, lane);
                if rows.is_empty() {
                    return;
                }
                let mut gs = g.lock(lane);
                let mut ls = l.lock(lane);
                let mut ps = p.lock(lane);
                for (off, i) in rows.enumerate() {
                    let out = &mut gs[off * dim..(off + 1) * dim];
                    ls[off] = provider.grad(i, params.row(i), iter, seed, out) as f64;
                    ps[off] = crate::simd::sum_sq_diff(params.row(i), &mean);
                }
            });
        }
        per_node.iter().sum()
    }

    /// Consensus distance `Σ_i ‖x_i − x̄‖²`, the O(nP) metrics probe.
    /// The mean is the serial [`StackedParams::mean`] (lane-independent),
    /// and the sharded pass writes one partial **per node** — the same
    /// ordered per-row reduction [`crate::simd::sum_sq_diff`] the serial
    /// [`StackedParams::consensus_distance`] uses — reduced serially in
    /// node order. So the value is bitwise-identical to the serial probe
    /// and for any lane count, like everything else the engine computes.
    pub fn consensus_distance(&self, params: &StackedParams) -> f64 {
        let n = params.n;
        let lanes = self.lanes;
        // Serial mean, identical to the plain probe's (lane-independent).
        let mean = params.mean();
        // Sharded per-node squared distances (row-local), then a serial
        // node-ordered reduction.
        let mut per_node = vec![0.0f64; n];
        {
            let p = Lanes::split(&mut per_node, n, 1, lanes);
            self.run(&|lane| {
                let rows = shard_range(n, lanes, lane);
                if rows.is_empty() {
                    return;
                }
                let mut ps = p.lock(lane);
                for (off, i) in rows.enumerate() {
                    ps[off] = crate::simd::sum_sq_diff(params.row(i), &mean);
                }
            });
        }
        per_node.iter().sum()
    }

    /// One sharded gossip step `out = W x` in f64 (the consensus
    /// simulation path): row-local sparse dot products, matching
    /// [`MixingPlan::matvec`] bitwise for any lane count.
    pub fn gossip_into(&self, plan: &MixingPlan, x: &[f64], out: &mut [f64]) {
        let n = plan.n;
        assert_eq!(x.len(), n, "gossip dimension mismatch");
        assert_eq!(out.len(), n, "gossip output mismatch");
        let lanes = self.lanes;
        let o = Lanes::split(out, n, 1, lanes);
        self.run(&|lane| {
            let rows = shard_range(n, lanes, lane);
            if rows.is_empty() {
                return;
            }
            let mut os = o.lock(lane);
            for (off, i) in rows.enumerate() {
                let r = plan.row(i);
                os[off] = r.cols.iter().zip(r.w64.iter()).map(|(&j, &w)| w * x[j as usize]).sum();
            }
        });
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        // Release the workers from their start barrier; they observe the
        // shutdown flag and exit without touching the (empty) job slot.
        self.shared.start.wait();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(lane: usize, shared: &Shared) {
    loop {
        shared.start.wait();
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Safety: the driving thread published the job before the start
        // barrier and will not clear it until after the done barrier.
        let job = unsafe { (*shared.job.0.get()).expect("engine: no job published") };
        let f = unsafe { &*job };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lane))).is_err() {
            shared.panicked.store(true, Ordering::SeqCst);
        }
        shared.done.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn shard_range_partitions_rows() {
        for (n, lanes) in [(8usize, 3usize), (1, 4), (16, 16), (10, 1), (5, 8)] {
            let mut covered = Vec::new();
            for lane in 0..lanes {
                covered.extend(shard_range(n, lanes, lane));
            }
            assert_eq!(covered, (0..n).collect::<Vec<_>>(), "n={n} lanes={lanes}");
        }
    }

    #[test]
    fn auto_lanes_threshold() {
        assert_eq!(auto_lanes(8, PARALLEL_MIN_ELEMS - 1), 1);
        let big = auto_lanes(1024, PARALLEL_MIN_ELEMS);
        assert!((1..=1024).contains(&big));
        // Never more lanes than rows.
        assert_eq!(auto_lanes(1, PARALLEL_MIN_ELEMS), 1);
    }

    #[test]
    fn budget_lanes_caps_auto_sizing() {
        // Below the threshold the cap is irrelevant: one lane.
        assert_eq!(budget_lanes(16, 8, PARALLEL_MIN_ELEMS - 1), 1);
        // Above it, the cap clamps whatever auto sizing picked.
        assert_eq!(budget_lanes(1, 1024, PARALLEL_MIN_ELEMS), 1);
        assert!(budget_lanes(2, 1024, PARALLEL_MIN_ELEMS) <= 2);
        // A zero cap still yields a runnable single lane.
        assert_eq!(budget_lanes(0, 1024, PARALLEL_MIN_ELEMS), 1);
    }

    #[test]
    fn engine_reuses_workers_across_rounds() {
        let engine = Engine::new(4);
        let hits = AtomicUsize::new(0);
        let lanes_seen = Mutex::new(vec![false; 4]);
        for _ in 0..100 {
            engine.run(&|lane| {
                hits.fetch_add(1, Ordering::SeqCst);
                lanes_seen.lock().unwrap()[lane] = true;
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 400);
        assert!(lanes_seen.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn single_lane_engine_runs_inline() {
        let engine = Engine::new(1);
        let hit = AtomicBool::new(false);
        engine.run(&|lane| {
            assert_eq!(lane, 0);
            hit.store(true, Ordering::SeqCst);
        });
        assert!(hit.load(Ordering::SeqCst));
    }

    #[test]
    fn lanes_split_disjoint_row_views() {
        let mut data = vec![0.0f32; 10 * 3];
        let lanes = Lanes::split(&mut data, 10, 3, 4);
        assert_eq!(lanes.lanes(), 4);
        for lane in 0..4 {
            let mut shard = lanes.lock(lane);
            let r = shard_range(10, 4, lane);
            assert_eq!(shard.len(), (r.end - r.start) * 3);
            for v in shard.iter_mut() {
                *v = lane as f32;
            }
        }
        drop(lanes);
        for lane in 0..4usize {
            for i in shard_range(10, 4, lane) {
                assert_eq!(data[i * 3], lane as f32);
            }
        }
    }

    #[test]
    fn lanes_split_empty_buffer() {
        let mut data: Vec<f32> = Vec::new();
        let lanes = Lanes::split(&mut data, 7, 5, 3);
        for lane in 0..3 {
            assert!(lanes.lock(lane).is_empty());
        }
    }

    #[test]
    fn gossip_matches_matvec_any_lane_count() {
        let plan = crate::topology::exponential::static_exp_plan(12);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let want = plan.matvec(&x);
        for lanes in [1usize, 2, 3, 5] {
            let engine = Engine::new(lanes);
            let mut out = vec![0.0f64; 12];
            engine.gossip_into(&plan, &x, &mut out);
            assert_eq!(out, want, "lanes={lanes}");
        }
    }

    #[test]
    fn engine_consensus_distance_matches_serial() {
        let mut s = StackedParams::zeros(9, 7);
        let mut rng = crate::util::rng::Pcg::seeded(11);
        for v in s.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        // Same f32 mean and f32 differences as the plain serial probe —
        // only the f64 per-node regrouping can differ.
        let want = s.consensus_distance();
        let base = Engine::new(1).consensus_distance(&s);
        assert!(
            (base - want).abs() < 1e-12 * want.max(1.0),
            "engine probe drifted from serial: {base} vs {want}"
        );
        // …and bitwise lane-count-invariant (per-node partials reduced
        // in node order).
        for lanes in [2usize, 3, 4, 9] {
            let engine = Engine::new(lanes);
            let got = engine.consensus_distance(&s);
            assert_eq!(got.to_bits(), base.to_bits(), "lanes={lanes}: {got} vs {base}");
        }
    }

    #[test]
    fn engine_panic_in_worker_propagates() {
        let engine = Engine::new(3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run(&|lane| {
                if lane == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // Pool stays usable after a worker panic.
        let hits = AtomicUsize::new(0);
        engine.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }
}
