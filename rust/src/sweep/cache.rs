//! On-disk sweep result cache: completed cells persist their records
//! under `<out_dir>/.cache/` keyed by (experiment id, cell-spec hash,
//! seed, scale), so re-running `exp all` skips every completed training
//! cell. A cache entry embeds its full key string, so a hash collision
//! or a stale entry from an older spec shape degrades to a miss, never
//! to wrong data.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::sink::Record;
use crate::util::json::Json;

/// FNV-1a, the classic 64-bit string hash — stable across runs and
/// platforms (cache file names must not depend on `DefaultHasher`'s
/// per-process seed).
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Cache format tag folded into every key (alongside the automatic
/// source fingerprint below); bump it if the on-disk entry *encoding*
/// itself ever changes shape.
pub const FORMAT: &str = "sweep-v1";

/// FNV-1a over every `.rs` file under `rust/src/`, computed by
/// `build.rs`. Folding it into the key means **any source change
/// invalidates the whole cache** — a fixed optimizer kernel or a new
/// sink column can never be silently papered over by results computed
/// with an older binary (the failure mode that matters most in a
/// paper-reproduction repo).
pub const SRC_FINGERPRINT: &str = env!("EXPOGRAPH_SRC_FINGERPRINT");

/// The full cache key: format tag, source fingerprint, experiment id,
/// seed, and scale prefix the cell-spec key, so changing any of them
/// invalidates every cell.
pub fn full_key(id: &str, seed: u64, scale: f64, cell_key: &str) -> String {
    format!("{FORMAT}|src={SRC_FINGERPRINT}|{id}|seed={seed}|scale={scale}|{cell_key}")
}

/// Handle on one sweep cache directory.
#[derive(Clone, Debug)]
pub struct Cache {
    dir: PathBuf,
}

impl Cache {
    /// Cache under `<out_dir>/.cache/` (created lazily on first store).
    pub fn under(out_dir: &Path) -> Cache {
        Cache { dir: out_dir.join(".cache") }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, id: &str, full_key: &str) -> PathBuf {
        self.dir.join(format!("{id}-{:016x}.json", fnv1a(full_key)))
    }

    /// Look a cell up; any failure (absent, unparseable, key mismatch)
    /// is a miss.
    pub fn load(&self, id: &str, full_key: &str) -> Option<Vec<Record>> {
        let text = std::fs::read_to_string(self.path(id, full_key)).ok()?;
        let doc = Json::parse(&text).ok()?;
        if doc.get("key")?.as_str()? != full_key {
            return None;
        }
        doc.get("records")?.as_array()?.iter().map(Record::from_json).collect()
    }

    /// Persist a completed cell. Failure is a warning, never an error —
    /// a read-only results directory must not fail the sweep itself.
    pub fn store(&self, id: &str, full_key: &str, records: &[Record]) {
        let mut root = BTreeMap::new();
        root.insert("key".to_string(), Json::Str(full_key.to_string()));
        root.insert(
            "records".to_string(),
            Json::Arr(records.iter().map(Record::to_json).collect()),
        );
        let path = self.path(id, full_key);
        let written = std::fs::create_dir_all(&self.dir)
            .and_then(|()| std::fs::write(&path, format!("{}\n", Json::Obj(root))));
        if let Err(e) = written {
            eprintln!("[sweep] warning: cache write {} failed: {e}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_then_load_roundtrips_records() {
        let tmp = std::env::temp_dir().join(format!("expograph-cache-{}", std::process::id()));
        let cache = Cache::under(&tmp);
        let key = full_key("t", 1, 0.5, "cell a");
        let records = vec![
            Record::new().with("x", 1.5).with("label", "a"),
            Record::new().with("x", f64::NAN).with("label", "b"),
        ];
        assert!(cache.load("t", &key).is_none());
        cache.store("t", &key, &records);
        let back = cache.load("t", &key).expect("hit after store");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].num("x"), 1.5);
        assert!(back[1].num("x").is_nan());
        assert_eq!(back[1].text("label"), "b");
        // Different seed/scale/cell key ⇒ miss.
        assert!(cache.load("t", &full_key("t", 2, 0.5, "cell a")).is_none());
        assert!(cache.load("t", &full_key("t", 1, 0.25, "cell a")).is_none());
        assert!(cache.load("t", &full_key("t", 1, 0.5, "cell b")).is_none());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn fnv1a_is_stable() {
        // Known FNV-1a vectors (the empty string is the offset basis).
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a("cell a"), fnv1a("cell b"));
    }
}
