//! Bounded job scheduler for sweep cells: fans independent cells out
//! across a thread pool, with a **lane budget** so the outer sweep jobs
//! and each cell's inner [`crate::engine::Engine`] never oversubscribe
//! the machine (`jobs × lanes ≤ cores`), and **deterministic collection
//! in grid order** so output is byte-identical for any job count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Host parallelism (≥ 1).
pub fn cores() -> usize {
    std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// Resolve a requested job count: `0` means auto (one job per core),
/// and no point spawning more jobs than cells.
pub fn effective_jobs(requested: usize, cells: usize) -> usize {
    let jobs = if requested == 0 { cores() } else { requested };
    jobs.clamp(1, cells.max(1))
}

/// Pure lane-budget arithmetic (separated from [`cores`] so tests can
/// pin it for any machine shape): the largest per-job engine lane count
/// with `jobs × lanes ≤ cores`, floored at 1 lane.
pub fn lane_budget_for(cores: usize, jobs: usize) -> usize {
    (cores / jobs.max(1)).max(1)
}

/// Per-job engine lane cap on this host.
pub fn lane_budget(jobs: usize) -> usize {
    lane_budget_for(cores(), jobs)
}

/// Run `run(index, cell)` for every cell on a pool of `jobs` worker
/// threads (work-stealing via a shared cursor) and return the results
/// **in cell order** — the caller cannot observe the execution order.
///
/// A panicking cell propagates to the caller once every in-flight cell
/// has finished (the panic surfaces when the thread scope joins).
pub fn run_parallel<S, R>(cells: &[S], jobs: usize, run: &(dyn Fn(usize, &S) -> R + Sync)) -> Vec<R>
where
    S: Sync,
    R: Send,
{
    let jobs = effective_jobs(jobs, cells.len());
    if jobs <= 1 || cells.len() <= 1 {
        return cells.iter().enumerate().map(|(i, c)| run(i, c)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let result = run(i, &cells[i]);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("every cell completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_cell_order_for_any_job_count() {
        let cells: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = cells.iter().map(|c| c * c).collect();
        for jobs in [1usize, 2, 4, 16] {
            let got = run_parallel(&cells, jobs, &|_, &c| c * c);
            assert_eq!(got, want, "jobs={jobs}");
        }
    }

    #[test]
    fn lane_budget_never_oversubscribes() {
        for cores in [1usize, 2, 4, 8, 96] {
            for jobs in 1..=cores {
                let lanes = lane_budget_for(cores, jobs);
                assert!(lanes >= 1);
                assert!(
                    jobs * lanes <= cores,
                    "jobs={jobs} × lanes={lanes} > cores={cores}"
                );
            }
            // More jobs than cores: the budget floors at one lane each —
            // the engine never *multiplies* the user's oversubscription.
            assert_eq!(lane_budget_for(cores, cores * 3), 1);
        }
    }

    #[test]
    fn effective_jobs_resolves_auto_and_caps_at_cells() {
        assert_eq!(effective_jobs(0, 1000), cores());
        assert_eq!(effective_jobs(5, 3), 3);
        assert_eq!(effective_jobs(5, 0), 1);
        assert_eq!(effective_jobs(2, 100), 2);
    }

    #[test]
    fn cell_panic_propagates() {
        let cells: Vec<usize> = (0..8).collect();
        let caught = std::panic::catch_unwind(|| {
            run_parallel(&cells, 4, &|_, &c| {
                if c == 5 {
                    panic!("boom");
                }
                c
            })
        });
        assert!(caught.is_err());
    }
}
