//! Declarative sweep harness (docs/DESIGN.md §Sweep).
//!
//! The paper's whole evaluation is one grid — topology × algorithm ×
//! n × dataset × scenario — and every runner in [`crate::exp`] used to
//! hand-roll it as nested `for` loops with per-runner CSV plumbing and
//! strictly serial cell execution. This module replaces those loops:
//!
//! * [`Axis`]/[`Grid`] — declare the cartesian product over typed cell
//!   specs once; grid order is the output order.
//! * [`Sweep::run`] — a bounded scheduler fans independent cells out
//!   across a thread pool (`--jobs`, 0 = auto) under a **lane budget**
//!   (`jobs × engine lanes ≤ cores`, [`sched::lane_budget`]) so outer
//!   jobs and each cell's inner [`crate::engine::Engine`] compose
//!   without oversubscription. Collection is **deterministic in grid
//!   order**: training is bitwise lane-invariant (§Engine), so CSV /
//!   JSON / table output is byte-identical for any job count.
//! * [`Record`]/[`Sink`] — one schema per experiment streams to CSV +
//!   JSON + paper-style text table, with the unified non-finite policy
//!   (empty CSV field, `-` in tables, `null` in JSON).
//! * [`Cache`] — completed cells persist under `<out>/.cache/` keyed by
//!   (experiment id, cell-spec hash, seed, scale); a warm re-run of
//!   `exp all` executes zero training cells.

pub mod cache;
pub mod grid;
pub mod sched;
pub mod sink;

pub use cache::Cache;
pub use grid::{Axis, Grid};
pub use sink::{table_num, Col, NumFmt, Record, Sink, Value};

use std::path::Path;

/// Per-cell execution context handed to the run closure.
pub struct CellCtx {
    /// Index of this cell in grid order.
    pub index: usize,
    /// Engine lane cap for this cell (the lane budget): the cell may
    /// use up to this many lanes without oversubscribing the sweep.
    pub lanes: usize,
}

/// One collected cell: its records, and whether they came from cache.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub records: Vec<Record>,
    pub cached: bool,
}

/// A configured sweep over one experiment id. Build via
/// [`Sweep::new`] (or `exp::Ctx::runner`), then [`Sweep::run`] a grid.
pub struct Sweep<'a> {
    id: &'a str,
    seed: u64,
    scale: f64,
    jobs: usize,
    cache: Option<Cache>,
}

impl<'a> Sweep<'a> {
    /// A sweep with no cache and auto job count. `seed` and `scale`
    /// prefix every cell's cache key — changing either invalidates all
    /// cells.
    pub fn new(id: &'a str, seed: u64, scale: f64) -> Sweep<'a> {
        Sweep { id, seed, scale, jobs: 0, cache: None }
    }

    /// Requested parallel jobs (0 = auto: one per core).
    pub fn jobs(mut self, jobs: usize) -> Sweep<'a> {
        self.jobs = jobs;
        self
    }

    /// Enable the on-disk result cache under `<out_dir>/.cache/`.
    pub fn cache_under(mut self, out_dir: &Path) -> Sweep<'a> {
        self.cache = Some(Cache::under(out_dir));
        self
    }

    /// Run every cell (cache-aware, lane-budgeted, parallel) and return
    /// results in grid order. `key` must be a stable, injective
    /// description of the cell spec (derived `Debug` of the spec struct
    /// is the usual choice); `run_cell` produces the cell's records.
    ///
    /// The cache is probed up front and the job count is sized by the
    /// **misses** — a nearly-warm sweep hands its few cold cells the
    /// whole lane budget instead of a `cores/jobs` sliver sized for
    /// cells that never execute.
    pub fn run<S, K, F>(&self, cells: &[S], key: K, run_cell: F) -> Vec<CellResult>
    where
        S: Sync,
        K: Fn(&S) -> String + Sync,
        F: Fn(&S, &CellCtx) -> Vec<Record> + Sync,
    {
        let t0 = std::time::Instant::now();
        let keys: Vec<String> = cells
            .iter()
            .map(|cell| cache::full_key(self.id, self.seed, self.scale, &key(cell)))
            .collect();
        let preloaded: Vec<Option<Vec<Record>>> = match &self.cache {
            Some(cache) => keys.iter().map(|k| cache.load(self.id, k)).collect(),
            None => cells.iter().map(|_| None).collect(),
        };
        let misses = preloaded.iter().filter(|r| r.is_none()).count();
        let jobs = sched::effective_jobs(self.jobs, misses);
        let lanes = sched::lane_budget(jobs);
        let results = sched::run_parallel(cells, jobs, &|index, cell| {
            if let Some(records) = &preloaded[index] {
                return CellResult { records: records.clone(), cached: true };
            }
            let records = run_cell(cell, &CellCtx { index, lanes });
            if let Some(cache) = &self.cache {
                cache.store(self.id, &keys[index], &records);
            }
            CellResult { records, cached: false }
        });
        // Stderr on purpose: stdout is the deterministic report surface.
        eprintln!(
            "[sweep {}] {} cells ({} run, {} cached) in {:.1}s — jobs={jobs}, lane cap={lanes}",
            self.id,
            cells.len(),
            misses,
            cells.len() - misses,
            t0.elapsed().as_secs_f64()
        );
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn uncached_sweep_runs_every_cell_in_order() {
        let sweep = Sweep::new("unit", 1, 1.0).jobs(3);
        let cells: Vec<usize> = (0..10).collect();
        let out = sweep.run(
            &cells,
            |c| format!("{c}"),
            |&c, cc| {
                assert!(cc.lanes >= 1);
                vec![Record::new().with("v", c * 2)]
            },
        );
        assert_eq!(out.len(), 10);
        for (i, cell) in out.iter().enumerate() {
            assert!(!cell.cached);
            assert_eq!(cell.records[0].num("v"), (i * 2) as f64);
        }
    }

    #[test]
    fn cache_skips_reruns_and_seed_invalidates() {
        let tmp = std::env::temp_dir().join(format!("expograph-sweep-{}", std::process::id()));
        let cells: Vec<usize> = (0..4).collect();
        let runs = AtomicUsize::new(0);
        let run_all = |seed: u64| {
            Sweep::new("unit", seed, 1.0).jobs(2).cache_under(&tmp).run(
                &cells,
                |c| format!("{c}"),
                |&c, _| {
                    runs.fetch_add(1, Ordering::Relaxed);
                    vec![Record::new().with("v", c)]
                },
            )
        };
        let cold = run_all(7);
        assert_eq!(runs.load(Ordering::Relaxed), 4);
        assert!(cold.iter().all(|c| !c.cached));
        let warm = run_all(7);
        assert_eq!(runs.load(Ordering::Relaxed), 4, "warm run must execute zero cells");
        assert!(warm.iter().all(|c| c.cached));
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.records, b.records);
        }
        run_all(8);
        assert_eq!(runs.load(Ordering::Relaxed), 8, "new seed must invalidate");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
