//! Declarative experiment grids: named [`Axis`] values combined by
//! cartesian product into a [`Grid`] of typed cell specs, in a fixed
//! **grid order** (outer axis slowest) that the scheduler's collection
//! step preserves — output is byte-identical for any `--jobs`.

/// One named dimension of a sweep (`topology`, `n`, `algorithm`, …).
///
/// The name exists to make grid declarations self-documenting at the
/// call site; it deliberately does **not** flow into cache keys or
/// sink columns — those come from the typed cell spec the product
/// constructor builds, which is the single source of truth.
#[derive(Clone, Debug)]
pub struct Axis<T> {
    pub name: &'static str,
    pub values: Vec<T>,
}

impl<T> Axis<T> {
    pub fn new(name: &'static str, values: impl Into<Vec<T>>) -> Axis<T> {
        Axis { name: name.into(), values: values.into() }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// A flat list of typed cell specs in grid order. The index arithmetic
/// (`product2` ⇒ `i·|b| + j`) is part of the contract: experiments use
/// it to pivot collected results back into paper-style tables.
#[derive(Clone, Debug)]
pub struct Grid<S> {
    cells: Vec<S>,
}

impl<S> Grid<S> {
    /// Escape hatch for ragged (non-product) grids — e.g. a sweep whose
    /// cell list includes a baseline row outside the product.
    pub fn from_cells(cells: Vec<S>) -> Grid<S> {
        Grid { cells }
    }

    /// Cartesian product of two axes; cell `(i, j)` lands at `i·|b| + j`.
    pub fn product2<A, B>(a: &Axis<A>, b: &Axis<B>, mk: impl Fn(&A, &B) -> S) -> Grid<S> {
        let mut cells = Vec::with_capacity(a.len() * b.len());
        for x in &a.values {
            for y in &b.values {
                cells.push(mk(x, y));
            }
        }
        Grid { cells }
    }

    /// Cartesian product of three axes; cell `(i, j, k)` lands at
    /// `(i·|b| + j)·|c| + k`.
    pub fn product3<A, B, C>(
        a: &Axis<A>,
        b: &Axis<B>,
        c: &Axis<C>,
        mk: impl Fn(&A, &B, &C) -> S,
    ) -> Grid<S> {
        let mut cells = Vec::with_capacity(a.len() * b.len() * c.len());
        for x in &a.values {
            for y in &b.values {
                for z in &c.values {
                    cells.push(mk(x, y, z));
                }
            }
        }
        Grid { cells }
    }

    pub fn cells(&self) -> &[S] {
        &self.cells
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn product2_is_row_major() {
        let g = Grid::product2(
            &Axis::new("a", vec!["x", "y"]),
            &Axis::new("b", vec![1usize, 2, 3]),
            |&s, &n| (s, n),
        );
        assert_eq!(g.len(), 6);
        assert_eq!(g.cells()[0], ("x", 1));
        assert_eq!(g.cells()[2], ("x", 3));
        // (i, j) lands at i·|b| + j.
        let (i, j) = (1usize, 2usize);
        assert_eq!(g.cells()[i * 3 + j], ("y", 3));
    }

    #[test]
    fn product3_nests_last_axis_fastest() {
        let g = Grid::product3(
            &Axis::new("a", vec![0usize, 1]),
            &Axis::new("b", vec![0usize, 1]),
            &Axis::new("c", vec![0usize, 1, 2]),
            |&a, &b, &c| (a, b, c),
        );
        assert_eq!(g.len(), 12);
        let (i, j, k) = (1usize, 0usize, 2usize);
        assert_eq!(g.cells()[(i * 2 + j) * 3 + k], (1, 0, 2));
    }
}
