//! The sweep **sink layer**: one [`Record`] schema per experiment that
//! streams to CSV, JSON, and paper-style text tables from a single
//! definition (docs/DESIGN.md §Sweep).
//!
//! This is also the one place that decides how non-finite numbers are
//! rendered: an **empty field** in CSV (via [`crate::util::csv::num_cell`]),
//! a **`-`** in text tables ([`table_num`]), and **`null`** in JSON —
//! experiments no longer hand-roll `is_nan` checks per call site.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::csv::{num_cell, CsvWriter};
use crate::util::json::Json;
use crate::util::table::TextTable;
use anyhow::{Context, Result};

/// One cell value of a record: everything an experiment emits is a
/// string, a number, or a flag. Non-finite numbers are legal — the
/// renderers map them to the unified empty/`-`/`null` forms.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    /// Numeric view: `Num` as-is, `Bool` as 0/1 (handy for aggregation),
    /// `Str` is NaN.
    pub fn num(&self) -> f64 {
        match self {
            Value::Num(v) => *v,
            Value::Bool(b) => f64::from(u8::from(*b)),
            Value::Str(_) => f64::NAN,
        }
    }

    /// Canonical CSV cell (full precision, non-finite ⇒ empty).
    pub fn csv_cell(&self) -> String {
        match self {
            Value::Str(s) => s.clone(),
            Value::Num(v) => num_cell(*v),
            Value::Bool(b) => b.to_string(),
        }
    }

    /// Canonical text-table cell (non-finite ⇒ `-`).
    pub fn table_cell(&self, fmt: NumFmt) -> String {
        match self {
            Value::Str(s) => s.clone(),
            Value::Num(v) => table_num(*v, fmt),
            Value::Bool(b) => b.to_string(),
        }
    }

    /// JSON form (non-finite ⇒ `null` — `NaN`/`inf` are not JSON).
    pub fn to_json(&self) -> Json {
        match self {
            Value::Str(s) => Json::Str(s.clone()),
            Value::Num(v) if v.is_finite() => Json::Num(*v),
            Value::Num(_) => Json::Null,
            Value::Bool(b) => Json::Bool(*b),
        }
    }

    /// Inverse of [`Value::to_json`]; `null` comes back as NaN (the
    /// non-finite distinction is collapsed — renderers treat all
    /// non-finite values alike, so cached output stays byte-identical).
    pub fn from_json(j: &Json) -> Option<Value> {
        match j {
            Json::Str(s) => Some(Value::Str(s.clone())),
            Json::Num(v) => Some(Value::Num(*v)),
            Json::Bool(b) => Some(Value::Bool(*b)),
            Json::Null => Some(Value::Num(f64::NAN)),
            _ => None,
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Num(v)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::Num(v as f64)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::Num(v as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

/// One named row of experiment output — what a sweep cell returns and
/// what the result cache serializes. Field *names* address the values
/// (sinks select by schema); fields are kept **name-sorted**, so
/// equality and `Debug` are insertion-order-insensitive and records
/// compare equal across a cache round-trip (which alphabetizes fields
/// through the JSON object encoding) regardless of builder order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Record {
    fields: Vec<(String, Value)>,
}

impl Record {
    pub fn new() -> Record {
        Record::default()
    }

    /// Builder-style field insert (name-sorted position). Panics on a
    /// duplicate name: the JSON object encoding of the cache would
    /// silently collapse duplicates, breaking warm/cold byte identity.
    pub fn with(mut self, name: &str, value: impl Into<Value>) -> Record {
        let pos = self.fields.partition_point(|(n, _)| n.as_str() < name);
        if self.fields.get(pos).is_some_and(|(n, _)| n == name) {
            panic!("record already has a field named '{name}'");
        }
        self.fields.insert(pos, (name.to_string(), value.into()));
        self
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.fields.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Numeric field accessor; panics on a missing field (a schema bug,
    /// not a data condition — absent *values* are NaN, not absent fields).
    pub fn num(&self, name: &str) -> f64 {
        self.get(name).unwrap_or_else(|| panic!("record has no field '{name}'")).num()
    }

    /// String field accessor; panics unless the field is a `Str`.
    pub fn text(&self, name: &str) -> &str {
        match self.get(name) {
            Some(Value::Str(s)) => s,
            other => panic!("record field '{name}' is not a string: {other:?}"),
        }
    }

    /// Boolean field accessor; panics unless the field is a `Bool`.
    pub fn flag(&self, name: &str) -> bool {
        match self.get(name) {
            Some(Value::Bool(b)) => *b,
            other => panic!("record field '{name}' is not a bool: {other:?}"),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (name, value) in &self.fields {
            obj.insert(name.clone(), value.to_json());
        }
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> Option<Record> {
        let obj = j.as_object()?;
        let mut rec = Record::new();
        for (name, value) in obj {
            rec.fields.push((name.clone(), Value::from_json(value)?));
        }
        Some(rec)
    }
}

/// Text-table display format for numeric cells. CSV and JSON always get
/// full precision; only the human-facing table rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumFmt {
    /// Shortest round-trip representation.
    Auto,
    /// Fixed decimals: `{:.p}`.
    Fixed(usize),
    /// Scientific: `{:.p e}` (the paper's residue/MSE style).
    Sci(usize),
    /// Percentage: `100·v` at fixed decimals (accuracy columns).
    Pct(usize),
    /// Signed percentage: `{:+.p}` of `100·v` (diff columns).
    PctSigned(usize),
}

/// The canonical numeric **text-table** cell: non-finite renders as `-`
/// (the satellite of docs/DESIGN.md §Sweep: one NaN policy, one place).
pub fn table_num(v: f64, fmt: NumFmt) -> String {
    if !v.is_finite() {
        return "-".to_string();
    }
    match fmt {
        NumFmt::Auto => num_cell(v),
        NumFmt::Fixed(p) => format!("{v:.p$}"),
        NumFmt::Sci(p) => format!("{v:.p$e}"),
        NumFmt::Pct(p) => {
            let x = 100.0 * v;
            format!("{x:.p$}")
        }
        NumFmt::PctSigned(p) => {
            let x = 100.0 * v;
            format!("{x:+.p$}")
        }
    }
}

/// One output column: a record field name plus its table format.
#[derive(Clone, Debug)]
pub struct Col {
    pub name: String,
    pub fmt: NumFmt,
}

impl Col {
    pub fn auto(name: impl Into<String>) -> Col {
        Col { name: name.into(), fmt: NumFmt::Auto }
    }

    pub fn fixed(name: impl Into<String>, prec: usize) -> Col {
        Col { name: name.into(), fmt: NumFmt::Fixed(prec) }
    }

    pub fn sci(name: impl Into<String>, prec: usize) -> Col {
        Col { name: name.into(), fmt: NumFmt::Sci(prec) }
    }
}

/// Collects records against a fixed column schema and renders all three
/// output surfaces — `<name>.csv`, `<name>.json`, and a [`TextTable`] —
/// from that one definition.
pub struct Sink {
    cols: Vec<Col>,
    rows: Vec<Vec<Value>>,
}

impl Sink {
    pub fn new(cols: Vec<Col>) -> Sink {
        Sink { cols, rows: Vec::new() }
    }

    /// Append one record, selecting the schema's fields by name.
    /// Panics on a missing field (schema/record mismatch is a bug).
    pub fn push(&mut self, rec: &Record) {
        let row = self
            .cols
            .iter()
            .map(|c| {
                rec.get(&c.name)
                    .unwrap_or_else(|| panic!("record missing sink field '{}'", c.name))
                    .clone()
            })
            .collect();
        self.rows.push(row);
    }

    /// Append a raw row (for sinks fed by reshaped, cross-cell data —
    /// e.g. the wide iteration-series CSVs of the figure experiments).
    /// Panics on arity mismatch.
    pub fn push_values(&mut self, row: Vec<Value>) {
        assert_eq!(row.len(), self.cols.len(), "sink row arity mismatch");
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[Vec<Value>] {
        &self.rows
    }

    pub fn csv(&self) -> CsvWriter {
        let names: Vec<&str> = self.cols.iter().map(|c| c.name.as_str()).collect();
        let mut w = CsvWriter::new(&names);
        for row in &self.rows {
            w.row(&row.iter().map(Value::csv_cell).collect::<Vec<_>>());
        }
        w
    }

    /// `{"columns": [...], "rows": [[...], ...]}` — column-ordered, so
    /// the document round-trips the schema as well as the data.
    pub fn json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert(
            "columns".to_string(),
            Json::Arr(self.cols.iter().map(|c| Json::Str(c.name.clone())).collect()),
        );
        root.insert(
            "rows".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(Value::to_json).collect()))
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    pub fn table(&self) -> TextTable {
        let names: Vec<&str> = self.cols.iter().map(|c| c.name.as_str()).collect();
        let mut t = TextTable::new(&names);
        for row in &self.rows {
            t.row(row.iter().zip(&self.cols).map(|(v, c)| v.table_cell(c.fmt)).collect());
        }
        t
    }

    pub fn write_csv(&self, dir: &Path, name: &str) -> Result<()> {
        let path = dir.join(format!("{name}.csv"));
        self.csv().write(&path).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn write_json(&self, dir: &Path, name: &str) -> Result<()> {
        let path = dir.join(format!("{name}.json"));
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        std::fs::write(&path, format!("{}\n", self.json()))
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Write both machine-readable surfaces (`<name>.csv` + `<name>.json`).
    pub fn write(&self, dir: &Path, name: &str) -> Result<()> {
        self.write_csv(dir, name)?;
        self.write_json(dir, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let rec = Record::new()
            .with("topology", "ring")
            .with("n", 32usize)
            .with("gap", 0.123456789)
            .with("reached", true)
            .with("missing", f64::NAN);
        let back = Record::from_json(&Json::parse(&rec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.text("topology"), "ring");
        assert_eq!(back.num("n"), 32.0);
        assert_eq!(back.num("gap").to_bits(), 0.123456789f64.to_bits());
        assert!(back.flag("reached"));
        assert!(back.num("missing").is_nan());
    }

    #[test]
    fn record_equality_is_builder_order_insensitive() {
        // Cache round-trips alphabetize fields (JSON object encoding);
        // name-sorted storage keeps cold == warm for any builder order.
        let a = Record::new().with("value", 1.5).with("cell", 2usize);
        let b = Record::new().with("cell", 2usize).with("value", 1.5);
        assert_eq!(a, b);
        let warm = Record::from_json(&Json::parse(&a.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(warm, a);
    }

    #[test]
    fn nan_policy_empty_csv_dash_table_null_json() {
        let mut sink = Sink::new(vec![Col::auto("name"), Col::fixed("v", 2)]);
        sink.push(&Record::new().with("name", "a").with("v", 0.5));
        sink.push(&Record::new().with("name", "b").with("v", f64::NAN));
        let csv = sink.csv().render();
        assert_eq!(csv, "name,v\na,0.5\nb,\n");
        let table = sink.table().render();
        assert!(table.contains("0.50"), "{table}");
        assert!(table.lines().last().unwrap().trim_end().ends_with('-'), "{table}");
        let json = sink.json().to_string();
        assert!(json.contains("null"), "{json}");
    }

    #[test]
    fn table_num_formats() {
        assert_eq!(table_num(0.004321, NumFmt::Sci(2)), "4.32e-3");
        assert_eq!(table_num(0.8512, NumFmt::Pct(2)), "85.12");
        assert_eq!(table_num(0.0123, NumFmt::PctSigned(2)), "+1.23");
        assert_eq!(table_num(-0.0123, NumFmt::PctSigned(2)), "-1.23");
        assert_eq!(table_num(1.5, NumFmt::Fixed(3)), "1.500");
        assert_eq!(table_num(f64::INFINITY, NumFmt::Fixed(3)), "-");
        assert_eq!(table_num(f64::NAN, NumFmt::Auto), "-");
    }

    #[test]
    fn sink_selects_schema_fields_by_name() {
        let mut sink = Sink::new(vec![Col::auto("b"), Col::auto("a")]);
        sink.push(&Record::new().with("a", 1usize).with("b", 2usize).with("extra", 3usize));
        assert_eq!(sink.csv().render(), "b,a\n2,1\n");
    }
}
