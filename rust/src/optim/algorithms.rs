//! Concrete decentralized optimization algorithms (see module docs of
//! [`crate::optim`] for the update rules and provenance).

use super::Optimizer;
use crate::coordinator::mixing::MixingPlan;
use crate::coordinator::state::StackedParams;

/// Decentralized SGD (no momentum): `x⁺ = W(x − γ g)`.
pub struct DSgd {
    x: StackedParams,
    buf: StackedParams,
    pre: StackedParams,
}

impl DSgd {
    pub fn new(x: StackedParams) -> Self {
        let buf = StackedParams::zeros(x.n, x.dim);
        let pre = StackedParams::zeros(x.n, x.dim);
        DSgd { x, buf, pre }
    }
}

impl Optimizer for DSgd {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32) {
        // pre = x − γ g, then x = W·pre.
        for (p, (x, g)) in self
            .pre
            .data
            .iter_mut()
            .zip(self.x.data.iter().zip(grads.data.iter()))
        {
            *p = x - lr * g;
        }
        w.mix(&self.pre, &mut self.buf);
        std::mem::swap(&mut self.x.data, &mut self.buf.data);
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Algorithm 1 of the paper (Yu et al. [64]):
/// `m⁺ = W(βm + g)`, `x⁺ = W(x − γm)` — note `x⁺` uses the *pre-update*
/// momentum, exactly as written in the paper.
pub struct DmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
    x_buf: StackedParams,
    m_buf: StackedParams,
}

impl DmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        let x_buf = StackedParams::zeros(x.n, x.dim);
        let m_buf = StackedParams::zeros(x.n, x.dim);
        DmSgd { x, m, beta, x_buf, m_buf }
    }

    pub fn momentum(&self) -> &StackedParams {
        &self.m
    }
}

impl Optimizer for DmSgd {
    fn name(&self) -> &'static str {
        "dmsgd"
    }

    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32) {
        w.mix_dmsgd(
            &mut self.x,
            &mut self.m,
            grads,
            self.beta,
            lr,
            &mut self.x_buf,
            &mut self.m_buf,
        );
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Vanilla DmSGD (Assran et al. [3]): momentum stays local.
/// `m⁺ = βm + g`, `x⁺ = Wx − γ m⁺`.
pub struct VanillaDmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
    buf: StackedParams,
}

impl VanillaDmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        let buf = StackedParams::zeros(x.n, x.dim);
        VanillaDmSgd { x, m, beta, buf }
    }
}

impl Optimizer for VanillaDmSgd {
    fn name(&self) -> &'static str {
        "vanilla_dmsgd"
    }

    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32) {
        // Local momentum refresh.
        for (m, g) in self.m.data.iter_mut().zip(grads.data.iter()) {
            *m = self.beta * *m + g;
        }
        // Gossip the model, then apply the local momentum step.
        w.mix(&self.x, &mut self.buf);
        for (x, (b, m)) in self
            .x
            .data
            .iter_mut()
            .zip(self.buf.data.iter().zip(self.m.data.iter()))
        {
            *x = b - lr * m;
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Quasi-global momentum DmSGD (Lin et al. [32]): the momentum buffer
/// tracks the *realized* model displacement (which already includes the
/// gossip), making it a cheap proxy for the global update direction on
/// heterogeneous data.
///
/// `x_half = x − γ(g + β m)`, `x⁺ = W·x_half`,
/// `m⁺ = β m + (1−β)(x − x⁺)/γ`.
pub struct QgDmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
    half: StackedParams,
    buf: StackedParams,
}

impl QgDmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        let half = StackedParams::zeros(x.n, x.dim);
        let buf = StackedParams::zeros(x.n, x.dim);
        QgDmSgd { x, m, beta, half, buf }
    }
}

impl Optimizer for QgDmSgd {
    fn name(&self) -> &'static str {
        "qg_dmsgd"
    }

    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32) {
        for (h, ((x, g), m)) in self.half.data.iter_mut().zip(
            self.x
                .data
                .iter()
                .zip(grads.data.iter())
                .zip(self.m.data.iter()),
        ) {
            *h = x - lr * (g + self.beta * m);
        }
        w.mix(&self.half, &mut self.buf);
        // m⁺ from the realized displacement, then commit x⁺.
        let inv_lr = 1.0 / lr.max(1e-12);
        for ((m, x), b) in self
            .m
            .data
            .iter_mut()
            .zip(self.x.data.iter_mut())
            .zip(self.buf.data.iter())
        {
            *m = self.beta * *m + (1.0 - self.beta) * (*x - *b) * inv_lr;
            *x = *b;
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Parallel momentum SGD baseline: exact global gradient averaging.
/// All rows stay identical: `ḡ = (1/n)Σ g_i`, `m⁺ = βm + ḡ`,
/// `x⁺ = x − γ m⁺` broadcast to every node.
pub struct ParallelMSgd {
    x: StackedParams,
    m: Vec<f32>,
    g_mean: Vec<f32>,
    beta: f32,
}

impl ParallelMSgd {
    pub fn new(mut x: StackedParams, beta: f32) -> Self {
        // Enforce exact initial consensus.
        x.allreduce();
        let dim = x.dim;
        ParallelMSgd { x, m: vec![0.0; dim], g_mean: vec![0.0; dim], beta }
    }
}

impl Optimizer for ParallelMSgd {
    fn name(&self) -> &'static str {
        "parallel_sgd"
    }

    fn step(&mut self, _w: &MixingPlan, grads: &StackedParams, lr: f32) {
        grads.mean_into(&mut self.g_mean);
        for (m, g) in self.m.iter_mut().zip(self.g_mean.iter()) {
            *m = self.beta * *m + g;
        }
        let dim = self.x.dim;
        // Update row 0, then broadcast.
        {
            let row0 = &mut self.x.data[0..dim];
            for (x, m) in row0.iter_mut().zip(self.m.iter()) {
                *x -= lr * m;
            }
        }
        let (first, rest) = self.x.data.split_at_mut(dim);
        for chunk in rest.chunks_mut(dim) {
            chunk.copy_from_slice(first);
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }

    fn is_parallel(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn grads(n: usize, dim: usize, seed: u64) -> StackedParams {
        let mut rng = Pcg::seeded(seed);
        let mut g = StackedParams::zeros(n, dim);
        for v in g.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        g
    }

    fn full_avg(n: usize) -> MixingPlan {
        MixingPlan::averaging(n)
    }

    #[test]
    fn dmsgd_with_full_averaging_equals_parallel_msgd() {
        // Sanity anchor: with W = J and identical init, Algorithm 1 reduces
        // to parallel momentum SGD (with the paper's one-step momentum
        // delay applied to both).
        let n = 4;
        let dim = 3;
        let init = vec![0.5f32; dim];
        let w = full_avg(n);
        let mut dmsgd = DmSgd::new(StackedParams::replicate(n, &init), 0.9);
        // Manual parallel reference implementing the same recursion:
        // m̄⁺ = βm̄ + ḡ ; x̄⁺ = x̄ − γm̄ (old m̄).
        let mut xbar = vec![0.5f32; dim];
        let mut mbar = vec![0.0f32; dim];
        for k in 0..10 {
            let g = grads(n, dim, 100 + k);
            let gbar = g.mean();
            dmsgd.step(&w, &g, 0.1);
            let old_m = mbar.clone();
            for j in 0..dim {
                mbar[j] = 0.9 * mbar[j] + gbar[j];
                xbar[j] -= 0.1 * old_m[j];
            }
            for i in 0..n {
                for j in 0..dim {
                    assert!(
                        (dmsgd.params().row(i)[j] - xbar[j]).abs() < 1e-4,
                        "k={k} i={i} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn dsgd_descends_quadratic() {
        // f_i(x) = ½‖x − c_i‖²; DSGD over a ring must converge to the mean
        // of the c_i.
        let n = 8;
        let dim = 4;
        let w = crate::topology::metropolis::metropolis_plan(&crate::topology::graphs::ring(n));
        let mut targets = StackedParams::zeros(n, dim);
        let mut rng = Pcg::seeded(5);
        for v in targets.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let target_mean = targets.mean();
        let mut opt = DSgd::new(StackedParams::zeros(n, dim));
        let mut g = StackedParams::zeros(n, dim);
        // Heterogeneous targets leave a consensus bias O(γ·b/(1−ρ)); decay
        // γ to drive it down (Fig. 13's halving schedule in miniature).
        for k in 0..1200 {
            for i in 0..n {
                for j in 0..dim {
                    g.row_mut(i)[j] = opt.params().row(i)[j] - targets.row(i)[j];
                }
            }
            let lr = 0.2 * 0.5f32.powi((k / 200) as i32);
            opt.step(&w, &g, lr);
        }
        let mean = opt.params().mean();
        for j in 0..dim {
            assert!((mean[j] - target_mean[j]).abs() < 1e-2, "j={j}");
        }
        assert!(opt.params().consensus_distance() < 1e-2);
    }

    #[test]
    fn all_momentum_variants_descend_quadratic() {
        let n = 8;
        let dim = 4;
        let w_all: Vec<MixingPlan> = (0..3)
            .map(|t| crate::topology::exponential::one_peer_exp_plan(n, t))
            .collect();
        let mut targets = StackedParams::zeros(n, dim);
        let mut rng = Pcg::seeded(6);
        for v in targets.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let target_mean = targets.mean();
        let opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(DmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(VanillaDmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(QgDmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(ParallelMSgd::new(StackedParams::zeros(n, dim), 0.8)),
        ];
        for mut opt in opts {
            let mut g = StackedParams::zeros(n, dim);
            for k in 0..800 {
                for i in 0..n {
                    for j in 0..dim {
                        g.row_mut(i)[j] = opt.params().row(i)[j] - targets.row(i)[j];
                    }
                }
                opt.step(&w_all[k % 3], &g, 0.05);
            }
            let mean = opt.params().mean();
            let err: f32 = (0..dim).map(|j| (mean[j] - target_mean[j]).abs()).fold(0.0, f32::max);
            assert!(err < 5e-2, "{}: err={err}", opt.name());
        }
    }

    #[test]
    fn parallel_msgd_keeps_exact_consensus() {
        let n = 6;
        let dim = 5;
        let mut opt = ParallelMSgd::new(StackedParams::replicate(n, &vec![1.0; dim]), 0.9);
        let w = full_avg(n);
        for k in 0..5 {
            let g = grads(n, dim, k);
            opt.step(&w, &g, 0.1);
            assert!(opt.params().consensus_distance() < 1e-12);
        }
    }

    #[test]
    fn dsgd_equals_dmsgd_beta0_modulo_delay() {
        // DmSGD(β=0) applies gradients with one extra W and one-step delay:
        // x^{k+1} = W x^k − γ W m^k, m^{k+1} = W g^k. After two steps from
        // m⁰ = 0 both have applied g⁰ exactly once through two mixes.
        let n = 4;
        let dim = 2;
        let w = full_avg(n);
        let mut a = DSgd::new(StackedParams::zeros(n, dim));
        let mut b = DmSgd::new(StackedParams::zeros(n, dim), 0.0);
        let g0 = grads(n, dim, 1);
        let zero = StackedParams::zeros(n, dim);
        // a: one step with g0. b: g0 then a zero-grad step to flush delay.
        a.step(&w, &g0, 0.1);
        b.step(&w, &g0, 0.1);
        b.step(&w, &zero, 0.1);
        for i in 0..n {
            for j in 0..dim {
                assert!((a.params().row(i)[j] - b.params().row(i)[j]).abs() < 1e-6);
            }
        }
    }
}
