//! Concrete decentralized optimization algorithms (see module docs of
//! [`crate::optim`] for the update rules and provenance).
//!
//! Every algorithm is expressed as a **shard-local fused kernel**
//! ([`Optimizer::step_shard`]): the pre/post element loops of the update
//! rule are folded into the mixing accumulation, so each of `x`, `m`, `g`
//! streams exactly once per nonzero (the pattern `mix_dmsgd` pioneered
//! for DmSGD, now uniform across the zoo). Output rows land in the
//! caller's [`StepScratch`]; the serial [`Optimizer::commit`] adopts them
//! by swapping buffers. The engine shards `step_shard` over its worker
//! pool; the legacy [`Optimizer::step`] runs the same kernel over the
//! single full-range shard — bitwise the same trajectory either way.

// The shard kernels legitimately take the full step context (phase, row
// range, plan, grads, lr, both scratch views).
#![allow(clippy::too_many_arguments)]

use std::ops::Range;

use super::{damp_rows, Optimizer, StepScratch};
use crate::compress::StreamState;
use crate::coordinator::mixing::MixingPlan;
use crate::coordinator::state::StackedParams;
use crate::simd::fmaf;

/// Decentralized SGD (no momentum): `x⁺ = W(x − γ g)`.
pub struct DSgd {
    x: StackedParams,
}

impl DSgd {
    pub fn new(x: StackedParams) -> Self {
        DSgd { x }
    }
}

impl Optimizer for DSgd {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        _b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let g = &grads.data;
        // Fused: x⁺_i = Σ_j w_ij (x_j − γ g_j), no materialized pre-stack.
        w.mix_fused_rows(rows, dim, a, |j: usize, k: usize| {
            let s = j * dim + k;
            fmaf(-lr, g[s], x[s])
        });
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        1
    }

    fn payload_shard(
        &self,
        _phase: usize,
        _stream: usize,
        rows: Range<usize>,
        grads: &StackedParams,
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let g = &grads.data;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                let s = i * dim + k;
                out[off + k] = fmaf(-lr, g[s], x[s]);
            }
        }
    }

    fn step_shard_q(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        _b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let h = &q[0].h.data;
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| h[j * dim + k]);
        damp_rows(rows, dim, gamma, q[0], a);
    }

    fn async_streams(&self) -> usize {
        1
    }

    fn stage_shard_async(
        &self,
        _stream: usize,
        rows: Range<usize>,
        g_rows: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                out[off + k] = fmaf(-lr, g_rows[off + k], x[i * dim + k]);
            }
        }
    }

    fn step_shard_async(
        &self,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        src: &(dyn Fn(usize, usize, usize, usize) -> f32 + Sync),
        damp: Option<(f32, &[&[f32]])>,
        a: &mut [f32],
        _b: &mut [f32],
    ) {
        // The payload x_j − γ g_j is what the executor versioned; mixing
        // the resolved versions is the same fmaf fold as the dense
        // kernel, so at τ=0 (all-fresh) the trajectory is bitwise equal.
        let dim = self.x.dim;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let ao = &mut a[off..off + dim];
            w.mix_fused_rows(i..i + 1, dim, ao, |j: usize, k: usize| src(i, 0, j, k));
            if let Some((gamma, praw)) = damp {
                let p = &praw[0][i * dim..(i + 1) * dim];
                for k in 0..dim {
                    ao[k] = fmaf(gamma, ao[k] - src(i, 0, i, k), p[k]);
                }
            }
        }
    }

    fn take_async_state(&mut self) -> (StackedParams, StackedParams) {
        (std::mem::replace(&mut self.x, StackedParams::zeros(0, 0)), StackedParams::zeros(0, 0))
    }

    fn restore_async_state(&mut self, x: StackedParams, _m: StackedParams) {
        self.x = x;
    }

    fn stage_node_async(
        &self,
        _stream: usize,
        x_row: &[f32],
        _m_row: &[f32],
        g_row: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        for k in 0..x_row.len() {
            out[k] = fmaf(-lr, g_row[k], x_row[k]);
        }
    }

    fn step_node_async(
        &self,
        i: usize,
        w: &MixingPlan,
        _g_row: &[f32],
        _lr: f32,
        src: &dyn Fn(usize, usize, usize) -> f32,
        damp: Option<(f32, &[&[f32]])>,
        x_row: &mut [f32],
        _m_row: &mut [f32],
        _tmp: &mut [f32],
    ) {
        // Same fmaf fold as the shard entry; the mix writes x_row from
        // scratch (payload versions live in the ring), so in-place is
        // exactly the swap-commit value.
        let dim = x_row.len();
        w.mix_fused_rows(i..i + 1, dim, x_row, |j: usize, k: usize| src(0, j, k));
        if let Some((gamma, praw)) = damp {
            for k in 0..dim {
                x_row[k] = fmaf(gamma, x_row[k] - src(0, i, k), praw[0][k]);
            }
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Algorithm 1 of the paper (Yu et al. [64]):
/// `m⁺ = W(βm + g)`, `x⁺ = W(x − γm)` — note `x⁺` uses the *pre-update*
/// momentum, exactly as written in the paper.
pub struct DmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
}

impl DmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        DmSgd { x, m, beta }
    }

    pub fn momentum(&self) -> &StackedParams {
        &self.m
    }
}

impl Optimizer for DmSgd {
    fn name(&self) -> &'static str {
        "dmsgd"
    }

    fn needs_secondary(&self) -> bool {
        true
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        // The original fused double-mix kernel (one pass over x/m/g per
        // nonzero, two-nonzero fast path for one-peer rows).
        w.mix_dmsgd_rows(
            rows,
            &self.x.data,
            &self.m.data,
            &grads.data,
            self.beta,
            lr,
            self.x.dim,
            a,
            b,
        );
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
        std::mem::swap(&mut self.m.data, &mut scratch.b.data);
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        // Two stacks gossip each round: x − γm and βm + g.
        2
    }

    fn payload_shard(
        &self,
        _phase: usize,
        stream: usize,
        rows: Range<usize>,
        grads: &StackedParams,
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                let s = i * dim + k;
                out[off + k] = if stream == 0 {
                    fmaf(-lr, m[s], x[s])
                } else {
                    fmaf(beta, m[s], g[s])
                };
            }
        }
    }

    fn step_shard_q(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let h0 = &q[0].h.data;
        let h1 = &q[1].h.data;
        w.mix_fused_rows2(
            rows.clone(),
            dim,
            a,
            b,
            |j: usize, k: usize| h0[j * dim + k],
            |j: usize, k: usize| h1[j * dim + k],
        );
        damp_rows(rows.clone(), dim, gamma, q[0], a);
        damp_rows(rows, dim, gamma, q[1], b);
    }

    fn async_streams(&self) -> usize {
        2
    }

    fn stage_shard_async(
        &self,
        stream: usize,
        rows: Range<usize>,
        g_rows: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let beta = self.beta;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                let s = i * dim + k;
                out[off + k] = if stream == 0 {
                    fmaf(-lr, m[s], x[s])
                } else {
                    fmaf(beta, m[s], g_rows[off + k])
                };
            }
        }
    }

    fn step_shard_async(
        &self,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        src: &(dyn Fn(usize, usize, usize, usize) -> f32 + Sync),
        damp: Option<(f32, &[&[f32]])>,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        // Both gossiped stacks (x − γm and βm + g) are versioned; the
        // dual fold is the same `mix_fused_rows2` behind
        // `mix_dmsgd_rows`, so τ=0 stays bitwise equal to sync.
        let dim = self.x.dim;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let ao = &mut a[off..off + dim];
            let bo = &mut b[off..off + dim];
            w.mix_fused_rows2(
                i..i + 1,
                dim,
                ao,
                bo,
                |j: usize, k: usize| src(i, 0, j, k),
                |j: usize, k: usize| src(i, 1, j, k),
            );
            if let Some((gamma, praw)) = damp {
                let p0 = &praw[0][i * dim..(i + 1) * dim];
                let p1 = &praw[1][i * dim..(i + 1) * dim];
                for k in 0..dim {
                    ao[k] = fmaf(gamma, ao[k] - src(i, 0, i, k), p0[k]);
                }
                for k in 0..dim {
                    bo[k] = fmaf(gamma, bo[k] - src(i, 1, i, k), p1[k]);
                }
            }
        }
    }

    fn take_async_state(&mut self) -> (StackedParams, StackedParams) {
        (
            std::mem::replace(&mut self.x, StackedParams::zeros(0, 0)),
            std::mem::replace(&mut self.m, StackedParams::zeros(0, 0)),
        )
    }

    fn restore_async_state(&mut self, x: StackedParams, m: StackedParams) {
        self.x = x;
        self.m = m;
    }

    fn stage_node_async(
        &self,
        stream: usize,
        x_row: &[f32],
        m_row: &[f32],
        g_row: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        let beta = self.beta;
        for k in 0..x_row.len() {
            out[k] = if stream == 0 {
                fmaf(-lr, m_row[k], x_row[k])
            } else {
                fmaf(beta, m_row[k], g_row[k])
            };
        }
    }

    fn step_node_async(
        &self,
        i: usize,
        w: &MixingPlan,
        _g_row: &[f32],
        _lr: f32,
        src: &dyn Fn(usize, usize, usize) -> f32,
        damp: Option<(f32, &[&[f32]])>,
        x_row: &mut [f32],
        m_row: &mut [f32],
        _tmp: &mut [f32],
    ) {
        // Dual fold over the two versioned streams; both mixes write
        // their output rows from scratch, so in-place x/m updates equal
        // the swap-commit values.
        let dim = x_row.len();
        w.mix_fused_rows2(
            i..i + 1,
            dim,
            x_row,
            m_row,
            |j: usize, k: usize| src(0, j, k),
            |j: usize, k: usize| src(1, j, k),
        );
        if let Some((gamma, praw)) = damp {
            for k in 0..dim {
                x_row[k] = fmaf(gamma, x_row[k] - src(0, i, k), praw[0][k]);
            }
            for k in 0..dim {
                m_row[k] = fmaf(gamma, m_row[k] - src(1, i, k), praw[1][k]);
            }
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Vanilla DmSGD (Assran et al. [3]): momentum stays local.
/// `m⁺ = βm + g`, `x⁺ = Wx − γ m⁺`.
pub struct VanillaDmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
}

impl VanillaDmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        VanillaDmSgd { x, m, beta }
    }
}

impl Optimizer for VanillaDmSgd {
    fn name(&self) -> &'static str {
        "vanilla_dmsgd"
    }

    fn needs_secondary(&self) -> bool {
        true
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        // Mix the model, then fold the (row-local) momentum refresh and
        // its application into the same pass over the output rows:
        // b_i = βm_i + g_i ; a_i = (Wx)_i − γ b_i.
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| x[j * dim + k]);
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let (mi, gi) = (&m[i * dim..(i + 1) * dim], &g[i * dim..(i + 1) * dim]);
            let ao = &mut a[off..off + dim];
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                let mp = fmaf(beta, mi[k], gi[k]);
                bo[k] = mp;
                ao[k] = fmaf(-lr, mp, ao[k]);
            }
        }
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
        std::mem::swap(&mut self.m.data, &mut scratch.b.data);
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        // Only the model gossips; momentum is node-local by definition.
        1
    }

    fn payload_shard(
        &self,
        _phase: usize,
        _stream: usize,
        rows: Range<usize>,
        _grads: &StackedParams,
        _lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            out[off..off + dim].copy_from_slice(&x[i * dim..(i + 1) * dim]);
        }
    }

    fn step_shard_q(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        let hq = &q[0].h.data;
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| hq[j * dim + k]);
        damp_rows(rows.clone(), dim, gamma, q[0], a);
        // The momentum refresh/application stays the dense row-local tail.
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let (mi, gi) = (&m[i * dim..(i + 1) * dim], &g[i * dim..(i + 1) * dim]);
            let ao = &mut a[off..off + dim];
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                let mp = fmaf(beta, mi[k], gi[k]);
                bo[k] = mp;
                ao[k] = fmaf(-lr, mp, ao[k]);
            }
        }
    }

    fn async_streams(&self) -> usize {
        1
    }

    fn stage_shard_async(
        &self,
        _stream: usize,
        rows: Range<usize>,
        _g_rows: &[f32],
        _lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            out[off..off + dim].copy_from_slice(&x[i * dim..(i + 1) * dim]);
        }
    }

    fn step_shard_async(
        &self,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        src: &(dyn Fn(usize, usize, usize, usize) -> f32 + Sync),
        damp: Option<(f32, &[&[f32]])>,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        // Mix the versioned model payload, then the row-local momentum
        // refresh — same tail as the dense kernel.
        let dim = self.x.dim;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let ao = &mut a[off..off + dim];
            w.mix_fused_rows(i..i + 1, dim, ao, |j: usize, k: usize| src(i, 0, j, k));
            if let Some((gamma, praw)) = damp {
                let p = &praw[0][i * dim..(i + 1) * dim];
                for k in 0..dim {
                    ao[k] = fmaf(gamma, ao[k] - src(i, 0, i, k), p[k]);
                }
            }
            let (mi, gi) = (&m[i * dim..(i + 1) * dim], &g[i * dim..(i + 1) * dim]);
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                let mp = fmaf(beta, mi[k], gi[k]);
                bo[k] = mp;
                ao[k] = fmaf(-lr, mp, ao[k]);
            }
        }
    }

    fn take_async_state(&mut self) -> (StackedParams, StackedParams) {
        (
            std::mem::replace(&mut self.x, StackedParams::zeros(0, 0)),
            std::mem::replace(&mut self.m, StackedParams::zeros(0, 0)),
        )
    }

    fn restore_async_state(&mut self, x: StackedParams, m: StackedParams) {
        self.x = x;
        self.m = m;
    }

    fn stage_node_async(
        &self,
        _stream: usize,
        x_row: &[f32],
        _m_row: &[f32],
        _g_row: &[f32],
        _lr: f32,
        out: &mut [f32],
    ) {
        out.copy_from_slice(x_row);
    }

    fn step_node_async(
        &self,
        i: usize,
        w: &MixingPlan,
        g_row: &[f32],
        lr: f32,
        src: &dyn Fn(usize, usize, usize) -> f32,
        damp: Option<(f32, &[&[f32]])>,
        x_row: &mut [f32],
        m_row: &mut [f32],
        _tmp: &mut [f32],
    ) {
        // Mix the versioned model payload into x_row, then the row-local
        // momentum refresh — each element reads its pre-update value
        // before writing, so in-place equals the swap-commit values.
        let dim = x_row.len();
        let beta = self.beta;
        w.mix_fused_rows(i..i + 1, dim, x_row, |j: usize, k: usize| src(0, j, k));
        if let Some((gamma, praw)) = damp {
            for k in 0..dim {
                x_row[k] = fmaf(gamma, x_row[k] - src(0, i, k), praw[0][k]);
            }
        }
        for k in 0..dim {
            let mp = fmaf(beta, m_row[k], g_row[k]);
            m_row[k] = mp;
            x_row[k] = fmaf(-lr, mp, x_row[k]);
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Quasi-global momentum DmSGD (Lin et al. [32]): the momentum buffer
/// tracks the *realized* model displacement (which already includes the
/// gossip), making it a cheap proxy for the global update direction on
/// heterogeneous data.
///
/// `x_half = x − γ(g + β m)`, `x⁺ = W·x_half`,
/// `m⁺ = β m + (1−β)(x − x⁺)/γ`.
pub struct QgDmSgd {
    x: StackedParams,
    m: StackedParams,
    beta: f32,
}

impl QgDmSgd {
    pub fn new(x: StackedParams, beta: f32) -> Self {
        let m = StackedParams::zeros(x.n, x.dim);
        QgDmSgd { x, m, beta }
    }
}

impl Optimizer for QgDmSgd {
    fn name(&self) -> &'static str {
        "qg_dmsgd"
    }

    fn needs_secondary(&self) -> bool {
        true
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        // Fused half-step + mix: a_i = Σ_j w_ij (x_j − γ(g_j + β m_j)).
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| {
            let s = j * dim + k;
            fmaf(-lr, fmaf(beta, m[s], g[s]), x[s])
        });
        // m⁺ from the realized displacement (row-local on the shard).
        let inv_lr = 1.0 / lr.max(1e-12);
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let (mi, xi) = (&m[i * dim..(i + 1) * dim], &x[i * dim..(i + 1) * dim]);
            let ao = &a[off..off + dim];
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                bo[k] = fmaf(beta, mi[k], (1.0 - beta) * (xi[k] - ao[k]) * inv_lr);
            }
        }
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
        std::mem::swap(&mut self.m.data, &mut scratch.b.data);
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        1
    }

    fn payload_shard(
        &self,
        _phase: usize,
        _stream: usize,
        rows: Range<usize>,
        grads: &StackedParams,
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let g = &grads.data;
        let beta = self.beta;
        let base = rows.start;
        // The gossiped half-step x_half = x − γ(g + βm).
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                let s = i * dim + k;
                out[off + k] = fmaf(-lr, fmaf(beta, m[s], g[s]), x[s]);
            }
        }
    }

    fn step_shard_q(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let beta = self.beta;
        let hq = &q[0].h.data;
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| hq[j * dim + k]);
        damp_rows(rows.clone(), dim, gamma, q[0], a);
        // m⁺ from the realized displacement — identical tail to the
        // dense kernel, now reading the damped-compressed x⁺.
        let inv_lr = 1.0 / lr.max(1e-12);
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let (mi, xi) = (&m[i * dim..(i + 1) * dim], &x[i * dim..(i + 1) * dim]);
            let ao = &a[off..off + dim];
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                bo[k] = fmaf(beta, mi[k], (1.0 - beta) * (xi[k] - ao[k]) * inv_lr);
            }
        }
    }

    fn async_streams(&self) -> usize {
        1
    }

    fn stage_shard_async(
        &self,
        _stream: usize,
        rows: Range<usize>,
        g_rows: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let beta = self.beta;
        let base = rows.start;
        // The gossiped half-step x_half = x − γ(g + βm).
        for i in rows {
            let off = (i - base) * dim;
            for k in 0..dim {
                let s = i * dim + k;
                out[off + k] = fmaf(-lr, fmaf(beta, m[s], g_rows[off + k]), x[s]);
            }
        }
    }

    fn step_shard_async(
        &self,
        rows: Range<usize>,
        w: &MixingPlan,
        _grads: &StackedParams,
        lr: f32,
        src: &(dyn Fn(usize, usize, usize, usize) -> f32 + Sync),
        damp: Option<(f32, &[&[f32]])>,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        // Mix the versioned half-step payload, then refresh m from the
        // realized displacement — the same row-local tail as the dense
        // kernel.
        let dim = self.x.dim;
        let x = &self.x.data;
        let m = &self.m.data;
        let beta = self.beta;
        let inv_lr = 1.0 / lr.max(1e-12);
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            let ao = &mut a[off..off + dim];
            w.mix_fused_rows(i..i + 1, dim, ao, |j: usize, k: usize| src(i, 0, j, k));
            if let Some((gamma, praw)) = damp {
                let p = &praw[0][i * dim..(i + 1) * dim];
                for k in 0..dim {
                    ao[k] = fmaf(gamma, ao[k] - src(i, 0, i, k), p[k]);
                }
            }
            let (mi, xi) = (&m[i * dim..(i + 1) * dim], &x[i * dim..(i + 1) * dim]);
            let bo = &mut b[off..off + dim];
            for k in 0..dim {
                bo[k] = fmaf(beta, mi[k], (1.0 - beta) * (xi[k] - ao[k]) * inv_lr);
            }
        }
    }

    fn take_async_state(&mut self) -> (StackedParams, StackedParams) {
        (
            std::mem::replace(&mut self.x, StackedParams::zeros(0, 0)),
            std::mem::replace(&mut self.m, StackedParams::zeros(0, 0)),
        )
    }

    fn restore_async_state(&mut self, x: StackedParams, m: StackedParams) {
        self.x = x;
        self.m = m;
    }

    fn stage_node_async(
        &self,
        _stream: usize,
        x_row: &[f32],
        m_row: &[f32],
        g_row: &[f32],
        lr: f32,
        out: &mut [f32],
    ) {
        let beta = self.beta;
        // The gossiped half-step x_half = x − γ(g + βm).
        for k in 0..x_row.len() {
            out[k] = fmaf(-lr, fmaf(beta, m_row[k], g_row[k]), x_row[k]);
        }
    }

    fn step_node_async(
        &self,
        i: usize,
        w: &MixingPlan,
        _g_row: &[f32],
        lr: f32,
        src: &dyn Fn(usize, usize, usize) -> f32,
        damp: Option<(f32, &[&[f32]])>,
        x_row: &mut [f32],
        m_row: &mut [f32],
        tmp: &mut [f32],
    ) {
        // The momentum refresh reads the *pre-mix* model row after the
        // mix, so x⁺ is built in `tmp` and adopted at the end — same
        // float ops as the shard entry + swap commit.
        let dim = x_row.len();
        let beta = self.beta;
        let out = &mut tmp[..dim];
        w.mix_fused_rows(i..i + 1, dim, out, |j: usize, k: usize| src(0, j, k));
        if let Some((gamma, praw)) = damp {
            for k in 0..dim {
                out[k] = fmaf(gamma, out[k] - src(0, i, k), praw[0][k]);
            }
        }
        let inv_lr = 1.0 / lr.max(1e-12);
        for k in 0..dim {
            m_row[k] = fmaf(beta, m_row[k], (1.0 - beta) * (x_row[k] - out[k]) * inv_lr);
        }
        x_row.copy_from_slice(out);
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Parallel momentum SGD baseline: exact global gradient averaging.
/// All rows stay identical: `ḡ = (1/n)Σ g_i`, `m⁺ = βm + ḡ`,
/// `x⁺ = x − γ m⁺` broadcast to every node.
pub struct ParallelMSgd {
    x: StackedParams,
    m: Vec<f32>,
    g_mean: Vec<f32>,
    /// The post-step row, staged by `prepare`; `step_shard` broadcasts it.
    canonical: Vec<f32>,
    beta: f32,
}

impl ParallelMSgd {
    pub fn new(mut x: StackedParams, beta: f32) -> Self {
        // Enforce exact initial consensus.
        x.allreduce();
        let dim = x.dim;
        ParallelMSgd {
            x,
            m: vec![0.0; dim],
            g_mean: vec![0.0; dim],
            canonical: vec![0.0; dim],
            beta,
        }
    }
}

impl Optimizer for ParallelMSgd {
    fn name(&self) -> &'static str {
        "parallel_sgd"
    }

    fn prepare(&mut self, _w: &MixingPlan, grads: &StackedParams, lr: f32) {
        // Serial head: the global reduction has no row-local form (and is
        // where exact averaging earns its β·n-fold message cost).
        grads.mean_into(&mut self.g_mean);
        for (m, g) in self.m.iter_mut().zip(self.g_mean.iter()) {
            *m = fmaf(self.beta, *m, *g);
        }
        let dim = self.x.dim;
        let row0 = &self.x.data[..dim];
        for ((c, x), m) in self.canonical.iter_mut().zip(row0).zip(self.m.iter()) {
            *c = fmaf(-lr, *m, *x);
        }
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        a: &mut [f32],
        _b: &mut [f32],
    ) {
        // Broadcast the staged canonical row across the shard.
        let dim = self.x.dim;
        let base = rows.start;
        for i in rows {
            let off = (i - base) * dim;
            a[off..off + dim].copy_from_slice(&self.canonical);
        }
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }

    fn is_parallel(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn grads(n: usize, dim: usize, seed: u64) -> StackedParams {
        let mut rng = Pcg::seeded(seed);
        let mut g = StackedParams::zeros(n, dim);
        for v in g.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        g
    }

    fn full_avg(n: usize) -> MixingPlan {
        MixingPlan::averaging(n)
    }

    #[test]
    fn dmsgd_with_full_averaging_equals_parallel_msgd() {
        // Sanity anchor: with W = J and identical init, Algorithm 1 reduces
        // to parallel momentum SGD (with the paper's one-step momentum
        // delay applied to both).
        let n = 4;
        let dim = 3;
        let init = vec![0.5f32; dim];
        let w = full_avg(n);
        let mut dmsgd = DmSgd::new(StackedParams::replicate(n, &init), 0.9);
        // Manual parallel reference implementing the same recursion:
        // m̄⁺ = βm̄ + ḡ ; x̄⁺ = x̄ − γm̄ (old m̄).
        let mut xbar = vec![0.5f32; dim];
        let mut mbar = vec![0.0f32; dim];
        for k in 0..10 {
            let g = grads(n, dim, 100 + k);
            let gbar = g.mean();
            dmsgd.step(&w, &g, 0.1);
            let old_m = mbar.clone();
            for j in 0..dim {
                mbar[j] = 0.9 * mbar[j] + gbar[j];
                xbar[j] -= 0.1 * old_m[j];
            }
            for i in 0..n {
                for j in 0..dim {
                    assert!(
                        (dmsgd.params().row(i)[j] - xbar[j]).abs() < 1e-4,
                        "k={k} i={i} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn dsgd_descends_quadratic() {
        // f_i(x) = ½‖x − c_i‖²; DSGD over a ring must converge to the mean
        // of the c_i.
        let n = 8;
        let dim = 4;
        let w = crate::topology::metropolis::metropolis_plan(&crate::topology::graphs::ring(n));
        let mut targets = StackedParams::zeros(n, dim);
        let mut rng = Pcg::seeded(5);
        for v in targets.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let target_mean = targets.mean();
        let mut opt = DSgd::new(StackedParams::zeros(n, dim));
        let mut g = StackedParams::zeros(n, dim);
        // Heterogeneous targets leave a consensus bias O(γ·b/(1−ρ)); decay
        // γ to drive it down (Fig. 13's halving schedule in miniature).
        for k in 0..1200 {
            for i in 0..n {
                for j in 0..dim {
                    g.row_mut(i)[j] = opt.params().row(i)[j] - targets.row(i)[j];
                }
            }
            let lr = 0.2 * 0.5f32.powi((k / 200) as i32);
            opt.step(&w, &g, lr);
        }
        let mean = opt.params().mean();
        for j in 0..dim {
            assert!((mean[j] - target_mean[j]).abs() < 1e-2, "j={j}");
        }
        assert!(opt.params().consensus_distance() < 1e-2);
    }

    #[test]
    fn all_momentum_variants_descend_quadratic() {
        let n = 8;
        let dim = 4;
        let w_all: Vec<MixingPlan> = (0..3)
            .map(|t| crate::topology::exponential::one_peer_exp_plan(n, t))
            .collect();
        let mut targets = StackedParams::zeros(n, dim);
        let mut rng = Pcg::seeded(6);
        for v in targets.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let target_mean = targets.mean();
        let opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(DmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(VanillaDmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(QgDmSgd::new(StackedParams::zeros(n, dim), 0.8)),
            Box::new(ParallelMSgd::new(StackedParams::zeros(n, dim), 0.8)),
        ];
        for mut opt in opts {
            let mut g = StackedParams::zeros(n, dim);
            for k in 0..800 {
                for i in 0..n {
                    for j in 0..dim {
                        g.row_mut(i)[j] = opt.params().row(i)[j] - targets.row(i)[j];
                    }
                }
                opt.step(&w_all[k % 3], &g, 0.05);
            }
            let mean = opt.params().mean();
            let err: f32 = (0..dim).map(|j| (mean[j] - target_mean[j]).abs()).fold(0.0, f32::max);
            assert!(err < 5e-2, "{}: err={err}", opt.name());
        }
    }

    #[test]
    fn parallel_msgd_keeps_exact_consensus() {
        let n = 6;
        let dim = 5;
        let mut opt = ParallelMSgd::new(StackedParams::replicate(n, &vec![1.0; dim]), 0.9);
        let w = full_avg(n);
        for k in 0..5 {
            let g = grads(n, dim, k);
            opt.step(&w, &g, 0.1);
            assert!(opt.params().consensus_distance() < 1e-12);
        }
    }

    #[test]
    fn dsgd_equals_dmsgd_beta0_modulo_delay() {
        // DmSGD(β=0) applies gradients with one extra W and one-step delay:
        // x^{k+1} = W x^k − γ W m^k, m^{k+1} = W g^k. After two steps from
        // m⁰ = 0 both have applied g⁰ exactly once through two mixes.
        let n = 4;
        let dim = 2;
        let w = full_avg(n);
        let mut a = DSgd::new(StackedParams::zeros(n, dim));
        let mut b = DmSgd::new(StackedParams::zeros(n, dim), 0.0);
        let g0 = grads(n, dim, 1);
        let zero = StackedParams::zeros(n, dim);
        // a: one step with g0. b: g0 then a zero-grad step to flush delay.
        a.step(&w, &g0, 0.1);
        b.step(&w, &g0, 0.1);
        b.step(&w, &zero, 0.1);
        for i in 0..n {
            for j in 0..dim {
                assert!((a.params().row(i)[j] - b.params().row(i)[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn step_with_reuses_scratch_and_matches_step() {
        // step() (transient scratch) and step_with() (persistent scratch)
        // must produce the identical trajectory.
        let n = 8;
        let dim = 6;
        let w = crate::topology::exponential::static_exp_plan(n);
        let mut a = QgDmSgd::new(StackedParams::zeros(n, dim), 0.9);
        let mut b = QgDmSgd::new(StackedParams::zeros(n, dim), 0.9);
        let mut scratch = StepScratch::default();
        for k in 0..20 {
            let g = grads(n, dim, 500 + k);
            a.step(&w, &g, 0.05);
            b.step_with(&w, &g, 0.05, &mut scratch);
        }
        assert_eq!(a.params().data, b.params().data);
    }

    #[test]
    fn shard_kernels_match_full_range_bitwise() {
        // Computing a step in several disjoint shards must be bitwise
        // equal to the single full-range shard, for every algorithm.
        use crate::optim::AlgorithmKind;
        let n = 12;
        let dim = 9;
        let w = crate::topology::exponential::static_exp_plan(n);
        let init: Vec<f32> = (0..dim).map(|j| 0.3 * j as f32).collect();
        for algo in [
            AlgorithmKind::DSgd,
            AlgorithmKind::DmSgd,
            AlgorithmKind::VanillaDmSgd,
            AlgorithmKind::QgDmSgd,
            AlgorithmKind::ParallelSgd,
            AlgorithmKind::D2,
            AlgorithmKind::GradientTracking,
        ] {
            let mut whole = algo.build(n, &init, 0.9);
            let mut sharded = algo.build(n, &init, 0.9);
            let mut scratch = StepScratch::default();
            let mut empty: [f32; 0] = [];
            // A couple of steps so shard bookkeeping compounds.
            for step in 0..3u64 {
                let g = grads(n, dim, 77 + step);
                whole.step(&w, &g, 0.05);
                // Drive the sharded copy manually: prepare, three uneven
                // shards, commit — exactly what the engine broadcast does.
                scratch.ensure(n, dim, sharded.needs_secondary());
                sharded.prepare(&w, &g, 0.05);
                for phase in 0..sharded.phases() {
                    for r in [0..5usize, 5..8, 8..12] {
                        let (s0, s1) = (r.start * dim, r.end * dim);
                        let a = &mut scratch.a.data[s0..s1];
                        let b: &mut [f32] = if scratch.b.data.is_empty() {
                            &mut empty
                        } else {
                            &mut scratch.b.data[s0..s1]
                        };
                        sharded.step_shard(phase, r.clone(), &w, &g, 0.05, a, b);
                    }
                    sharded.commit(phase, &w, &g, 0.05, &mut scratch);
                }
            }
            assert_eq!(
                whole.params().data,
                sharded.params().data,
                "{} shard/full divergence",
                whole.name()
            );
        }
    }
}
