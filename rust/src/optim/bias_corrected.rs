//! Bias-corrected decentralized optimizers — the extension direction the
//! paper points to in its conclusion ("symmetric time-varying graphs are
//! critical for D² and DecentLaM") and Remark 9's related work.
//!
//! * [`D2`] — D²/Exact-Diffusion (Tang et al. [57]): removes the data-
//!   heterogeneity bias of DSGD. Requires a **symmetric** weight matrix
//!   with `λ_min(W) > −1/3` — exponential graphs are asymmetric, which is
//!   exactly why the paper could not evaluate it; the
//!   [`crate::topology::hypercube_onepeer`] schedule satisfies both
//!   requirements while staying Ω(1) per iteration.
//! * [`GradientTracking`] — DIGing/NEXT-style tracking (Refs. [17, 52,
//!   69]): `y` tracks the global gradient average; works with arbitrary
//!   doubly-stochastic (including time-varying, asymmetric) matrices, so
//!   it composes with one-peer exponential graphs directly.
//!
//! Both converge to the *exact* consensus optimum with a constant step
//! size on heterogeneous deterministic problems, unlike DSGD whose fixed
//! point is O(γ·b/(1−ρ)) away — the property tested below.

// The shard kernels legitimately take the full step context (phase, row
// range, plan, grads, lr, both scratch views).
#![allow(clippy::too_many_arguments)]

use std::ops::Range;

use super::{damp_rows, Optimizer, StepScratch};
use crate::compress::StreamState;
use crate::coordinator::mixing::MixingPlan;
use crate::coordinator::state::StackedParams;
use crate::simd::fmaf;

/// D² / Exact-Diffusion:
///
/// ```text
/// x^{1}   = W (x^0 − γ g^0)
/// x^{k+1} = W (2 x^k − x^{k−1} − γ (g^k − g^{k−1}))        k ≥ 1
/// ```
///
/// Shard kernel: the correction term `pre_j` is produced on the fly per
/// nonzero (fused with the mixing accumulation); the secondary scratch
/// carries the gradient copy that becomes `g_prev` at commit.
pub struct D2 {
    x: StackedParams,
    x_prev: StackedParams,
    g_prev: StackedParams,
    first: bool,
    /// Mix with the lazy matrix `(I + W)/2` instead of `W` (the
    /// Exact-Diffusion convention [68]); guarantees `λ_min ≥ 0` so the
    /// `λ_min(W) > −1/3` condition holds for *any* symmetric
    /// doubly-stochastic W. This is the safe default.
    lazy: bool,
}

impl D2 {
    /// Lazy (Exact-Diffusion) variant — works for any symmetric W.
    pub fn new(x: StackedParams) -> Self {
        Self::with_lazy(x, true)
    }

    /// Plain D² — caller must ensure `λ_min(W) > −1/3` (e.g. the
    /// Metropolis hypercube at n = 8 has λ_min = −½ and diverges).
    pub fn plain(x: StackedParams) -> Self {
        Self::with_lazy(x, false)
    }

    fn with_lazy(x: StackedParams, lazy: bool) -> Self {
        let z = StackedParams::zeros(x.n, x.dim);
        D2 { x_prev: x.clone(), g_prev: z, x, first: true, lazy }
    }

    /// Element `k` of `pre_j` (flat index `s = j·dim + k`), produced on
    /// the fly inside the mixing accumulation and reused verbatim by the
    /// lazy post-pass so both sides of `(I + W)/2` see the same bits.
    #[inline(always)]
    fn pre_at(&self, grads: &StackedParams, lr: f32, s: usize) -> f32 {
        if self.first {
            fmaf(-lr, grads.data[s], self.x.data[s])
        } else {
            let corr = 2.0 * self.x.data[s] - self.x_prev.data[s];
            fmaf(-lr, grads.data[s] - self.g_prev.data[s], corr)
        }
    }
}

impl Optimizer for D2 {
    fn name(&self) -> &'static str {
        "d2"
    }

    fn needs_secondary(&self) -> bool {
        true
    }

    fn step_shard(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        // Stage the gradient copy that commit adopts as g_prev.
        for i in rows.clone() {
            let off = (i - base) * dim;
            b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
        }
        // a ← W·pre with the correction term produced on the fly.
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| {
            self.pre_at(grads, lr, j * dim + k)
        });
        if self.lazy {
            // a ← ((I + W)/2)·pre, with pre_i recomputed row-locally.
            for i in rows {
                let off = (i - base) * dim;
                let out = &mut a[off..off + dim];
                let s = i * dim;
                for (k, ov) in out.iter_mut().enumerate() {
                    *ov = 0.5 * (*ov + self.pre_at(grads, lr, s + k));
                }
            }
        }
    }

    fn commit(
        &mut self,
        _phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        // x_prev ← x, x ← W̃·pre, g_prev ← g (all buffer swaps).
        std::mem::swap(&mut self.x_prev.data, &mut self.x.data);
        std::mem::swap(&mut self.x.data, &mut scratch.a.data);
        std::mem::swap(&mut self.g_prev.data, &mut scratch.b.data);
        self.first = false;
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        1
    }

    fn payload_shard(
        &self,
        _phase: usize,
        _stream: usize,
        rows: Range<usize>,
        grads: &StackedParams,
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        // The gossiped stack is the bias-corrected pre-mix state.
        for i in rows {
            let off = (i - base) * dim;
            let s = i * dim;
            for k in 0..dim {
                out[off + k] = self.pre_at(grads, lr, s + k);
            }
        }
    }

    fn step_shard_q(
        &self,
        _phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        for i in rows.clone() {
            let off = (i - base) * dim;
            b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
        }
        let hq = &q[0].h.data;
        w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| hq[j * dim + k]);
        damp_rows(rows.clone(), dim, gamma, q[0], a);
        if self.lazy {
            // The self half of (I + W)/2 never touches the wire: use the
            // exact local pre, same as the dense kernel.
            for i in rows {
                let off = (i - base) * dim;
                let out = &mut a[off..off + dim];
                let s = i * dim;
                for (k, ov) in out.iter_mut().enumerate() {
                    *ov = 0.5 * (*ov + self.pre_at(grads, lr, s + k));
                }
            }
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

/// Gradient tracking (DIGing):
///
/// ```text
/// x^{k+1} = W (x^k − γ y^k)
/// y^{k+1} = W y^k + g^{k+1} − g^k
/// ```
///
/// `y⁰ = g⁰`. The caller supplies `g^{k}` each step; the tracker keeps
/// `y` and the previous gradient. Mean(y) = mean(g) is an invariant.
///
/// The only two-phase algorithm in the zoo: the x-update mixes the
/// *post-update* tracker, so phase 0 refreshes `y` (barrier), phase 1
/// mixes `x` against the new `y` and stages the `g_prev` copy.
pub struct GradientTracking {
    x: StackedParams,
    y: StackedParams,
    g_prev: StackedParams,
    first: bool,
}

impl GradientTracking {
    pub fn new(x: StackedParams) -> Self {
        let z = StackedParams::zeros(x.n, x.dim);
        GradientTracking { y: z.clone(), g_prev: z, x, first: true }
    }

    /// The tracking variable (for invariant tests).
    pub fn tracker(&self) -> &StackedParams {
        &self.y
    }
}

impl Optimizer for GradientTracking {
    fn name(&self) -> &'static str {
        "gradient_tracking"
    }

    fn phases(&self) -> usize {
        2
    }

    fn needs_secondary(&self) -> bool {
        true
    }

    fn step_shard(
        &self,
        phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        if phase == 0 {
            // b ← W y + g − g_prev (the next tracker; y⁰ = g⁰).
            if self.first {
                for i in rows {
                    let off = (i - base) * dim;
                    b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
                }
                return;
            }
            w.mix_fused_rows(rows.clone(), dim, b, |j: usize, k: usize| self.y.data[j * dim + k]);
            for i in rows {
                let off = (i - base) * dim;
                let out = &mut b[off..off + dim];
                let gi = &grads.data[i * dim..(i + 1) * dim];
                let gpi = &self.g_prev.data[i * dim..(i + 1) * dim];
                for (k, o) in out.iter_mut().enumerate() {
                    *o = (*o + gi[k]) - gpi[k];
                }
            }
        } else {
            // a ← W (x − γ y⁺) (y already swapped by the phase-0 commit);
            // b ← g (staged g_prev).
            for i in rows.clone() {
                let off = (i - base) * dim;
                b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
            }
            w.mix_fused_rows(rows, dim, a, |j: usize, k: usize| {
                let s = j * dim + k;
                fmaf(-lr, self.y.data[s], self.x.data[s])
            });
        }
    }

    fn commit(
        &mut self,
        phase: usize,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        scratch: &mut StepScratch,
    ) {
        if phase == 0 {
            std::mem::swap(&mut self.y.data, &mut scratch.b.data);
        } else {
            std::mem::swap(&mut self.x.data, &mut scratch.a.data);
            std::mem::swap(&mut self.g_prev.data, &mut scratch.b.data);
            self.first = false;
        }
    }

    fn phase_streams(&self, _phase: usize) -> usize {
        // Phase 0 gossips the tracker, phase 1 the model half-step.
        1
    }

    fn payload_shard(
        &self,
        phase: usize,
        _stream: usize,
        rows: Range<usize>,
        _grads: &StackedParams,
        lr: f32,
        out: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        if phase == 0 {
            // The tracker stack y (all zeros on the first step, where the
            // dense kernel skips the exchange too).
            for i in rows {
                let off = (i - base) * dim;
                out[off..off + dim].copy_from_slice(&self.y.data[i * dim..(i + 1) * dim]);
            }
        } else {
            // x − γ y⁺ (y already refreshed by the phase-0 commit).
            for i in rows {
                let off = (i - base) * dim;
                for k in 0..dim {
                    let s = i * dim + k;
                    out[off + k] = fmaf(-lr, self.y.data[s], self.x.data[s]);
                }
            }
        }
    }

    fn step_shard_q(
        &self,
        phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        _lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let dim = self.x.dim;
        let base = rows.start;
        let hq = &q[0].h.data;
        if phase == 0 {
            if self.first {
                // y⁰ = g⁰: no exchange happens on the first step.
                for i in rows {
                    let off = (i - base) * dim;
                    b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
                }
                return;
            }
            w.mix_fused_rows(rows.clone(), dim, b, |j: usize, k: usize| hq[j * dim + k]);
            damp_rows(rows.clone(), dim, gamma, q[0], b);
            for i in rows {
                let off = (i - base) * dim;
                let out = &mut b[off..off + dim];
                let gi = &grads.data[i * dim..(i + 1) * dim];
                let gpi = &self.g_prev.data[i * dim..(i + 1) * dim];
                for (k, o) in out.iter_mut().enumerate() {
                    *o = (*o + gi[k]) - gpi[k];
                }
            }
        } else {
            for i in rows.clone() {
                let off = (i - base) * dim;
                b[off..off + dim].copy_from_slice(&grads.data[i * dim..(i + 1) * dim]);
            }
            w.mix_fused_rows(rows.clone(), dim, a, |j: usize, k: usize| hq[j * dim + k]);
            damp_rows(rows, dim, gamma, q[0], a);
        }
    }

    fn params(&self) -> &StackedParams {
        &self.x
    }

    fn params_mut(&mut self) -> &mut StackedParams {
        &mut self.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::schedule::Schedule;
    use crate::topology::TopologyKind;
    use crate::util::rng::Pcg;

    /// Heterogeneous deterministic quadratics: f_i(x) = ½‖x − c_i‖².
    /// DSGD stalls at a γ-dependent bias; D² and tracking reach the exact
    /// optimum c̄ with constant γ.
    fn targets(n: usize, dim: usize, seed: u64) -> StackedParams {
        let mut rng = Pcg::seeded(seed);
        let mut t = StackedParams::zeros(n, dim);
        for v in t.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        t
    }

    fn run(opt: &mut dyn Optimizer, kind: TopologyKind, targets: &StackedParams, iters: usize, lr: f32) -> f64 {
        let n = targets.n;
        let dim = targets.dim;
        let mut sched = Schedule::new(kind, n, 1);
        let mut g = StackedParams::zeros(n, dim);
        for k in 0..iters {
            for i in 0..n {
                for j in 0..dim {
                    g.row_mut(i)[j] = opt.params().row(i)[j] - targets.row(i)[j];
                }
            }
            opt.step(sched.plan_at(k), &g, lr);
        }
        let mean_t = targets.mean();
        opt.params().mean_sq_error_to(&mean_t) + opt.params().consensus_distance()
    }

    #[test]
    fn d2_exact_on_static_hypercube() {
        // D² with a *static* symmetric W (λ_min ≥ 0): exact convergence
        // with constant γ despite heterogeneity.
        let n = 8;
        let dim = 4;
        let t = targets(n, dim, 3);
        let mut d2 = D2::new(StackedParams::zeros(n, dim));
        let err = run(&mut d2, TopologyKind::Hypercube, &t, 2500, 0.15);
        assert!(err < 1e-6, "D2 error {err}");
    }

    #[test]
    fn dsgd_biased_where_d2_exact() {
        // Same setting: DSGD's constant-γ fixed point keeps a bias.
        let n = 8;
        let dim = 4;
        let t = targets(n, dim, 3);
        let mut dsgd = super::super::DSgd::new(StackedParams::zeros(n, dim));
        let err_dsgd = run(&mut dsgd, TopologyKind::Hypercube, &t, 2500, 0.15);
        let mut d2 = D2::new(StackedParams::zeros(n, dim));
        let err_d2 = run(&mut d2, TopologyKind::Hypercube, &t, 2500, 0.15);
        assert!(
            err_dsgd > 1e3 * err_d2.max(1e-12),
            "dsgd {err_dsgd} vs d2 {err_d2}"
        );
    }

    #[test]
    fn plain_d2_diverges_when_eigenvalue_condition_fails() {
        // Metropolis hypercube at n = 8 has λ_min = −½ < −1/3: plain D²
        // diverges, the lazy (Exact-Diffusion) variant is exact.
        let n = 8;
        let dim = 4;
        let t = targets(n, dim, 3);
        let mut plain = D2::plain(StackedParams::zeros(n, dim));
        let err_plain = run(&mut plain, TopologyKind::Hypercube, &t, 400, 0.15);
        assert!(!err_plain.is_finite() || err_plain > 1.0, "plain D2: {err_plain}");
    }

    #[test]
    fn d2_unstable_on_time_varying_matchings() {
        // The paper's conclusion calls symmetric *time-varying* graphs
        // matching one-peer-exp performance an open problem. Concretely:
        // naive D² over the one-peer hypercube diverges — the per-mode
        // period map [[2−γ, −(1−γ)],[1,0]]²·[[0,0],[1,0]] has spectral
        // radius ≈ 1.57 > 1 at γ = 0.15. Pinning this behaviour documents
        // why symmetry alone is not enough (see docs/DESIGN.md §Extensions).
        let n = 8;
        let dim = 4;
        let t = targets(n, dim, 3);
        let mut d2 = D2::plain(StackedParams::zeros(n, dim));
        let err = run(&mut d2, TopologyKind::OnePeerHypercube, &t, 300, 0.15);
        assert!(
            !err.is_finite() || err > 1.0,
            "naive D² unexpectedly stable on time-varying matchings: {err}"
        );
    }

    #[test]
    fn tracking_exact_on_asymmetric_one_peer_exp() {
        // Gradient tracking doesn't need symmetry: exact on the one-peer
        // exponential graph where D²'s assumptions fail.
        let n = 8;
        let dim = 4;
        let t = targets(n, dim, 5);
        let mut gt = GradientTracking::new(StackedParams::zeros(n, dim));
        let err = run(&mut gt, TopologyKind::OnePeerExp, &t, 2500, 0.1);
        assert!(err < 1e-6, "tracking error {err}");
    }

    #[test]
    fn tracking_mean_invariant() {
        // Invariant: mean(y) == mean(g) after every step.
        let n = 4;
        let dim = 3;
        let t = targets(n, dim, 7);
        let mut gt = GradientTracking::new(StackedParams::zeros(n, dim));
        let mut sched = Schedule::new(TopologyKind::OnePeerExp, n, 1);
        let mut g = StackedParams::zeros(n, dim);
        for k in 0..10 {
            for i in 0..n {
                for j in 0..dim {
                    g.row_mut(i)[j] = gt.params().row(i)[j] - t.row(i)[j];
                }
            }
            gt.step(sched.plan_at(k), &g, 0.1);
            let ym = gt.tracker().mean();
            let gm = g.mean();
            for (a, b) in ym.iter().zip(gm.iter()) {
                assert!((a - b).abs() < 1e-5, "k={k}: mean(y) drifted");
            }
        }
    }
}
