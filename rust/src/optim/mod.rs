//! Decentralized optimizers.
//!
//! All operate on the stacked state `𝐱 ∈ R^{n×P}` with a per-iteration
//! doubly-stochastic weight matrix `W^{(k)}`:
//!
//! * [`DSgd`] — decentralized SGD, adapt-then-combine:
//!   `x⁺ = W(x − γ g)` (Lian et al. [30]; Table 10, Fig. 1).
//! * [`DmSgd`] — decentralized momentum SGD, Algorithm 1 of the paper
//!   (Yu et al. [64]): both the model *and the momentum* are partially
//!   averaged, and the model update uses the *previous* momentum:
//!   `m⁺ = W(βm + g)`, `x⁺ = W(x − γm)`.
//! * [`VanillaDmSgd`] — momentum kept local (Assran et al. [3]):
//!   `m⁺ = βm + g`, `x⁺ = Wx − γm⁺`.
//! * [`QgDmSgd`] — quasi-global momentum (Lin et al. [32]): local step
//!   with momentum, gossip, then momentum updated from the realized
//!   model displacement `m⁺ = βm + (1−β)(x − x⁺)/γ`.
//! * [`ParallelMSgd`] — the parallel (all-reduce) baseline: exact global
//!   gradient averaging plus ordinary momentum.
//!
//! Every optimizer exposes the same [`Optimizer`] interface so the
//! coordinator and the experiment harness can swap them freely.

use std::ops::Range;

use crate::compress::{stream_seed, GossipCompression, StreamState};
use crate::coordinator::mixing::MixingPlan;
use crate::coordinator::state::StackedParams;
use crate::engine::{shard_range, Engine, Lanes};

pub mod algorithms;
pub mod bias_corrected;

pub use algorithms::{DSgd, DmSgd, ParallelMSgd, QgDmSgd, VanillaDmSgd};
pub use bias_corrected::{GradientTracking, D2};

/// The algorithm grid of Tables 3–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    DSgd,
    DmSgd,
    VanillaDmSgd,
    QgDmSgd,
    ParallelSgd,
    /// D²/Exact-Diffusion [57] — requires symmetric W (see
    /// [`bias_corrected`]).
    D2,
    /// Gradient tracking (DIGing) — heterogeneity-robust on arbitrary
    /// doubly-stochastic schedules.
    GradientTracking,
}

impl AlgorithmKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::DSgd => "dsgd",
            AlgorithmKind::DmSgd => "dmsgd",
            AlgorithmKind::VanillaDmSgd => "vanilla_dmsgd",
            AlgorithmKind::QgDmSgd => "qg_dmsgd",
            AlgorithmKind::ParallelSgd => "parallel_sgd",
            AlgorithmKind::D2 => "d2",
            AlgorithmKind::GradientTracking => "gradient_tracking",
        }
    }

    pub fn parse(s: &str) -> Option<AlgorithmKind> {
        Some(match s {
            "dsgd" => AlgorithmKind::DSgd,
            "dmsgd" => AlgorithmKind::DmSgd,
            "vanilla_dmsgd" => AlgorithmKind::VanillaDmSgd,
            "qg_dmsgd" => AlgorithmKind::QgDmSgd,
            "parallel_sgd" | "parallel" => AlgorithmKind::ParallelSgd,
            "d2" => AlgorithmKind::D2,
            "gradient_tracking" | "diging" => AlgorithmKind::GradientTracking,
            _ => return None,
        })
    }

    /// Instantiate with replicated initial parameters.
    pub fn build(&self, n: usize, init: &[f32], beta: f32) -> Box<dyn Optimizer> {
        let x = StackedParams::replicate(n, init);
        match self {
            AlgorithmKind::DSgd => Box::new(DSgd::new(x)),
            AlgorithmKind::DmSgd => Box::new(DmSgd::new(x, beta)),
            AlgorithmKind::VanillaDmSgd => Box::new(VanillaDmSgd::new(x, beta)),
            AlgorithmKind::QgDmSgd => Box::new(QgDmSgd::new(x, beta)),
            AlgorithmKind::ParallelSgd => Box::new(ParallelMSgd::new(x, beta)),
            AlgorithmKind::D2 => Box::new(D2::new(x)),
            AlgorithmKind::GradientTracking => Box::new(GradientTracking::new(x)),
        }
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Caller-owned double-buffer scratch for one optimizer step. The shard
/// kernels write their output rows here; `commit` adopts the buffers by
/// swapping, so no optimizer state is copied. One `StepScratch` lives for
/// a whole training run (the engine path) — the legacy `step` wrapper
/// allocates a transient one per call.
#[derive(Debug)]
pub struct StepScratch {
    /// Primary output stack (the next `x`).
    pub a: StackedParams,
    /// Secondary output stack (next momentum / tracker / gradient copy);
    /// empty unless [`Optimizer::needs_secondary`].
    pub b: StackedParams,
}

impl Default for StepScratch {
    fn default() -> Self {
        StepScratch { a: StackedParams::zeros(0, 0), b: StackedParams::zeros(0, 0) }
    }
}

impl StepScratch {
    /// Size the buffers for an `n × dim` optimizer (no-op when already
    /// sized — the per-iteration fast path).
    pub fn ensure(&mut self, n: usize, dim: usize, secondary: bool) {
        if self.a.n != n || self.a.dim != dim {
            self.a = StackedParams::zeros(n, dim);
        }
        let (bn, bdim) = if secondary { (n, dim) } else { (0, 0) };
        if self.b.n != bn || self.b.dim != bdim {
            self.b = StackedParams::zeros(bn, bdim);
        }
    }
}

/// Row-local damped consensus correction shared by the compressed
/// kernels: after the standard fold `out = Σ_j w_ij h_j`, rewrite each
/// output row as `out_i = p_i + γ·(out_i − h_i)` — node `i` keeps its
/// exact local payload as the base and takes a damped step toward the
/// neighbor reconstructions (CHOCO-Gossip's consensus step). Touches
/// only row `i`'s slices, so lane invariance is preserved.
pub(crate) fn damp_rows(
    rows: Range<usize>,
    dim: usize,
    gamma: f32,
    st: &StreamState,
    out: &mut [f32],
) {
    let base = rows.start;
    let p = &st.p.data;
    let h = &st.h.data;
    for i in rows {
        let off = (i - base) * dim;
        let s = i * dim;
        for k in 0..dim {
            out[off + k] = crate::simd::fmaf(gamma, out[off + k] - h[s + k], p[s + k]);
        }
    }
}

/// Interface every decentralized optimizer implements.
///
/// The contract is **shard-local**: a step is `prepare` (serial, once),
/// then for each phase a fleet of [`Optimizer::step_shard`] calls over
/// disjoint row ranges (safe to run concurrently — `&self` plus disjoint
/// output slices), then a serial [`Optimizer::commit`] that adopts the
/// scratch via buffer swaps. Every kernel computes output row `i` from
/// the *pre-step* state in a fixed (ascending-neighbor) order, so results
/// are bitwise-identical for any sharding — the engine exploits this to
/// parallelize without changing a single bit of the trajectory
/// (docs/DESIGN.md §Engine).
pub trait Optimizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// Number of sharded phases per step (a barrier plus `commit` runs
    /// after each). Only gradient tracking needs two (its x-update mixes
    /// the *post-update* tracker).
    fn phases(&self) -> usize {
        1
    }

    /// Does this algorithm write the secondary scratch stack
    /// [`StepScratch::b`]?
    fn needs_secondary(&self) -> bool {
        false
    }

    /// Serial pre-step hook, run once before phase 0 (e.g. parallel
    /// SGD's exact global gradient reduction).
    fn prepare(&mut self, _w: &MixingPlan, _grads: &StackedParams, _lr: f32) {}

    /// The fused shard-local kernel: compute output rows `rows` of phase
    /// `phase` into the matching row slices `a`/`b` (shard views of the
    /// caller's [`StepScratch`]), reading the pre-step state through
    /// `&self`. One streaming pass per nonzero — the pre/post element
    /// loops of the update rule are folded into the mixing accumulation.
    #[allow(clippy::too_many_arguments)]
    fn step_shard(
        &self,
        phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        a: &mut [f32],
        b: &mut [f32],
    );

    /// Serial post-barrier commit for `phase`: adopt the scratch outputs
    /// (buffer swaps) and advance any serial state.
    fn commit(
        &mut self,
        phase: usize,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        scratch: &mut StepScratch,
    );

    /// One training iteration: per-node stochastic gradients `g^{(k)}` and
    /// this iteration's mixing plan (the sparse representation of
    /// `W^{(k)}`, borrowed from the schedule's cache), learning rate `γ_k`.
    ///
    /// Thin single-shard wrapper over `prepare`/`step_shard`/`commit`,
    /// kept so existing call sites work unchanged; the training loop
    /// itself uses [`Optimizer::step_engine`].
    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32) {
        let mut scratch = StepScratch::default();
        self.step_with(w, grads, lr, &mut scratch);
    }

    /// Single-shard step reusing caller-owned scratch (no allocation).
    fn step_with(
        &mut self,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        scratch: &mut StepScratch,
    ) {
        let n = self.params().n;
        let dim = self.params().dim;
        scratch.ensure(n, dim, self.needs_secondary());
        self.prepare(w, grads, lr);
        for phase in 0..self.phases() {
            {
                let a = &mut scratch.a.data[..];
                let b = &mut scratch.b.data[..];
                self.step_shard(phase, 0..n, w, grads, lr, a, b);
            }
            self.commit(phase, w, grads, lr, scratch);
        }
    }

    /// Engine-driven step: each phase is broadcast over the persistent
    /// worker pool (lane `t` computes its contiguous row shard), with the
    /// serial `commit` between barriers. Bitwise-identical to
    /// [`Optimizer::step`] for any lane count.
    fn step_engine(
        &mut self,
        engine: &Engine,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        scratch: &mut StepScratch,
    ) {
        if engine.lanes() == 1 {
            self.step_with(w, grads, lr, scratch);
            return;
        }
        let n = self.params().n;
        let dim = self.params().dim;
        scratch.ensure(n, dim, self.needs_secondary());
        self.prepare(w, grads, lr);
        let lanes = engine.lanes();
        for phase in 0..self.phases() {
            {
                let a = Lanes::split(&mut scratch.a.data, n, dim, lanes);
                let b = Lanes::split(&mut scratch.b.data, n, dim, lanes);
                let this: &Self = self;
                engine.run(&|lane| {
                    let rows = shard_range(n, lanes, lane);
                    if rows.is_empty() {
                        return;
                    }
                    let mut ga = a.lock(lane);
                    let mut gb = b.lock(lane);
                    this.step_shard(phase, rows, w, grads, lr, &mut ga[..], &mut gb[..]);
                });
            }
            self.commit(phase, w, grads, lr, scratch);
        }
    }

    /// Number of wire payload streams phase `phase` exchanges (DmSGD
    /// gossips two stacks per round, most algorithms one). `0` — the
    /// default — opts the algorithm out of wire compression: the
    /// compressed step drivers fall back to the dense kernels (e.g.
    /// parallel SGD's exact all-reduce stays full precision).
    fn phase_streams(&self, _phase: usize) -> usize {
        0
    }

    /// Stage the raw pre-mix payload of stream `stream` in `phase` for
    /// rows `rows` into the shard view `out` (row `rows.start` maps to
    /// offset 0). Row-local by contract, like [`Optimizer::step_shard`].
    /// Only called when [`Optimizer::phase_streams`] is nonzero.
    #[allow(clippy::too_many_arguments)]
    fn payload_shard(
        &self,
        _phase: usize,
        _stream: usize,
        _rows: Range<usize>,
        _grads: &StackedParams,
        _lr: f32,
        _out: &mut [f32],
    ) {
    }

    /// [`Optimizer::step_shard`] variant that mixes from the compressed
    /// reconstructions in `q` (one [`StreamState`] per stream of this
    /// phase, in [`Optimizer::payload_shard`] stream order) with the
    /// damped consensus step `out = p + γ(Wh − h)` instead of computing
    /// dense payloads on the fly. The default forwards to the dense
    /// kernel — correct for phases with zero streams, which put nothing
    /// on the wire.
    #[allow(clippy::too_many_arguments)]
    fn step_shard_q(
        &self,
        phase: usize,
        rows: Range<usize>,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        q: &[&StreamState],
        gamma: f32,
        a: &mut [f32],
        b: &mut [f32],
    ) {
        let _ = (q, gamma);
        self.step_shard(phase, rows, w, grads, lr, a, b);
    }

    /// Single-shard compressed step: stage payloads, advance the shared
    /// reconstructions through the compressor, mix from them. Identity
    /// compressors (and stream-less algorithms) delegate to the plain
    /// dense kernels, so they stay bitwise identical to
    /// [`Optimizer::step_with`].
    fn step_compressed(
        &mut self,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        scratch: &mut StepScratch,
        gz: &mut GossipCompression,
    ) {
        let phases = self.phases();
        let total: usize = (0..phases).map(|p| self.phase_streams(p)).sum();
        if gz.is_identity() || total == 0 {
            self.step_with(w, grads, lr, scratch);
            gz.advance();
            return;
        }
        let n = self.params().n;
        let dim = self.params().dim;
        scratch.ensure(n, dim, self.needs_secondary());
        gz.ensure(total, n, dim);
        self.prepare(w, grads, lr);
        let gamma = gz.gamma();
        let mut s0 = 0usize;
        for phase in 0..phases {
            let ns = self.phase_streams(phase);
            {
                let (comp, iter, seed, streams) = gz.parts_mut();
                for s in 0..ns {
                    let sseed = stream_seed(seed, s0 + s);
                    let StreamState { p, h } = &mut streams[s0 + s];
                    self.payload_shard(phase, s, 0..n, grads, lr, &mut p.data[..]);
                    for i in 0..n {
                        let o = i * dim;
                        comp.compress_row(
                            &p.data[o..o + dim],
                            &mut h.data[o..o + dim],
                            i,
                            iter,
                            sseed,
                        );
                    }
                }
            }
            {
                let q = gz.phase_states(s0, ns);
                let a = &mut scratch.a.data[..];
                let b = &mut scratch.b.data[..];
                self.step_shard_q(phase, 0..n, w, grads, lr, &q, gamma, a, b);
            }
            self.commit(phase, w, grads, lr, scratch);
            s0 += ns;
        }
        gz.advance();
    }

    /// Engine-driven compressed step: the payload staging + compression
    /// pass and the reconstruction-mixing pass are each broadcast over
    /// the worker pool. Compression state updates are row-local and the
    /// mixing kernels keep their fixed fold order, so trajectories are
    /// bitwise-identical for any lane count — same discipline as
    /// [`Optimizer::step_engine`].
    fn step_engine_compressed(
        &mut self,
        engine: &Engine,
        w: &MixingPlan,
        grads: &StackedParams,
        lr: f32,
        scratch: &mut StepScratch,
        gz: &mut GossipCompression,
    ) {
        if engine.lanes() == 1 {
            self.step_compressed(w, grads, lr, scratch, gz);
            return;
        }
        let phases = self.phases();
        let total: usize = (0..phases).map(|p| self.phase_streams(p)).sum();
        if gz.is_identity() || total == 0 {
            self.step_engine(engine, w, grads, lr, scratch);
            gz.advance();
            return;
        }
        let n = self.params().n;
        let dim = self.params().dim;
        scratch.ensure(n, dim, self.needs_secondary());
        gz.ensure(total, n, dim);
        self.prepare(w, grads, lr);
        let gamma = gz.gamma();
        let lanes = engine.lanes();
        let mut s0 = 0usize;
        for phase in 0..phases {
            let ns = self.phase_streams(phase);
            {
                let (comp, iter, seed, streams) = gz.parts_mut();
                for s in 0..ns {
                    let sseed = stream_seed(seed, s0 + s);
                    let StreamState { p, h } = &mut streams[s0 + s];
                    let pl = Lanes::split(&mut p.data, n, dim, lanes);
                    let hl = Lanes::split(&mut h.data, n, dim, lanes);
                    let this: &Self = self;
                    engine.run(&|lane| {
                        let rows = shard_range(n, lanes, lane);
                        if rows.is_empty() {
                            return;
                        }
                        let mut gp = pl.lock(lane);
                        let mut gh = hl.lock(lane);
                        this.payload_shard(phase, s, rows.clone(), grads, lr, &mut gp[..]);
                        for (r, i) in rows.enumerate() {
                            let o = r * dim;
                            comp.compress_row(
                                &gp[o..o + dim],
                                &mut gh[o..o + dim],
                                i,
                                iter,
                                sseed,
                            );
                        }
                    });
                }
            }
            {
                let q = gz.phase_states(s0, ns);
                let qs: &[&StreamState] = &q;
                let a = Lanes::split(&mut scratch.a.data, n, dim, lanes);
                let b = Lanes::split(&mut scratch.b.data, n, dim, lanes);
                let this: &Self = self;
                engine.run(&|lane| {
                    let rows = shard_range(n, lanes, lane);
                    if rows.is_empty() {
                        return;
                    }
                    let mut ga = a.lock(lane);
                    let mut gb = b.lock(lane);
                    this.step_shard_q(phase, rows, w, grads, lr, qs, gamma, &mut ga[..], &mut gb[..]);
                });
            }
            self.commit(phase, w, grads, lr, scratch);
            s0 += ns;
        }
        gz.advance();
    }

    /// Number of gossip payload streams the bounded-staleness async
    /// executor exchanges for this algorithm. `0` — the default — means
    /// the algorithm is not supported by `execution = async:<τ>` (the
    /// executor rejects it with a clear error). For the supported
    /// single-phase algorithms this equals [`Optimizer::phase_streams`]
    /// of phase 0 (the staging path *is* [`Optimizer::payload_shard`],
    /// so staged bytes match the sync wire payloads bitwise).
    fn async_streams(&self) -> usize {
        0
    }

    /// Stage the raw gossip payload of async stream `stream` for rows
    /// `rows` into the shard view `out` (row `rows.start` at offset 0),
    /// like [`Optimizer::payload_shard`] — except the gradient rows
    /// arrive as the *shard-local* slice `g_rows` (same layout as
    /// `out`), which lets the executor fuse staging into the gradient
    /// dispatch: the lane that just computed its gradient rows stages
    /// its payload rows in the same barrier round. Expressions must
    /// match [`Optimizer::payload_shard`] exactly.
    fn stage_shard_async(
        &self,
        _stream: usize,
        _rows: Range<usize>,
        _g_rows: &[f32],
        _lr: f32,
        _out: &mut [f32],
    ) {
        panic!("{} does not support async execution", self.name());
    }

    /// Async-mode shard kernel: compute output rows `rows` into the
    /// shard views `a`/`b` exactly like [`Optimizer::step_shard`], but
    /// pull every mixed payload element through `src(reader, stream,
    /// col, elem)` — the executor resolves `(reader, col)` to whichever
    /// committed payload version the bounded-staleness clock makes
    /// visible. `damp = Some((gamma, praw))` composes with compressed
    /// gossip: after the mix, each output row is rewritten
    /// `out = p + γ·(out − h)` per stream, where `p` is the node's raw
    /// payload (`praw[stream]`, full `n×dim`) and `h` its own
    /// reconstruction (`src(i, stream, i, ·)`) — the same damped
    /// consensus step as [`damp_rows`]. Row-local by contract; commit
    /// via the ordinary [`Optimizer::commit`] of phase 0.
    #[allow(clippy::too_many_arguments)]
    fn step_shard_async(
        &self,
        _rows: Range<usize>,
        _w: &MixingPlan,
        _grads: &StackedParams,
        _lr: f32,
        _src: &(dyn Fn(usize, usize, usize, usize) -> f32 + Sync),
        _damp: Option<(f32, &[&[f32]])>,
        _a: &mut [f32],
        _b: &mut [f32],
    ) {
        panic!("{} does not support async execution", self.name());
    }

    /// Hand the out-of-order executor ownership of the `(x, m)` stacks
    /// so `(node, wave)` tasks can update rows **in place** (no step
    /// scratch, no serial commit — a wave's per-node writes land exactly
    /// where the serial swap would have put them). Momentum-free
    /// algorithms return an empty secondary stack. Pair with
    /// [`Optimizer::restore_async_state`]; the shard entry points are
    /// unusable in between (the optimizer's own stacks are empty).
    fn take_async_state(&mut self) -> (StackedParams, StackedParams) {
        panic!("{} does not support async execution", self.name());
    }

    /// Put the stacks taken by [`Optimizer::take_async_state`] back.
    fn restore_async_state(&mut self, _x: StackedParams, _m: StackedParams) {
        panic!("{} does not support async execution", self.name());
    }

    /// Per-node form of [`Optimizer::stage_shard_async`]: stage node
    /// `i`'s raw payload row of `stream` into `out` from its state rows
    /// (`x_row`/`m_row` — the rows taken by
    /// [`Optimizer::take_async_state`]) and its gradient row. Same
    /// expressions as the shard entry, row for row, so staged payloads
    /// are bitwise identical. The row length is `x_row.len()` (the
    /// optimizer's own stacks are empty while the state is taken).
    fn stage_node_async(
        &self,
        _stream: usize,
        _x_row: &[f32],
        _m_row: &[f32],
        _g_row: &[f32],
        _lr: f32,
        _out: &mut [f32],
    ) {
        panic!("{} does not support async execution", self.name());
    }

    /// Per-node form of [`Optimizer::step_shard_async`]: compute node
    /// `i`'s post-step rows **in place** over `x_row`/`m_row`, pulling
    /// every mixed payload element through `src(stream, col, elem)`
    /// (the reader is fixed at `i`, otherwise the same resolved-version
    /// contract as the shard entry). `damp = Some((gamma, praw))` is
    /// the compressed-gossip consensus step; here `praw[stream]` is
    /// node `i`'s raw payload **row** (length `x_row.len()`), not the
    /// full stack. `tmp` is a caller-owned row-sized scratch for
    /// kernels whose update reads the pre-mix row after mixing
    /// (quasi-global momentum). Same fold order and float ops as the
    /// shard entry + serial swap commit, so trajectories are bitwise
    /// identical.
    #[allow(clippy::too_many_arguments)]
    fn step_node_async(
        &self,
        _i: usize,
        _w: &MixingPlan,
        _g_row: &[f32],
        _lr: f32,
        _src: &dyn Fn(usize, usize, usize) -> f32,
        _damp: Option<(f32, &[&[f32]])>,
        _x_row: &mut [f32],
        _m_row: &mut [f32],
        _tmp: &mut [f32],
    ) {
        panic!("{} does not support async execution", self.name());
    }

    /// Current stacked parameters.
    fn params(&self) -> &StackedParams;

    /// Mutable parameters (used by the warm-up all-reduce).
    fn params_mut(&mut self) -> &mut StackedParams;

    /// Does this optimizer ignore `W` and use exact global averaging?
    fn is_parallel(&self) -> bool {
        false
    }
}
