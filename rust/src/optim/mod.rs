//! Decentralized optimizers.
//!
//! All operate on the stacked state `𝐱 ∈ R^{n×P}` with a per-iteration
//! doubly-stochastic weight matrix `W^{(k)}`:
//!
//! * [`DSgd`] — decentralized SGD, adapt-then-combine:
//!   `x⁺ = W(x − γ g)` (Lian et al. [30]; Table 10, Fig. 1).
//! * [`DmSgd`] — decentralized momentum SGD, Algorithm 1 of the paper
//!   (Yu et al. [64]): both the model *and the momentum* are partially
//!   averaged, and the model update uses the *previous* momentum:
//!   `m⁺ = W(βm + g)`, `x⁺ = W(x − γm)`.
//! * [`VanillaDmSgd`] — momentum kept local (Assran et al. [3]):
//!   `m⁺ = βm + g`, `x⁺ = Wx − γm⁺`.
//! * [`QgDmSgd`] — quasi-global momentum (Lin et al. [32]): local step
//!   with momentum, gossip, then momentum updated from the realized
//!   model displacement `m⁺ = βm + (1−β)(x − x⁺)/γ`.
//! * [`ParallelMSgd`] — the parallel (all-reduce) baseline: exact global
//!   gradient averaging plus ordinary momentum.
//!
//! Every optimizer exposes the same [`Optimizer`] interface so the
//! coordinator and the experiment harness can swap them freely.

use crate::coordinator::mixing::MixingPlan;
use crate::coordinator::state::StackedParams;

pub mod algorithms;
pub mod bias_corrected;

pub use algorithms::{DSgd, DmSgd, ParallelMSgd, QgDmSgd, VanillaDmSgd};
pub use bias_corrected::{GradientTracking, D2};

/// The algorithm grid of Tables 3–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    DSgd,
    DmSgd,
    VanillaDmSgd,
    QgDmSgd,
    ParallelSgd,
    /// D²/Exact-Diffusion [57] — requires symmetric W (see
    /// [`bias_corrected`]).
    D2,
    /// Gradient tracking (DIGing) — heterogeneity-robust on arbitrary
    /// doubly-stochastic schedules.
    GradientTracking,
}

impl AlgorithmKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::DSgd => "dsgd",
            AlgorithmKind::DmSgd => "dmsgd",
            AlgorithmKind::VanillaDmSgd => "vanilla_dmsgd",
            AlgorithmKind::QgDmSgd => "qg_dmsgd",
            AlgorithmKind::ParallelSgd => "parallel_sgd",
            AlgorithmKind::D2 => "d2",
            AlgorithmKind::GradientTracking => "gradient_tracking",
        }
    }

    pub fn parse(s: &str) -> Option<AlgorithmKind> {
        Some(match s {
            "dsgd" => AlgorithmKind::DSgd,
            "dmsgd" => AlgorithmKind::DmSgd,
            "vanilla_dmsgd" => AlgorithmKind::VanillaDmSgd,
            "qg_dmsgd" => AlgorithmKind::QgDmSgd,
            "parallel_sgd" | "parallel" => AlgorithmKind::ParallelSgd,
            "d2" => AlgorithmKind::D2,
            "gradient_tracking" | "diging" => AlgorithmKind::GradientTracking,
            _ => return None,
        })
    }

    /// Instantiate with replicated initial parameters.
    pub fn build(&self, n: usize, init: &[f32], beta: f32) -> Box<dyn Optimizer> {
        let x = StackedParams::replicate(n, init);
        match self {
            AlgorithmKind::DSgd => Box::new(DSgd::new(x)),
            AlgorithmKind::DmSgd => Box::new(DmSgd::new(x, beta)),
            AlgorithmKind::VanillaDmSgd => Box::new(VanillaDmSgd::new(x, beta)),
            AlgorithmKind::QgDmSgd => Box::new(QgDmSgd::new(x, beta)),
            AlgorithmKind::ParallelSgd => Box::new(ParallelMSgd::new(x, beta)),
            AlgorithmKind::D2 => Box::new(D2::new(x)),
            AlgorithmKind::GradientTracking => Box::new(GradientTracking::new(x)),
        }
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Interface every decentralized optimizer implements.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// One training iteration: per-node stochastic gradients `g^{(k)}` and
    /// this iteration's mixing plan (the sparse representation of
    /// `W^{(k)}`, borrowed from the schedule's cache), learning rate `γ_k`.
    fn step(&mut self, w: &MixingPlan, grads: &StackedParams, lr: f32);

    /// Current stacked parameters.
    fn params(&self) -> &StackedParams;

    /// Mutable parameters (used by the warm-up all-reduce).
    fn params_mut(&mut self) -> &mut StackedParams;

    /// Does this optimizer ignore `W` and use exact global averaging?
    fn is_parallel(&self) -> bool {
        false
    }
}
