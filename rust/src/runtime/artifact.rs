//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `artifacts/manifest.json` describes every HLO-text
//! artifact (input shapes/dtypes, output arity, model metadata).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element dtype of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "float32" => Dtype::F32,
            "int32" => Dtype::I32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// One input tensor description.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// Absolute path to the `.hlo.txt` file.
    pub path: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub num_outputs: usize,
    /// Free-form metadata (param_count, model config, …).
    pub meta: BTreeMap<String, f64>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).map(|v| *v as usize)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let mut artifacts = Vec::new();
        for entry in doc
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?
        {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let mut inputs = Vec::new();
            for inp in entry
                .get("inputs")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
            {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_array)
                    .ok_or_else(|| anyhow!("input missing shape"))?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape")))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = Dtype::parse(
                    inp.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
                )?;
                inputs.push(InputSpec { shape, dtype });
            }
            let num_outputs = entry
                .get("num_outputs")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact {name} missing num_outputs"))?;
            let mut meta = BTreeMap::new();
            if let Some(obj) = entry.get("meta").and_then(Json::as_object) {
                for (k, v) in obj {
                    if let Some(num) = v.as_f64() {
                        meta.insert(k.clone(), num);
                    }
                }
            }
            artifacts.push(ArtifactSpec { name, path: dir.join(file), inputs, num_outputs, meta });
        }
        Ok(Manifest { artifacts, dir })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest (have: {:?})",
                self.artifacts.iter().map(|a| a.name.as_str()).collect::<Vec<_>>()))
    }

    /// Default artifacts directory: `$EXPOGRAPH_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("EXPOGRAPH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let tmp = std::env::temp_dir().join(format!("expograph-test-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        write_manifest(
            &tmp,
            r#"{"version":1,"artifacts":[
                {"name":"a","file":"a.hlo.txt",
                 "inputs":[{"shape":[3,4],"dtype":"float32"},{"shape":[2],"dtype":"int32"}],
                 "num_outputs":2,"meta":{"param_count":12}}]}"#,
        );
        let m = Manifest::load(&tmp).unwrap();
        let a = m.get("a").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![3, 4]);
        assert_eq!(a.inputs[0].num_elements(), 12);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.meta_usize("param_count"), Some(12));
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration: if `make artifacts` ran, the real manifest parses.
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("logreg_grad").is_ok());
            assert!(m.get("transformer_step").is_ok());
            assert!(m.get("gossip_update").is_ok());
        }
    }
}
