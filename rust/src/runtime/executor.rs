//! PJRT execution of AOT artifacts.
//!
//! `Runtime` owns the CPU PJRT client; `Executable` wraps one compiled
//! artifact with shape checking against the manifest; `TransformerExecutor`
//! and `LogRegExecutor` add typed front-ends matching the artifact
//! signatures emitted by `python/compile/aot.py`.
//!
//! PJRT handles are `Rc`-backed (not `Send`/`Sync`), so executors live on
//! the coordinator thread; per-node gradient calls are issued sequentially
//! (one CPU client already uses all cores for a single execution).

use super::artifact::{ArtifactSpec, Dtype, Manifest};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Typed view of one artifact input buffer.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// The PJRT runtime: client + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl Runtime {
    /// CPU client over the artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest })
    }

    /// Default artifacts location (env var or workspace `artifacts/`).
    pub fn from_default_dir() -> Result<Runtime> {
        Runtime::new(Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        Ok(Executable { exe, spec })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with shape-checked inputs; returns the decomposed output
    /// tuple as literals.
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (idx, (input, ispec)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (input, ispec.dtype) {
                (Input::F32(data), Dtype::F32) => {
                    if data.len() != ispec.num_elements() {
                        bail!(
                            "{} input {idx}: expected {} f32 elements, got {}",
                            self.spec.name,
                            ispec.num_elements(),
                            data.len()
                        );
                    }
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                (Input::I32(data), Dtype::I32) => {
                    if data.len() != ispec.num_elements() {
                        bail!(
                            "{} input {idx}: expected {} i32 elements, got {}",
                            self.spec.name,
                            ispec.num_elements(),
                            data.len()
                        );
                    }
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                _ => bail!("{} input {idx}: dtype mismatch", self.spec.name),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outputs = result.to_tuple()?;
        if outputs.len() != self.spec.num_outputs {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.spec.name,
                outputs.len(),
                self.spec.num_outputs
            );
        }
        Ok(outputs)
    }
}

/// Typed front-end for the `transformer_step*` artifacts:
/// `(flat_params f32[P], window i32[B, S+1]) → (loss f32[], grad f32[P])`.
pub struct TransformerExecutor {
    exe: Executable,
    pub param_count: usize,
    pub batch: usize,
    pub seq: usize,
}

impl TransformerExecutor {
    pub fn load(rt: &Runtime, name: &str) -> Result<TransformerExecutor> {
        let exe = rt.load(name)?;
        let spec = exe.spec();
        let param_count = spec
            .meta_usize("param_count")
            .context("transformer artifact missing param_count meta")?;
        let batch = spec.meta_usize("batch").context("missing batch meta")?;
        let seq = spec.meta_usize("seq").context("missing seq meta")?;
        Ok(TransformerExecutor { exe, param_count, batch, seq })
    }

    /// One gradient evaluation. `window` is `batch × (seq+1)` i32 tokens.
    pub fn loss_and_grad(&self, params: &[f32], window: &[i32], grad_out: &mut [f32]) -> Result<f32> {
        let outputs = self.exe.run(&[Input::F32(params), Input::I32(window)])?;
        let loss = outputs[0].to_vec::<f32>()?[0];
        let grad = outputs[1].to_vec::<f32>()?;
        if grad.len() != grad_out.len() {
            bail!("grad length {} vs buffer {}", grad.len(), grad_out.len());
        }
        grad_out.copy_from_slice(&grad);
        Ok(loss)
    }
}

/// Typed front-end for `logreg_grad`:
/// `(x f32[d], h f32[B,d], y f32[B]) → (loss f32[], grad f32[d])`.
pub struct LogRegExecutor {
    exe: Executable,
    pub d: usize,
    pub batch: usize,
}

impl LogRegExecutor {
    pub fn load(rt: &Runtime) -> Result<LogRegExecutor> {
        let exe = rt.load("logreg_grad")?;
        let d = exe.spec().meta_usize("d").context("missing d meta")?;
        let batch = exe.spec().meta_usize("batch").context("missing batch meta")?;
        Ok(LogRegExecutor { exe, d, batch })
    }

    pub fn loss_and_grad(&self, x: &[f32], h: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let outputs = self.exe.run(&[Input::F32(x), Input::F32(h), Input::F32(y)])?;
        Ok((outputs[0].to_vec::<f32>()?[0], outputs[1].to_vec::<f32>()?))
    }
}

/// Typed front-end for the `gossip_update*` artifacts (the Pallas kernel
/// path): `(W, X, M, G, β, γ) → (X′, M′)` over `n × p` stacked state.
pub struct GossipExecutor {
    exe: Executable,
    pub n: usize,
    pub p: usize,
}

impl GossipExecutor {
    pub fn load(rt: &Runtime, name: &str) -> Result<GossipExecutor> {
        let exe = rt.load(name)?;
        let n = exe.spec().meta_usize("n").context("missing n meta")?;
        let p = exe.spec().meta_usize("p").context("missing p meta")?;
        Ok(GossipExecutor { exe, n, p })
    }

    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &self,
        w: &[f32],
        x: &[f32],
        m: &[f32],
        g: &[f32],
        beta: f32,
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let outputs = self.exe.run(&[
            Input::F32(w),
            Input::F32(x),
            Input::F32(m),
            Input::F32(g),
            Input::F32(&[beta]),
            Input::F32(&[gamma]),
        ])?;
        Ok((outputs[0].to_vec::<f32>()?, outputs[1].to_vec::<f32>()?))
    }
}
