//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client from
//! the coordinator hot path. Python is never invoked here.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest};
pub use executor::{Executable, GossipExecutor, Input, LogRegExecutor, Runtime, TransformerExecutor};
