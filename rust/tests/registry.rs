//! Registry conformance suite (docs/DESIGN.md §Topology registry):
//! property tests that every registered [`TopologyFamily`] — paper zoo
//! and open extensions alike — honors the trait contract, plus the
//! schedule-cache guarantee that finite-time families serve τ-period
//! borrowed plans with no per-iteration allocation.

use expograph::topology::family::{self, Topology};
use expograph::topology::plan::MixingPlan;
use expograph::topology::schedule::Schedule;
use expograph::topology::TopologyFamily;
use expograph::util::rng::Pcg;

/// A size the family accepts (power of two for the hypercube families).
fn valid_n(topo: Topology, rng: &mut Pcg) -> usize {
    if topo.requires_pow2() {
        1usize << (1 + rng.below(6)) // 2..64
    } else {
        2 + rng.below(40)
    }
}

/// Every registered family produces row-stochastic plans with
/// non-negative weights, and — when it guarantees a degree bound —
/// every realized plan respects it.
#[test]
fn prop_every_family_row_stochastic_and_degree_bounded() {
    let mut rng = Pcg::seeded(0xFA111);
    for case in 0..25 {
        let seed = rng.next_u64();
        for topo in family::families() {
            let n = valid_n(topo, &mut rng);
            let mut sched = Schedule::from_family(topo, n, seed);
            for k in 0..5 {
                let plan = sched.plan_at(k);
                assert_eq!(plan.n, n, "case {case}: {topo} n={n}");
                for (i, row) in plan.rows_vec().iter().enumerate() {
                    let sum: f64 = row.iter().map(|&(_, w)| w).sum();
                    assert!(
                        (sum - 1.0).abs() < 1e-9,
                        "case {case}: {topo} n={n} k={k} row {i} sums to {sum}"
                    );
                    assert!(
                        row.iter().all(|&(_, w)| w >= 0.0),
                        "case {case}: {topo} n={n} k={k} row {i} has negative weight"
                    );
                }
                if let Some(bound) = topo.max_degree_bound(n) {
                    assert!(
                        plan.max_degree <= bound,
                        "case {case}: {topo} n={n} k={k}: degree {} > declared bound {bound}",
                        plan.max_degree
                    );
                }
            }
        }
    }
}

/// Every registered name and alias round-trips through config-name
/// parsing to the same family, names are globally unique, and the
/// canonical-name listing is consistent with lookup.
#[test]
fn prop_names_roundtrip_through_config_parsing() {
    let mut seen = std::collections::BTreeSet::new();
    for topo in family::families() {
        for name in topo.family().names() {
            assert!(seen.insert(*name), "duplicate registered name {name}");
            let found = family::find(name)
                .unwrap_or_else(|| panic!("registered name {name} does not parse"));
            assert_eq!(found, topo, "{name} parses to a different family");
            let cfg = expograph::config::parse_topology(name)
                .unwrap_or_else(|e| panic!("config rejects registered name {name}: {e}"));
            assert_eq!(cfg, topo, "config parse of {name} drifted from the registry");
        }
        assert!(
            family::names().contains(&topo.name()),
            "{topo} missing from the canonical listing"
        );
    }
    assert!(family::find("not_a_topology").is_none());
    let err = expograph::config::parse_topology("not_a_topology").unwrap_err().to_string();
    for name in family::names() {
        assert!(err.contains(name), "unknown-topology error must list {name}: {err}");
    }
}

/// Declared exact-averaging periods are honest: for every family and
/// size where `exact_period` is `Some(τ)`, the τ-step product of the
/// schedule's own plans equals `J` to 1e-12.
#[test]
fn prop_declared_exact_periods_are_exact() {
    let mut rng = Pcg::seeded(0xFA222);
    for _case in 0..15 {
        for topo in family::families() {
            let n = valid_n(topo, &mut rng);
            if let Some(err) = expograph::consensus::exact_period_error(topo, n, 0) {
                assert!(err < 1e-12, "{topo} n={n}: declared exact but |prod - J| = {err}");
            }
        }
    }
}

/// The schedule cache serves finite-time families as τ-period
/// **borrowed** plans: `plan_at(k)` and `plan_at(k + τ)` return the
/// same cached `MixingPlan` (pointer-identical — no per-iteration
/// allocation), and `period()` reports the declared exact period.
#[test]
fn finite_time_schedules_serve_borrowed_period_plans() {
    for (name, n) in [("base4", 12usize), ("base4", 48), ("base2", 24), ("ceca", 12), ("ceca", 48)]
    {
        let topo = family::find(name).unwrap();
        let period = topo.exact_period(n).unwrap();
        let mut sched = Schedule::from_family(topo, n, 7);
        assert_eq!(sched.period(), Some(period), "{name} n={n}");
        for k in 0..period {
            let first = sched.plan_at(k) as *const MixingPlan;
            for cycle in 1..4 {
                let again = sched.plan_at(k + cycle * period) as *const MixingPlan;
                assert_eq!(
                    first, again,
                    "{name} n={n} k={k}: cycle {cycle} re-allocated instead of borrowing"
                );
            }
        }
    }
    // Same contract as the paper's one-peer exponential cache.
    let mut one_peer = Schedule::new(expograph::topology::TopologyKind::OnePeerExp, 16, 0);
    let p0 = one_peer.plan_at(0) as *const MixingPlan;
    assert_eq!(p0, one_peer.plan_at(4) as *const MixingPlan);
}

/// Finite-time family plans flow through netsim fault degradation like
/// any other plan: degraded rows stay row-stochastic and the
/// communication degree never grows (docs/DESIGN.md §NetSim).
#[test]
fn finite_time_plans_degrade_safely() {
    use expograph::costmodel::CostModel;
    use expograph::netsim::{NetSim, Scenario};
    for name in ["base4", "ceca"] {
        let topo = family::find(name).unwrap();
        let mut sched = Schedule::from_family(topo, 12, 3);
        let scen = Scenario { drop_prob: 0.5, dropout: vec![(2, 0, 2)], ..Scenario::clean() };
        let mut sim = NetSim::new(&CostModel::paper_default(0.1), scen, 5);
        let mut degraded_any = false;
        for k in 0..4 {
            let plan = sched.plan_at(k).clone();
            let out = sim.simulate_round(k, &plan, 1e6);
            if let Some(d) = &out.degraded {
                degraded_any = true;
                for (i, row) in d.rows_vec().iter().enumerate() {
                    let sum: f64 = row.iter().map(|&(_, w)| w).sum();
                    assert!((sum - 1.0).abs() < 1e-9, "{name} k={k} row {i} sums to {sum}");
                    assert!(row.iter().all(|&(_, w)| w >= 0.0), "{name} k={k} row {i}");
                }
                assert!(d.max_degree <= plan.max_degree, "{name} k={k}: degree grew");
            }
        }
        assert!(degraded_any, "{name}: the dropout window must degrade at least one round");
    }
}

/// The base-2 family *is* the one-peer exponential schedule at powers
/// of two — weight for weight — while still being exact everywhere else.
#[test]
fn base2_collapses_to_one_peer_exp_at_powers_of_two() {
    use expograph::topology::exponential::tau;
    for n in [4usize, 16, 64] {
        let base2 = family::find("base2").unwrap();
        let mut a = Schedule::from_family(base2, n, 0);
        let mut b = Schedule::new(expograph::topology::TopologyKind::OnePeerExp, n, 0);
        for k in 0..2 * tau(n) {
            assert_eq!(a.plan_at(k).rows_vec(), b.plan_at(k).rows_vec(), "n={n} k={k}");
        }
    }
}
