//! Sweep-harness acceptance suite (docs/DESIGN.md §Sweep), run by name
//! in CI:
//!
//! * **grid-order determinism** — CSV/JSON output bytes are identical
//!   for `jobs ∈ {1, 4}`, on both an analysis grid and a real training
//!   grid (training is bitwise lane-invariant, §Engine);
//! * **cache semantics** — a warm re-run executes zero cells and
//!   reproduces the output byte-for-byte; changing seed or scale
//!   invalidates;
//! * **lane budget** — `jobs × engine lanes` never exceeds the core
//!   count.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use expograph::config::SweepConfig;
use expograph::exp::{self, Ctx};
use expograph::sweep::{sched, Record, Sweep};

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("expograph-sweeptest-{tag}-{}", std::process::id()))
}

fn run_exp(id: &str, out: &Path, jobs: usize, cache: bool, seed: u64) {
    let ctx = Ctx {
        out_dir: out.to_path_buf(),
        scale: 0.02,
        seed,
        sweep: SweepConfig { jobs, cache },
    };
    exp::run(id, &ctx).unwrap_or_else(|e| panic!("exp {id} failed: {e}"));
}

fn read(out: &Path, name: &str) -> Vec<u8> {
    std::fs::read(out.join(name)).unwrap_or_else(|e| panic!("reading {name}: {e}"))
}

/// (a) Grid-order determinism: byte-identical CSV + JSON for jobs 1 vs 4
/// across an analysis grid (table1), a consensus grid (fig4), and a real
/// training grid (table10 — full DSGD runs per cell).
#[test]
fn output_bytes_identical_for_jobs_1_and_4() {
    for id in ["table1", "fig4", "table10"] {
        let serial = tmp_dir(&format!("{id}-j1"));
        let parallel = tmp_dir(&format!("{id}-j4"));
        run_exp(id, &serial, 1, false, 3);
        run_exp(id, &parallel, 4, false, 3);
        for ext in ["csv", "json"] {
            let name = format!("{id}.{ext}");
            assert_eq!(
                read(&serial, &name),
                read(&parallel, &name),
                "{name} differs between --jobs 1 and --jobs 4"
            );
        }
        std::fs::remove_dir_all(&serial).ok();
        std::fs::remove_dir_all(&parallel).ok();
    }
}

/// (b) Cache hit/miss semantics on the harness API: a warm run executes
/// zero cells and returns equal records; seed and scale changes each
/// invalidate every cell.
#[test]
fn cache_hits_skip_execution_and_seed_or_scale_invalidate() {
    let tmp = tmp_dir("cache");
    let cells: Vec<usize> = (0..6).collect();
    let executions = AtomicUsize::new(0);
    let sweep_once = |seed: u64, scale: f64| {
        Sweep::new("cachetest", seed, scale).jobs(3).cache_under(&tmp).run(
            &cells,
            |c| format!("cell={c}"),
            |&c, _| {
                executions.fetch_add(1, Ordering::Relaxed);
                // A little synthetic "experiment": quadratic decay values.
                vec![Record::new().with("cell", c).with("value", 1.0 / (1 + c * c) as f64)]
            },
        )
    };
    let cold = sweep_once(1, 1.0);
    assert_eq!(executions.load(Ordering::Relaxed), 6);
    assert!(cold.iter().all(|c| !c.cached));

    let warm = sweep_once(1, 1.0);
    assert_eq!(executions.load(Ordering::Relaxed), 6, "warm run must execute zero cells");
    assert!(warm.iter().all(|c| c.cached));
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.records, b.records, "cache must reproduce records exactly");
    }

    sweep_once(2, 1.0);
    assert_eq!(executions.load(Ordering::Relaxed), 12, "seed change must invalidate");
    sweep_once(2, 0.5);
    assert_eq!(executions.load(Ordering::Relaxed), 18, "scale change must invalidate");
    // ... and both earlier configurations are still warm.
    sweep_once(1, 1.0);
    sweep_once(2, 1.0);
    assert_eq!(executions.load(Ordering::Relaxed), 18);
    std::fs::remove_dir_all(&tmp).ok();
}

/// (b′) End-to-end warm cache on a real experiment: the second `exp`
/// invocation reproduces CSV + JSON byte-for-byte from cache.
#[test]
fn warm_experiment_rerun_is_byte_identical() {
    let tmp = tmp_dir("warm");
    run_exp("fig4", &tmp, 2, true, 5);
    let csv = read(&tmp, "fig4.csv");
    let json = read(&tmp, "fig4.json");
    assert!(tmp.join(".cache").is_dir(), "cache directory populated");
    run_exp("fig4", &tmp, 2, true, 5);
    assert_eq!(read(&tmp, "fig4.csv"), csv);
    assert_eq!(read(&tmp, "fig4.json"), json);
    std::fs::remove_dir_all(&tmp).ok();
}

/// (c) Lane-budget arithmetic: `jobs × lanes ≤ cores` for every job
/// count up to the core count, on synthetic shapes and this host.
#[test]
fn lane_budget_never_exceeds_core_count() {
    for cores in [1usize, 2, 3, 4, 6, 8, 12, 16, 32, 96, 128] {
        for jobs in 1..=cores {
            let lanes = sched::lane_budget_for(cores, jobs);
            assert!(lanes >= 1, "cores={cores} jobs={jobs}");
            assert!(
                jobs * lanes <= cores,
                "oversubscribed: jobs={jobs} × lanes={lanes} > cores={cores}"
            );
        }
        // Oversubscribed job counts floor at one lane per job.
        for jobs in [cores + 1, 2 * cores, 10 * cores] {
            assert_eq!(sched::lane_budget_for(cores, jobs), 1);
        }
    }
    // The host-facing wrapper agrees with the pure arithmetic.
    for jobs in 1..=sched::cores() {
        assert!(jobs * sched::lane_budget(jobs) <= sched::cores());
    }
}
